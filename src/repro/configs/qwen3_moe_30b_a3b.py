"""Config module for --arch: re-exports the canonical config from archs.py."""
from repro.configs.archs import QWEN3_MOE_30B_A3B as CONFIG

__all__ = ["CONFIG"]
