"""Config module for --arch: re-exports the canonical config from archs.py."""
from repro.configs.archs import PALIGEMMA_3B as CONFIG

__all__ = ["CONFIG"]
