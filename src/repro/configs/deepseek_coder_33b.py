"""Config module for --arch: re-exports the canonical config from archs.py."""
from repro.configs.archs import DEEPSEEK_CODER_33B as CONFIG

__all__ = ["CONFIG"]
