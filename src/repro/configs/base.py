"""Config dataclasses for model architectures and workload shapes.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; the registry maps ``--arch`` ids to them.  Configs are
frozen dataclasses so they can be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionConfig:
    kind: str = "gqa"  # "gqa" | "mla" | "local" | "none"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int = 0  # sliding window size for kind=="local"
    # MLA (DeepSeek-V2) parameters
    q_lora_rank: int = 0  # 0 = dense q projection
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0
    first_dense: int = 0  # number of leading dense layers
    dense_ff: int = 0  # d_ff used by those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    ngroups: int = 1


@dataclass(frozen=True)
class HybridConfig:
    # repeating block pattern, e.g. ("rec", "rec", "attn")
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    lru_width: int = 2560
    conv_width: int = 4


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder / modality-frontend description for enc-dec, VLM and audio archs.

    Modality frontends are STUBS per the assignment: ``input_specs()`` supplies
    precomputed frame/patch embeddings.
    """

    num_layers: int = 0
    frontend: str = "none"  # "audio_frames" | "vision_patches" | "none"
    num_prefix: int = 0  # vision: number of patch embeddings prepended
    frame_ratio: int = 4  # audio: encoder_len = seq_len // frame_ratio


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "dense" | "moe" | "ssm" | "hybrid" | "encdec" | "vlm"
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encoder: Optional[EncoderConfig] = None
    act: str = "silu"  # "silu" | "gelu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    gemma_scaling: bool = False  # embed*sqrt(d), (1+w) RMSNorm
    dtype: str = "bfloat16"
    accum_steps: int = 1  # gradient-accumulation microbatches in train_step
    remat: bool = True
    optimizer: str = "adamw"  # "adamw" | "adafactor" (100B+ memory budget)
    grad_accum_dtype: str = "float32"  # "bfloat16" halves grad-AR volume
    source: str = ""  # provenance note "[arXiv:... ; tier]"

    # ---------------------------------------------------------------- helpers
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for hybrid archs (else uniform)."""
        if self.family == "hybrid":
            assert self.hybrid is not None
            pat = self.hybrid.pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        return tuple(["block"] * self.num_layers)

    # -------------------------------------------------------- analytic counts
    def attn_params_per_layer(self) -> int:
        a = self.attention
        d = self.d_model
        if a.kind == "mla":
            q = d * a.q_lora_rank + a.q_lora_rank * a.q_dim if a.q_lora_rank else d * a.q_dim
            kv = d * (a.kv_lora_rank + a.qk_rope_head_dim)
            kv += a.kv_lora_rank * a.num_heads * (a.qk_nope_head_dim + a.v_head_dim)
            o = a.num_heads * a.v_head_dim * d
            return q + kv + o
        if a.kind == "none":
            return 0
        qd = a.num_heads * a.head_dim
        kvd = a.num_kv_heads * a.head_dim
        return d * (qd + 2 * kvd) + qd * d

    def mlp_params(self, ff: int) -> int:
        return 3 * self.d_model * ff  # gated MLPs (SwiGLU / GeGLU) everywhere

    def n_params(self) -> int:
        """Total parameter count (analytic)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.family == "ssm":
            s = self.ssm
            di = self.d_inner
            conv_dim = di + 2 * s.ngroups * s.d_state
            per = (
                d * (2 * di + 2 * s.ngroups * s.d_state + self.ssm_heads)  # in_proj
                + conv_dim * s.d_conv
                + self.ssm_heads  # A_log
                + self.ssm_heads  # D
                + di  # norm gate
                + di * d  # out_proj
                + d  # layer norm
            )
            return total + per * self.num_layers + d
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            total += d  # pre-norm
            if self.family == "hybrid" and kind == "rec":
                w = self.hybrid.lru_width
                total += 2 * d * w + w * d  # linear x, gate branch, out
                total += w * self.hybrid.conv_width  # conv1d
                total += 3 * w  # lru gates a, input gate params approx
            else:
                total += self.attn_params_per_layer()
            total += d  # post-attn norm
            if self.moe is not None and i >= self.moe.first_dense:
                m = self.moe
                total += d * m.num_experts  # router
                total += (m.num_experts + m.num_shared) * 3 * d * m.expert_ff
            elif self.moe is not None:
                total += self.mlp_params(self.moe.dense_ff)
            else:
                total += self.mlp_params(self.d_ff)
        total += d  # final norm
        if self.family == "encdec":
            e = self.encoder
            enc_per = self.attn_params_per_layer() + self.mlp_params(self.d_ff) + 2 * d
            dec_cross = self.attn_params_per_layer() + d
            total += e.num_layers * enc_per + self.num_layers * dec_cross + d
        return total

    def n_active_params(self) -> int:
        """Active (per-token) params for MoE archs; == n_params for dense."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        full = self.n_params()
        inactive_experts = m.num_experts - m.top_k
        moe_layers = self.num_layers - m.first_dense
        return full - moe_layers * inactive_experts * 3 * self.d_model * m.expert_ff

    def encoder_params(self) -> int:
        """Params of the encoder stack (enc-dec archs only)."""
        if self.family != "encdec" or self.encoder is None:
            return 0
        d = self.d_model
        per = self.attn_params_per_layer() + self.mlp_params(self.d_ff) + 2 * d
        return self.encoder.num_layers * per

    def flops_per_token(self, seq_len: int, training: bool = False) -> float:
        """Approximate MODEL_FLOPS per token: 6*N_active for train, 2*N_active
        for inference, plus attention O(S) term.  For enc-dec archs the
        encoder runs seq/frame_ratio positions, so its params contribute at
        1/frame_ratio of the decoder-token rate."""
        n = self.n_active_params()
        if self.family == "encdec" and self.encoder is not None:
            enc = self.encoder_params()
            n = (n - enc) + enc / self.encoder.frame_ratio
        base = (6.0 if training else 2.0) * n
        # attention score/values FLOPs: 2*2*H*hd*S per token (causal halves it)
        a = self.attention
        if a.kind != "none":
            hd = a.head_dim if a.kind != "mla" else (a.qk_nope_head_dim + a.qk_rope_head_dim)
            eff_s = min(seq_len, a.window) if a.kind == "local" else seq_len
            attn = 2 * 2 * a.num_heads * hd * eff_s * 0.5
            n_attn_layers = sum(1 for k in self.layer_kinds() if k in ("block", "attn"))
            base += (3.0 if training else 1.0) * attn * n_attn_layers
        return base


@dataclass(frozen=True)
class WorkloadShape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": WorkloadShape("train_4k", "train", 4096, 256),
    "prefill_32k": WorkloadShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": WorkloadShape("decode_32k", "decode", 32768, 128),
    "long_500k": WorkloadShape("long_500k", "decode", 524288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def supports_shape(cfg: ModelConfig, shape: WorkloadShape) -> bool:
    """long_500k only runs on sub-quadratic archs (per assignment)."""
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True
