"""Config module for --arch: re-exports the canonical config from archs.py."""
from repro.configs.archs import MAMBA2_27B as CONFIG

__all__ = ["CONFIG"]
