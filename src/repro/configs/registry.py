"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus reduced
smoke-test configs of the same family."""
from __future__ import annotations

import dataclasses

from repro.configs import archs
from repro.configs.base import (
    AttentionConfig,
    EncoderConfig,
    ModelConfig,
    SHAPES,
    WorkloadShape,
    supports_shape,
)

ARCHS = dict(archs.ALL)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def list_archs():
    return sorted(ARCHS)


def reduced_config(arch: str) -> ModelConfig:
    """A tiny config of the same family, used by smoke tests and CPU examples.

    Keeps the structural features (GQA ratio, MLA, MoE routing, hybrid
    pattern, enc-dec, frontends) while shrinking width/depth/vocab."""
    cfg = get_config(arch)
    a = cfg.attention
    kw = {}
    if a.kind == "mla":
        kw["attention"] = dataclasses.replace(
            a,
            num_heads=4,
            num_kv_heads=4,
            kv_lora_rank=32,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
            head_dim=16,
        )
    elif a.kind == "none":
        kw["attention"] = a
    else:
        n_kv = max(1, min(a.num_kv_heads, 2))
        kw["attention"] = dataclasses.replace(
            a, num_heads=4, num_kv_heads=n_kv, head_dim=16, window=min(a.window, 32) or a.window
        )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, expert_ff=32, dense_ff=64 if cfg.moe.dense_ff else 0
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=8, chunk=16)
    if cfg.hybrid is not None:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, lru_width=64)
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(
            cfg.encoder,
            num_layers=min(cfg.encoder.num_layers, 2),
            num_prefix=min(cfg.encoder.num_prefix, 8) or cfg.encoder.num_prefix,
        )
    n_layers = 4 if cfg.family != "hybrid" else 6  # hybrid: two full (rec,rec,attn) groups
    return cfg.replace(
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        accum_steps=1,
        remat=False,
        **kw,
    )


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells.  40 nominal; long_500k is skipped for
    pure full-attention archs per the assignment."""
    out = []
    for arch in sorted(ARCHS):
        cfg = ARCHS[arch]
        for shape in SHAPES.values():
            if supports_shape(cfg, shape) or include_skipped:
                out.append((arch, shape.name))
    return out
