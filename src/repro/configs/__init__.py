from repro.configs.base import (
    AttentionConfig, EncoderConfig, HybridConfig, ModelConfig, MoEConfig,
    SSMConfig, SHAPES, WorkloadShape, supports_shape,
)
from repro.configs.registry import ARCHS, cells, get_config, list_archs, reduced_config

__all__ = [
    "AttentionConfig", "EncoderConfig", "HybridConfig", "ModelConfig",
    "MoEConfig", "SSMConfig", "SHAPES", "WorkloadShape", "supports_shape",
    "ARCHS", "cells", "get_config", "list_archs", "reduced_config",
]
