"""The ten assigned architectures, exact published configs.

Each is importable as ``repro.configs.archs.<ID>`` and registered in
``repro.configs.registry``.  Sources are carried in ``ModelConfig.source``.
"""
from __future__ import annotations

from repro.configs.base import (
    AttentionConfig,
    EncoderConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)

# --------------------------------------------------------------------------
# [audio] seamless-m4t-medium — enc-dec, 12L enc + 12L dec, d_model=1024,
# 16H (GQA kv=16), d_ff=4096, vocab=256206.  Audio frontend is a STUB:
# input_specs() supplies precomputed frame embeddings (encoder_len = seq/4).
SEAMLESS_M4T_MEDIUM = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=256256,  # published 256206, padded to a multiple of 256 for TP shardability
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64),
    encoder=EncoderConfig(num_layers=12, frontend="audio_frames", frame_ratio=4),
    act="silu",
    accum_steps=1,
    source="[arXiv:2308.11596; hf]",
)

# --------------------------------------------------------------------------
# [dense] llama3-405b — 126L d=16384 128H (GQA kv=8) d_ff=53248 vocab=128256
LLAMA3_405B = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    d_ff=53248,
    vocab_size=128256,
    attention=AttentionConfig(
        num_heads=128, num_kv_heads=8, head_dim=128, rope_theta=500000.0
    ),
    accum_steps=8,
    source="[arXiv:2407.21783; unverified]",
)

# --------------------------------------------------------------------------
# [dense] qwen1.5-110b — 80L d=8192 64H (GQA kv=8) d_ff=49152 vocab=152064,
# QKV bias.
QWEN15_110B = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    d_ff=49152,
    vocab_size=152064,
    attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128, qkv_bias=True),
    accum_steps=4,
    source="[hf:Qwen/Qwen1.5-0.5B; hf]",
)

# --------------------------------------------------------------------------
# [dense] deepseek-67b — 95L d=8192 64H (GQA kv=8) d_ff=22016 vocab=102400
DEEPSEEK_67B = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    d_ff=22016,
    vocab_size=102400,
    attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128),
    accum_steps=4,
    source="[arXiv:2401.02954; hf]",
)

# --------------------------------------------------------------------------
# [dense] deepseek-coder-33b — 62L d=7168 56H (GQA kv=8) d_ff=19200 vocab=32256
DEEPSEEK_CODER_33B = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    d_ff=19200,
    vocab_size=32256,
    attention=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128),
    accum_steps=2,
    source="[arXiv:2401.14196; hf]",
)

# --------------------------------------------------------------------------
# [moe] deepseek-v2-lite-16b — 27L d=2048 16H, MLA kv_lora=512,
# MoE: 2 shared + 64 routed top-6 (assignment text also mentions "160 routed",
# which is full V2; V2-LITE per HF config is 64 routed — see DESIGN.md).
# First layer dense (d_ff=10944), expert d_ff=1408.
DEEPSEEK_V2_LITE_16B = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    d_ff=1408,
    vocab_size=102400,
    attention=AttentionConfig(
        kind="mla",
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        q_lora_rank=0,  # V2-Lite has no q compression
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        expert_ff=1408,
        num_shared=2,
        first_dense=1,
        dense_ff=10944,
    ),
    accum_steps=1,
    source="[arXiv:2405.04434; hf]",
)

# --------------------------------------------------------------------------
# [moe] qwen3-moe-30b-a3b — 48L d=2048 32H (GQA kv=4) expert d_ff=768,
# 128 experts top-8, vocab=151936, q/k norm.
QWEN3_MOE_30B_A3B = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    d_ff=768,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=32, num_kv_heads=4, head_dim=128, qk_norm=True, rope_theta=1000000.0
    ),
    moe=MoEConfig(num_experts=128, top_k=8, expert_ff=768, num_shared=0),
    accum_steps=1,
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
)

# --------------------------------------------------------------------------
# [vlm] paligemma-3b — gemma backbone 18L d=2048 8H (MQA kv=1) d_ff=16384
# vocab=257216.  SigLIP vision tower is a STUB supplying 256 patch embeddings.
PALIGEMMA_3B = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=257216,
    attention=AttentionConfig(num_heads=8, num_kv_heads=1, head_dim=256),
    encoder=EncoderConfig(frontend="vision_patches", num_prefix=256),
    act="gelu",
    gemma_scaling=True,
    tie_embeddings=True,
    accum_steps=1,
    source="[arXiv:2407.07726; hf]",
)

# --------------------------------------------------------------------------
# [hybrid] recurrentgemma-2b — 26L d=2560 10H (MQA kv=1) d_ff=7680
# vocab=256000, RG-LRU + local attention 1:2 pattern (rec,rec,attn),
# window=2048, lru_width=2560.
RECURRENTGEMMA_2B = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    d_ff=7680,
    vocab_size=256000,
    attention=AttentionConfig(
        kind="local", num_heads=10, num_kv_heads=1, head_dim=256, window=2048
    ),
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), lru_width=2560, conv_width=4),
    act="gelu",
    gemma_scaling=True,
    tie_embeddings=True,
    accum_steps=1,
    source="[arXiv:2402.19427; hf]",
)

# --------------------------------------------------------------------------
# [ssm] mamba2-2.7b — 64L d=2560, attn-free, vocab=50280 (padded to 50288 for
# 16-divisibility), ssm_state=128, head_dim=64, expand=2 (d_inner=5120).
MAMBA2_27B = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    d_ff=0,
    vocab_size=50288,
    attention=AttentionConfig(kind="none", num_heads=0, num_kv_heads=0, head_dim=0),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256, ngroups=1),
    tie_embeddings=True,
    accum_steps=1,
    source="[arXiv:2405.21060; unverified]",
)

ALL = {
    "seamless-m4t-medium": SEAMLESS_M4T_MEDIUM,
    "llama3-405b": LLAMA3_405B,
    "qwen1.5-110b": QWEN15_110B,
    "deepseek-67b": DEEPSEEK_67B,
    "deepseek-coder-33b": DEEPSEEK_CODER_33B,
    "deepseek-v2-lite-16b": DEEPSEEK_V2_LITE_16B,
    "qwen3-moe-30b-a3b": QWEN3_MOE_30B_A3B,
    "paligemma-3b": PALIGEMMA_3B,
    "recurrentgemma-2b": RECURRENTGEMMA_2B,
    "mamba2-2.7b": MAMBA2_27B,
}
