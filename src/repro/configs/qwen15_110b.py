"""Config module for --arch: re-exports the canonical config from archs.py."""
from repro.configs.archs import QWEN15_110B as CONFIG

__all__ = ["CONFIG"]
