"""Config module for --arch: re-exports the canonical config from archs.py."""
from repro.configs.archs import SEAMLESS_M4T_MEDIUM as CONFIG

__all__ = ["CONFIG"]
