"""Streaming statistics for the adaptive serving loop (DESIGN.md §4).

The server maintains, per installed plan version:

* ``StreamingRate`` per stage — observed proxy keep-rates and UDF pass
  rates, compared against the plan's ``est_reduction`` /
  ``est_selectivity``;
* ``CusumDetector`` per signal — a one-sided CUSUM on the absolute
  deviation between observed and expected rates, so a sustained shift
  triggers re-optimization while sampling noise does not;
* ``Reservoir`` — a strided ring buffer of recent feature rows (with any
  UDF labels the server has already paid for) that becomes the fresh
  optimization sample when drift fires;
* pairwise ``StreamingKappa2`` (core/correlation.py) over audited label
  columns — a shift in predicate correlation structure escalates the
  cheap re-allocation to a warm-started branch-and-bound re-search.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class AdaptivePolicy:
    """Knobs for drift detection and re-optimization."""

    slack: float = 0.08  # deviation below this is ignored (CUSUM drift term)
    threshold: float = 120.0  # cumulative deviation-weighted records to trigger
    audit_rate: float = 0.02  # fraction of records with ALL UDFs run (unbiased stats)
    audit_baseline: int = 200  # audit records that freeze the reference rates
    audit_window: int = 400  # recent-audit window for the escalation decision
    audit_importance: bool = True  # score-distance-weighted audit sampling (IPW-corrected)
    audit_floor: float = 0.25  # min propensity as a fraction of audit_rate (IPW weights stay bounded)
    reservoir_capacity: int = 1024
    reservoir_stride: int = 2  # keep every k-th record (widens the recency window)
    min_reservoir: int = 256  # don't re-optimize on fewer sampled rows
    cooldown_records: int = 2048  # records between consecutive swaps
    kappa_tol: float = 0.08  # |kappa^2 shift| that escalates alloc -> B&B resume
    # pooled labels (fleet-wide, IPW-weighted) that freeze the coordinator's
    # cross-host kappa^2 baseline — reached ~K× sooner than any single
    # host's local audit_baseline, which is what makes evenly-split
    # correlation drifts visible at the fleet level (DESIGN.md §6).
    # 0 (default) disables coordinator-initiated pooled swaps: pooling
    # changes WHO may open a swap (the coordinator, without any vote
    # quorum), so fleets opt in explicitly; ~120 is a typical setting
    kappa_pool_baseline: int = 0
    regret_tol: float = 0.1  # relative cost-model regret that escalates alloc -> B&B
    step: float = 0.05  # Algorithm-1 grid for re-optimization
    escalate: str = "auto"  # "auto" (cost-model regret) | "alloc" | "bnb"

    def choose_escalation(self, plan, fresh_sels: Dict[int, float]) -> Tuple[str, float]:
        """Pick re-optimization depth from the stale plan's estimated
        COST-MODEL REGRET, not the raw rate-shift magnitude: a large
        selectivity shift that leaves the incumbent order optimal only
        needs a re-allocation, while a mild shift that inverts the order
        optimum needs the B&B re-search.  Returns (mode, regret)."""
        regret, _best = estimate_order_regret(plan, fresh_sels)
        return ("bnb" if regret > self.regret_tol else "alloc"), regret


def estimate_order_regret(plan, fresh_sels: Dict[int, float]) -> Tuple[float, Tuple[int, ...]]:
    """Relative Eq.-3.1 regret of keeping the incumbent stage ORDER under
    fresh unconditional selectivity estimates (audit/reservoir statistics).

    Each stage keeps its built proxy (cost, reduction, alpha); only the
    selectivities are refreshed and the order permuted — exactly the part
    of the plan a cheap re-allocation cannot change.  Predicate
    independence is assumed (the estimate has only marginals); a
    correlation-structure shift is escalated separately via kappa².
    Returns (relative regret in [0, 1), best order found).

    Orders are enumerated exhaustively only up to 6 stages; beyond that
    the candidate is the rank-ordering greedy (ascending per-stage cost /
    (1 - pass-rate), the classic optimal rule for independent filters) —
    this runs inside the serving loop on every auto-mode drift trigger,
    so it must stay far cheaper than the B&B it decides whether to pay
    for.
    """
    from itertools import permutations

    from repro.core.cost import plan_cost

    by_pred = {s.pred_idx: s for s in plan.stages}

    def stage_terms(p: int) -> Tuple[float, float]:
        """(unit cost at the stage, pass-rate) under fresh selectivities."""
        s = by_pred[p]
        alpha = s.alpha if s.proxy is not None else 1.0
        red = s.est_reduction if s.proxy is not None else 0.0
        sel = float(fresh_sels.get(p, s.est_selectivity))
        pcost = s.proxy.cost if s.proxy is not None else 0.0
        unit = pcost + (1.0 - red) * plan.query.predicates[p].udf.cost
        return unit, sel * alpha

    def cost_of(order: Tuple[int, ...]) -> float:
        alphas, reds, sels, pcosts, ucosts = [], [], [], [], []
        for p in order:
            s = by_pred[p]
            alphas.append(s.alpha if s.proxy is not None else 1.0)
            reds.append(s.est_reduction if s.proxy is not None else 0.0)
            sels.append(float(fresh_sels.get(p, s.est_selectivity)))
            pcosts.append(s.proxy.cost if s.proxy is not None else 0.0)
            ucosts.append(plan.query.predicates[p].udf.cost)
        return plan_cost(alphas, reds, sels, pcosts, ucosts)

    if len(plan.order) <= 6:
        candidates = permutations(plan.order)
    else:
        greedy = tuple(sorted(
            plan.order,
            key=lambda p: stage_terms(p)[0] / max(1.0 - stage_terms(p)[1], 1e-9),
        ))
        candidates = [greedy]
    incumbent = cost_of(plan.order)
    best_order, best_cost = plan.order, incumbent
    for order in candidates:
        c = cost_of(order)
        if c < best_cost:
            best_order, best_cost = order, c
    regret = (incumbent - best_cost) / max(incumbent, 1e-12)
    return float(regret), tuple(best_order)


class ImportanceAuditSampler:
    """Score-distance-weighted audit selection with inverse-propensity
    correction.

    Uniform auditing spends most of its UDF budget on records far from
    every proxy threshold — records whose labels the proxies already get
    right.  This sampler up-weights records NEAR a decision boundary
    (small ``margin`` = distance from the record's score to the nearest
    stage threshold) and corrects the induced bias by weighting each
    audited record by ``1 / propensity`` (Horvitz-Thompson), so corrected
    selectivity estimates stay unbiased on any stream — property-tested in
    ``tests/test_streaming_stats.py``.

    Propensities are floored at ``floor * rate`` so IPW weights stay
    bounded, and mean-normalized so the expected audit budget stays
    ``rate * N`` per chunk.
    """

    def __init__(self, rate: float, floor: float = 0.25):
        self.rate = float(rate)
        self.floor = float(floor)

    def propensities(self, margins: Optional[np.ndarray], n: int) -> np.ndarray:
        """Per-record audit probability.  ``margins=None`` (no fused scorer
        to read distances from) degrades to uniform ``rate``."""
        if margins is None:
            return np.full(n, self.rate)
        m = np.abs(np.asarray(margins, np.float64))
        scale = np.median(m)
        if not np.isfinite(scale) or scale <= 0.0:
            return np.full(n, self.rate)
        w = 2.0 / (1.0 + m / scale)  # (0, 2]: ~2 at the boundary, ->0 far away
        w /= max(w.mean(), 1e-12)  # E[#audits] stays rate * N
        return np.clip(self.rate * w, self.floor * self.rate, 1.0)

    def select(self, margins: Optional[np.ndarray], n: int,
               rng: np.random.RandomState):
        """Returns (selected bool (n,), ipw weights (n_selected,))."""
        p = self.propensities(margins, n)
        sel = rng.random_sample(n) < p
        return sel, 1.0 / p[sel]


class StreamingRate:
    """Chunk-wise keep-rate estimator: exactly matches the batch empirical
    rate over the same rows, regardless of chunking.  Counts may be
    fractional (importance-weighted audit totals)."""

    def __init__(self):
        self.kept = 0.0
        self.seen = 0.0

    def update(self, kept: float, seen: float) -> None:
        self.kept += kept
        self.seen += seen

    @property
    def rate(self) -> float:
        return self.kept / self.seen if self.seen else 0.0


class CusumDetector:
    """One-sided CUSUM on |observed - expected| with a slack deadband.

    ``update`` folds one batch: the score grows by
    ``weight * (|obs - exp| - slack)`` and is clamped at zero, so short
    noise bursts decay while a sustained shift accumulates to the
    threshold.  ``weight`` is the number of records in the batch — the
    threshold therefore reads as "deviation-weighted records".
    """

    def __init__(self, slack: float, threshold: float):
        self.slack = slack
        self.threshold = threshold
        self.score = 0.0

    def update(self, observed: float, expected: float, weight: float) -> bool:
        dev = abs(observed - expected) - self.slack
        self.score = max(0.0, self.score + weight * dev)
        return self.score >= self.threshold

    def reset(self) -> None:
        self.score = 0.0


class Reservoir:
    """Strided ring buffer of recent stream rows + observed sigma labels.

    Every ``stride``-th submitted record lands in a slot (round-robin), so
    the buffer always holds the last ``capacity * stride`` records'
    subsample — recency is what drift re-optimization needs, not a uniform
    all-history sample.  ``observe`` attaches per-predicate sigma outcomes
    for rows whose UDFs the server has already run (audit records mainly);
    those labels seed the rebased ProxyBuilder so re-optimization does not
    re-pay UDF calls it already made.
    """

    def __init__(self, n_preds: int, capacity: int = 1024, stride: int = 2):
        self.n_preds = n_preds
        self.capacity = capacity
        self.stride = max(1, stride)
        self._rows: List[Optional[np.ndarray]] = [None] * capacity
        self._known: List[np.ndarray] = [np.zeros(capacity, bool)
                                         for _ in range(n_preds)]
        self._sigma: List[np.ndarray] = [np.zeros(capacity, bool)
                                         for _ in range(n_preds)]
        self._weight: np.ndarray = np.ones(capacity)  # IPW audit weights
        self._slot_of: Dict[int, int] = {}  # global record idx -> slot
        self._idx_at: List[Optional[int]] = [None] * capacity
        self._tick = 0
        self._write = 0

    def add(self, idx: int, row: np.ndarray, *, force: bool = False) -> bool:
        """Offer one record; returns True when it was sampled in.

        ``force=True`` bypasses the stride gate (no-op if already
        resident): audited records are force-added so their paid-for UDF
        labels always ride into the next re-optimization sample and the
        reservoir's selectivity estimates.  This tilts the ROW sample
        slightly toward proxy thresholds (forced rows are an ~audit_rate
        share of entries, with a bounded propensity ratio): the
        ``selectivity`` estimator undoes the tilt with the stored IPW
        weights, while the re-optimization training sample accepts it —
        boundary-heavy labeled rows are where a retrained proxy's
        decision surface needs resolution (active-learning flavored, and
        the rebuilt plan's thresholds are re-validated on the full
        R-curve either way)."""
        if force:
            if int(idx) in self._slot_of:
                return True
        else:
            take = self._tick % self.stride == 0
            self._tick += 1
            if not take:
                return False
        slot = self._write % self.capacity
        self._write += 1
        old = self._idx_at[slot]
        if old is not None:
            self._slot_of.pop(old, None)
        self._rows[slot] = np.asarray(row, np.float32)
        self._idx_at[slot] = int(idx)
        self._slot_of[int(idx)] = slot
        for p in range(self.n_preds):
            self._known[p][slot] = False
            self._sigma[p][slot] = False
        self._weight[slot] = 1.0
        return True

    def observe(self, idx: int, pred_idx: int, sigma: bool,
                weight: float = 1.0) -> None:
        """Attach an observed sigma label; ``weight`` is the record's
        inverse audit propensity, so reservoir selectivities can undo the
        importance sampling bias (labels arrive via threshold-weighted
        audits, not uniformly)."""
        slot = self._slot_of.get(int(idx))
        if slot is None:
            return
        self._known[pred_idx][slot] = True
        self._sigma[pred_idx][slot] = bool(sigma)
        self._weight[slot] = float(weight)

    def selectivity(self, pred_idx: int, *, min_labels: int = 16) -> Optional[float]:
        """IPW-corrected unconditional selectivity estimate over the
        reservoir's labeled rows — the freshest drift-grade statistic the
        server has (the reservoir spans only the last
        ``capacity * stride`` records).  None below ``min_labels``."""
        known = self._known[pred_idx]
        if int(known.sum()) < min_labels:
            return None
        w = self._weight[known]
        s = self._sigma[pred_idx][known]
        denom = float(w.sum())
        return float((w * s).sum() / denom) if denom > 0 else None

    @property
    def size(self) -> int:
        return sum(r is not None for r in self._rows)

    def export(self) -> "ReservoirSample":
        """Full weighted snapshot — rows, labels, AND the per-row IPW
        weights.  ``sample()`` used to drop the weights, which silently
        broke any downstream estimator over the exported rows (the audit
        tilt toward proxy thresholds became uncorrectable once the rows
        left the reservoir); multi-host merging needs them preserved."""
        slots = [s for s, r in enumerate(self._rows) if r is not None]
        x = (np.stack([self._rows[s] for s in slots]) if slots
             else np.empty((0, 0), np.float32))
        known_sigma = {
            p: (self._known[p][slots].copy(), self._sigma[p][slots].copy())
            for p in range(self.n_preds)
        }
        return ReservoirSample(
            indices=np.asarray([self._idx_at[s] for s in slots], np.int64),
            x=x, known_sigma=known_sigma,
            weights=self._weight[slots].copy(),
        )

    def sample(self) -> Tuple[np.ndarray, Dict[int, Tuple[np.ndarray, np.ndarray]]]:
        """(x (M, F), {pred_idx: (known_mask (M,), sigma (M,))}) — the
        re-optimization sample.  Use ``export()`` when the consumer needs
        the IPW weights too (selectivity estimation, multi-host merge)."""
        exp = self.export()
        return exp.x, exp.known_sigma


@dataclass
class ReservoirSample:
    """One reservoir's exported snapshot (or a merge of several).

    ``weights[i]`` is row i's inverse inclusion propensity into the
    LABELED pool (audit IPW; 1.0 for unlabeled strided rows), so any
    Horvitz-Thompson estimator over the export matches the reservoir's own
    ``selectivity`` — including after concatenating exports from many
    hosts (``merge_reservoir_samples``, order-insensitive by symmetry of
    the weighted sums).
    """

    indices: np.ndarray  # (M,) global record indices
    x: np.ndarray  # (M, F)
    known_sigma: Dict[int, Tuple[np.ndarray, np.ndarray]]
    weights: np.ndarray  # (M,) inverse inclusion propensities

    @property
    def n_rows(self) -> int:
        return int(self.x.shape[0])


def merge_reservoir_samples(samples: List["ReservoirSample"]) -> "ReservoirSample":
    """Pool per-host reservoir exports into one optimization sample,
    IPW weights preserved.  Pure concatenation: each row keeps the weight
    its own host assigned (inclusion was decided host-locally), so the
    merged HT estimator equals the one a single reservoir holding the
    union would produce — the multi-host merge property test."""
    samples = [s for s in samples if s.n_rows]
    if not samples:
        return ReservoirSample(
            indices=np.empty(0, np.int64), x=np.empty((0, 0), np.float32),
            known_sigma={}, weights=np.empty(0))
    preds = sorted({p for s in samples for p in s.known_sigma})
    known_sigma = {}
    for p in preds:
        ks = [s.known_sigma.get(
            p, (np.zeros(s.n_rows, bool), np.zeros(s.n_rows, bool)))
            for s in samples]
        known_sigma[p] = (np.concatenate([k for k, _ in ks]),
                         np.concatenate([g for _, g in ks]))
    return ReservoirSample(
        indices=np.concatenate([s.indices for s in samples]),
        x=np.concatenate([s.x for s in samples]),
        known_sigma=known_sigma,
        weights=np.concatenate([s.weights for s in samples]),
    )


def ipw_selectivity(sample: "ReservoirSample", pred_idx: int,
                    *, min_labels: int = 1) -> Optional[float]:
    """Horvitz-Thompson selectivity over an exported (or merged) sample:
    ``Σ w·σ / Σ w`` across labeled rows.  None below ``min_labels``."""
    ks = sample.known_sigma.get(pred_idx)
    if ks is None:
        return None
    known, sigma = ks
    if int(known.sum()) < min_labels:
        return None
    w = sample.weights[known]
    denom = float(w.sum())
    return float((w * sigma[known]).sum() / denom) if denom > 0 else None


@dataclass
class DriftEvent:
    """One trigger of the drift detector (recorded in ServeStats)."""

    at_record: int
    signal: str  # e.g. "stage1:udf", "stage0:proxy", "audit:sel:2"
    observed: float
    expected: float
    escalated: bool  # True -> warm B&B resume, False -> re-allocation
    reopt_ms: float = 0.0
    nodes_visited: int = 0
    plan_version: int = 0
    order_before: tuple = ()
    order_after: tuple = ()
