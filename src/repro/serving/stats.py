"""Streaming statistics for the adaptive serving loop (DESIGN.md §4).

The server maintains, per installed plan version:

* ``StreamingRate`` per stage — observed proxy keep-rates and UDF pass
  rates, compared against the plan's ``est_reduction`` /
  ``est_selectivity``;
* ``CusumDetector`` per signal — a one-sided CUSUM on the absolute
  deviation between observed and expected rates, so a sustained shift
  triggers re-optimization while sampling noise does not;
* ``Reservoir`` — a strided ring buffer of recent feature rows (with any
  UDF labels the server has already paid for) that becomes the fresh
  optimization sample when drift fires;
* pairwise ``StreamingKappa2`` (core/correlation.py) over audited label
  columns — a shift in predicate correlation structure escalates the
  cheap re-allocation to a warm-started branch-and-bound re-search.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class AdaptivePolicy:
    """Knobs for drift detection and re-optimization."""

    slack: float = 0.08  # deviation below this is ignored (CUSUM drift term)
    threshold: float = 120.0  # cumulative deviation-weighted records to trigger
    audit_rate: float = 0.02  # fraction of records with ALL UDFs run (unbiased stats)
    audit_baseline: int = 200  # audit records that freeze the reference rates
    audit_window: int = 400  # recent-audit window for the escalation decision
    reservoir_capacity: int = 1024
    reservoir_stride: int = 2  # keep every k-th record (widens the recency window)
    min_reservoir: int = 256  # don't re-optimize on fewer sampled rows
    cooldown_records: int = 2048  # records between consecutive swaps
    kappa_tol: float = 0.08  # |kappa^2 shift| that escalates alloc -> B&B resume
    sel_tol: float = 0.15  # unconditional selectivity shift that escalates
    step: float = 0.05  # Algorithm-1 grid for re-optimization
    escalate: str = "auto"  # "auto" | "alloc" | "bnb"


class StreamingRate:
    """Chunk-wise keep-rate estimator: exactly matches the batch empirical
    rate over the same rows, regardless of chunking."""

    def __init__(self):
        self.kept = 0
        self.seen = 0

    def update(self, kept: int, seen: int) -> None:
        self.kept += int(kept)
        self.seen += int(seen)

    @property
    def rate(self) -> float:
        return self.kept / self.seen if self.seen else 0.0


class CusumDetector:
    """One-sided CUSUM on |observed - expected| with a slack deadband.

    ``update`` folds one batch: the score grows by
    ``weight * (|obs - exp| - slack)`` and is clamped at zero, so short
    noise bursts decay while a sustained shift accumulates to the
    threshold.  ``weight`` is the number of records in the batch — the
    threshold therefore reads as "deviation-weighted records".
    """

    def __init__(self, slack: float, threshold: float):
        self.slack = slack
        self.threshold = threshold
        self.score = 0.0

    def update(self, observed: float, expected: float, weight: float) -> bool:
        dev = abs(observed - expected) - self.slack
        self.score = max(0.0, self.score + weight * dev)
        return self.score >= self.threshold

    def reset(self) -> None:
        self.score = 0.0


class Reservoir:
    """Strided ring buffer of recent stream rows + observed sigma labels.

    Every ``stride``-th submitted record lands in a slot (round-robin), so
    the buffer always holds the last ``capacity * stride`` records'
    subsample — recency is what drift re-optimization needs, not a uniform
    all-history sample.  ``observe`` attaches per-predicate sigma outcomes
    for rows whose UDFs the server has already run (audit records mainly);
    those labels seed the rebased ProxyBuilder so re-optimization does not
    re-pay UDF calls it already made.
    """

    def __init__(self, n_preds: int, capacity: int = 1024, stride: int = 2):
        self.n_preds = n_preds
        self.capacity = capacity
        self.stride = max(1, stride)
        self._rows: List[Optional[np.ndarray]] = [None] * capacity
        self._known: List[np.ndarray] = [np.zeros(capacity, bool)
                                         for _ in range(n_preds)]
        self._sigma: List[np.ndarray] = [np.zeros(capacity, bool)
                                         for _ in range(n_preds)]
        self._slot_of: Dict[int, int] = {}  # global record idx -> slot
        self._idx_at: List[Optional[int]] = [None] * capacity
        self._tick = 0
        self._write = 0

    def add(self, idx: int, row: np.ndarray) -> bool:
        """Offer one record; returns True when it was sampled in."""
        take = self._tick % self.stride == 0
        self._tick += 1
        if not take:
            return False
        slot = self._write % self.capacity
        self._write += 1
        old = self._idx_at[slot]
        if old is not None:
            self._slot_of.pop(old, None)
        self._rows[slot] = np.asarray(row, np.float32)
        self._idx_at[slot] = int(idx)
        self._slot_of[int(idx)] = slot
        for p in range(self.n_preds):
            self._known[p][slot] = False
            self._sigma[p][slot] = False
        return True

    def observe(self, idx: int, pred_idx: int, sigma: bool) -> None:
        slot = self._slot_of.get(int(idx))
        if slot is None:
            return
        self._known[pred_idx][slot] = True
        self._sigma[pred_idx][slot] = bool(sigma)

    @property
    def size(self) -> int:
        return sum(r is not None for r in self._rows)

    def sample(self) -> Tuple[np.ndarray, Dict[int, Tuple[np.ndarray, np.ndarray]]]:
        """(x (M, F), {pred_idx: (known_mask (M,), sigma (M,))})."""
        slots = [s for s, r in enumerate(self._rows) if r is not None]
        x = np.stack([self._rows[s] for s in slots])
        known_sigma = {
            p: (self._known[p][slots].copy(), self._sigma[p][slots].copy())
            for p in range(self.n_preds)
        }
        return x, known_sigma


@dataclass
class DriftEvent:
    """One trigger of the drift detector (recorded in ServeStats)."""

    at_record: int
    signal: str  # e.g. "stage1:udf", "stage0:proxy", "audit:sel:2"
    observed: float
    expected: float
    escalated: bool  # True -> warm B&B resume, False -> re-allocation
    reopt_ms: float = 0.0
    nodes_visited: int = 0
    plan_version: int = 0
    order_before: tuple = ()
    order_after: tuple = ()
