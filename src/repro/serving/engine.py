"""Batched cascade serving engine (continuous batching over the proxy
cascade).

The paper's executor streams rows; on TPU we keep static shapes (DESIGN.md
§3):

  * every cascade stage has a fixed-size device microbatch;
  * proxy scoring runs the fused Pallas kernel over full tiles;
  * survivors are pushed to the next stage's HOST queue; the scheduler
    drains whichever stage has a full tile ready (UDFs always run dense);
  * a final drain pass flushes partial tiles at end-of-stream.

Nothing is dropped: a hypothesis property test asserts conservation
(every record is either rejected by some stage or emitted).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.query import PhysicalPlan


@dataclass
class ServeStats:
    stage_in: List[int]
    stage_udf_batches: List[int]
    stage_kept: List[int]
    emitted: int = 0
    rejected: int = 0
    wall_ms: float = 0.0
    model_cost_ms: float = 0.0


class CascadeServer:
    """Continuous-batching executor for a compiled cascade plan."""

    def __init__(self, plan: PhysicalPlan, *, tile: int = 1024, use_kernel: bool = True):
        self.plan = plan
        self.tile = tile
        self.use_kernel = use_kernel
        n = len(plan.stages)
        self.queues: List[deque] = [deque() for _ in range(n)]  # (idx, row) pending per stage
        self.emitted: List[int] = []
        self.stats = ServeStats(
            stage_in=[0] * n, stage_udf_batches=[0] * n, stage_kept=[0] * n
        )
        self._scorer = None
        if use_kernel:
            try:
                from repro.kernels.ops import proxy_score_batch

                self._scorer = proxy_score_batch
            except Exception:  # pragma: no cover - kernel optional
                self._scorer = None

    # ------------------------------------------------------------- plumbing
    def submit(self, indices: np.ndarray, rows: np.ndarray):
        for i, r in zip(indices, rows):
            self.queues[0].append((int(i), r))

    def _run_stage_batch(self, si: int, batch: List):
        stage = self.plan.stages[si]
        idxs = np.asarray([b[0] for b in batch])
        x = np.stack([b[1] for b in batch])
        self.stats.stage_in[si] += len(batch)
        if stage.proxy is not None:
            if self._scorer is not None and stage.proxy.kind == "svm":
                keep = self._scorer(stage.proxy.params, x, stage.threshold)
            else:
                keep = stage.proxy.score(x) >= stage.threshold
            self.stats.model_cost_ms += len(x) * stage.proxy.cost
            idxs, x = idxs[keep], x[keep]
        if len(idxs) == 0:
            return
        pred = self.plan.query.predicates[stage.pred_idx]
        labels = pred.udf(x)
        self.stats.model_cost_ms += len(x) * pred.udf.cost
        self.stats.stage_udf_batches[si] += 1
        passed = pred.evaluate(labels)
        self.stats.stage_kept[si] += int(passed.sum())
        survivors = [(int(i), r) for i, r, p in zip(idxs, x, passed) if p]
        if si + 1 < len(self.plan.stages):
            self.queues[si + 1].extend(survivors)
        else:
            self.emitted.extend(i for i, _ in survivors)
            self.stats.emitted += len(survivors)

    def pump(self, *, drain: bool = False):
        """Run every stage whose queue holds >= one full tile.  Steady state
        drains later stages first (keeps output latency low); the end-of-
        stream drain runs FORWARD so survivors flow through every stage."""
        n = len(self.plan.stages)
        order = range(n) if drain else reversed(range(n))
        for si in order:
            q = self.queues[si]
            while len(q) >= self.tile or (drain and q):
                take = min(self.tile, len(q))
                batch = [q.popleft() for _ in range(take)]
                self._run_stage_batch(si, batch)

    def run_stream(self, x: np.ndarray, *, chunk: int = 4096) -> ServeStats:
        t0 = time.perf_counter()
        n = x.shape[0]
        for s in range(0, n, chunk):
            idx = np.arange(s, min(s + chunk, n))
            self.submit(idx, x[idx])
            self.pump()
        self.pump(drain=True)
        self.stats.wall_ms = (time.perf_counter() - t0) * 1e3
        self.stats.rejected = n - self.stats.emitted
        return self.stats
