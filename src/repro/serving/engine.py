"""Batched cascade serving engine (continuous batching over the proxy
cascade).

The paper's executor streams rows; on TPU we keep static shapes (DESIGN.md
§3):

  * every cascade stage has a fixed-size device microbatch;
  * proxy scoring runs the fused Pallas kernel over full tiles;
  * survivors are pushed to the next stage's HOST queue; the scheduler
    drains whichever stage has a full tile ready (UDFs always run dense);
  * a final drain pass flushes partial tiles at end-of-stream.

Fused hot path: when every proxied stage is linear, a ``CascadeScorer``
scores each incoming chunk ONCE at submit time — one fused Pallas pass
yields every stage's keep decision — and the per-record mask rows ride
through the stage queues with the record.  Stage execution then never
re-folds, re-scores, or re-traces: the gate is a mask lookup.  Per-stage
``proxy_ms`` / ``used_kernel`` land in ServeStats so benchmark runs can
prove which path they measured.

Nothing is dropped: a hypothesis property test asserts conservation
(every record is either rejected by some stage or emitted).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.query import PhysicalPlan


@dataclass
class ServeStats:
    stage_in: List[int]
    stage_udf_batches: List[int]
    stage_kept: List[int]
    stage_proxy_ms: List[float]
    stage_used_kernel: List[bool]
    emitted: int = 0
    rejected: int = 0
    wall_ms: float = 0.0
    model_cost_ms: float = 0.0
    fused_score_ms: float = 0.0  # submit-time fused whole-cascade scoring

    @property
    def proxy_total_ms(self) -> float:
        return self.fused_score_ms + sum(self.stage_proxy_ms)


class CascadeServer:
    """Continuous-batching executor for a compiled cascade plan."""

    def __init__(self, plan: PhysicalPlan, *, tile: int = 1024, use_kernel: bool = True,
                 fused: bool = True):
        self.plan = plan
        self.tile = tile
        self.use_kernel = use_kernel
        n = len(plan.stages)
        # queue entries: (global idx, feature row, mask row | None)
        self.queues: List[deque] = [deque() for _ in range(n)]
        self.emitted: List[int] = []
        self.stats = ServeStats(
            stage_in=[0] * n, stage_udf_batches=[0] * n, stage_kept=[0] * n,
            stage_proxy_ms=[0.0] * n, stage_used_kernel=[False] * n,
        )
        self._scorer = None
        self._cascade = None
        if use_kernel:
            try:
                from repro.kernels.ops import CascadeScorer, proxy_score_batch
            except ImportError:  # pragma: no cover - kernel optional
                CascadeScorer = proxy_score_batch = None
            if proxy_score_batch is not None:
                self._scorer = proxy_score_batch
                if fused:
                    # a from_plan failure is a real bug — let it propagate
                    cascade = CascadeScorer.from_plan(plan, max_tile=max(tile, 1024))
                    # score-at-submit only pays off when every gated stage is
                    # covered; otherwise fall back to per-stage kernel calls
                    if cascade is not None and cascade.covers_all(plan):
                        self._cascade = cascade

    # ------------------------------------------------------------- plumbing
    def submit(self, indices: np.ndarray, rows: np.ndarray):
        if self._cascade is not None and len(rows):
            t0 = time.perf_counter()
            masks = self._cascade.score_masks(np.asarray(rows, np.float32))
            self.stats.fused_score_ms += (time.perf_counter() - t0) * 1e3
            for i, r, m in zip(indices, rows, masks):
                self.queues[0].append((int(i), r, m))
        else:
            for i, r in zip(indices, rows):
                self.queues[0].append((int(i), r, None))

    def _run_stage_batch(self, si: int, batch: List):
        stage = self.plan.stages[si]
        idxs = np.asarray([b[0] for b in batch])
        x = np.stack([b[1] for b in batch])
        mrows = [b[2] for b in batch]
        self.stats.stage_in[si] += len(batch)
        if stage.proxy is not None:
            t0 = time.perf_counter()
            col = self._cascade.stage_cols[si] if self._cascade is not None else None
            if col is not None and mrows[0] is not None:
                # fused path: the gate was computed once at submit time
                keep = np.asarray([m[col] for m in mrows], bool)
                self.stats.stage_used_kernel[si] = True
            elif self._scorer is not None and stage.proxy.kind == "svm":
                keep = self._scorer(stage.proxy.params, x, stage.threshold)
                self.stats.stage_used_kernel[si] = True
            else:
                keep = stage.proxy.score(x) >= stage.threshold
            self.stats.stage_proxy_ms[si] += (time.perf_counter() - t0) * 1e3
            self.stats.model_cost_ms += len(x) * stage.proxy.cost
            idxs, x = idxs[keep], x[keep]
            mrows = [m for m, k in zip(mrows, keep) if k]
        if len(idxs) == 0:
            return
        pred = self.plan.query.predicates[stage.pred_idx]
        labels = pred.udf(x)
        self.stats.model_cost_ms += len(x) * pred.udf.cost
        self.stats.stage_udf_batches[si] += 1
        passed = pred.evaluate(labels)
        self.stats.stage_kept[si] += int(passed.sum())
        survivors = [
            (int(i), r, m) for i, r, m, p in zip(idxs, x, mrows, passed) if p
        ]
        if si + 1 < len(self.plan.stages):
            self.queues[si + 1].extend(survivors)
        else:
            self.emitted.extend(i for i, _, _ in survivors)
            self.stats.emitted += len(survivors)

    def pump(self, *, drain: bool = False):
        """Run every stage whose queue holds >= one full tile.  Steady state
        drains later stages first (keeps output latency low); the end-of-
        stream drain runs FORWARD so survivors flow through every stage."""
        n = len(self.plan.stages)
        order = range(n) if drain else reversed(range(n))
        for si in order:
            q = self.queues[si]
            while len(q) >= self.tile or (drain and q):
                take = min(self.tile, len(q))
                batch = [q.popleft() for _ in range(take)]
                self._run_stage_batch(si, batch)

    def run_stream(self, x: np.ndarray, *, chunk: int = 4096) -> ServeStats:
        t0 = time.perf_counter()
        n = x.shape[0]
        for s in range(0, n, chunk):
            idx = np.arange(s, min(s + chunk, n))
            self.submit(idx, x[idx])
            self.pump()
        self.pump(drain=True)
        self.stats.wall_ms = (time.perf_counter() - t0) * 1e3
        self.stats.rejected = n - self.stats.emitted
        return self.stats
