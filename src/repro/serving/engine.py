"""Batched cascade serving engine (continuous batching over the proxy
cascade) with optional drift-adaptive re-optimization.

The paper's executor streams rows; on TPU we keep static shapes (DESIGN.md
§3):

  * every cascade stage has a fixed-size device microbatch;
  * proxy scoring runs the fused Pallas kernel over full tiles;
  * survivors are pushed to the next stage's HOST queue; the scheduler
    drains whichever stage has a full tile ready (UDFs always run dense);
  * a final drain pass flushes partial tiles at end-of-stream.

Fused hot path: a ``CascadeScorer`` covers EVERY proxied stage — linear,
MLP, or mixed, all lowered to the packed ProxyFamily format — and scores
each incoming chunk ONCE at submit time: one fused two-pass Pallas GEMM
yields every stage's keep decision, and the per-record mask rows ride
through the stage queues with the record.  Stage execution then never
re-packs, re-scores, or re-traces: the gate is a mask lookup.

Adaptive serving (DESIGN.md §4): with ``adaptive=True`` the server keeps
streaming statistics — per-stage observed keep-rates vs the plan's
estimates, an audited unbiased per-predicate selectivity, pairwise
kappa^2 over audit labels, and a reservoir of recent (partially labeled)
rows.  A CUSUM trigger on any signal re-optimizes mid-stream: a cheap
re-allocation on the incumbent order, or a warm-started branch-and-bound
``resume`` when the correlation structure shifted.  The new plan is
hot-swapped behind a versioned ``_PlanState``: in-flight queue entries
finish under the plan (and mask rows) they were scored with, so record
conservation holds across swaps; new submissions score through the new
plan's ``CascadeScorer`` (compile-cached per plan version).

Nothing is dropped: hypothesis property tests assert conservation (every
record is either rejected by some stage or emitted exactly once), on the
static AND the drift-swapping paths.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.correlation import StreamingKappa2
from repro.core.query import PhysicalPlan
from repro.serving.stats import (

    AdaptivePolicy,
    CusumDetector,
    DriftEvent,
    ImportanceAuditSampler,
    Reservoir,
    StreamingRate,
)
from repro.util import advisory_wall_ms


@dataclass
class ServeStats:
    stage_in: List[int]
    stage_udf_batches: List[int]
    stage_kept: List[int]
    stage_proxy_ms: List[float]
    stage_used_kernel: List[bool]
    emitted: int = 0
    rejected: int = 0
    wall_ms: float = 0.0
    model_cost_ms: float = 0.0
    fused_score_ms: float = 0.0  # submit-time fused whole-cascade scoring
    # ----- adaptive serving -----
    plan_swaps: int = 0
    reopt_ms: float = 0.0  # wall time inside re-optimization
    reopt_udf_cost_ms: float = 0.0  # cost-model charge for reservoir labeling
    audit_records: int = 0
    audit_cost_ms: float = 0.0  # cost-model charge for audit UDF runs
    scorer_cache_hits: int = 0
    plan_cache_writebacks: int = 0  # committed plans recorded cross-query
    drift_events: List[DriftEvent] = field(default_factory=list)

    @property
    def proxy_total_ms(self) -> float:
        return self.fused_score_ms + sum(self.stage_proxy_ms)


class _AuditMonitor:
    """Unconditional per-predicate selectivity watcher over audit records.

    Audit records are importance-sampled toward proxy thresholds, so every
    update carries inverse-propensity-corrected totals: ``kept_w`` /
    ``seen_w`` are Horvitz-Thompson sums (sigma_i / p_i and 1 / p_i over
    the audited subset) whose ratio is an unbiased selectivity estimate,
    while ``n_audited`` (the actual UDF runs) drives the baseline freeze,
    the recency window, and the CUSUM weight — statistical information
    scales with labels paid for, not with IPW-expanded pseudo-counts.

    The first ``baseline_n`` audited records after a plan install define
    the reference rate; afterwards a CUSUM accumulates sustained
    deviation.  (Per-stage keep-rates are conditioned on the prefix, so
    only the audit stream gives an unbiased drift signal per predicate.)
    """

    def __init__(self, policy: AdaptivePolicy):
        self.rate = StreamingRate()
        self.baseline: Optional[float] = None
        self.baseline_n = policy.audit_baseline
        self.cusum = CusumDetector(policy.slack, policy.threshold)
        self._window: deque = deque()  # (kept_w, seen_w, n_audited), recent only
        self._window_n = policy.audit_window
        self._audited = 0

    def update(self, kept_w: float, seen_w: float, n_audited: int) -> bool:
        self.rate.update(kept_w, seen_w)
        self._audited += int(n_audited)
        self._window.append((kept_w, seen_w, n_audited))
        while sum(a for _, _, a in self._window) - self._window[0][2] >= self._window_n:
            self._window.popleft()
        if self.baseline is None:
            if self._audited >= self.baseline_n:
                self.baseline = self.rate.rate
            return False
        return self.cusum.update(kept_w / seen_w if seen_w else 0.0,
                                 self.baseline, n_audited)

    @property
    def has_window(self) -> bool:
        return any(s > 0 for _, s, _ in self._window)

    @property
    def recent_rate(self) -> float:
        seen = sum(s for _, s, _ in self._window)
        return sum(k for k, _, _ in self._window) / seen if seen else 0.0


class _PlanState:
    """One installed plan version: its compiled scorer, its stage queues,
    and (while current) its drift monitors.  Queue entries are
    (global idx, feature row, mask row | None); the mask row is only ever
    interpreted through THIS state's ``stage_cols`` — versioned masks."""

    def __init__(self, version: int, plan: PhysicalPlan, cascade,
                 policy: Optional[AdaptivePolicy]):
        self.version = version
        self.plan = plan
        self.cascade = cascade
        n = len(plan.stages)
        self.queues: List[deque] = [deque() for _ in range(n)]
        self.stage_rate = [StreamingRate() for _ in range(n)]
        self.stage_cusum = (
            [CusumDetector(policy.slack, policy.threshold) for _ in range(n)]
            if policy is not None else None
        )

    def expected_keep(self, si: int) -> float:
        s = self.plan.stages[si]
        return s.est_selectivity * (s.alpha if s.proxy is not None else 1.0)

    def empty(self) -> bool:
        return all(not q for q in self.queues)


class CascadeServer:
    """Continuous-batching executor for a compiled cascade plan.

    ``adaptive=True`` turns on the drift-triggered re-optimization loop;
    the plan should then come from ``optimize(..., keep_state=True)`` so
    re-search can warm-start from the previous branch-and-bound tree (a
    stateless plan still adapts, but re-search cold-starts).
    """

    def __init__(self, plan: PhysicalPlan, *, tile: int = 1024,
                 use_kernel: bool = True, fused: bool = True,
                 adaptive: bool = False,
                 policy: Optional[AdaptivePolicy] = None, seed: int = 0,
                 plan_cache=None, scorer=None):
        self.query = plan.query
        self.tile = tile
        self.use_kernel = use_kernel
        self.fused = fused
        self.adaptive = adaptive
        self.policy = policy or AdaptivePolicy()
        # cross-query plan cache (core.plan_cache.PlanCache): every plan
        # this server commits — the initial install and each drift
        # re-optimization — is written back so a similar future query can
        # warm-start its optimization (DESIGN.md §8)
        self.plan_cache = plan_cache
        n = len(plan.stages)
        self.emitted: List[int] = []
        # plan version each emission was scored AND served under (parallel
        # to ``emitted``): queue entries never migrate between _PlanStates,
        # so the draining state's version IS the scoring version
        self.emitted_versions: List[int] = []
        self.stats = ServeStats(
            stage_in=[0] * n, stage_udf_batches=[0] * n, stage_kept=[0] * n,
            stage_proxy_ms=[0.0] * n, stage_used_kernel=[False] * n,
        )
        self._scorer = None  # legacy per-stage kernel fallback
        if use_kernel:
            try:
                from repro.kernels.ops import proxy_score_batch
            except ImportError:  # pragma: no cover - kernel optional
                proxy_score_batch = None
            self._scorer = proxy_score_batch
        # cross-query UDF evaluation hook (serving/multiquery.py): when a
        # session installs a runner, ``_eval_udf`` routes every stage and
        # audit UDF call through it — fn(pred, idxs, x) -> (labels,
        # cost_ms) — so identical (udf, record) evaluations dedupe across
        # the session's queries and only fresh work is charged
        self.udf_runner = None
        self._states: List[_PlanState] = []
        self._install(plan, scorer=scorer)
        self._record_to_cache(plan)
        # adaptive machinery
        self._rng = np.random.RandomState(seed)
        self._audit_sampler = ImportanceAuditSampler(
            self.policy.audit_rate, floor=self.policy.audit_floor)
        self._reservoir = Reservoir(
            self.query.n, capacity=self.policy.reservoir_capacity,
            stride=self.policy.reservoir_stride,
        )
        self._records_submitted = 0
        self._last_swap_at = 0
        self._drift: Optional[Tuple[str, float, float]] = None
        # record-finalization hooks (the serving front end's completion
        # attribution): fn(emitted_ids, rejected_ids, plan_version) fires
        # once per executed stage batch with the indices that left the
        # pipeline there — emitted at the last stage, rejected anywhere
        self._finalize_hooks: List = []

    # ------------------------------------------------------------ versioning
    @property
    def plan(self) -> PhysicalPlan:
        return self._states[-1].plan

    @property
    def plan_version(self) -> int:
        return self._states[-1].version

    def _install(self, plan: PhysicalPlan, *, scorer=None,
                 version: Optional[int] = None):
        cascade = None
        if scorer is not None:
            if not scorer.covers_all(plan):
                raise ValueError("pre-built scorer does not cover the plan")
            cascade = scorer
        elif self.use_kernel and self.fused:
            from repro.kernels.ops import cascade_scorer_for_plan

            # a from_plan failure is a real bug — let it propagate
            built, hit = cascade_scorer_for_plan(
                plan, max_tile=max(self.tile, 1024))
            # score-at-submit only pays off when every gated stage is
            # covered; otherwise fall back to per-stage kernel calls
            if built is not None and built.covers_all(plan):
                cascade = built
                self.stats.scorer_cache_hits += int(hit)
        if version is None:
            version = self._states[-1].version + 1 if self._states else 0
        elif self._states and version <= self._states[-1].version:
            raise ValueError(
                f"plan version must advance: {version} <= "
                f"{self._states[-1].version}")
        self._states.append(_PlanState(
            version, plan, cascade, self.policy if self.adaptive else None))
        # fresh drift baselines for the new plan
        self._audit_mon = {p: _AuditMonitor(self.policy)
                           for p in range(self.query.n)}
        self._kappa: Dict[Tuple[int, int], StreamingKappa2] = {
            (i, j): StreamingKappa2()
            for i in range(self.query.n) for j in range(i + 1, self.query.n)
        }
        self._kappa_snapshot: Optional[Dict[Tuple[int, int], float]] = None

    def _record_to_cache(self, plan: PhysicalPlan) -> None:
        """Write a committed plan back to the cross-query plan cache.
        Fingerprinted with this server's re-optimization step so the
        initial plan and every drift re-plan of the same query land on
        one entry, each write refreshing it with reservoir-fresh
        selectivities."""
        if self.plan_cache is None:
            return
        if self.plan_cache.record_plan(plan, step=self.policy.step) is not None:
            self.stats.plan_cache_writebacks += 1

    # --------------------------------------- external coordination (sharded)
    def install_plan(self, plan: PhysicalPlan, *, scorer=None,
                     version: Optional[int] = None) -> int:
        """Hot-swap to an externally-decided plan (multi-host quorum swaps,
        DESIGN.md §6): ``scorer`` may be a pre-built/deserialized
        ``CascadeScorer``; ``version`` pins the global epoch so every host
        serves the same version number.  In-flight entries still finish
        under the version that scored them.  Returns the installed
        version."""
        self._install(plan, scorer=scorer, version=version)
        self.stats.plan_swaps += 1
        self._last_swap_at = self._records_submitted
        self._drift = None  # stale local trigger: superseded by the swap
        return self._states[-1].version

    def take_drift(self) -> Optional[Tuple[str, float, float]]:
        """Pop the pending local drift trigger (signal, observed, expected)
        without re-optimizing — the sharded serving loop turns it into a
        quorum VOTE instead of a local swap.  Clearing it re-arms
        ``_may_trigger`` (cooldown still applies)."""
        drift, self._drift = self._drift, None
        return drift

    def reservoir_export(self):
        """Weighted snapshot of the local reservoir (rows + labels + IPW
        weights) for coordinator-side merging."""
        return self._reservoir.export()

    def kappa_export(self):
        """Cumulative weighted IPW contingency counts per predicate pair
        (reset at every plan install) — the fleet coordinator sums these
        across hosts into pooled ``StreamingKappa2`` tables, so
        correlation evidence too weak for any single shard's guard still
        escalates at the fleet level (DESIGN.md §6)."""
        return {pair: k.export() for pair, k in self._kappa.items()}

    def has_ready_batch(self, *, drain: bool = False) -> bool:
        """Whether ``pump_one(drain=drain)`` would find work: a
        superseded version with anything queued, a full tile at the
        current version, or (under ``drain``) anything at all."""
        for st in self._states[:-1]:
            if not st.empty():
                return True
        if drain:
            return not self._states[-1].empty()
        return any(len(q) >= self.tile for q in self._states[-1].queues)

    def in_flight(self) -> int:
        """Records sitting in ANY plan version's stage queues — zero after
        a full drain, or something was lost in the pipe (the falsifiable
        half of the conservation check; emitted-list uniqueness is the
        other)."""
        return sum(len(q) for s in self._states for q in s.queues)

    # ------------------------------------------------------------- plumbing
    def add_finalize_hook(self, fn) -> None:
        """Register ``fn(emitted_ids, rejected_ids, plan_version)`` to be
        called whenever records leave the pipeline (emitted from the last
        stage, or rejected by a proxy gate / predicate at any stage).
        Every submitted record is reported to the hooks exactly once —
        the serving front end leans on this for per-request completion
        latency attribution (DESIGN.md §7)."""
        self._finalize_hooks.append(fn)

    def _notify_finalized(self, emitted: List[int], rejected: List[int],
                          version: int) -> None:
        if not self._finalize_hooks or not (emitted or rejected):
            return
        for fn in self._finalize_hooks:
            fn(emitted, rejected, version)

    def submit(self, indices: np.ndarray, rows: np.ndarray, *,
               masks: Optional[np.ndarray] = None,
               margins: Optional[np.ndarray] = None):
        """``masks`` (N, P in THIS plan's column layout) short-circuits
        the fused scoring pass — the multi-query session scores one
        stacked launch for every tenant and hands each engine its own
        column slice.  Mask rows are versioned exactly like locally
        scored ones: they ride the current state's queues and are only
        read through its ``stage_cols``."""
        if len(rows) == 0:
            # short-circuit: the front end's batching loop ticks on every
            # arrival-poll, so idle ticks would otherwise still walk the
            # zip-append path and count into ``_records_submitted`` (whose
            # delta since the last swap feeds the ``_may_trigger``
            # cooldown arithmetic) — an empty submission must be a no-op
            return
        cur = self._states[-1]
        rows = np.asarray(rows, np.float32)
        if masks is not None:
            masks = np.asarray(masks, bool)
            for i, r, m in zip(indices, rows, masks):
                cur.queues[0].append((int(i), r, m))
        elif cur.cascade is not None and len(rows):
            t0 = advisory_wall_ms()
            if self.adaptive and self.policy.audit_importance:
                # the importance-audit weights need score-to-threshold
                # distances; the margin reduction runs on device in the
                # same fused pass that produces the masks
                masks, margins = cur.cascade.score_margins(rows)
            else:
                masks = cur.cascade.score_masks(rows)
            self.stats.fused_score_ms += advisory_wall_ms() - t0
            for i, r, m in zip(indices, rows, masks):
                cur.queues[0].append((int(i), r, m))
        else:
            for i, r in zip(indices, rows):
                cur.queues[0].append((int(i), r, None))
        if self.adaptive and len(rows):
            self._observe_chunk(np.asarray(indices), rows, margins)
        self._records_submitted += len(rows)

    def _eval_udf(self, pred, idxs: np.ndarray, x: np.ndarray):
        """Run ``pred``'s UDF over ``x`` and return (labels, cost_ms).
        The default path runs and charges everything; a session-installed
        ``udf_runner`` dedupes repeat (udf, record) evaluations across
        queries and charges only the fresh ones."""
        if self.udf_runner is not None:
            return self.udf_runner(pred, idxs, x)
        return pred.udf(x), len(x) * pred.udf.cost

    def _observe_chunk(self, indices: np.ndarray, rows: np.ndarray,
                       margins: Optional[np.ndarray] = None):
        """Reservoir-sample the chunk and audit a small subset: audit
        records get EVERY UDF run up front (charged to the cost model),
        yielding drift-grade selectivity/correlation statistics and
        pre-labeled reservoir rows for re-optimization.

        The audit subset is importance-sampled toward records near proxy
        thresholds (``margins`` = score distance to the nearest stage
        threshold): those labels carry the most information about whether
        the thresholds still sit where the optimizer put them.  The
        induced bias is removed with inverse-propensity weights before the
        selectivity monitors see the totals, so corrected estimates stay
        unbiased on any stream (property-tested)."""
        for i, r in zip(indices, rows):
            self._reservoir.add(int(i), r)
        sel, ipw = self._audit_sampler.select(
            margins if self.policy.audit_importance else None,
            len(rows), self._rng)
        if not sel.any():
            return
        xa, ia = rows[sel], indices[sel]
        for i, r in zip(ia, xa):  # audited rows always enter the reservoir
            self._reservoir.add(int(i), r, force=True)
        labels_by_pred = {}
        for p, pred in enumerate(self.query.predicates):
            labels, cost = self._eval_udf(pred, ia, xa)
            labels_by_pred[p] = labels
            sigma = pred.evaluate(labels)
            self.stats.audit_cost_ms += cost
            self.stats.model_cost_ms += cost
            for idx, s, w in zip(ia, sigma, ipw):
                self._reservoir.observe(int(idx), p, bool(s), weight=float(w))
            kept_w = float(np.sum(sigma * ipw))
            seen_w = float(np.sum(ipw))
            if self._audit_mon[p].update(kept_w, seen_w, len(xa)) \
                    and self._may_trigger():
                self._drift = (
                    f"audit:sel:{p}", self._audit_mon[p].recent_rate,
                    self._audit_mon[p].baseline,
                )
        for (i, j), k in self._kappa.items():
            # IPW weights keep the contingency table a population estimate
            # despite the threshold-weighted audit subset
            k.update(labels_by_pred[i], labels_by_pred[j], weights=ipw)
        if self._kappa_snapshot is None and all(
                m.baseline is not None for m in self._audit_mon.values()):
            self._kappa_snapshot = {k: v.value() for k, v in self._kappa.items()}
        self.stats.audit_records += int(sel.sum())

    def _may_trigger(self) -> bool:
        return (
            self.adaptive
            and self._drift is None
            and self._reservoir.size >= self.policy.min_reservoir
            and (self._records_submitted - self._last_swap_at
                 >= self.policy.cooldown_records)
        )

    def _run_stage_batch(self, state: _PlanState, si: int, batch: List):
        stage = state.plan.stages[si]
        idxs = np.asarray([b[0] for b in batch])
        x = np.stack([b[1] for b in batch])
        mrows = [b[2] for b in batch]
        self.stats.stage_in[si] += len(batch)
        n_enter = len(batch)
        rejected_ids: List[int] = []
        if stage.proxy is not None:
            t0 = advisory_wall_ms()
            col = state.cascade.stage_cols[si] if state.cascade is not None else None
            if col is not None and mrows[0] is not None:
                # fused path: the gate was computed once at submit time
                keep = np.asarray([m[col] for m in mrows], bool)
                self.stats.stage_used_kernel[si] = True
            elif self._scorer is not None:
                keep = self._scorer(stage.proxy.params, x, stage.threshold)
                self.stats.stage_used_kernel[si] = True
            else:
                keep = stage.proxy.score(x) >= stage.threshold
            self.stats.stage_proxy_ms[si] += advisory_wall_ms() - t0
            self.stats.model_cost_ms += len(x) * stage.proxy.cost
            rejected_ids.extend(int(i) for i in idxs[~keep])
            idxs, x = idxs[keep], x[keep]
            mrows = [m for m, k in zip(mrows, keep) if k]
        if len(idxs) == 0:
            self._note_stage_outcome(state, si, 0, n_enter)
            self._notify_finalized([], rejected_ids, state.version)
            return
        pred = state.plan.query.predicates[stage.pred_idx]
        labels, udf_cost = self._eval_udf(pred, idxs, x)
        self.stats.model_cost_ms += udf_cost
        self.stats.stage_udf_batches[si] += 1
        passed = pred.evaluate(labels)
        self.stats.stage_kept[si] += int(passed.sum())
        rejected_ids.extend(int(i) for i in idxs[~passed])
        survivors = [
            (int(i), r, m) for i, r, m, p in zip(idxs, x, mrows, passed) if p
        ]
        self._note_stage_outcome(state, si, len(survivors), n_enter)
        emitted_ids: List[int] = []
        if si + 1 < len(state.plan.stages):
            state.queues[si + 1].extend(survivors)
        else:
            emitted_ids = [i for i, _, _ in survivors]
            self.emitted.extend(emitted_ids)
            self.emitted_versions.extend([state.version] * len(survivors))
            self.stats.emitted += len(survivors)
        self._notify_finalized(emitted_ids, rejected_ids, state.version)

    def _note_stage_outcome(self, state: _PlanState, si: int, kept: int,
                            seen: int):
        """Per-stage combined keep-rate (proxy gate AND predicate) vs the
        plan's estimate ``s_i * alpha_i`` — the conditioned drift signal."""
        state.stage_rate[si].update(kept, seen)
        if state.stage_cusum is None or state is not self._states[-1]:
            return  # superseded versions just drain; no drift bookkeeping
        batch_rate = kept / seen if seen else 0.0
        if state.stage_cusum[si].update(
                batch_rate, state.expected_keep(si), seen) \
                and self._may_trigger():
            # record the BATCH rate: the escalation decision reads the
            # magnitude of the fresh deviation, not the diluted cumulative
            self._drift = (
                f"stage{si}:keep", batch_rate, state.expected_keep(si),
            )

    def _pump_state(self, state: _PlanState, *, drain: bool):
        """Steady state drains later stages first (keeps output latency
        low); drains run FORWARD so survivors flow through every stage."""
        n = len(state.plan.stages)
        order = range(n) if drain else reversed(range(n))
        for si in order:
            q = state.queues[si]
            while len(q) >= self.tile or (drain and q):
                take = min(self.tile, len(q))
                batch = [q.popleft() for _ in range(take)]
                self._run_stage_batch(state, si, batch)

    def pump(self, *, drain: bool = False):
        """Run every stage whose queue holds >= one full tile.  Superseded
        plan versions flush completely first — their in-flight entries
        finish under the plan (and masks) that scored them."""
        for state in self._states[:-1]:
            self._pump_state(state, drain=True)
        self._states = [s for s in self._states
                        if s is self._states[-1] or not s.empty()]
        self._pump_state(self._states[-1], drain=drain)

    def pump_one(self, *, drain: bool = False) -> bool:
        """Run AT MOST one stage batch — the multi-query scheduler's
        service quantum: it charges the cost-model delta of exactly one
        batch to the tenant it picked.  Superseded versions still take
        precedence (same ordering as ``pump``); returns False when no
        batch was ready (nothing >= a tile, or nothing at all under
        ``drain``)."""
        self._states = [s for s in self._states
                        if s is self._states[-1] or not s.empty()]
        for state in self._states:
            is_cur = state is self._states[-1]
            flush = drain or not is_cur
            n = len(state.plan.stages)
            order = range(n) if flush else reversed(range(n))
            for si in order:
                q = state.queues[si]
                if len(q) >= self.tile or (flush and q):
                    take = min(self.tile, len(q))
                    batch = [q.popleft() for _ in range(take)]
                    self._run_stage_batch(state, si, batch)
                    return True
        return False

    # ----------------------------------------------------------- adaptivity
    def _escalate(self) -> Tuple[str, bool]:
        """Decide re-optimization depth from the stale plan's estimated
        COST-MODEL REGRET (``AdaptivePolicy.choose_escalation``): the
        audit monitors' corrected selectivities re-cost the incumbent
        order against every permutation (Eq. 3.1); only a regret beyond
        ``regret_tol`` — a drift a re-allocation cannot fix, because the
        order optimum moved — pays for the warm branch-and-bound resume.
        A kappa² correlation-structure shift also escalates: the regret
        estimate only has marginals, so a correlation change invalidates
        it and re-opens the order question directly."""
        if self.policy.escalate in ("alloc", "bnb"):
            return self.policy.escalate, self.policy.escalate == "bnb"
        if self._kappa_snapshot is not None:
            for key, k in self._kappa.items():
                if abs(k.value() - self._kappa_snapshot[key]) > self.policy.kappa_tol:
                    return "bnb", True
        # freshest selectivities first: the reservoir spans only the last
        # ~capacity*stride records (IPW-corrected labels), while the audit
        # monitors' window can stretch tens of thousands of records back
        fresh_sels = {}
        for p in range(self.query.n):
            sel = self._reservoir.selectivity(p)
            if sel is None:
                mon = self._audit_mon[p]
                if mon.baseline is not None and mon.has_window:
                    sel = mon.recent_rate
            if sel is not None:
                # 0.0 is EVIDENCE (a collapsed predicate is the strongest
                # reorder signal there is), not absence of data — absence
                # is the None above
                fresh_sels[p] = sel
        mode, _regret = self.policy.choose_escalation(
            self._states[-1].plan, fresh_sels)
        return mode, mode == "bnb"

    def escalation_hint(self) -> Tuple[str, bool]:
        """Public read of the local escalation decision (mode, escalated)
        — the sharded serving loop attaches it to a quorum vote instead of
        acting on it locally."""
        return self._escalate()

    def maybe_reoptimize(self) -> bool:
        """Re-optimize and hot-swap if a drift trigger is pending.  Called
        between chunks by ``run_stream``; external drivers can call it at
        any batch boundary."""
        if not (self.adaptive and self._drift):
            return False
        from repro.core.api import REBUILD_DEFAULTS, rebuild_plan

        signal, observed, expected = self._drift
        # the triggering deviation is recorded in the DriftEvent below; the
        # escalation decision itself reads fresh statistics, not magnitude
        mode, escalated = self._escalate()
        old = self._states[-1]
        t0 = advisory_wall_ms()
        x_s, known_sigma = self._reservoir.sample()
        new_plan = rebuild_plan(
            old.plan, x_s,
            REBUILD_DEFAULTS.replace(reopt=mode, step=self.policy.step),
            known_sigma=known_sigma)
        reopt_ms = advisory_wall_ms() - t0
        self.stats.reopt_ms += reopt_ms
        # the builder's UDF labeling on reservoir rows is real model work
        for p, cnt in new_plan.meta["stats"]["udf_calls"].items():
            charge = cnt * self.query.predicates[p].udf.cost
            self.stats.reopt_udf_cost_ms += charge
            self.stats.model_cost_ms += charge
        self._install(new_plan)
        self.stats.plan_swaps += 1
        self._record_to_cache(new_plan)
        trace = new_plan.meta.get("trace") or {}
        self.stats.drift_events.append(DriftEvent(
            at_record=self._records_submitted, signal=signal,
            observed=float(observed), expected=float(expected),
            escalated=escalated, reopt_ms=reopt_ms,
            nodes_visited=int(trace.get("nodes_visited", 0)),
            plan_version=self._states[-1].version,
            order_before=old.plan.order, order_after=new_plan.order,
        ))
        self._last_swap_at = self._records_submitted
        self._drift = None
        return True

    # -------------------------------------------------------------- driver
    def run_stream(self, x: np.ndarray, *, chunk: int = 4096) -> ServeStats:
        t0 = advisory_wall_ms()
        n = x.shape[0]
        for s in range(0, n, chunk):
            idx = np.arange(s, min(s + chunk, n))
            self.submit(idx, x[idx])
            self.pump()
            if self.adaptive:
                self.maybe_reoptimize()
        self.pump(drain=True)
        self.stats.wall_ms = advisory_wall_ms() - t0
        self.stats.rejected = n - self.stats.emitted
        return self.stats
