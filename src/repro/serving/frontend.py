"""SLO-aware request front end over the continuous-batching engine.

The engine (``serving/engine.py``) scores whatever chunk ``submit`` hands
it — there is no notion of a *request*, no admission control, and no
latency metric.  This module adds the request level (DESIGN.md §7):

  * requests (a contiguous index range + feature rows + a per-request
    latency deadline) arrive on a queue with an arrival timestamp;
  * a batching loop coalesces pending rows into micro-batches sized to
    the fused scorer's bucket ladder (``CascadeScorer.buckets``), so
    coalescing only ever produces shapes the compile cache already
    holds — no recompiles on the admission path;
  * each tick drives ``CascadeServer.submit`` + ``pump(drain=True)`` and
    attributes completion latency per request from arrival to the tick
    in which its last record left the pipeline (via the engine's
    finalize hooks);
  * **goodput** — requests/s that met their SLO — is reported next to
    raw cost-model throughput, and a backpressure policy degrades to a
    cheaper plan (dropping trailing cascade stages, each ladder level
    priced exactly by Eq. 3.1 ``plan_cost``) when the predicted queue
    wait exceeds the deadline budget, instead of queueing forever.
    Requests whose deadline expires before their rows were submitted are
    **shed explicitly** — counted, attributed, and never silently lost.

Time base: everything is the engine's deterministic cost-model clock
(``ServeStats.model_cost_ms``), NOT wall-clock — ``fused_score_ms`` is
host time and never enters any decision or reported metric here, so runs
are bit-reproducible and gateable (DESIGN.md §2).

Conservation contract (property-tested): every admitted record is
exactly one of {emitted, rejected-by-the-cascade, explicitly shed};
admission-rejected requests (deadline provably unmeetable at the
cheapest degrade rung — refused up front, distinct from shed) never
contribute records to any of the three;
``engine.in_flight() == 0`` after ``drain()``; shed records never appear
in ``engine.emitted``.  This holds across deadline expiry, degrade
installs, and external (quorum) plan hot-swaps.

Record indices must be globally unique across requests — they are the
attribution key back to the owning request (the engine's
emitted-uniqueness invariant already demands this).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost import plan_cost
from repro.core.query import PhysicalPlan
from repro.serving.engine import CascadeServer


# ------------------------------------------------------------- degrade ladder
def degrade_ladder(plan: PhysicalPlan, *, min_stages: int = 1) -> List[PhysicalPlan]:
    """Cheaper-plan ladder for backpressure: level k drops the last k
    cascade stages (level 0 is the full plan), down to ``min_stages``.

    Trailing stages are the cheapest to cut: the prefix product already
    made them rare, so dropping them sheds the *most expensive
    per-surviving-record* work while leaving the heavily-reducing front
    of the cascade intact.  Each level is re-priced exactly with the
    Eq. 3.1 cost model over the stages it keeps — the same estimates the
    optimizer priced the full plan with — so the backpressure loop can
    reason about capacity in the same (cost-model ms / record) currency
    as throughput.  Semantics under degrade are a documented relaxation:
    dropped predicates are not evaluated, so emission is a superset of
    the exact answer (recall preserved, precision degraded) for the
    records served at that level.

    ``meta`` is shared with the base plan (quant_dtype etc. must carry so
    the degraded scorer packs at the same dtype) plus a ``degrade_level``
    stamp.
    """
    ladder = [plan]
    n = len(plan.stages)
    for k in range(1, n - min_stages + 1):
        stages = list(plan.stages[: n - k])
        est = plan_cost(
            [s.alpha if s.proxy is not None else 1.0 for s in stages],
            [s.est_reduction if s.proxy is not None else 0.0 for s in stages],
            [s.est_selectivity for s in stages],
            [s.proxy.cost if s.proxy is not None else 0.0 for s in stages],
            [plan.query.predicates[s.pred_idx].udf.cost for s in stages],
        )
        meta = dict(plan.meta)
        meta["degrade_level"] = k
        ladder.append(PhysicalPlan(plan.query, stages, est, meta))
    return ladder


# ------------------------------------------------------------------ requests
@dataclass
class Request:
    """One client request: serve ``indices``/``rows`` within
    ``deadline_ms`` (cost-model ms, relative to ``arrival_ms``)."""

    rid: int
    indices: np.ndarray
    rows: np.ndarray
    arrival_ms: float
    deadline_ms: float
    # ---- bookkeeping (owned by the front end) ----
    cursor: int = 0           # rows [0, cursor) submitted or shed
    outstanding: int = 0      # submitted, not yet finalized by the engine
    emitted: int = 0
    rejected: int = 0
    shed_ids: List[int] = field(default_factory=list)
    done_ms: Optional[float] = None
    # refused at admission: no row was ever submitted or shed — the
    # deadline was provably unmeetable even at the cheapest degrade rung
    admission_rejected: bool = False

    @property
    def n(self) -> int:
        return len(self.rows)

    @property
    def shed(self) -> int:
        return len(self.shed_ids)

    @property
    def submitted(self) -> int:
        return self.cursor - self.shed

    @property
    def absolute_deadline(self) -> float:
        return self.arrival_ms + self.deadline_ms

    @property
    def done(self) -> bool:
        return self.done_ms is not None

    @property
    def latency_ms(self) -> Optional[float]:
        return None if self.done_ms is None else self.done_ms - self.arrival_ms

    @property
    def met_slo(self) -> bool:
        """A request meets its SLO iff it finished within the deadline
        AND nothing was shed — shed work is an explicit SLO miss, never a
        silent success.  An admission-rejected request finishes instantly
        but served zero records: never an SLO success."""
        return (self.done_ms is not None and self.shed == 0
                and not self.admission_rejected
                and self.latency_ms <= self.deadline_ms + 1e-9)


# -------------------------------------------------------------------- policy
@dataclass
class SLOPolicy:
    """Knobs for the admission / backpressure / degrade loop.

    ``degrade_headroom`` / ``restore_headroom`` are hysteresis fractions
    of the tightest pending deadline budget: degrade (as many ladder
    levels as the burst requires, re-predicting after each) when the
    predicted queue wait exceeds ``degrade_headroom`` of it, restore one
    level per tick when the wait falls below ``restore_headroom``.  The
    gap between them prevents flapping.  ``degrade=False`` turns the ladder off entirely
    (shed-only backpressure — the sharded fleet mode, where plan
    versions are pinned to quorum epochs and a local install would break
    the epoch ordering; see DESIGN.md §7)."""

    shed_expired: bool = True
    # refuse (at admission) requests whose deadline cannot be met even at
    # the cheapest degrade rung with ZERO queueing — rejecting up front
    # costs nothing; shedding later costs the capacity already spent
    admission_control: bool = True
    degrade: bool = True
    min_stages: int = 1
    degrade_headroom: float = 0.85
    restore_headroom: float = 0.30
    # wait-for-coalesce: hold a sub-bucket batch if the next arrival is
    # within this fraction of the tightest pending budget
    coalesce_wait_frac: float = 0.25
    max_batch: Optional[int] = None  # cap rows per submit (default: top bucket)
    cost_ewma: float = 0.25          # smoothing for observed per-row cost


@dataclass
class FrontEndStats:
    requests_total: int = 0
    requests_done: int = 0
    requests_met_slo: int = 0
    requests_shed: int = 0        # requests with >= 1 shed record
    # admission-time refusals: distinct from shed — a rejected request
    # never occupied queue capacity or engine work at all
    requests_rejected_admission: int = 0
    records_rejected_admission: int = 0
    records_submitted: int = 0
    records_emitted: int = 0
    records_rejected: int = 0
    records_shed: int = 0
    batches: int = 0
    degrades: int = 0
    restores: int = 0
    final_level: int = 0
    served_ms: float = 0.0        # cost-model ms spanned by the run

    @property
    def throughput_rps(self) -> float:
        """Raw request throughput: completed requests per cost-model
        second (shed-or-late requests still complete and count here)."""
        return self.requests_done / (self.served_ms / 1e3) if self.served_ms else 0.0

    @property
    def goodput_rps(self) -> float:
        """Requests per cost-model second that met their SLO
        (SNIPPETS.md §2's latency/goodput framing)."""
        return self.requests_met_slo / (self.served_ms / 1e3) if self.served_ms else 0.0

    @property
    def goodput_ratio(self) -> float:
        """goodput / throughput over the same run — the gated quantity
        (requests_met / requests_done; time base cancels)."""
        return self.requests_met_slo / self.requests_done if self.requests_done else 0.0


# ----------------------------------------------------------------- front end
class ServingFrontEnd:
    """Request queue + batching loop + SLO accounting over a
    ``CascadeServer``.

    Usage::

        fe = ServingFrontEnd(engine, policy=SLOPolicy())
        fe.submit_request(idx, rows, deadline_ms=50.0, arrival_ms=0.0)
        fe.run()          # drive to completion (offline trace)
        fe.stats.goodput_ratio

    or tick-at-a-time via ``step()`` for drivers that interleave other
    work (quorum swaps, drift re-optimization) between ticks.
    """

    def __init__(self, engine: CascadeServer, *,
                 policy: Optional[SLOPolicy] = None):
        self.engine = engine
        self.policy = policy or SLOPolicy()
        self.stats = FrontEndStats()
        self.now_ms = 0.0
        self.requests: Dict[int, Request] = {}
        self._arrivals: List[Request] = []   # not yet admitted, arrival order
        self._pending: Deque[Request] = deque()  # admitted, rows left to submit
        self._owner: Dict[int, int] = {}     # record idx -> rid
        self._next_rid = 0
        self._cost_seen = float(engine.stats.model_cost_ms)
        self._t0_ms: Optional[float] = None
        self._just_finalized: List[int] = []  # rids whose outstanding hit 0
        # degrade ladder: scorers are prebuilt ONCE here so a mid-stream
        # degrade install is a compile-cache hit, never a recompile
        self._ladder: List[Tuple[PhysicalPlan, object]] = []
        self.level = 0
        base = engine.plan
        self._base_cost = base.est_total_cost or 1.0
        if self.policy.degrade and len(base.stages) > self.policy.min_stages:
            from repro.kernels.ops import cascade_scorer_for_plan

            for p in degrade_ladder(base, min_stages=self.policy.min_stages):
                scorer, _ = cascade_scorer_for_plan(
                    p, max_tile=max(engine.tile, 1024))
                self._ladder.append((p, scorer))
        # per-row cost estimate (cost-model ms) for wait prediction,
        # seeded from the plan's own Eq. 3.1 estimate
        self._row_ms = float(self._base_cost)
        # called with the index array right before each engine.submit —
        # the batching loop defers rows past their chunk arrival, so
        # anything keyed to "version current at submission" (e.g. the
        # sharded submit_version cross-check) must attach HERE, not at
        # request ingestion
        self._submit_hooks: List = []
        engine.add_finalize_hook(self._on_finalized)
        cascade = engine._states[-1].cascade
        top = cascade.buckets[-1] if cascade is not None else engine.tile
        # coalescing ladder: geometric from the engine tile up to the
        # scorer's top compile bucket.  The scorer bucket-pads EVERY
        # submission to a cached static shape, so sub-bucket micro-batches
        # never recompile — a coarse autotuned block_m (e.g. a single
        # 1024-row bucket) must not force the front end to hold small
        # requests hostage while their deadline burns.
        buckets = []
        size = min(max(engine.tile, 1), top)
        while size < top:
            buckets.append(size)
            size *= 2
        buckets.append(top)
        self._buckets: Tuple[int, ...] = tuple(buckets)

    def add_submit_hook(self, fn) -> None:
        """Register ``fn(indices)`` to run right before every
        ``engine.submit`` the batching loop issues."""
        self._submit_hooks.append(fn)

    # ------------------------------------------------------------- ingestion
    def submit_request(self, indices, rows, *, deadline_ms: float,
                       arrival_ms: float = 0.0) -> int:
        """Enqueue a request; returns its rid.  ``arrival_ms`` is on the
        cost-model clock (an offline trace replays arrivals by passing
        increasing stamps)."""
        indices = np.asarray(indices)
        rows = np.asarray(rows, np.float32)
        if len(indices) != len(rows):
            raise ValueError("indices/rows length mismatch")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, indices, rows, float(arrival_ms), float(deadline_ms))
        self.requests[rid] = req
        self._arrivals.append(req)
        self._arrivals.sort(key=lambda r: r.arrival_ms)
        self.stats.requests_total += 1
        for i in indices:
            i = int(i)
            if i in self._owner:
                raise ValueError(
                    f"record index {i} already owned by request "
                    f"{self._owner[i]}: indices must be globally unique")
            self._owner[i] = rid
        return rid

    # ------------------------------------------------------- engine callback
    def _on_finalized(self, emitted: List[int], rejected: List[int],
                      version: int) -> None:
        for ids, what in ((emitted, "emitted"), (rejected, "rejected")):
            for i in ids:
                rid = self._owner.get(int(i))
                if rid is None:
                    continue  # records submitted around the front end
                req = self.requests[rid]
                req.outstanding -= 1
                if what == "emitted":
                    req.emitted += 1
                    self.stats.records_emitted += 1
                else:
                    req.rejected += 1
                    self.stats.records_rejected += 1
                if req.outstanding == 0 and req.cursor >= req.n:
                    self._just_finalized.append(rid)

    # ------------------------------------------------------------ inner gear
    def _tightest_budget(self) -> Optional[float]:
        """min over pending requests of (absolute deadline - now)."""
        budgets = [r.absolute_deadline - self.now_ms for r in self._pending]
        return min(budgets) if budgets else None

    def _queued_rows(self) -> int:
        return sum(r.n - r.cursor for r in self._pending)

    def _predicted_wait_ms(self) -> float:
        """Queue-drain estimate: unsubmitted rows x EWMA per-row
        cost-model cost (observed at the CURRENT degrade level)."""
        return self._queued_rows() * self._row_ms

    def _cheapest_row_ms(self) -> float:
        """Per-row cost-model estimate at the degrade ladder's CHEAPEST
        rung: the observed EWMA (tracking the current level) rescaled by
        the Eq. 3.1 price ratio — the best-case service rate any amount
        of degrading could reach."""
        cur_est = (self._ladder[self.level][0].est_total_cost
                   if self._ladder else self._base_cost) or self._base_cost
        cheap_est = (self._ladder[-1][0].est_total_cost
                     if self._ladder else cur_est) or cur_est
        return self._row_ms * cheap_est / max(cur_est, 1e-12)

    def _admit(self) -> int:
        n = 0
        while self._arrivals and self._arrivals[0].arrival_ms <= self.now_ms + 1e-9:
            req = self._arrivals.pop(0)
            if self._t0_ms is None:
                self._t0_ms = req.arrival_ms
            if req.n == 0:  # degenerate empty request: done on arrival
                self._finish(req)
                continue
            if self.policy.admission_control \
                    and req.n * self._cheapest_row_ms() > req.deadline_ms + 1e-9:
                # provably unmeetable: even with an empty queue at the
                # cheapest rung, pure service time exceeds the deadline.
                # Refuse now — the client learns immediately, and no
                # queue slot or engine work is wasted on a lost cause
                req.admission_rejected = True
                self.stats.requests_rejected_admission += 1
                self.stats.records_rejected_admission += req.n
                self._finish(req)
                continue
            self._pending.append(req)
            n += 1
        return n

    def _shed_expired(self) -> None:
        """Drop the *unsubmitted* remainder of any pending request whose
        deadline has already passed — submitted rows still finish (the
        engine never abandons in-flight work), but spending more capacity
        on a lost cause only makes the next request late too.  Shedding
        is explicit: ids are recorded on the request and counted."""
        if not self.policy.shed_expired:
            return
        keep: Deque[Request] = deque()
        for req in self._pending:
            if req.absolute_deadline < self.now_ms - 1e-9 and req.cursor < req.n:
                shed = [int(i) for i in req.indices[req.cursor:]]
                req.shed_ids.extend(shed)
                req.cursor = req.n
                self.stats.records_shed += len(shed)
                self.stats.requests_shed += 1
                if req.outstanding == 0:
                    self._finish(req)
            else:
                keep.append(req)
        self._pending = keep

    def _backpressure(self) -> None:
        """One hysteresis ladder step per tick, driven by predicted wait
        vs the tightest pending deadline budget."""
        if not self._ladder:
            return
        budget = self._tightest_budget()
        if budget is None:
            # idle queue: drift back toward the full plan
            if self.level > 0:
                self._set_level(self.level - 1, restore=True)
            return
        wait = self._predicted_wait_ms()
        if wait > self.policy.degrade_headroom * max(budget, 0.0):
            # escalate as many levels as the burst needs IN THIS TICK —
            # an arrival burst can outrun a one-level-per-tick ladder
            # before the queue ever drains (_set_level rescales _row_ms,
            # so the re-predicted wait reflects each cheaper level)
            while wait > self.policy.degrade_headroom * max(budget, 0.0) \
                    and self.level < len(self._ladder) - 1:
                self._set_level(self.level + 1)
                wait = self._predicted_wait_ms()
        elif wait < self.policy.restore_headroom * max(budget, 0.0) \
                and self.level > 0:
            self._set_level(self.level - 1, restore=True)

    def _set_level(self, level: int, *, restore: bool = False) -> None:
        plan, scorer = self._ladder[level]
        # scale the per-row cost estimate to the new level's Eq. 3.1
        # price so the next tick's wait prediction doesn't lag a ladder
        # step behind reality
        old_est = (self._ladder[self.level][0].est_total_cost or self._base_cost)
        new_est = plan.est_total_cost or self._base_cost
        self._row_ms *= new_est / max(old_est, 1e-12)
        self.level = level
        self.engine.install_plan(plan, scorer=scorer)
        if restore:
            self.stats.restores += 1
        else:
            self.stats.degrades += 1
        self.stats.final_level = level

    def _coalesce(self) -> Tuple[List[int], List[np.ndarray]]:
        """FIFO-assemble the next micro-batch: fill to the largest
        coalescing-ladder rung that the queue can cover (requests split
        freely across batches), never beyond the scorer's top bucket —
        the scorer bucket-pads every rung, so each resulting shape is
        already in the fused scorer's compile cache."""
        queued = self._queued_rows()
        if queued == 0:
            return [], []
        cap = self.policy.max_batch or self._buckets[-1]
        budget = self._tightest_budget()
        if budget is not None and self._row_ms > 0:
            # completion is attributed per batch, so the head-of-queue
            # request waits for EVERY row coalesced in front of its last
            # one — never grow the batch past what its remaining deadline
            # budget can pay for (degrade_headroom keeps slack for EWMA
            # noise; floor 1 so the queue always makes progress — an
            # already-expired head is _shed_expired's problem, not ours)
            afford = int(self.policy.degrade_headroom
                         * max(budget, 0.0) / self._row_ms)
            cap = max(1, min(cap, afford))
        target = self._buckets[0]
        for b in self._buckets:
            if b <= min(queued, cap):
                target = b
        take = min(queued, target, cap)
        idxs: List[int] = []
        rows: List[np.ndarray] = []
        while take > 0 and self._pending:
            req = self._pending[0]
            k = min(take, req.n - req.cursor)
            sl = slice(req.cursor, req.cursor + k)
            idxs.extend(int(i) for i in req.indices[sl])
            rows.extend(req.rows[sl])
            req.cursor += k
            req.outstanding += k
            take -= k
            if req.cursor >= req.n:
                self._pending.popleft()
        return idxs, rows

    def _should_wait(self) -> bool:
        """Hold a sub-bucket batch when another arrival is imminent
        relative to the tightest deadline — classic batching/latency
        tradeoff, resolved in favor of the deadline."""
        if not self._arrivals or self._queued_rows() >= self._buckets[0]:
            return False
        budget = self._tightest_budget()
        if budget is None:
            return True  # nothing pending at all: just jump to the arrival
        gap = self._arrivals[0].arrival_ms - self.now_ms
        return gap <= self.policy.coalesce_wait_frac * budget

    def _advance_clock(self) -> None:
        cost = float(self.engine.stats.model_cost_ms)
        self.now_ms += cost - self._cost_seen
        self._cost_seen = cost

    def _finish(self, req: Request) -> None:
        if req.done_ms is not None:
            return
        req.done_ms = self.now_ms
        self.stats.requests_done += 1
        if req.met_slo:
            self.stats.requests_met_slo += 1

    def _flush_finalized(self) -> None:
        for rid in self._just_finalized:
            self._finish(self.requests[rid])
        self._just_finalized.clear()

    # ------------------------------------------------------------------ loop
    def step(self) -> bool:
        """One tick: admit, shed, backpressure, coalesce+submit, drain,
        advance the clock, stamp completions.  Returns False when no work
        remains anywhere (arrivals, queue, engine)."""
        self._admit()
        self._shed_expired()
        self._backpressure()
        idxs, rows = ([], []) if self._should_wait() else self._coalesce()
        if idxs:
            submitted = len(idxs)
            arr = np.asarray(idxs)
            for hook in self._submit_hooks:
                hook(arr)
            self.engine.submit(arr, np.stack(rows))
            # drain-mode pump: a serving loop flushes partial tiles every
            # tick — deadline latency beats tile efficiency, and the
            # cost model charges per record either way
            self.engine.pump(drain=True)
            self.stats.records_submitted += submitted
            self.stats.batches += 1
            before = self.now_ms
            self._advance_clock()
            tick_ms = self.now_ms - before
            if submitted and tick_ms > 0:
                a = self.policy.cost_ewma
                self._row_ms += a * (tick_ms / submitted - self._row_ms)
            self._flush_finalized()
            self._shed_expired()  # the tick may have blown deadlines
        elif self._arrivals and not self._pending:
            # idle: jump the clock to the next arrival
            self.now_ms = max(self.now_ms, self._arrivals[0].arrival_ms)
        elif self._arrivals:
            # waiting to coalesce: time passes to the arrival we held for
            self.now_ms = max(self.now_ms, self._arrivals[0].arrival_ms)
        self.stats.served_ms = self.now_ms - (self._t0_ms or 0.0)
        return bool(self._arrivals or self._pending
                    or self.engine.in_flight() > 0)

    def drain(self) -> None:
        """Flush everything in flight and stamp the stragglers."""
        self.engine.pump(drain=True)
        self._advance_clock()
        self._flush_finalized()
        for req in list(self._pending):
            if req.cursor >= req.n and req.outstanding == 0:
                self._finish(req)
        self.stats.served_ms = self.now_ms - (self._t0_ms or 0.0)

    def run(self, *, max_ticks: int = 1_000_000) -> FrontEndStats:
        ticks = 0
        while self.step():
            ticks += 1
            if ticks >= max_ticks:  # pragma: no cover - safety valve
                break
        self.drain()
        return self.stats

    # -------------------------------------------------------------- external
    def on_external_swap(self) -> None:
        """Tell the front end an external (quorum) plan swap happened:
        the degrade ladder belongs to the OLD plan, so it is rebuilt only
        on the next explicit request — here we just drop it and reset the
        level (sharded mode runs shed-only anyway; DESIGN.md §7)."""
        self.level = 0
        self._ladder = []

    # ---------------------------------------------------------- verification
    def conserved(self) -> Tuple[bool, str]:
        """The falsifiable conservation statement, checkable after
        ``drain()``: per request submitted == emitted + rejected,
        cursor covered every row, engine pipe empty, and no shed id was
        ever emitted."""
        if self.engine.in_flight() != 0:
            return False, f"in_flight={self.engine.in_flight()} after drain"
        emitted = set(self.engine.emitted)
        if len(emitted) != len(self.engine.emitted):
            return False, "duplicate emissions"
        for req in self.requests.values():
            if req.admission_rejected:
                # never entered the pipeline: nothing submitted, shed,
                # emitted, or in flight may be attributed to it
                if (req.cursor, req.outstanding, req.emitted,
                        req.rejected, req.shed) != (0, 0, 0, 0, 0):
                    return False, (f"rid {req.rid}: admission-rejected "
                                   f"request has pipeline activity")
                continue
            if req.cursor != req.n:
                return False, f"rid {req.rid}: {req.n - req.cursor} rows unaccounted"
            if req.submitted != req.emitted + req.rejected:
                return False, (f"rid {req.rid}: submitted {req.submitted} != "
                               f"emitted {req.emitted} + rejected {req.rejected}")
            for i in req.shed_ids:
                if i in emitted:
                    return False, f"rid {req.rid}: shed record {i} was emitted"
        return True, "ok"
