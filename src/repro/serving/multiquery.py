"""Multi-query, multi-tenant cascade serving (DESIGN.md §10).

One ``CoreSession`` registers N concurrent cascade queries
(``core.api.QueryHandle``) and serves them through this shared engine:

* **One fused launch per chunk** — every tenant's proxied stages stack
  into a single block-diagonal packed cascade
  (``CascadeScorer.from_plans``), deduping columns whose packed params
  AND threshold are byte-identical; each tenant's engine receives its
  own column slice of the stacked mask matrix.  Because the readout is
  block-diagonal, the sliced masks are bit-identical to the tenant's
  isolated scorer (property-tested, including across a mid-stream
  hot-swap of one tenant's plan only).
* **Cross-query UDF dedupe** — identical (udf, record) predicate
  evaluations run ONCE per session: a result cache keyed on the UDF
  content fingerprint (name, declared cost, class count) serves repeat
  lookups for free, and only fresh evaluations are charged to the cost
  model.  The session assumes one shared record-id space (the same
  global index always denotes the same row).
* **Weighted-fair scheduling** — device time is allocated by marginal
  Eq. 3.1 benefit: each tenant's default weight is the cost the cascade
  saves per unit of device cost it spends, and a virtual-time WFQ picks
  the backlogged tenant with the smallest served-cost/weight.  A
  newly-backlogged tenant syncs to the minimum backlogged virtual time,
  so idle periods cannot bank credit; the starvation bound (no
  continuously-backlogged tenant falls behind its weighted share by
  more than a constant number of batches) is property-tested.
* **Per-tenant isolation under swaps** — each tenant keeps its own
  ``CascadeServer`` (versioned ``_PlanState``s, drift monitors,
  conservation); a swap restacks the SHARED scorer but never reinstalls
  the other tenants' plans, so their in-flight masks stay valid and
  their traffic never stalls (the distributed analogue lives in
  ``distributed/consensus.MultiQueryCoordinator``: per-query epochs in
  quorum swaps).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.engine import CascadeServer
from repro.util import advisory_wall_ms


def udf_fingerprint(udf) -> str:
    """Content identity of an ML UDF for cross-query dedupe: the same
    convention the plan cache's predicate idents use (name, declared
    cost, class count) — two queries naming the same model share its
    evaluations."""
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((str(udf.name), float(udf.cost),
                   int(udf.n_classes))).encode())
    return h.hexdigest()


class UdfResultCache:
    """(udf fingerprint, record idx) -> label store shared by every
    tenant engine in a session.  ``runner`` plugs into
    ``CascadeServer.udf_runner``: it evaluates only the records the
    session has never run through this UDF, charges only those to the
    cost model, and replays the rest bit-identically."""

    def __init__(self):
        self._results: Dict[str, Dict[int, object]] = {}
        self.hits = 0
        self.misses = 0
        self.saved_cost_ms = 0.0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def runner(self, pred, idxs: np.ndarray, x: np.ndarray):
        fp = udf_fingerprint(pred.udf)
        store = self._results.setdefault(fp, {})
        missing = [k for k, i in enumerate(idxs) if int(i) not in store]
        if missing:
            fresh = pred.udf(x[missing])
            for k, lab in zip(missing, fresh):
                store[int(idxs[k])] = lab
        labels = np.asarray([store[int(i)] for i in idxs])
        n_hit = len(idxs) - len(missing)
        self.hits += n_hit
        self.misses += len(missing)
        self.saved_cost_ms += n_hit * pred.udf.cost
        return labels, len(missing) * pred.udf.cost

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate,
                "saved_cost_ms": self.saved_cost_ms,
                "udfs": len(self._results)}


def eq31_benefit(plan) -> float:
    """Marginal Eq. 3.1 benefit of serving through ``plan``'s cascade
    instead of the unproxied conjunction: cost saved per unit of device
    cost spent.  The scheduler's default per-tenant weight — device time
    flows toward the tenants whose cascades buy the most."""
    orig = sum(p.udf.cost for p in plan.query.predicates)
    spent = max(float(plan.est_total_cost), 1e-9)
    return float(np.clip((orig - spent) / spent, 0.1, 100.0))


class FairScheduler:
    """Weighted-fair queueing over tenant service on the cost-model
    clock.  ``pick`` returns the backlogged tenant with minimum virtual
    time (served cost / weight, ties to the heavier weight then the
    lower qid); ``charge`` advances it by one service quantum.  The
    ``service_log`` keeps every (qid, cost) grant so fairness is
    auditable after the fact."""

    def __init__(self, weights: Dict[int, float]):
        self.weights = {int(q): max(float(w), 1e-6)
                        for q, w in weights.items()}
        self.vtime = {q: 0.0 for q in self.weights}
        self.served_cost = {q: 0.0 for q in self.weights}
        self.service_log: List[Tuple[int, float]] = []
        self._backlogged = {q: False for q in self.weights}

    def pick(self, backlogged: Sequence[int]) -> int:
        incumbents = [q for q in backlogged if self._backlogged[q]]
        if incumbents:
            # v-time sync on re-entry: an idle tenant resumes at the floor
            # of the tenants that STAYED backlogged, not at its own stale
            # clock — a newcomer's vtime must not define the floor, or it
            # replays banked credit and starves the incumbents for a
            # stretch proportional to its idle time
            floor = min(self.vtime[q] for q in incumbents)
            for q in backlogged:
                if not self._backlogged[q]:
                    self.vtime[q] = max(self.vtime[q], floor)
        active = set(backlogged)
        for q in self._backlogged:
            self._backlogged[q] = q in active
        return min(backlogged,
                   key=lambda q: (self.vtime[q], -self.weights[q], q))

    def charge(self, qid: int, cost_ms: float) -> None:
        cost_ms = max(float(cost_ms), 1e-9)
        self.vtime[qid] += cost_ms / self.weights[qid]
        self.served_cost[qid] += cost_ms
        self.service_log.append((qid, cost_ms))

    def as_dict(self) -> dict:
        return {"weights": dict(self.weights),
                "served_cost_ms": dict(self.served_cost),
                "grants": len(self.service_log)}


@dataclass
class SessionStats:
    queries: int = 0
    restacks: int = 0
    shared_cols: int = 0          # columns in the stacked scorer
    stacked_cols_saved: int = 0   # columns deduped across tenants
    shared_score_ms: float = 0.0  # wall inside the stacked fused pass
    finalized_per_query: List[int] = field(default_factory=list)


class MultiQueryEngine:
    """The shared serving engine behind ``CoreSession.serve()`` for N>1
    registered queries.  Owns one ``CascadeServer`` per tenant (so every
    single-query invariant — versioned swaps, conservation, drift state
    — holds per tenant), one stacked ``CascadeScorer`` across all
    tenants' plans, one cross-query ``UdfResultCache``, and one
    ``FairScheduler`` granting device time by Eq. 3.1 benefit."""

    def __init__(self, handles, *, tile: int = 1024,
                 use_kernel: bool = True, adaptive: bool = False,
                 policy=None, seed: int = 0, plan_cache=None,
                 weights: Optional[Dict[int, float]] = None,
                 max_tile: int = 8192):
        self.handles = list(handles)
        if len(self.handles) < 2:
            raise ValueError("MultiQueryEngine needs >= 2 query handles")
        for h in self.handles:
            if h.plan is None:
                raise ValueError(
                    f"query {h.qid} has no plan: optimize before serving")
        self.tile = tile
        self.use_kernel = use_kernel
        self.adaptive = adaptive
        self.max_tile = max_tile
        self.udf_cache = UdfResultCache()
        self.stats = SessionStats(queries=len(self.handles),
                                  finalized_per_query=[0] * len(self.handles))
        self.servers: List[CascadeServer] = []
        for h in self.handles:
            srv = CascadeServer(
                h.plan, tile=tile, use_kernel=use_kernel,
                adaptive=adaptive, policy=policy,
                seed=seed + 101 * h.qid, plan_cache=plan_cache)
            srv.udf_runner = self.udf_cache.runner
            srv.add_finalize_hook(self._finalize_hook(h.qid))
            self.servers.append(srv)
        self._versions = [s.plan_version for s in self.servers]
        self.scorer = None
        self._gcols: List[List[int]] = []
        self._restack()
        self.stats.restacks = 0  # the initial stack is not a re-stack
        if weights is None:
            weights = {h.qid: eq31_benefit(h.plan) for h in self.handles}
        self.scheduler = FairScheduler(weights)

    def _finalize_hook(self, qid: int):
        def hook(emitted, rejected, _version):
            self.stats.finalized_per_query[qid] += len(emitted) + len(rejected)
        return hook

    # ------------------------------------------------------------- stacking
    def _restack(self) -> None:
        """(Re)build the shared stacked scorer over every tenant's
        CURRENT plan.  Per-tenant engines are untouched: their local
        column layouts — and therefore every in-flight mask row — stay
        valid, so one tenant's swap never invalidates another's
        traffic."""
        from repro.kernels.ops import CascadeScorer

        plans = [s.plan for s in self.servers]
        if self.use_kernel:
            self.scorer, col_maps = CascadeScorer.from_plans(
                plans, max_tile=self.max_tile)
        else:
            self.scorer, col_maps = None, [[None] * len(p.stages)
                                           for p in plans]
        # per-tenant shared->local slice: the tenant's local scorer
        # numbers its proxied stages 0..P_q-1 in stage order, so the
        # slice is just the shared columns of those stages in order
        self._gcols = [[c for c in cols if c is not None]
                       for cols in col_maps]
        if self.scorer is not None:
            total_local = sum(len(g) for g in self._gcols)
            self.stats.shared_cols = self.scorer.n_proxies
            self.stats.stacked_cols_saved = total_local - self.scorer.n_proxies
        self.stats.restacks += 1

    def _sync_plans(self) -> None:
        cur = [s.plan_version for s in self.servers]
        if cur != self._versions:
            self._versions = cur
            for h, s in zip(self.handles, self.servers):
                h.plan = s.plan
            self._restack()

    def install_plan(self, qid: int, plan, *, scorer=None,
                     version: Optional[int] = None) -> int:
        """Hot-swap ONE tenant's plan (the session analogue of
        ``CascadeServer.install_plan``); the shared scorer restacks, the
        other tenants' states and in-flight masks are untouched."""
        v = self.servers[qid].install_plan(plan, scorer=scorer,
                                           version=version)
        self._sync_plans()
        return v

    # -------------------------------------------------------------- serving
    def submit(self, indices, rows, *, qids=None) -> None:
        """Coalesced cross-tenant submission: ONE stacked fused launch
        scores the chunk for every target query, then each tenant's
        engine receives its own mask slice."""
        indices = np.asarray(indices)
        rows = np.asarray(rows, np.float32)
        if len(rows) == 0:
            return
        targets = range(len(self.servers)) if qids is None else qids
        full = None
        if self.scorer is not None:
            t0 = advisory_wall_ms()
            full = self.scorer.score_masks(rows)
            self.stats.shared_score_ms += advisory_wall_ms() - t0
        for q in targets:
            srv = self.servers[q]
            if full is not None and self._gcols[q]:
                srv.submit(indices, rows, masks=full[:, self._gcols[q]])
            else:
                srv.submit(indices, rows)

    def _ready(self, srv: CascadeServer, drain: bool) -> bool:
        return srv.has_ready_batch(drain=drain)

    def pump(self, *, drain: bool = False) -> None:
        """Scheduler loop: grant one stage batch at a time to the
        backlogged tenant with minimum virtual time, charging the exact
        cost-model delta of that batch."""
        while True:
            backlogged = [q for q, s in enumerate(self.servers)
                          if self._ready(s, drain)]
            if not backlogged:
                return
            q = self.scheduler.pick(backlogged)
            srv = self.servers[q]
            cost0 = srv.stats.model_cost_ms
            if not srv.pump_one(drain=drain):
                return
            self.scheduler.charge(q, srv.stats.model_cost_ms - cost0)

    def maybe_reoptimize(self) -> bool:
        swapped = False
        for srv in self.servers:
            if srv.maybe_reoptimize():
                swapped = True
        if swapped:
            self._sync_plans()
        return swapped

    def drain(self) -> None:
        while any(s.in_flight() for s in self.servers):
            self.pump(drain=True)

    def run_stream(self, x: np.ndarray, *, chunk: int = 4096
                   ) -> "SessionStats":
        """Broadcast the stream to every registered query (the shared-
        corpus workload the session exists for) and drive to drain."""
        t0 = advisory_wall_ms()
        n = x.shape[0]
        for s0 in range(0, n, chunk):
            idx = np.arange(s0, min(s0 + chunk, n))
            self.submit(idx, x[idx])
            self.pump()
            if self.adaptive:
                self.maybe_reoptimize()
        self.drain()
        for srv in self.servers:
            srv.stats.wall_ms = advisory_wall_ms() - t0
            srv.stats.rejected = n - srv.stats.emitted
        return self.stats

    # ------------------------------------------------------------ accounting
    @property
    def emitted(self) -> List[List[int]]:
        return [srv.emitted for srv in self.servers]

    def model_cost_ms(self) -> float:
        """Total session device cost on the cost-model clock (each
        tenant's charges already exclude deduped UDF evaluations)."""
        return float(sum(s.stats.model_cost_ms for s in self.servers))

    def query_stats(self, qid: int) -> dict:
        srv = self.servers[qid]
        return {
            "qid": qid,
            "emitted": srv.stats.emitted,
            "rejected": srv.stats.rejected,
            "model_cost_ms": srv.stats.model_cost_ms,
            "plan_version": srv.plan_version,
            "plan_swaps": srv.stats.plan_swaps,
            "in_flight": srv.in_flight(),
            "served_cost_ms": self.scheduler.served_cost.get(qid, 0.0),
            "weight": self.scheduler.weights.get(qid),
            "finalized": self.stats.finalized_per_query[qid],
        }

    def conserved(self) -> Tuple[bool, str]:
        """Per-query conservation: nothing in flight after a drain, and
        no record emitted twice by any tenant."""
        for q, srv in enumerate(self.servers):
            if srv.in_flight():
                return False, f"query {q}: {srv.in_flight()} in flight"
            if len(srv.emitted) != len(set(srv.emitted)):
                return False, f"query {q}: duplicate emissions"
        return True, "ok"

    def session_stats(self) -> dict:
        return {
            "queries": self.stats.queries,
            "restacks": self.stats.restacks,
            "shared_cols": self.stats.shared_cols,
            "stacked_cols_saved": self.stats.stacked_cols_saved,
            "shared_score_ms": self.stats.shared_score_ms,
            "model_cost_ms": self.model_cost_ms(),
            "dedupe": self.udf_cache.as_dict(),
            "scheduler": self.scheduler.as_dict(),
            "finalized_per_query": list(self.stats.finalized_per_query),
        }
