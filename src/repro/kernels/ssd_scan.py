"""Mamba-2 SSD chunk kernel: the intra-chunk (quadratic) block of the
state-space-duality decomposition.

Per (batch, head, chunk) tile, computes in VMEM:
  * L = exp(segsum(dA))                  (Q, Q) decay matrix
  * y_diag = (C B^T * L) x               intra-chunk output
  * state  = B^T (decay * x)             the chunk's contribution to the
                                         inter-chunk recurrence
  * chunk_decay = exp(sum dA)

The O(nc) inter-chunk recurrence is tiny and stays in jnp (``ops.ssd``),
mirroring the real mamba2 kernel split (chunk_scan / chunk_state kernels +
host-level state passing).  Q (chunk length) is the VMEM tile: 64..256 keeps
(Q,Q)+(Q,N)+(Q,P) well under VMEM for N=P=128 at fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dA_ref, b_ref, c_ref, y_ref, st_ref, dec_ref):
    x = x_ref[...].astype(jnp.float32)  # (Q, P)
    dA = dA_ref[...].astype(jnp.float32)  # (Q,)
    Bm = b_ref[...].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[...].astype(jnp.float32)  # (Q, N)
    Q = x.shape[0]
    cum = jnp.cumsum(dA)  # (Q,)
    seg = cum[:, None] - cum[None, :]
    tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32) * L  # (Q, Q)
    y_ref[...] = jnp.dot(scores, x, preferred_element_type=jnp.float32)
    decay_states = jnp.exp(cum[-1] - cum)  # (Q,)
    st_ref[...] = jnp.dot(Bm.T, x * decay_states[:, None],
                          preferred_element_type=jnp.float32)  # (N, P)
    dec_ref[0] = jnp.exp(cum[-1])


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dA, B, C, *, interpret: bool = True):
    """Batched intra-chunk SSD.

    x: (nc, Q, H, P); dA: (nc, Q, H); B, C: (nc, Q, H, N) (groups already
    broadcast to heads).  Returns (y_diag (nc,Q,H,P), states (nc,H,P,N),
    chunk_decay (nc,H)) — all fp32.
    """
    nc, Q, H, P = x.shape
    N = B.shape[-1]
    xt = x.transpose(0, 2, 1, 3).reshape(nc * H, Q, P)
    dAt = dA.transpose(0, 2, 1).reshape(nc * H, Q)
    Bt = B.transpose(0, 2, 1, 3).reshape(nc * H, Q, N)
    Ct = C.transpose(0, 2, 1, 3).reshape(nc * H, Q, N)

    y, st, dec = pl.pallas_call(
        _kernel,
        grid=(nc * H,),
        in_specs=[
            pl.BlockSpec((None, Q, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, Q), lambda i: (i, 0)),
            pl.BlockSpec((None, Q, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, Q, N), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, N, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nc * H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((nc * H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((nc * H, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dAt, Bt, Ct)
    y_diag = y.reshape(nc, H, Q, P).transpose(0, 2, 1, 3)
    states = st.reshape(nc, H, N, P).transpose(0, 1, 3, 2)  # (nc, H, P, N)
    chunk_decay = dec.reshape(nc, H)
    return y_diag, states, chunk_decay
