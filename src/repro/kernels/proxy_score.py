"""Fused proxy-scoring kernel: scores = x @ W + b; mask = scores >= theta.

This is the paper's hot loop — every record in the stream is scored by the
cascade's proxies.  Fusing the GEMM, bias, and threshold comparison avoids
three HBM round-trips for the (N, P) intermediate; the (N, F) record block
is loaded into VMEM exactly once per proxy set.

Standardization ((x - mean) / scale) is folded into W and b by the ops.py
wrapper, so the kernel sees a single affine map.

BlockSpec layout: grid over record tiles (bm rows); the proxy dim P is
padded to the 128-lane width so the MXU matmul is aligned; F (feature dim,
64..1024) stays resident per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, thr_ref, score_ref, mask_ref):
    x = x_ref[...]
    w = w_ref[...]
    s = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    s = s + b_ref[...][None, :]
    score_ref[...] = s
    mask_ref[...] = s >= thr_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def proxy_score(x, w, b, thresholds, *, block_m: int = 256, interpret: bool = True):
    """x: (N, F); w: (F, P); b, thresholds: (P,).

    Returns (scores (N, P) f32, mask (N, P) bool).  N is padded to block_m
    and P to the 128-lane width internally.
    """
    N, F = x.shape
    P = w.shape[1]
    pad_n = (-N) % block_m
    pad_p = (-P) % 128
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    if pad_p:
        w = jnp.pad(w, ((0, 0), (0, pad_p)))
        b = jnp.pad(b, (0, pad_p))
        thresholds = jnp.pad(thresholds, (0, pad_p), constant_values=jnp.inf)
    Np, Pp = x.shape[0], w.shape[1]

    grid = (Np // block_m,)
    scores, mask = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, F), lambda i: (i, 0)),
            pl.BlockSpec((F, Pp), lambda i: (0, 0)),
            pl.BlockSpec((Pp,), lambda i: (0,)),
            pl.BlockSpec((Pp,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, Pp), lambda i: (i, 0)),
            pl.BlockSpec((block_m, Pp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, Pp), jnp.float32),
            jax.ShapeDtypeStruct((Np, Pp), jnp.bool_),
        ],
        interpret=interpret,
    )(x, w, b, thresholds)
    return scores[:N, :P], mask[:N, :P]
