"""Fused whole-cascade proxy scoring: a two-pass stacked GEMM.

This is the paper's hot loop — every record in the stream is scored by the
cascade's proxies.  Every proxy family lowers to the same packed depth-1
MLP form (see ``core/proxy_family.py``), so ONE kernel covers linear and
MLP stages alike:

    hid    = relu(x @ w1 + b1)        # hidden GEMM over ALL stages at once
    scores = hid @ w2 + b2            # block-diagonal readout GEMM
    mask   = scores >= thresholds

Linear stages occupy two hidden columns via the exact +/- trick
(``relu(z) - relu(-z) == z``); MLP stages occupy their true hidden width.
Feature standardization is folded into ``(w1, b1)`` at pack time, so the
kernel sees two affine maps and a relu — no per-stage branching.

Fusing both GEMMs, the bias adds, and the threshold comparison avoids four
HBM round-trips for the (N, H·P) and (N, P) intermediates; the (N, F)
record block is loaded into VMEM exactly once per cascade.

BlockSpec layout: grid over record tiles (bm rows); the stacked hidden dim
H·P and the stage dim P are each padded to the 128-lane width so both MXU
matmuls are aligned; F (feature dim, 64..1024) stays resident per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_cascade_kernel(n_proxies, with_scores, with_compaction):
    """Fused whole-cascade tile kernel: hidden GEMM + relu, then the
    block-diagonal readout GEMM scores every stage column; optionally a
    block-local prefix sum packs survivor positions so the wrapper can
    assemble dense per-stage survivor index lists without a host
    round-trip.

    The prefix sum runs over the first ``n_proxies`` (real) columns only —
    the lane-pad columns are all-False and would triple the scan cost.
    ``with_scores`` / ``with_compaction`` drop output writes the caller
    won't read (each is a full (block_m, P) HBM round-trip): the serving
    engine gates on masks alone, the executor needs masks + compaction.
    """

    def kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, thr_ref, scale_ref,
               valid_ref, *out_refs):
        x = x_ref[...]
        # weight loads dequantize in-register: int8 codes (quantized packed
        # cascade) widen to f32 on the way into the MXU — the HBM->VMEM
        # traffic is 1 byte/weight, the arithmetic stays f32
        hid = jnp.dot(x.astype(jnp.float32), w1_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        hid = jnp.maximum(hid + b1_ref[...][None, :], 0.0)
        # readout over the REAL stage columns only — the lane-pad columns
        # of w2 are all-zero and would multiply the second GEMM's cost by
        # ~128/P for nothing (the MXU pads the n-dim internally either way)
        s = jnp.dot(hid, w2_ref[...][:, :n_proxies].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        # the single dequantizing multiply: per-stage readout scales (all
        # ones for fp32 cascades — ``x * 1.0`` is an IEEE identity, so the
        # fp32 path stays bit-exact through this op)
        s = s * scale_ref[...][None, :n_proxies] + b2_ref[...][None, :n_proxies]
        m = (s >= thr_ref[...][None, :n_proxies]) & valid_ref[...]
        pad = w2_ref.shape[1] - n_proxies
        refs = list(out_refs)
        if with_scores:
            refs.pop(0)[...] = jnp.pad(s, ((0, 0), (0, pad)))
        refs.pop(0)[...] = jnp.pad(m, ((0, 0), (0, pad)))
        if with_compaction:
            mi = m.astype(jnp.int32)
            inclusive = jnp.cumsum(mi, axis=0)
            if pad:
                inclusive = jnp.pad(inclusive, ((0, 0), (0, pad)))
                mi = jnp.pad(mi, ((0, 0), (0, pad)))
            refs.pop(0)[...] = inclusive - mi  # local packed slot per row
            refs.pop(0)[...] = inclusive[-1:, :]  # block survivor totals

    return kernel


def _pm_pack_linear_operands(w, b):
    """(F, P) affine stack -> two-pass operands via the +/- trick, h-major:
    columns [w | -w], readout (+1, -1) block-diagonal."""
    F, P = w.shape
    w1 = jnp.concatenate([w, -w], axis=1)  # (F, 2P): h-major [h=0 | h=1]
    b1 = jnp.concatenate([b, -b])
    eye = jnp.eye(P, dtype=jnp.float32)
    w2 = jnp.concatenate([eye, -eye], axis=0)  # (2P, P)
    return w1, b1, w2, jnp.zeros((P,), jnp.float32)


def proxy_score(x, w, b, thresholds, *, block_m: int = 256, interpret: bool = True):
    """x: (N, F); w: (F, P); b, thresholds: (P,).

    Linear-stack convenience: returns (scores (N, P) f32, mask (N, P)
    bool).  Thin wrapper over the packed ``cascade_score`` — the +/- trick
    makes the two-pass scores bit-identical to the single affine map, so
    the pad/grid plumbing and kernel body exist exactly once.
    """
    w1, b1, w2, b2 = _pm_pack_linear_operands(jnp.asarray(w, jnp.float32),
                                              jnp.asarray(b, jnp.float32))
    scores, mask, _packed, _counts = cascade_score(
        x, w1, b1, w2, b2, thresholds, x.shape[0], block_m=block_m,
        interpret=interpret, with_scores=True, with_compaction=False,
    )
    return scores, mask


@functools.partial(jax.jit, static_argnames=(
    "block_m", "interpret", "with_scores", "with_compaction", "compact_cols"))
def cascade_score(x, w1, b1, w2, b2, thresholds, n_valid, *,
                  out_scale=None,
                  block_m: int = 256, interpret: bool = True,
                  with_scores: bool = True, with_compaction: bool = True,
                  compact_cols=None):
    """One fused two-pass GEMM over a record tile for a whole cascade.

    x: (N, F) record tile (rows >= ``n_valid`` are padding and are masked
    out of every stage); w1: (F, HP) stacked folded hidden weights (HP =
    hidden bucket x stages, h-major — see
    ``core.proxy_family.cascade_kernel_operands``); b1: (HP,); w2:
    (HP, P) block-diagonal readout; b2, thresholds: (P,).

    ``out_scale`` (P,) are per-stage readout dequantization scales for
    weight-only-quantized cascades (``scores = readout * out_scale + b2``);
    None means ones — the fp32 path, bit-identical to the pre-quantization
    kernel (``x * 1.0`` preserves every bit).  ``w1``/``w2`` may be int8
    code matrices; they widen to f32 in-register after the VMEM load.

    Returns:
      scores (N, P) f32          raw proxy scores (None if not with_scores)
      mask   (N, P) bool         per-stage keep masks (padding rows False)
      packed (C, N) int32        compacted survivor row indices per
                                 *assembled* stage: with ``compact_cols``
                                 a static tuple of column indices, C =
                                 len(compact_cols) and row ``c`` holds the
                                 ascending rows where mask[:, cols[c]] is
                                 True (tail -1); C = P when compact_cols is
                                 None (None if not with_compaction)
      counts (P,)  int32         survivors per stage, ALL columns (None
                                 when not with_compaction)

    Compaction runs on device: the kernel emits block-local exclusive
    prefix sums + per-block totals; this wrapper turns them into global
    packed slots with an inter-block scan and a single scatter, so a dense
    UDF batch index list exists without materialising the boolean mask on
    the host.  ``with_scores=False`` / ``with_compaction=False`` drop the
    outputs (and their HBM round-trips) a caller won't read — the serving
    engine gates on masks alone.  ``compact_cols`` gates the scatter
    assembly per column: the executor consumes the packed list only for
    its first full-tile stage, so later columns' O(N) scatters are skipped
    instead of computed-then-discarded.
    """
    N, F = x.shape
    HP = w1.shape[1]
    P = w2.shape[1]
    if out_scale is None:
        out_scale = jnp.ones_like(b2)
    pad_n = (-N) % block_m
    pad_hp = (-HP) % 128
    pad_p = (-P) % 128
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    if pad_hp:
        w1 = jnp.pad(w1, ((0, 0), (0, pad_hp)))
        b1 = jnp.pad(b1, (0, pad_hp))
        w2 = jnp.pad(w2, ((0, pad_hp), (0, 0)))
    if pad_p:
        w2 = jnp.pad(w2, ((0, 0), (0, pad_p)))
        b2 = jnp.pad(b2, (0, pad_p))
        thresholds = jnp.pad(thresholds, (0, pad_p), constant_values=jnp.inf)
        out_scale = jnp.pad(out_scale, (0, pad_p), constant_values=1.0)
    Np, HPp, Pp = x.shape[0], w1.shape[1], w2.shape[1]
    valid = (jnp.arange(Np, dtype=jnp.int32) < n_valid)[:, None]

    nb = Np // block_m
    tile_spec = pl.BlockSpec((block_m, Pp), lambda i: (i, 0))
    out_specs, out_shape = [], []
    if with_scores:
        out_specs.append(tile_spec)
        out_shape.append(jax.ShapeDtypeStruct((Np, Pp), jnp.float32))
    out_specs.append(tile_spec)
    out_shape.append(jax.ShapeDtypeStruct((Np, Pp), jnp.bool_))
    if with_compaction:
        out_specs += [tile_spec, pl.BlockSpec((1, Pp), lambda i: (i, 0))]
        out_shape += [jax.ShapeDtypeStruct((Np, Pp), jnp.int32),
                      jax.ShapeDtypeStruct((nb, Pp), jnp.int32)]
    outs = pl.pallas_call(
        _make_cascade_kernel(P, with_scores, with_compaction),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_m, F), lambda i: (i, 0)),
            pl.BlockSpec((F, HPp), lambda i: (0, 0)),
            pl.BlockSpec((HPp,), lambda i: (0,)),
            pl.BlockSpec((HPp, Pp), lambda i: (0, 0)),
            pl.BlockSpec((Pp,), lambda i: (0,)),
            pl.BlockSpec((Pp,), lambda i: (0,)),
            pl.BlockSpec((Pp,), lambda i: (0,)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, w1, b1, w2, b2, thresholds, out_scale, valid)
    outs = list(outs)
    scores = outs.pop(0) if with_scores else None
    mask = outs.pop(0)
    mask_p = mask[:, :P]
    if not with_compaction:
        return (scores[:N, :P] if with_scores else None,
                mask_p[:N], None, None)
    pos, cnt = outs

    # inter-block exclusive scan of the per-block survivor counts gives each
    # block its base slot; scatter rows to (stage, slot), dropping rejects.
    # Assembly runs only over the REAL P columns — the lane-pad columns are
    # all-False and would multiply the scatter cost ~128/P for nothing —
    # and, when ``compact_cols`` names the columns a caller will actually
    # consume, only over those.
    cols_sel = tuple(range(P)) if compact_cols is None else tuple(compact_cols)
    ci = jnp.asarray(cols_sel, jnp.int32)
    C = len(cols_sel)
    cnt_sel = cnt[:, ci]  # (nb, C)
    block_base = jnp.cumsum(cnt_sel, axis=0) - cnt_sel
    gpos = pos[:, ci] + jnp.repeat(block_base, block_m, axis=0,
                                   total_repeat_length=Np)
    mask_sel = mask_p[:, ci]
    gpos = jnp.where(mask_sel, gpos, Np)  # sentinel slot -> dropped by scatter
    rows = jnp.broadcast_to(jnp.arange(Np, dtype=jnp.int32)[:, None], (Np, C))
    cols = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None, :], (Np, C))
    packed = jnp.full((C, Np), -1, jnp.int32).at[cols, gpos].set(
        rows, mode="drop")
    counts = jnp.sum(cnt[:, :P], axis=0)
    return (scores[:N, :P] if with_scores else None,
            mask_p[:N], packed[:, :N], counts)
