"""Fused proxy-scoring kernel: scores = x @ W + b; mask = scores >= theta.

This is the paper's hot loop — every record in the stream is scored by the
cascade's proxies.  Fusing the GEMM, bias, and threshold comparison avoids
three HBM round-trips for the (N, P) intermediate; the (N, F) record block
is loaded into VMEM exactly once per proxy set.

Standardization ((x - mean) / scale) is folded into W and b by the ops.py
wrapper, so the kernel sees a single affine map.

BlockSpec layout: grid over record tiles (bm rows); the proxy dim P is
padded to the 128-lane width so the MXU matmul is aligned; F (feature dim,
64..1024) stays resident per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_cascade_kernel(n_proxies, with_scores, with_compaction):
    """Fused whole-cascade tile kernel: one GEMM scores every proxy column;
    optionally a block-local prefix sum packs survivor positions so the
    wrapper can assemble dense per-stage survivor index lists without a
    host round-trip.

    The prefix sum runs over the first ``n_proxies`` (real) columns only —
    the lane-pad columns are all-False and would triple the scan cost.
    ``with_scores`` / ``with_compaction`` drop output writes the caller
    won't read (each is a full (block_m, P) HBM round-trip): the serving
    engine gates on masks alone, the executor needs masks + compaction.
    """

    def kernel(x_ref, w_ref, b_ref, thr_ref, valid_ref, *out_refs):
        x = x_ref[...]
        w = w_ref[...]
        s = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        s = s + b_ref[...][None, :]
        m = (s >= thr_ref[...][None, :]) & valid_ref[...]
        refs = list(out_refs)
        if with_scores:
            refs.pop(0)[...] = s
        refs.pop(0)[...] = m
        if with_compaction:
            mi = m[:, :n_proxies].astype(jnp.int32)
            inclusive = jnp.cumsum(mi, axis=0)
            pad = m.shape[1] - n_proxies
            if pad:
                inclusive = jnp.pad(inclusive, ((0, 0), (0, pad)))
                mi = jnp.pad(mi, ((0, 0), (0, pad)))
            refs.pop(0)[...] = inclusive - mi  # local packed slot per row
            refs.pop(0)[...] = inclusive[-1:, :]  # block survivor totals

    return kernel


def proxy_score(x, w, b, thresholds, *, block_m: int = 256, interpret: bool = True):
    """x: (N, F); w: (F, P); b, thresholds: (P,).

    Returns (scores (N, P) f32, mask (N, P) bool).  Thin wrapper over
    ``cascade_score(with_compaction=False)`` — the pad/grid plumbing and
    kernel body exist exactly once (ROADMAP cleanup, PR 2).
    """
    scores, mask, _packed, _counts = cascade_score(
        x, w, b, thresholds, x.shape[0], block_m=block_m, interpret=interpret,
        with_scores=True, with_compaction=False,
    )
    return scores, mask


@functools.partial(jax.jit, static_argnames=(
    "block_m", "interpret", "with_scores", "with_compaction", "compact_cols"))
def cascade_score(x, w, b, thresholds, n_valid, *, block_m: int = 256,
                  interpret: bool = True, with_scores: bool = True,
                  with_compaction: bool = True, compact_cols=None):
    """One fused pass over a record tile for a whole cascade.

    x: (N, F) record tile (rows >= ``n_valid`` are padding and are masked
    out of every stage); w: (F, P) stacked proxy weights, one column per
    cascade stage; b, thresholds: (P,).

    Returns:
      scores (N, P) f32          raw proxy scores (None if not with_scores)
      mask   (N, P) bool         per-stage keep masks (padding rows False)
      packed (C, N) int32        compacted survivor row indices per
                                 *assembled* stage: with ``compact_cols``
                                 a static tuple of column indices, C =
                                 len(compact_cols) and row ``c`` holds the
                                 ascending rows where mask[:, cols[c]] is
                                 True (tail -1); C = P when compact_cols is
                                 None (None if not with_compaction)
      counts (P,)  int32         survivors per stage, ALL columns (None
                                 when not with_compaction)

    Compaction runs on device: the kernel emits block-local exclusive
    prefix sums + per-block totals; this wrapper turns them into global
    packed slots with an inter-block scan and a single scatter, so a dense
    UDF batch index list exists without materialising the boolean mask on
    the host.  ``with_scores=False`` / ``with_compaction=False`` drop the
    outputs (and their HBM round-trips) a caller won't read — the serving
    engine gates on masks alone.  ``compact_cols`` gates the scatter
    assembly per column: the executor consumes the packed list only for
    its first full-tile stage, so later columns' O(N) scatters are skipped
    instead of computed-then-discarded.
    """
    N, F = x.shape
    P = w.shape[1]
    pad_n = (-N) % block_m
    pad_p = (-P) % 128
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    if pad_p:
        w = jnp.pad(w, ((0, 0), (0, pad_p)))
        b = jnp.pad(b, (0, pad_p))
        thresholds = jnp.pad(thresholds, (0, pad_p), constant_values=jnp.inf)
    Np, Pp = x.shape[0], w.shape[1]
    valid = (jnp.arange(Np, dtype=jnp.int32) < n_valid)[:, None]

    nb = Np // block_m
    tile_spec = pl.BlockSpec((block_m, Pp), lambda i: (i, 0))
    out_specs, out_shape = [], []
    if with_scores:
        out_specs.append(tile_spec)
        out_shape.append(jax.ShapeDtypeStruct((Np, Pp), jnp.float32))
    out_specs.append(tile_spec)
    out_shape.append(jax.ShapeDtypeStruct((Np, Pp), jnp.bool_))
    if with_compaction:
        out_specs += [tile_spec, pl.BlockSpec((1, Pp), lambda i: (i, 0))]
        out_shape += [jax.ShapeDtypeStruct((Np, Pp), jnp.int32),
                      jax.ShapeDtypeStruct((nb, Pp), jnp.int32)]
    outs = pl.pallas_call(
        _make_cascade_kernel(P, with_scores, with_compaction),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_m, F), lambda i: (i, 0)),
            pl.BlockSpec((F, Pp), lambda i: (0, 0)),
            pl.BlockSpec((Pp,), lambda i: (0,)),
            pl.BlockSpec((Pp,), lambda i: (0,)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, w, b, thresholds, valid)
    outs = list(outs)
    scores = outs.pop(0) if with_scores else None
    mask = outs.pop(0)
    mask_p = mask[:, :P]
    if not with_compaction:
        return (scores[:N, :P] if with_scores else None,
                mask_p[:N], None, None)
    pos, cnt = outs

    # inter-block exclusive scan of the per-block survivor counts gives each
    # block its base slot; scatter rows to (stage, slot), dropping rejects.
    # Assembly runs only over the REAL P columns — the lane-pad columns are
    # all-False and would multiply the scatter cost ~128/P for nothing —
    # and, when ``compact_cols`` names the columns a caller will actually
    # consume, only over those.
    cols_sel = tuple(range(P)) if compact_cols is None else tuple(compact_cols)
    ci = jnp.asarray(cols_sel, jnp.int32)
    C = len(cols_sel)
    cnt_sel = cnt[:, ci]  # (nb, C)
    block_base = jnp.cumsum(cnt_sel, axis=0) - cnt_sel
    gpos = pos[:, ci] + jnp.repeat(block_base, block_m, axis=0,
                                   total_repeat_length=Np)
    mask_sel = mask_p[:, ci]
    gpos = jnp.where(mask_sel, gpos, Np)  # sentinel slot -> dropped by scatter
    rows = jnp.broadcast_to(jnp.arange(Np, dtype=jnp.int32)[:, None], (Np, C))
    cols = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None, :], (Np, C))
    packed = jnp.full((C, Np), -1, jnp.int32).at[cols, gpos].set(
        rows, mode="drop")
    counts = jnp.sum(cnt[:, :P], axis=0)
    return (scores[:N, :P] if with_scores else None,
            mask_p[:N], packed[:, :N], counts)
