"""Roofline-driven tile autotuner for the fused cascade scorer.

Replaces the static ~8 MB VMEM heuristic in ``CascadeScorer.__init__``
with a swept cost model: for each candidate ``block_m`` (and weight
dtype) it computes the bytes the kernel actually moves per launch — the
bucket-padded x tile, the stacked packed weights at their storage width
(fp32 = 4 B, int8/fp8 codes = 1 B), and the mask/compaction outputs —
plus the GEMM FLOPs, and scores the cell with a two-knee roofline

    t = LAUNCH + nb * STEP + max(bytes / HBM_BW, flops / PEAK)

The sweep is deliberately a MODEL, not a wall-clock timer: in this
container Pallas runs in interpret mode, where per-cell timings measure
the Python interpreter, not the memory system.  The model's byte counts
are exact (they are the operand nbytes the compiled kernel streams), so
the ranking is the bandwidth-bound ranking a TPU would see; wall-clock
stays an advisory column (``measure_cell``) for runs on real hardware.

Feasibility reuses the PREVIOUS static heuristic's bound — per-row VMEM
footprint ``4*(F + HPp) + 9*Pp`` bytes against an 8 MB budget — so with
the default full-tile row hint the tuner picks exactly the block the old
heuristic picked (no disruption to compiled-program caches), and only
diverges where the old rule was wrong: small serving chunks, where a
full-budget block pads 8-16x the rows actually scored.

Winning configs are cached keyed by (F, HP-bucket, P-bucket, dtype,
backend, hint-bucket, max_tile); set ``CORE_AUTOTUNE_CACHE=/path.json``
to persist the table across processes so repeat serving runs skip the
sweep entirely.
"""
from __future__ import annotations

import json
import os
from typing import NamedTuple, Optional, Tuple

import numpy as np

# Nominal single-core accelerator envelope (TPUv4-ish).  Only RATIOS of
# modeled times ever gate anything, so the absolute calibration is free
# to be nominal; the byte counts feeding them are exact.  A backend can
# override these with MEASURED constants via ``calibrate_backend`` /
# ``set_backend_constants`` — the default path (no registration) uses
# these module constants unchanged.
HBM_BYTES_PER_S = 1.2e12
PEAK_FLOPS = 7.0e13
LAUNCH_OVERHEAD_S = 5.0e-6
GRID_STEP_OVERHEAD_S = 1.5e-6
VMEM_BLOCK_BUDGET = 8 << 20  # same budget the old static heuristic used
WEIGHT_RESIDENT_BYTES = 4 << 20  # weights this small stay pinned in VMEM


class BackendConstants(NamedTuple):
    """Roofline envelope for one backend.  ``source`` records where the
    numbers came from: "default" (the baked nominal constants) or
    "measured" (``calibrate_backend`` fitted them from wall-clock)."""

    hbm_bytes_per_s: float = HBM_BYTES_PER_S
    peak_flops: float = PEAK_FLOPS
    launch_overhead_s: float = LAUNCH_OVERHEAD_S
    grid_step_overhead_s: float = GRID_STEP_OVERHEAD_S
    source: str = "default"


_DEFAULT_CONSTANTS = BackendConstants()
_BACKEND_CONSTANTS: dict = {}  # backend name -> BackendConstants


def backend_constants(backend: Optional[str] = None) -> BackendConstants:
    """Constants for ``backend`` — the calibrated set if one was
    registered, the nominal defaults otherwise (so the default path is
    numerically identical to the pre-calibration tuner)."""
    return _BACKEND_CONSTANTS.get(str(backend), _DEFAULT_CONSTANTS)


def set_backend_constants(backend: str, constants: BackendConstants) -> None:
    """Register measured constants for ``backend`` and invalidate every
    cached sweep winner keyed to it — a winner picked under the nominal
    envelope may not survive the measured one."""
    _BACKEND_CONSTANTS[str(backend)] = constants
    for key in [k for k in _CACHE if k[4] == str(backend)]:
        del _CACHE[key]


def reset_backend_constants() -> None:
    _BACKEND_CONSTANTS.clear()


def _ceil128(n: int) -> int:
    return -(-int(n) // 128) * 128


def static_heuristic_block_m(n_features: int, hp: int, n_proxies: int,
                             max_tile: int = 8192) -> int:
    """The pre-autotune rule, verbatim: largest power-of-two block >= 256
    whose per-row footprint fits the 8 MB budget.  Kept callable so the
    sweep can report "chosen vs static" and tests can pin equivalence."""
    hpp = _ceil128(hp)
    pp = _ceil128(n_proxies)
    per_row = 4 * (int(n_features) + hpp) + 9 * pp
    budget_rows = VMEM_BLOCK_BUDGET // per_row
    block_m = 256
    while block_m * 2 <= min(budget_rows, max_tile):
        block_m *= 2
    return min(block_m, max_tile)


class CellModel(NamedTuple):
    """Roofline model of one (block_m, dtype) sweep cell."""

    block_m: int
    dtype: str
    n_rows: int
    npad: int          # bucket-padded rows the launch actually scores
    nb: int            # grid steps
    bytes_moved: int   # exact operand bytes streamed per launch
    flops: int
    t_model_s: float
    mbu: float         # model bandwidth utilization: useful bytes / (t*BW)
    feasible: bool     # per-block footprint within the VMEM budget


class TunedConfig(NamedTuple):
    block_m: int
    dtype: str
    t_model_s: float
    bytes_moved: int
    mbu: float
    static_block_m: int  # what the old heuristic would have picked
    source: str          # "sweep" | "cache"


def _weight_bytes(n_features: int, hp: int, n_proxies: int, dtype: str) -> int:
    from repro.core.proxy_family import QUANT_WEIGHT_BYTES

    wb = QUANT_WEIGHT_BYTES[dtype]
    hpp = _ceil128(hp)
    pp = _ceil128(n_proxies)
    # w1 (F, HPp) + w2 (HPp, Pp) at storage width; b1/b2/thr/out_scale f32
    return (int(n_features) * hpp * wb + hpp * pp * wb
            + hpp * 4 + 3 * pp * 4)


def padded_rows(n_rows: int, block_m: int, max_tile: int) -> int:
    """The scorer's bucket ladder: block_m * 2^k, capped at max_tile."""
    size = block_m
    while size < min(n_rows, max_tile):
        size *= 2
    return min(size, max_tile)


def cell_model(n_features: int, hp: int, n_proxies: int, dtype: str,
               block_m: int, n_rows: int, *,
               max_tile: int = 8192,
               backend: Optional[str] = None) -> CellModel:
    """Roofline-score one sweep cell for a chunk of ``n_rows`` records.

    ``backend`` selects the bandwidth/flops/overhead envelope: a backend
    with registered measured constants (``calibrate_backend``) is scored
    under those; anything else — including the default ``None`` — uses
    the nominal module constants, bit-identically to before."""
    bc = backend_constants(backend)
    hpp = _ceil128(hp)
    pp = _ceil128(n_proxies)
    npad = padded_rows(n_rows, block_m, max_tile)
    nb = -(-npad // block_m)
    wbytes = _weight_bytes(n_features, hp, n_proxies, dtype)
    refetch = 1 if wbytes <= WEIGHT_RESIDENT_BYTES else nb
    x_bytes = npad * n_features * 4
    out_bytes = npad * pp * (1 + 4)  # keep mask + compacted survivor ids
    bytes_moved = x_bytes + out_bytes + wbytes * refetch
    flops = 2 * npad * (n_features * hpp + hpp * pp)
    t_mem = bytes_moved / bc.hbm_bytes_per_s
    t_flop = flops / bc.peak_flops
    t = bc.launch_overhead_s + nb * bc.grid_step_overhead_s + max(t_mem, t_flop)
    # useful bytes: the unpadded rows' traffic + one copy of the weights
    useful = n_rows * (n_features * 4 + pp * 5) + wbytes
    mbu = useful / (t * bc.hbm_bytes_per_s)
    per_row = 4 * (n_features + hpp) + 9 * pp
    feasible = per_row * block_m <= VMEM_BLOCK_BUDGET
    return CellModel(block_m=int(block_m), dtype=dtype, n_rows=int(n_rows),
                     npad=int(npad), nb=int(nb),
                     bytes_moved=int(bytes_moved), flops=int(flops),
                     t_model_s=float(t), mbu=float(mbu), feasible=feasible)


def _candidates(max_tile: int) -> Tuple[int, ...]:
    out, c = [], 128
    while c <= max_tile:
        out.append(c)
        c *= 2
    return tuple(out) or (max_tile,)


# ----------------------------------------------------------------- cache
_CACHE: dict = {}
_STATS = {"sweeps": 0, "hits": 0}
_DISK_LOADED = False


def autotune_stats() -> dict:
    return dict(_STATS)


def reset_autotune_stats() -> None:
    _STATS["sweeps"] = 0
    _STATS["hits"] = 0


def clear_autotune_cache() -> None:
    global _DISK_LOADED
    _CACHE.clear()
    _DISK_LOADED = False


def _hint_bucket(n_rows_hint: int, max_tile: int) -> int:
    return padded_rows(min(int(n_rows_hint), max_tile), 128, max_tile)


def _cache_key(n_features, hp, n_proxies, dtype, backend, hint_b, max_tile):
    return (int(n_features), _ceil128(hp), _ceil128(n_proxies), str(dtype),
            str(backend), int(hint_b), int(max_tile))


def _disk_path() -> Optional[str]:
    return os.environ.get("CORE_AUTOTUNE_CACHE") or None


def _read_disk_table(path: str) -> dict:
    """Parse the on-disk table into {key tuple: TunedConfig}.  A corrupt,
    partial, or wrong-schema file (a concurrent writer died mid-write
    before the save path became atomic, or the user pointed
    ``CORE_AUTOTUNE_CACHE`` at an unrelated file) yields {} with a
    warning — the sweep is cheap, silently-poisoned configs are not."""
    table: dict = {}
    if not os.path.exists(path):
        return table
    try:
        with open(path) as f:
            raw = json.load(f)
        for key_s, cfg in raw.items():
            table[tuple(json.loads(key_s))] = TunedConfig(
                block_m=int(cfg["block_m"]), dtype=str(cfg["dtype"]),
                t_model_s=float(cfg["t_model_s"]),
                bytes_moved=int(cfg["bytes_moved"]), mbu=float(cfg["mbu"]),
                static_block_m=int(cfg["static_block_m"]), source="cache")
    except (OSError, ValueError, KeyError, TypeError):
        import warnings

        warnings.warn(
            f"CORE_AUTOTUNE_CACHE at {path!r} is corrupt or partial; "
            f"ignoring it and falling back to a fresh sweep",
            RuntimeWarning, stacklevel=3)
        return {}
    return table


def _load_disk_cache() -> None:
    global _DISK_LOADED
    _DISK_LOADED = True
    path = _disk_path()
    if not path:
        return
    for key, cfg in _read_disk_table(path).items():
        # disk entries were swept under the nominal envelope; a backend
        # running calibrated constants must re-sweep, not inherit them
        if backend_constants(key[4]).source != "default":
            continue
        _CACHE.setdefault(key, cfg)


def _save_disk_cache() -> None:
    """Persist the in-memory table: merge-on-save + atomic replace.

    K subprocess hosts all point at one cache file, so the naive
    ``open(path, "w")`` had two failure modes: interleaved writes could
    corrupt the JSON, and a host that swept shape A would clobber the
    entries a peer had just saved for shape B (last writer wins on the
    WHOLE table).  Re-reading the file immediately before writing keeps
    peers' fresh entries (our in-memory values win only for keys we hold
    — both sides swept the same deterministic model, so ties are
    identical anyway), and writing via a same-directory temp file +
    ``os.replace`` makes the publish atomic: readers see the old table
    or the new one, never a torn prefix."""
    path = _disk_path()
    if not path:
        return
    merged = _read_disk_table(path)
    # never publish winners swept under MEASURED constants: they price
    # this machine's silicon, and the shared table is read by peers whose
    # calibration (or lack of one) differs
    merged.update({k: v for k, v in _CACHE.items()
                   if backend_constants(k[4]).source == "default"})
    table = {
        json.dumps(list(k)): {
            "block_m": v.block_m, "dtype": v.dtype,
            "t_model_s": v.t_model_s, "bytes_moved": v.bytes_moved,
            "mbu": v.mbu, "static_block_m": v.static_block_m,
        }
        for k, v in merged.items()
    }
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(table, f, indent=0, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def choose_block_m(n_features: int, hp: int, n_proxies: int,
                   dtype: str = "float32", *,
                   n_rows_hint: Optional[int] = None,
                   max_tile: int = 8192,
                   backend: Optional[str] = None) -> TunedConfig:
    """Pick ``block_m`` for the fused scorer by roofline sweep.

    ``n_rows_hint`` is the expected serving chunk size; None means "full
    tiles" (n_rows_hint = max_tile), under which the winner coincides
    with the old static heuristic by construction (same feasibility
    bound; equal bytes at every feasible block, so fewer grid steps win).
    """
    if backend is None:
        import jax

        backend = jax.default_backend()
    if not _DISK_LOADED:
        _load_disk_cache()
    hint = max_tile if n_rows_hint is None else int(n_rows_hint)
    hint_b = _hint_bucket(max(hint, 1), max_tile)
    key = _cache_key(n_features, hp, n_proxies, dtype, backend, hint_b,
                     max_tile)
    hit = _CACHE.get(key)
    if hit is not None:
        _STATS["hits"] += 1
        return hit._replace(source="cache")
    _STATS["sweeps"] += 1
    static_bm = static_heuristic_block_m(n_features, hp, n_proxies, max_tile)
    cells = [cell_model(n_features, hp, n_proxies, dtype, bm, hint_b,
                        max_tile=max_tile, backend=backend)
             for bm in _candidates(max_tile)]
    feasible = [c for c in cells if c.feasible]
    if not feasible:
        # degenerate shape: even the old heuristic's floor blows the
        # budget — keep its pick so behavior is unchanged
        feasible = [c for c in cells if c.block_m == static_bm] or cells[:1]
    best = min(feasible, key=lambda c: (c.t_model_s, -c.block_m))
    cfg = TunedConfig(block_m=best.block_m, dtype=dtype,
                      t_model_s=best.t_model_s,
                      bytes_moved=best.bytes_moved, mbu=best.mbu,
                      static_block_m=static_bm, source="sweep")
    _CACHE[key] = cfg
    # calibrated winners are this process's measurement — persisting them
    # would poison peers running under the nominal (or their own
    # measured) envelope, since the disk key does not carry constants
    if backend_constants(backend).source == "default":
        _save_disk_cache()
    return cfg


# ----------------------------------------------------------------- sweep
def sweep_table(shapes, dtypes=("float32", "int8"), *,
                n_rows_hints=(256, 1024, 8192), max_tile: int = 8192):
    """Full sweep over workload shapes x dtypes x chunk hints; the rows
    behind ``benchmarks/roofline.py`` and the nightly CI artifact.

    ``shapes``: iterable of (name, F, HP, P).  Returns a list of dicts,
    one per (shape, dtype, hint): the winning cell, the static
    heuristic's cell at the same hint, and whether the tuner's pick
    strictly beats it under the model.
    """
    rows = []
    for name, f, hp, p in shapes:
        static_bm = static_heuristic_block_m(f, hp, p, max_tile)
        for dtype in dtypes:
            for hint in n_rows_hints:
                cfg = choose_block_m(f, hp, p, dtype, n_rows_hint=hint,
                                     max_tile=max_tile, backend="model")
                stat = cell_model(f, hp, p, dtype, static_bm, hint,
                                  max_tile=max_tile)
                rows.append({
                    "shape": name, "F": int(f), "HP": int(hp), "P": int(p),
                    "dtype": dtype, "n_rows": int(hint),
                    "block_m": cfg.block_m, "static_block_m": static_bm,
                    "t_model_us": cfg.t_model_s * 1e6,
                    "t_static_us": stat.t_model_s * 1e6,
                    "bytes_moved": cfg.bytes_moved,
                    "bytes_static": stat.bytes_moved,
                    "mbu": cfg.mbu,
                    "beats_static": cfg.t_model_s < stat.t_model_s,
                    "source": cfg.source,
                })
    return rows


def calibrate_backend(scorer, *, backend: Optional[str] = None,
                      rows: Tuple[int, int] = (256, 8192),
                      repeats: int = 3,
                      register: bool = True) -> BackendConstants:
    """Fit the roofline constants for THIS backend from measured
    wall-clock instead of the baked TPU-ish defaults.

    Two ``measure_cell`` points bracket the chunk-size axis: the byte
    delta between them over the time delta is the achieved streaming
    bandwidth (the fixed launch/overhead terms cancel in the
    difference), the small-point residual after memory time prices the
    launch overhead, and peak FLOPs scale with the fitted bandwidth
    ratio (the model only ever compares cells on one backend, so the
    compute roof needs the right ORDER, not the right absolute).  Every
    fitted constant is clamped positive; a degenerate measurement (zero
    or negative deltas — e.g. interpret mode noise) falls back to the
    nominal default for that constant rather than registering garbage.

    ``register=True`` installs the result via ``set_backend_constants``
    so subsequent ``choose_block_m`` sweeps for this backend score under
    the measured envelope.  Runs that never call this keep the default
    constants and pick byte-identical blocks to the pre-calibration
    tuner."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    f = int(scorer.n_features)
    hp = int(scorer.w1.shape[1])
    p = int(scorer.n_proxies)
    dtype = str(scorer.dtype)
    bm = int(scorer.block_m)
    mt = int(scorer.max_tile)
    r_small, r_large = int(min(rows)), int(max(rows))
    t_small = measure_cell(scorer, r_small, repeats=repeats)
    t_large = measure_cell(scorer, r_large, repeats=repeats)
    cm_small = cell_model(f, hp, p, dtype, bm, r_small, max_tile=mt)
    cm_large = cell_model(f, hp, p, dtype, bm, r_large, max_tile=mt)
    d_bytes = cm_large.bytes_moved - cm_small.bytes_moved
    d_t = t_large - t_small
    if d_bytes > 0 and d_t > 1e-9:
        bw = float(d_bytes) / float(d_t)
    else:
        bw = _DEFAULT_CONSTANTS.hbm_bytes_per_s
    # the compute roof scales with the memory roof: only the RATIO of
    # the two roofs (the knee position) affects any ranking on a single
    # backend, and preserving the default ratio keeps it where exact
    # byte/flop counts put it
    peak = _DEFAULT_CONSTANTS.peak_flops * (
        bw / _DEFAULT_CONSTANTS.hbm_bytes_per_s)
    launch = t_small - cm_small.bytes_moved / bw \
        - cm_small.nb * _DEFAULT_CONSTANTS.grid_step_overhead_s
    if launch <= 0:
        launch = _DEFAULT_CONSTANTS.launch_overhead_s
    bc = BackendConstants(
        hbm_bytes_per_s=bw, peak_flops=peak,
        launch_overhead_s=float(launch),
        grid_step_overhead_s=_DEFAULT_CONSTANTS.grid_step_overhead_s,
        source="measured")
    if register:
        set_backend_constants(str(backend), bc)
    return bc


def measure_cell(scorer, n_rows: int, *, repeats: int = 3) -> float:
    """Advisory wall-clock: seconds per ``score_masks`` call on a random
    chunk.  Meaningful on compiled backends only; in interpret mode it
    times Python, so callers must treat it as a non-gating column."""
    import time

    rng = np.random.RandomState(0)
    x = rng.randn(n_rows, scorer.n_features).astype(np.float32)
    scorer.score_masks(x)  # warm the jit cache
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        scorer.score_masks(x)
        best = min(best, time.perf_counter() - t0)
    return best
