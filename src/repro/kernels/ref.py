"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def proxy_score_ref(x, w, b, thresholds):
    """x: (N, F); w: (F, P); b: (P,); thresholds: (P,).
    Returns (scores (N, P) f32, mask (N, P) bool)."""
    scores = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    return scores, scores >= thresholds.astype(jnp.float32)


def cascade_score_ref(x, w1, b1, w2, b2, thresholds, out_scale=None):
    """Two-pass packed-cascade oracle (the parity reference for the fused
    ``cascade_score`` kernel, every proxy family included).

    x: (N, F); w1: (F, HP) stacked folded hidden weights; b1: (HP,);
    w2: (HP, P) block-diagonal readout; b2, thresholds: (P,).
    ``out_scale`` (P,) are the per-stage readout dequantization scales of
    a weight-only-quantized cascade (``w1``/``w2`` then carry int8 codes);
    None means the fp32 path — multiplying by ones is an IEEE identity, so
    the oracle stays bit-compatible with its pre-quantization self.
    Returns (scores (N, P) f32, mask (N, P) bool, packed survivor index
    lists per stage) — ``packed[p]`` are the ascending row indices where
    stage p's mask is True.
    """
    if out_scale is None:
        out_scale = jnp.ones_like(b2.astype(jnp.float32))
    hid = jnp.maximum(
        jnp.dot(x.astype(jnp.float32), w1.astype(jnp.float32))
        + b1.astype(jnp.float32), 0.0)
    scores = (jnp.dot(hid, w2.astype(jnp.float32))
              * out_scale.astype(jnp.float32) + b2.astype(jnp.float32))
    mask = scores >= thresholds.astype(jnp.float32)
    m = np.asarray(mask)
    packed = [np.flatnonzero(m[:, p]).astype(np.int32) for p in range(m.shape[1])]
    return scores, mask, packed


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D) with H % K == 0.  fp32 softmax."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, K, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, D)


def ssd_chunk_ref(x, dA, B, C):
    """Per-chunk SSD terms (the kernel computes these for every chunk):

    x: (nc, Q, H, P) inputs (pre-multiplied by dt)
    dA: (nc, Q, H) per-step log-decay (dt * A, negative)
    B, C: (nc, Q, H, N) input/output projections (groups pre-broadcast)

    Returns:
      y_diag: (nc, Q, H, P) intra-chunk output
      states: (nc, H, P, N) per-chunk end state contribution
      chunk_decay: (nc, H) exp(sum dA) per chunk
    """
    dAc = jnp.moveaxis(dA.astype(jnp.float32), -1, 1)  # (nc, H, Q)
    cum = jnp.cumsum(dAc, axis=-1)  # (nc, H, Q)
    Q = x.shape[1]
    seg = cum[..., :, None] - cum[..., None, :]  # (nc, H, Q, Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jnp.einsum("cqhn,cshn->chqs", C.astype(jnp.float32), B.astype(jnp.float32))
    y_diag = jnp.einsum("chqs,chqs,cshp->cqhp", scores, L, x.astype(jnp.float32))
    decay_states = jnp.exp(cum[..., -1:] - cum)  # (nc, H, Q)
    states = jnp.einsum(
        "cqhn,chq,cqhp->chpn", B.astype(jnp.float32), decay_states, x.astype(jnp.float32)
    )
    return y_diag, states, jnp.exp(cum[..., -1])
