"""Jit'd wrappers around the Pallas kernels.

On TPU the kernels run compiled (interpret=False); in this CPU container
they run in interpret mode, which executes the kernel body in Python for
correctness validation — the BlockSpec tiling is identical either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention
from repro.kernels.proxy_score import cascade_score, proxy_score
from repro.kernels.ssd_scan import ssd_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    return not _on_tpu()


# ----------------------------------------------------------- proxy scoring
def fold_standardizer(params):
    """Fold (x - mean)/scale into (w, b): the kernel then applies a single
    affine map.  params: LinearParams."""
    w = np.asarray(params.w) / np.asarray(params.scale)
    b = float(params.b) - float(np.asarray(params.mean) @ w)
    return w.astype(np.float32), np.float32(b)


# Folding is pure per parameter set, so memoize by object identity.  The
# cache holds a strong reference to the params, which keeps each id() valid
# for the lifetime of its entry; size-bounded FIFO eviction caps memory.
_FOLD_CACHE: dict = {}
_FOLD_CACHE_MAX = 512


def fold_standardizer_cached(params):
    """Memoized fold_standardizer keyed on LinearParams identity: repeated
    scoring of the same proxy (every microbatch of every stage) folds once."""
    key = id(params)
    hit = _FOLD_CACHE.get(key)
    if hit is not None and hit[0] is params:
        return hit[1], hit[2]
    w, b = fold_standardizer(params)
    if len(_FOLD_CACHE) >= _FOLD_CACHE_MAX:
        _FOLD_CACHE.pop(next(iter(_FOLD_CACHE)))
    _FOLD_CACHE[key] = (params, w, b)
    return w, b


def proxy_score_batch(params, x, threshold: float):
    """Single-proxy convenience used by the executor: returns keep mask."""
    w, b = fold_standardizer_cached(params)
    _scores, mask = proxy_score(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(w)[:, None],
        jnp.asarray([b]),
        jnp.asarray([threshold], jnp.float32),
        interpret=interpret_default(),
    )
    return np.asarray(mask[:, 0])


def proxy_score_multi(param_list, x, thresholds):
    """Score several linear proxies in ONE fused pass (the serving engine
    evaluates a cascade's proxies together when profitable)."""
    ws, bs = zip(*(fold_standardizer_cached(p) for p in param_list))
    w = jnp.stack([jnp.asarray(w) for w in ws], axis=1)  # (F, P)
    b = jnp.asarray(bs)
    scores, mask = proxy_score(
        jnp.asarray(x, jnp.float32), w, b, jnp.asarray(thresholds, jnp.float32),
        interpret=interpret_default(),
    )
    return np.asarray(scores), np.asarray(mask)


class CascadeScorer:
    """Whole-cascade fused scorer (DESIGN.md §3).

    Folds every stage's standardizer ONCE at construction ("plan-compile
    time"), keeps the stacked (F, P) weight / bias / threshold tensors on
    device, and scores record tiles through the fused ``cascade_score``
    Pallas pass: one kernel invocation yields every stage's keep mask plus
    on-device-compacted survivor index lists.

    Input batches are bucket-padded to a small geometric ladder of static
    shapes so ``jax.jit`` traces a handful of programs total instead of one
    per survivor count; batches larger than the top bucket are chunked.
    """

    def __init__(self, param_list, thresholds, *, block_m: int = 2048,
                 interpret=None, max_tile: int = 8192):
        if not param_list:
            raise ValueError("CascadeScorer needs at least one linear proxy")
        folded = [fold_standardizer_cached(p) for p in param_list]
        self.w = jnp.stack([jnp.asarray(w) for w, _ in folded], axis=1)  # (F, P)
        self.b = jnp.asarray(np.asarray([b for _, b in folded], np.float32))
        self.thr = jnp.asarray(np.asarray(thresholds, np.float32))
        self.n_proxies = len(param_list)
        self.n_features = int(self.w.shape[0])
        self.block_m = min(block_m, max_tile)
        self.interpret = interpret_default() if interpret is None else interpret
        buckets = []
        size = self.block_m
        while size < max_tile:
            buckets.append(size)
            size *= 2
        buckets.append(max_tile)
        self.buckets = tuple(buckets)
        self.max_tile = max_tile
        # stage index -> proxy column (filled by from_plan; identity default)
        self.stage_cols = list(range(self.n_proxies))

    @classmethod
    def from_plan(cls, plan, **kw):
        """Build a scorer over the plan's linear ("svm") proxy stages.

        Returns None when no stage is linear.  ``scorer.stage_cols[si]`` maps
        stage index to its proxy column, or None for stages the fused path
        does not cover (no proxy, or an MLP proxy — those keep the reference
        scorer).
        """
        params, thrs, cols = [], [], []
        for stage in plan.stages:
            if stage.proxy is not None and stage.proxy.kind == "svm":
                cols.append(len(params))
                params.append(stage.proxy.params)
                thrs.append(stage.threshold)
            else:
                cols.append(None)
        if not params:
            return None
        scorer = cls(params, thrs, **kw)
        scorer.stage_cols = cols
        return scorer

    def covers_all(self, plan) -> bool:
        return all(
            col is not None
            for col, stage in zip(self.stage_cols, plan.stages)
            if stage.proxy is not None
        )

    def _bucket(self, n: int) -> int:
        for size in self.buckets:
            if n <= size:
                return size
        return self.max_tile

    def _pad_tile(self, x_tile: np.ndarray) -> np.ndarray:
        n = x_tile.shape[0]
        bucket = self._bucket(n)
        if n < bucket:  # bucket-pad: static shape -> no retrace
            xp = np.zeros((bucket, x_tile.shape[1]), np.float32)
            xp[:n] = x_tile
            return xp
        return np.ascontiguousarray(x_tile, np.float32)

    def _score_tile(self, x_tile: np.ndarray, need_scores: bool,
                    need_compaction: bool = True, compact_cols=None):
        n = x_tile.shape[0]
        scores, mask, packed, counts = cascade_score(
            jnp.asarray(self._pad_tile(x_tile)), self.w, self.b, self.thr, n,
            block_m=self.block_m, interpret=self.interpret,
            with_scores=need_scores, with_compaction=need_compaction,
            compact_cols=compact_cols,
        )
        return (np.asarray(scores[:n]) if need_scores else None,
                np.asarray(mask[:n]),
                np.asarray(packed) if need_compaction else None,
                np.asarray(counts) if need_compaction else None)

    def score_compact(self, x: np.ndarray, *, need_scores: bool = False,
                      compact_cols=None):
        """Score every stage over ``x`` (N, F) in one fused pass per tile.

        Returns (scores (N, P) | None, masks (N, P), packed, counts) where
        ``packed[p][:counts[p]]`` are the ascending row indices surviving
        stage p's proxy gate (dense UDF batch order).  ``scores`` is only
        fetched off device when ``need_scores`` (the engines gate on masks).

        ``compact_cols`` restricts survivor-list assembly to the named
        proxy columns (the executor only consumes the first full-tile
        stage's list); unassembled entries of ``packed`` are None.  The
        per-stage survivor ``counts`` cover every column either way.
        """
        x = np.asarray(x, np.float32)
        n = x.shape[0]
        cols_sel = (tuple(range(self.n_proxies)) if compact_cols is None
                    else tuple(int(c) for c in compact_cols))
        kernel_cols = None if compact_cols is None else cols_sel
        if n <= self.max_tile:
            scores, masks, packed, counts = self._score_tile(
                x, need_scores, compact_cols=kernel_cols)
            out = [None] * self.n_proxies
            for ci, col in enumerate(cols_sel):
                out[col] = packed[ci, :counts[col]]
            return scores, masks, out, counts
        scores = np.empty((n, self.n_proxies), np.float32) if need_scores else None
        masks = np.empty((n, self.n_proxies), bool)
        parts = {col: [] for col in cols_sel}
        counts = np.zeros(self.n_proxies, np.int32)
        for start in range(0, n, self.max_tile):
            stop = min(start + self.max_tile, n)
            s, m, pk, cnt = self._score_tile(
                x[start:stop], need_scores, compact_cols=kernel_cols)
            if need_scores:
                scores[start:stop] = s
            masks[start:stop] = m
            counts += cnt
            for ci, col in enumerate(cols_sel):
                parts[col].append(pk[ci, :cnt[col]] + start)
        packed = [None] * self.n_proxies
        for col in cols_sel:
            packed[col] = (np.concatenate(parts[col]) if parts[col]
                           else np.empty(0, np.int32))
        return scores, masks, packed, counts

    def score_masks(self, x: np.ndarray) -> np.ndarray:
        """Per-stage keep masks only (N, P): skips the compaction outputs
        and their device round-trips — the serving engine's submit-time
        path gates on mask rows alone."""
        x = np.asarray(x, np.float32)
        n = x.shape[0]
        masks = np.empty((n, self.n_proxies), bool)
        for start in range(0, n, self.max_tile):
            stop = min(start + self.max_tile, n)
            _s, mask, _pk, _cnt = self._score_tile(
                x[start:stop], need_scores=False, need_compaction=False)
            masks[start:stop] = mask
        return masks


# --------------------------------------------- scorer compile cache (serving)
# The adaptive server hot-swaps plans mid-stream and can oscillate between
# plan versions; each CascadeScorer carries folded weights + jit programs,
# so re-entering a previously compiled plan version must be a cache hit,
# not a refold + retrace.  Keyed on the stages' proxy-parameter identities
# and thresholds; values hold strong refs to the params so ids stay valid.
_SCORER_CACHE: dict = {}
_SCORER_CACHE_MAX = 64


def _plan_scorer_key(plan, max_tile: int):
    return tuple(
        (s.pred_idx,
         id(s.proxy.params) if s.proxy is not None else None,
         float(s.threshold))
        for s in plan.stages
    ) + (int(max_tile),)


def cascade_scorer_for_plan(plan, *, max_tile: int = 8192):
    """Memoized ``CascadeScorer.from_plan``.

    Returns (scorer | None, cache_hit).  None means the plan has no linear
    stage (nothing to fuse) — that outcome is cached too.
    """
    key = _plan_scorer_key(plan, max_tile)
    params_now = tuple(
        s.proxy.params if s.proxy is not None else None for s in plan.stages)
    hit = _SCORER_CACHE.get(key)
    if hit is not None and len(hit[0]) == len(params_now) and all(
            a is b for a, b in zip(hit[0], params_now)):
        return hit[1], True
    scorer = CascadeScorer.from_plan(plan, max_tile=max_tile)
    if len(_SCORER_CACHE) >= _SCORER_CACHE_MAX:
        _SCORER_CACHE.pop(next(iter(_SCORER_CACHE)))
    _SCORER_CACHE[key] = (params_now, scorer)
    return scorer, False


# -------------------------------------------------------------- attention
def attention(q, k, v, *, causal=True):
    return flash_attention(q, k, v, causal=causal, interpret=interpret_default())


# ------------------------------------------------------------------- SSD
def ssd(x, dt, A_log, B, C, D, chunk: int):
    """Full SSD forward built on the chunk kernel + jnp inter-chunk scan.

    Same signature/semantics as models.ssm.ssd_chunked (b, s, h, p)...
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = s // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = dt.astype(jnp.float32) * A[None, None, :]
    xdt = (x * dt[..., None].astype(x.dtype)).reshape(b, nc, chunk, h, p)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).reshape(b, nc, chunk, h, n)
    Ch = jnp.repeat(C, rep, axis=2).reshape(b, nc, chunk, h, n)
    dAc = dA.reshape(b, nc, chunk, h)

    def per_batch(args):
        xb, dab, bb, cb = args
        return ssd_chunk(xb, dab, bb, cb, interpret=interpret_default())

    # vmap over batch: kernel grid covers (nc*h); batch handled by vmap
    y_diag, states, chunk_decay = jax.vmap(
        lambda xb, dab, bb, cb: ssd_chunk(xb, dab, bb, cb, interpret=interpret_default())
    )(xdt, dAc, Bh, Ch)
    # inter-chunk recurrence (nc steps, tiny)
    def scan_body(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry

    from jax import lax

    final, prev = lax.scan(
        scan_body,
        jnp.zeros((b, h, p, n), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev = prev.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)
    cum = jnp.cumsum(dAc.transpose(0, 3, 1, 2), axis=-1)  # (b, h, nc, Q)
    state_decay_out = jnp.exp(cum)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Ch.astype(jnp.float32), prev, state_decay_out)
    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final
