"""Jit'd wrappers around the Pallas kernels.

On TPU the kernels run compiled (interpret=False); in this CPU container
they run in interpret mode, which executes the kernel body in Python for
correctness validation — the BlockSpec tiling is identical either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune
from repro.kernels.flash_attention import flash_attention
from repro.kernels.proxy_score import cascade_score
from repro.kernels.ssd_scan import ssd_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    return not _on_tpu()


# ----------------------------------------------------------- proxy scoring
def fold_standardizer(params):
    """Fold (x - mean)/scale into (w, b): the kernel then applies a single
    affine map.  params: LinearParams.  (Kept as the linear parity oracle's
    fold; execution paths go through the family packers.)"""
    w = np.asarray(params.w) / np.asarray(params.scale)
    b = float(params.b) - float(np.asarray(params.mean) @ w)
    return w.astype(np.float32), np.float32(b)


# Packing (standardizer fold + lowering to the depth-1 MLP form) is pure
# per parameter set, so memoize by object identity.  The cache holds a
# strong reference to the params, which keeps each id() valid for the
# lifetime of its entry; size-bounded FIFO eviction caps memory.
_PACK_CACHE: dict = {}
_PACK_CACHE_MAX = 512


def pack_proxy_cached(params):
    """Memoized ``family_of(params).pack``: repeated scoring of the same
    proxy (every microbatch of every stage) packs once."""
    from repro.core.proxy_family import family_of

    # id() is safe HERE only because the entry holds a strong ref to params
    # and the hit path re-checks `hit[0] is params` before trusting the key.
    key = id(params)  # corelint: disable=identity-cache-key
    hit = _PACK_CACHE.get(key)
    if hit is not None and hit[0] is params:
        return hit[1]
    packed = family_of(params).pack(params)
    if len(_PACK_CACHE) >= _PACK_CACHE_MAX:
        _PACK_CACHE.pop(next(iter(_PACK_CACHE)))
    _PACK_CACHE[key] = (params, packed)
    return packed


_OPERAND_CACHE: dict = {}


def _kernel_operands_cached(params):
    """Device-resident (w1, b1, w2, b2) for a single proxy, memoized on
    params identity — the per-stage path packs and uploads once, not per
    microbatch."""
    from repro.core.proxy_family import cascade_kernel_operands, pack_cascade

    # same id()-plus-strong-ref-plus-`is`-recheck pattern as pack_proxy_cached
    key = id(params)  # corelint: disable=identity-cache-key
    hit = _OPERAND_CACHE.get(key)
    if hit is not None and hit[0] is params:
        return hit[1]
    ops = tuple(jnp.asarray(a) for a in cascade_kernel_operands(
        pack_cascade([params], pack_fn=pack_proxy_cached)))
    if len(_OPERAND_CACHE) >= _PACK_CACHE_MAX:
        _OPERAND_CACHE.pop(next(iter(_OPERAND_CACHE)))
    _OPERAND_CACHE[key] = (params, ops)
    return ops


def proxy_score_batch(params, x, threshold: float):
    """Single-proxy convenience used by the per-stage kernel path: returns
    the keep mask.  Family-agnostic — params may be any registered family's."""
    w1, b1, w2, b2 = _kernel_operands_cached(params)
    _scores, mask, _pk, _cnt = cascade_score(
        jnp.asarray(x, jnp.float32), w1, b1, w2, b2,
        jnp.asarray([threshold], jnp.float32), x.shape[0],
        interpret=interpret_default(), with_scores=False,
        with_compaction=False,
    )
    return np.asarray(mask[:, 0])


class CascadeScorer:
    """Whole-cascade fused scorer (DESIGN.md §3), every proxy family.

    Packs every stage's params ONCE at construction ("plan-compile time")
    via the family registry — standardizers folded, each stage lowered to
    the packed depth-1 MLP form, the whole cascade stacked into
    bucket-padded ``(F, H, P)`` tensors kept on device — and scores record
    tiles through the fused two-pass ``cascade_score`` Pallas kernel: one
    launch yields every stage's keep mask plus on-device-compacted
    survivor index lists, for linear, MLP, and mixed cascades alike.

    Input batches are bucket-padded to a small geometric ladder of static
    shapes so ``jax.jit`` traces a handful of programs total instead of one
    per survivor count; batches larger than the top bucket are chunked.
    """

    def __init__(self, param_list, thresholds, *, block_m: int = None,
                 interpret=None, max_tile: int = 8192,
                 dtype: str = "float32", n_rows_hint: int = None,
                 packed=None):
        from repro.core.proxy_family import (
            cascade_kernel_operands, pack_cascade, quantize_cascade)

        if not param_list:
            raise ValueError("CascadeScorer needs at least one proxy")
        if packed is None:
            packed = pack_cascade(list(param_list), pack_fn=pack_proxy_cached)
            if dtype != "float32":
                # weight-only quantization at plan-compile time: scales
                # folded so the kernel dequantizes once per tile
                packed = quantize_cascade(packed, dtype)
        self.packed = packed
        self.dtype = packed.dtype
        w1, b1, w2, b2 = cascade_kernel_operands(self.packed)
        self.w1 = jnp.asarray(w1)  # (F, H*P) stacked hidden weights/codes
        self.b1 = jnp.asarray(b1)
        self.w2 = jnp.asarray(w2)  # (H*P, P) block-diagonal readout
        self.b2 = jnp.asarray(b2)
        self.out_scale = (None if self.packed.out_scale is None
                          else jnp.asarray(self.packed.out_scale))
        self.thr = jnp.asarray(np.asarray(thresholds, np.float32))
        self.families = self.packed.families
        self.n_proxies = len(param_list)
        self.n_features = int(self.w1.shape[0])
        if block_m is None:
            # roofline autotune (kernels/autotune.py): sweep candidate
            # blocks against exact per-launch byte counts at the expected
            # chunk size.  With no row hint the winner coincides with the
            # previous static 8MB-budget heuristic by construction (same
            # feasibility bound, equal bytes at every feasible block, so
            # fewer grid steps win); a small hint right-sizes the block
            # for serving chunks instead of padding 8-16x.  Cache-keyed
            # on (F, HP-bucket, P-bucket, dtype, backend, hint), so
            # repeat installs skip the sweep.
            cfg = autotune.choose_block_m(
                self.n_features, int(self.w1.shape[1]), self.n_proxies,
                self.dtype, n_rows_hint=n_rows_hint, max_tile=max_tile)
            block_m = cfg.block_m
        self.block_m = min(block_m, max_tile)
        self.interpret = interpret_default() if interpret is None else interpret
        buckets = []
        size = self.block_m
        while size < max_tile:
            buckets.append(size)
            size *= 2
        buckets.append(max_tile)
        self.buckets = tuple(buckets)
        self.max_tile = max_tile
        # stage index -> proxy column (filled by from_plan; identity default)
        self.stage_cols = list(range(self.n_proxies))

    @classmethod
    def from_plan(cls, plan, **kw):
        """Build a scorer over ALL of the plan's proxied stages (any
        family).  Returns None only when no stage carries a proxy.
        ``scorer.stage_cols[si]`` maps stage index to its proxy column, or
        None for proxy-less stages.  A plan stamped with
        ``meta["quant_dtype"]`` (optimizer flag or wire artifact) builds
        its scorer at that weight dtype unless the caller overrides.
        """
        kw.setdefault("dtype", plan.meta.get("quant_dtype", "float32"))
        params, thrs, cols = [], [], []
        for stage in plan.stages:
            if stage.proxy is not None:
                cols.append(len(params))
                params.append(stage.proxy.params)
                thrs.append(stage.threshold)
            else:
                cols.append(None)
        if not params:
            return None
        scorer = cls(params, thrs, **kw)
        scorer.stage_cols = cols
        return scorer

    def covers_all(self, plan) -> bool:
        """Every proxied stage has a column — trivially true since the
        packed format covers every registered family; kept as an API
        invariant check."""
        return all(
            col is not None
            for col, stage in zip(self.stage_cols, plan.stages)
            if stage.proxy is not None
        )

    @classmethod
    def from_plans(cls, plans, **kw):
        """Stack several plans' proxied stages into ONE packed cascade
        (multi-query serving, DESIGN.md §10).  Returns
        ``(scorer | None, col_maps)`` where ``col_maps[qi][si]`` is the
        shared-scorer column for plan ``qi``'s stage ``si`` (None for
        proxy-less stages).  Stages with byte-identical packed params AND
        threshold — keyed on the content fingerprint, never ``id()`` —
        share one column, so a predicate proxied identically by two
        queries is scored once per record, not once per query.

        Column masks are bit-identical to each plan's isolated scorer:
        the readout is block-diagonal, so a column's score sums only its
        own hidden block — every cross-block term is an exact float zero
        and stacking more columns cannot perturb the per-column sums.

        The weight storage dtype is the plans' common ``quant_dtype``
        when they agree; disagreeing tenants fall back to float32 (a
        shared launch must not silently quantize a tenant that asked for
        full precision).  ``None`` scorer means no plan has any proxied
        stage."""
        params, thrs = [], []
        col_of = {}
        col_maps = []
        for plan in plans:
            cols = []
            for stage in plan.stages:
                if stage.proxy is None:
                    cols.append(None)
                    continue
                key = (params_fingerprint(stage.proxy.params),
                       float(stage.threshold))
                col = col_of.get(key)
                if col is None:
                    col = len(params)
                    col_of[key] = col
                    params.append(stage.proxy.params)
                    thrs.append(stage.threshold)
                cols.append(col)
            col_maps.append(cols)
        if not params:
            return None, col_maps
        dtypes = {str(plan.meta.get("quant_dtype", "float32"))
                  for plan in plans}
        kw.setdefault("dtype",
                      dtypes.pop() if len(dtypes) == 1 else "float32")
        scorer = cls(params, thrs, **kw)
        scorer.stage_cols = list(range(len(params)))
        return scorer, col_maps

    def _bucket(self, n: int) -> int:
        for size in self.buckets:
            if n <= size:
                return size
        return self.max_tile

    def _pad_tile(self, x_tile: np.ndarray) -> np.ndarray:
        n = x_tile.shape[0]
        bucket = self._bucket(n)
        if n < bucket:  # bucket-pad: static shape -> no retrace
            xp = np.zeros((bucket, x_tile.shape[1]), np.float32)
            xp[:n] = x_tile
            return xp
        return np.ascontiguousarray(x_tile, np.float32)

    def _score_tile(self, x_tile: np.ndarray, need_scores: bool,
                    need_compaction: bool = True, compact_cols=None):
        n = x_tile.shape[0]
        scores, mask, packed, counts = cascade_score(
            jnp.asarray(self._pad_tile(x_tile)), self.w1, self.b1,
            self.w2, self.b2, self.thr, n, out_scale=self.out_scale,
            block_m=self.block_m, interpret=self.interpret,
            with_scores=need_scores, with_compaction=need_compaction,
            compact_cols=compact_cols,
        )
        return (np.asarray(scores[:n]) if need_scores else None,
                np.asarray(mask[:n]),
                np.asarray(packed) if need_compaction else None,
                np.asarray(counts) if need_compaction else None)

    def score_compact(self, x: np.ndarray, *, need_scores: bool = False,
                      compact_cols=None):
        """Score every stage over ``x`` (N, F) in one fused pass per tile.

        Returns (scores (N, P) | None, masks (N, P), packed, counts) where
        ``packed[p][:counts[p]]`` are the ascending row indices surviving
        stage p's proxy gate (dense UDF batch order).  ``scores`` is only
        fetched off device when ``need_scores`` (the engines gate on masks).

        ``compact_cols`` restricts survivor-list assembly to the named
        proxy columns (the executor only consumes the first full-tile
        stage's list); unassembled entries of ``packed`` are None.  The
        per-stage survivor ``counts`` cover every column either way.
        """
        x = np.asarray(x, np.float32)
        n = x.shape[0]
        cols_sel = (tuple(range(self.n_proxies)) if compact_cols is None
                    else tuple(int(c) for c in compact_cols))
        kernel_cols = None if compact_cols is None else cols_sel
        if n <= self.max_tile:
            scores, masks, packed, counts = self._score_tile(
                x, need_scores, compact_cols=kernel_cols)
            out = [None] * self.n_proxies
            for ci, col in enumerate(cols_sel):
                out[col] = packed[ci, :counts[col]]
            return scores, masks, out, counts
        scores = np.empty((n, self.n_proxies), np.float32) if need_scores else None
        masks = np.empty((n, self.n_proxies), bool)
        parts = {col: [] for col in cols_sel}
        counts = np.zeros(self.n_proxies, np.int32)
        for start in range(0, n, self.max_tile):
            stop = min(start + self.max_tile, n)
            s, m, pk, cnt = self._score_tile(
                x[start:stop], need_scores, compact_cols=kernel_cols)
            if need_scores:
                scores[start:stop] = s
            masks[start:stop] = m
            counts += cnt
            for ci, col in enumerate(cols_sel):
                parts[col].append(pk[ci, :cnt[col]] + start)
        packed = [None] * self.n_proxies
        for col in cols_sel:
            packed[col] = (np.concatenate(parts[col]) if parts[col]
                           else np.empty(0, np.int32))
        return scores, masks, packed, counts

    def score_masks(self, x: np.ndarray) -> np.ndarray:
        """Per-stage keep masks only (N, P): skips the compaction outputs
        and their device round-trips — the serving engine's submit-time
        path gates on mask rows alone."""
        x = np.asarray(x, np.float32)
        n = x.shape[0]
        masks = np.empty((n, self.n_proxies), bool)
        for start in range(0, n, self.max_tile):
            stop = min(start + self.max_tile, n)
            _s, mask, _pk, _cnt = self._score_tile(
                x[start:stop], need_scores=False, need_compaction=False)
            masks[start:stop] = mask
        return masks

    def score_margins(self, x: np.ndarray):
        """Masks (N, P) plus per-record distance to the NEAREST stage
        threshold (N,) — the importance-audit weight signal (records near
        any proxy decision boundary are the ones whose audited labels are
        most informative).  The min-|score - thr| reduction runs on
        device, so only an (N,) vector is fetched instead of the full
        (N, P) score matrix.  The kernel does write its (N, Pp) score
        output to HBM for this path — an in-kernel margin output could
        not be narrower anyway (TPU outputs are 128-lane minimum, the
        same width as the score tile for P <= 128), and the extra
        ~512 B/row is <0.1% of HBM bandwidth at full serving rate."""
        x = np.asarray(x, np.float32)
        n = x.shape[0]
        masks = np.empty((n, self.n_proxies), bool)
        margins = np.empty(n, np.float32)
        for start in range(0, n, self.max_tile):
            stop = min(start + self.max_tile, n)
            tile = x[start:stop]
            m = tile.shape[0]
            scores, mask, _pk, _cnt = cascade_score(
                jnp.asarray(self._pad_tile(tile)), self.w1, self.b1,
                self.w2, self.b2, self.thr, m, out_scale=self.out_scale,
                block_m=self.block_m, interpret=self.interpret,
                with_scores=True, with_compaction=False,
            )
            masks[start:stop] = np.asarray(mask[:m])
            margins[start:stop] = np.asarray(
                jnp.min(jnp.abs(scores[:m] - self.thr[None, :]), axis=1))
        return masks, margins


# --------------------------------------------- scorer compile cache (serving)
# The adaptive server hot-swaps plans mid-stream and can oscillate between
# plan versions; each CascadeScorer carries packed weights + jit programs,
# so re-entering a previously compiled plan version must be a cache hit,
# not a repack + retrace.  Keyed on a CONTENT fingerprint of every stage's
# packed parameters — (pred, family, packed-bytes digest, threshold) — not
# on ``id(params)``: an id key would need the cache to pin the params alive
# forever (or risk a recycled id aliasing a stale compiled scorer after the
# old params are garbage-collected), whereas the fingerprint is immune to
# id reuse by construction, lets swapped-out plans' params be collected,
# and makes byte-identical params (e.g. a deserialized wire artifact of a
# plan this process already compiled) a cache hit.
_SCORER_CACHE: dict = {}
_SCORER_CACHE_MAX = 64


def params_fingerprint(params) -> str:
    """Content digest of one proxy's PACKED parameters (folded depth-1
    form, family-agnostic).  Packing is memoized (``pack_proxy_cached``),
    so the recurring cost is one blake2b over ~F*hidden floats — paid per
    plan install, never per batch."""
    import hashlib

    pk = pack_proxy_cached(params)
    h = hashlib.blake2b(digest_size=16)
    h.update(str((pk.hidden,) + tuple(pk.w1.shape)).encode())
    for a in (pk.w1, pk.b1, pk.w2):
        h.update(np.ascontiguousarray(a, np.float32).tobytes())
    h.update(np.float32(pk.b2).tobytes())
    return h.hexdigest()


def _plan_scorer_key(plan, max_tile: int):
    # no family component: the packed fingerprint already determines the
    # compiled program bit-for-bit, so e.g. a deserialized wire copy
    # ("packed1" family) of a locally-built linear plan hits the same entry.
    # The quant dtype IS a key component: the same fp32 params packed at
    # int8 vs fp32 are different compiled programs (different codes and
    # masks), so a stale-dtype scorer must never be served.
    return tuple(
        (s.pred_idx,
         params_fingerprint(s.proxy.params) if s.proxy is not None else None,
         float(s.threshold))
        for s in plan.stages
    ) + (int(max_tile), str(plan.meta.get("quant_dtype", "float32")))


def cascade_scorer_for_plan(plan, *, max_tile: int = 8192):
    """Memoized ``CascadeScorer.from_plan``.

    Returns (scorer | None, cache_hit).  None means the plan has no
    proxied stage at all (nothing to fuse) — that outcome is cached too.
    """
    key = _plan_scorer_key(plan, max_tile)
    if key in _SCORER_CACHE:
        return _SCORER_CACHE[key], True
    scorer = CascadeScorer.from_plan(plan, max_tile=max_tile)
    if len(_SCORER_CACHE) >= _SCORER_CACHE_MAX:
        _SCORER_CACHE.pop(next(iter(_SCORER_CACHE)))
    _SCORER_CACHE[key] = scorer
    return scorer, False


# ------------------------------------------------- scorer wire format (v1)
# A plan swap in multi-host serving ships a single serializable artifact:
# the plan's stage metadata + the bucket-padded packed cascade tensors +
# thresholds (DESIGN.md §6).  Layout:
#
#   b"COREWIRE" | u16 version | u16 pad | u64 header_len
#   | header (canonical JSON, utf-8) | concatenated raw array payloads
#
# Every numeric tensor travels as raw dtype bytes (descriptors in the
# header), so deserialize(serialize(x)) is BIT-exact: the receiving host's
# scorer computes the identical masks, and re-serializing a deserialized
# artifact reproduces the original bytes (tested).  Scalar floats live in
# JSON, which round-trips float64 exactly (repr-based).  Deserialized
# plans carry ``packed1``-family proxies (the folded form is the wire
# truth; the training-side parameterization never travels).
WIRE_MAGIC = b"COREWIRE"
WIRE_VERSION = 1
# v1.1: the two pad bytes after the version become a MINOR field.  Minor 0
# is the v1 scorer artifact (bytes unchanged — round-trips stay bit-exact);
# minor 1 is a control FRAME wrapping a kind-tagged payload (re-sync
# catch-up installs, replicated coordinator state deltas).  v1 readers
# never see frames (they ride the control channel, not the artifact
# broadcast), and v1.1 readers still parse v1 artifacts byte-for-byte.
WIRE_MINOR_FRAME = 1
FRAME_RESYNC = "resync"  # payload: a v1 scorer artifact for a fenced host
FRAME_DELTA = "delta"  # payload: JSON-encoded consensus StateDelta
# payload: a v1/v1.2 scorer artifact; meta: the plan-cache stats sidecar
# (fingerprint digest + stat vector, B&B candidate orders and L-node
# measurements, hit counters) — one frame per persisted cache entry, so
# the cross-query plan cache (core/plan_cache.py) survives restarts and
# ships coordinator->fleet over the same wire family as everything else
FRAME_PLANCACHE = "plancache"
# v1.2: minor 2 is a QUANTIZED scorer artifact — the packed tensors travel
# as int8 (or fp8-simulated) codes, and the scorer header gains "dtype"
# plus a per-stage "out_scale" array ref.  fp32 artifacts keep minor 0
# with byte-identical layout (no new header keys), so v1.0 readers and
# blobs are untouched; readers reject any OTHER minor explicitly rather
# than misparsing a future format.
WIRE_MINOR_QUANT = 2


class WireFormatError(ValueError):
    """Malformed / incompatible scorer artifact."""


def pack_le(value: int, width: int) -> bytes:
    """Canonical little-endian unsigned field for COREWIRE containers.

    Every integer field in the wire family (scorer artifacts, control
    frames, the plan-cache file) is encoded through this pair so the
    byte-level layout discipline lives in one module
    (corelint: wire-pack-outside-ops).
    """
    return int(value).to_bytes(width, "little")


def unpack_le(buf, start: int, width: int) -> int:
    """Inverse of :func:`pack_le`: read ``width`` bytes at ``start``."""
    return int.from_bytes(bytes(buf[start:start + width]), "little")


class _ArrayPool:
    """Array blob registry for one serialization pass."""

    def __init__(self):
        self.descs: list = []
        self.blobs: list = []
        self._offset = 0

    def put(self, a: np.ndarray) -> int:
        a = np.ascontiguousarray(a)
        raw = a.tobytes()
        self.descs.append({
            "dtype": a.dtype.str, "shape": list(a.shape),
            "offset": self._offset, "nbytes": len(raw),
        })
        self.blobs.append(raw)
        self._offset += len(raw)
        return len(self.descs) - 1


def _pool_get(descs, payload: memoryview, ref: int) -> np.ndarray:
    d = descs[ref]
    a = np.frombuffer(
        payload[d["offset"]:d["offset"] + d["nbytes"]], dtype=np.dtype(d["dtype"])
    )
    return a.reshape(d["shape"]).copy()


def serialize_scorer(plan, scorer=None, *, max_tile: int = 8192) -> bytes:
    """Pack ``(plan, fused scorer)`` into the versioned wire artifact.

    ``scorer=None`` builds (or cache-hits) the plan's scorer first.  Only
    fully-proxied-or-proxyless stage metadata plus the packed cascade
    travels — never UDFs (the receiving host binds its own ``Query``).
    """
    import json

    if scorer is None:
        scorer, _ = cascade_scorer_for_plan(plan, max_tile=max_tile)
    if scorer is None:
        raise WireFormatError("plan has no proxied stage: nothing to ship")
    pool = _ArrayPool()
    packed = scorer.packed
    src_families = plan.meta.get("wire_src_families") or tuple(
        s.proxy.family for s in plan.stages if s.proxy is not None)
    stages = []
    for s in plan.stages:
        entry = {
            "pred_idx": int(s.pred_idx), "alpha": float(s.alpha),
            "threshold": float(s.threshold),
            "est_reduction": float(s.est_reduction),
            "est_selectivity": float(s.est_selectivity),
            "est_cost": float(s.est_cost),
            "proxy": None,
        }
        if s.proxy is not None:
            rc = s.proxy.r_curve
            entry["proxy"] = {
                "d": [int(i) for i in s.proxy.d],
                "cost": float(s.proxy.cost),
                "train_f1": float(s.proxy.train_f1),
                "n_train": int(s.proxy.n_train),
                "r_curve": {
                    "alphas": pool.put(np.asarray(rc.alphas)),
                    "thresholds": pool.put(np.asarray(rc.thresholds)),
                    "reductions": pool.put(np.asarray(rc.reductions)),
                },
            }
        stages.append(entry)
    header = {
        "wire_version": WIRE_VERSION,
        "plan": {
            "stages": stages,
            "est_total_cost": float(plan.est_total_cost),
            "plan_version": int(plan.meta.get("plan_version", 0)),
            "accuracy_target": float(plan.query.accuracy_target),
            "n_predicates": int(plan.query.n),
            "src_families": list(src_families),
        },
        "scorer": {
            "w1": pool.put(packed.w1), "b1": pool.put(packed.b1),
            "w2": pool.put(packed.w2), "b2": pool.put(packed.b2),
            "thr": pool.put(np.asarray(scorer.thr, np.float32)),
            "hidden": [int(h) for h in packed.hidden],
            "stage_cols": [None if c is None else int(c)
                           for c in scorer.stage_cols],
            "block_m": int(scorer.block_m),
            "max_tile": int(scorer.max_tile),
        },
        "arrays": pool.descs,
    }
    # v1.2 quantized artifact: dtype + per-stage readout scales ride the
    # header; minor stays 0 for fp32 so those blobs are byte-identical to
    # every earlier release (round-trip tests pin this).
    minor = 0
    if packed.dtype != "float32":
        minor = WIRE_MINOR_QUANT
        header["scorer"]["dtype"] = str(packed.dtype)
        header["scorer"]["out_scale"] = pool.put(
            np.asarray(packed.out_scale, np.float32))
    hdr = json.dumps(header, sort_keys=True,
                     separators=(",", ":")).encode("utf-8")
    out = bytearray()
    out += WIRE_MAGIC
    out += int(WIRE_VERSION).to_bytes(2, "little")
    out += int(minor).to_bytes(2, "little")
    out += len(hdr).to_bytes(8, "little")
    out += hdr
    for raw in pool.blobs:
        out += raw
    return bytes(out)


def serialize_frame(kind: str, epoch: int, payload: bytes,
                    meta: dict | None = None) -> bytes:
    """Wrap a control payload in a COREWIRE v1.1 frame:

      b"COREWIRE" | u16 major=1 | u16 minor=1 | u64 header_len
      | header JSON {"kind", "epoch", "meta", "payload_len"} | payload

    Frames carry the fault-tolerance control plane — re-sync catch-up
    artifacts for fenced hosts (``FRAME_RESYNC``, payload = a v1 scorer
    artifact) and replicated coordinator state deltas (``FRAME_DELTA``)
    — over the same wire family as the artifact broadcast.  Minor-version
    discrimination keeps it backward-compatible: a v1 scorer blob's bytes
    are untouched, and ``deserialize_scorer`` rejects frames explicitly
    instead of misparsing them."""
    import json

    hdr = json.dumps(
        {"kind": str(kind), "epoch": int(epoch), "meta": meta or {},
         "payload_len": len(payload)},
        sort_keys=True, separators=(",", ":")).encode("utf-8")
    out = bytearray()
    out += WIRE_MAGIC
    out += int(WIRE_VERSION).to_bytes(2, "little")
    out += int(WIRE_MINOR_FRAME).to_bytes(2, "little")
    out += len(hdr).to_bytes(8, "little")
    out += hdr
    out += payload
    return bytes(out)


def deserialize_frame(blob: bytes):
    """Inverse of ``serialize_frame``: returns (kind, epoch, payload,
    meta).  Raises ``WireFormatError`` on v1 artifacts (minor 0) so the
    two channels cannot be confused."""
    import json

    if blob[:len(WIRE_MAGIC)] != WIRE_MAGIC:
        raise WireFormatError("bad magic: not a COREWIRE frame")
    ver = int.from_bytes(blob[8:10], "little")
    minor = int.from_bytes(blob[10:12], "little")
    if ver != WIRE_VERSION or minor != WIRE_MINOR_FRAME:
        raise WireFormatError(
            f"wire {ver}.{minor} is not a v{WIRE_VERSION}.{WIRE_MINOR_FRAME} "
            f"control frame")
    hdr_len = int.from_bytes(blob[12:20], "little")
    header = json.loads(blob[20:20 + hdr_len].decode("utf-8"))
    payload = bytes(blob[20 + hdr_len:])
    if len(payload) != int(header["payload_len"]):
        raise WireFormatError(
            f"frame payload truncated: {len(payload)} != "
            f"{header['payload_len']}")
    return header["kind"], int(header["epoch"]), payload, header["meta"]


def deserialize_scorer(blob: bytes, query):
    """Inverse of ``serialize_scorer``: rebuild ``(plan, scorer)`` against
    the locally-bound ``query``.  The scorer's packed tensors, thresholds,
    and therefore every keep decision are bit-identical to the sender's;
    proxies come back as first-class ``packed1``-family models (reference
    scoring and the per-stage kernel fallback both still work)."""
    import json

    from repro.core.proxy import ProxyModel, RCurve
    from repro.core.proxy_family import unpack_cascade
    from repro.core.query import PhysicalPlan, PlanStage

    if blob[:len(WIRE_MAGIC)] != WIRE_MAGIC:
        raise WireFormatError("bad magic: not a CORE scorer artifact")
    ver = int.from_bytes(blob[8:10], "little")
    if ver != WIRE_VERSION:
        raise WireFormatError(f"wire version {ver} != supported {WIRE_VERSION}")
    minor = int.from_bytes(blob[10:12], "little")
    if minor == WIRE_MINOR_FRAME:
        raise WireFormatError(
            f"wire minor {minor} is a control frame, not a scorer artifact "
            f"(use deserialize_frame)")
    if minor not in (0, WIRE_MINOR_QUANT):
        raise WireFormatError(
            f"unknown wire minor {minor}: this reader supports scorer "
            f"artifacts v{WIRE_VERSION}.0 (fp32) and "
            f"v{WIRE_VERSION}.{WIRE_MINOR_QUANT} (quantized)")
    hdr_len = int.from_bytes(blob[12:20], "little")
    header = json.loads(blob[20:20 + hdr_len].decode("utf-8"))
    payload = memoryview(blob)[20 + hdr_len:]
    descs = header["arrays"]
    ph = header["plan"]
    if int(ph["n_predicates"]) != query.n:
        raise WireFormatError(
            f"artifact built for {ph['n_predicates']} predicates; local "
            f"query has {query.n}")
    if abs(float(ph["accuracy_target"]) - float(query.accuracy_target)) > 1e-12:
        raise WireFormatError("artifact/query accuracy targets differ")
    sh = header["scorer"]
    from repro.core.proxy_family import PackedCascade

    quant_dtype = str(sh.get("dtype", "float32"))
    packed = PackedCascade(
        w1=_pool_get(descs, payload, sh["w1"]),
        b1=_pool_get(descs, payload, sh["b1"]),
        w2=_pool_get(descs, payload, sh["w2"]),
        b2=_pool_get(descs, payload, sh["b2"]),
        hidden=tuple(int(h) for h in sh["hidden"]),
        families=tuple(ph["src_families"]),
        dtype=quant_dtype,
        out_scale=(_pool_get(descs, payload, sh["out_scale"])
                   if minor == WIRE_MINOR_QUANT else None),
    )
    thr = _pool_get(descs, payload, sh["thr"])
    params_by_col = [unpack_cascade(packed, c) for c in range(packed.n_stages)]
    stages = []
    for st in ph["stages"]:
        proxy = None
        col = sh["stage_cols"][len(stages)]
        if st["proxy"] is not None:
            if col is None:
                raise WireFormatError("proxied stage without a scorer column")
            rc = st["proxy"]["r_curve"]
            proxy = ProxyModel(
                pred_idx=int(st["pred_idx"]),
                d=tuple(st["proxy"]["d"]),
                family="packed1",
                params=params_by_col[col],
                r_curve=RCurve(
                    alphas=_pool_get(descs, payload, rc["alphas"]),
                    thresholds=_pool_get(descs, payload, rc["thresholds"]),
                    reductions=_pool_get(descs, payload, rc["reductions"]),
                ),
                cost=float(st["proxy"]["cost"]),
                train_f1=float(st["proxy"]["train_f1"]),
                n_train=int(st["proxy"]["n_train"]),
            )
        stages.append(PlanStage(
            pred_idx=int(st["pred_idx"]), proxy=proxy,
            alpha=float(st["alpha"]), threshold=float(st["threshold"]),
            est_reduction=float(st["est_reduction"]),
            est_selectivity=float(st["est_selectivity"]),
            est_cost=float(st["est_cost"]),
        ))
    meta = {
        "mode": "wire",
        "plan_version": int(ph["plan_version"]),
        "wire_src_families": tuple(ph["src_families"]),
    }
    if quant_dtype != "float32":
        meta["quant_dtype"] = quant_dtype
    plan = PhysicalPlan(
        query=query, stages=stages,
        est_total_cost=float(ph["est_total_cost"]),
        meta=meta,
    )
    # packed= hands the wire codes straight to the scorer — no re-pack,
    # no re-quantize — so the receiving host's masks are bit-identical to
    # the sender's and re-serializing reproduces the original bytes
    scorer = CascadeScorer(
        [params_by_col[c] for c in range(packed.n_stages)], thr,
        block_m=int(sh["block_m"]), max_tile=int(sh["max_tile"]),
        packed=packed,
    )
    scorer.stage_cols = [None if c is None else int(c)
                         for c in sh["stage_cols"]]
    return plan, scorer


# ------------------------------------------------------ quant parity gate
def quant_parity_report(plan, x, *, dtype: str = "int8",
                        calib_frac: float = 0.5,
                        max_tile: int = 8192) -> dict:
    """Decision-flip audit of a quantized cascade against its fp32 twin.

    The contract (DESIGN.md §3): quantization may flip a keep decision
    ONLY for records whose fp32 score sits within ``tol`` of the stage
    threshold, where ``tol`` is calibrated as 2x the max |quant - fp32|
    score error over the first ``calib_frac`` of ``x`` and VALIDATED on
    the held-out remainder.  Records with real margin must be untouched.

    Returns a report dict; ``flips_within_tol`` is the gate bit, the
    rest (score errors, per-stage selectivity deltas) are advisory.
    """
    x = np.asarray(x, np.float32)
    f32 = CascadeScorer.from_plan(plan, max_tile=max_tile, dtype="float32")
    if f32 is None:
        raise ValueError("plan has no proxied stage: nothing to audit")
    qs = CascadeScorer.from_plan(plan, max_tile=max_tile, dtype=dtype)
    n_cal = int(np.clip(int(len(x) * calib_frac), 1, len(x) - 1))
    thr = np.asarray(f32.thr)

    def _scores_masks(scorer, chunk):
        s, m, _pk, _cnt = scorer.score_compact(chunk, need_scores=True)
        return s, m

    s_f, m_f = _scores_masks(f32, x[:n_cal])
    s_q, _ = _scores_masks(qs, x[:n_cal])
    tol = 2.0 * float(np.max(np.abs(s_q - s_f)))
    ev_f, mask_f = _scores_masks(f32, x[n_cal:])
    ev_q, mask_q = _scores_masks(qs, x[n_cal:])
    flips = mask_f != mask_q
    near = np.abs(ev_f - thr[None, :]) <= tol
    sel_f = mask_f.mean(axis=0)
    sel_q = mask_q.mean(axis=0)
    return {
        "dtype": dtype,
        "tol": tol,
        "max_err_calib": float(np.max(np.abs(s_q - s_f))),
        "max_err_eval": float(np.max(np.abs(ev_q - ev_f))),
        "n_eval": int(flips.shape[0]),
        "n_flips": int(flips.sum()),
        "flip_rate": float(flips.mean()),
        "flips_within_tol": bool(np.all(near[flips])),
        "max_sel_delta": float(np.max(np.abs(sel_f - sel_q))),
        "sel_fp32": [float(v) for v in sel_f],
        "sel_quant": [float(v) for v in sel_q],
    }


# -------------------------------------------------------------- attention
def attention(q, k, v, *, causal=True):
    return flash_attention(q, k, v, causal=causal, interpret=interpret_default())


# ------------------------------------------------------------------- SSD
def ssd(x, dt, A_log, B, C, D, chunk: int):
    """Full SSD forward built on the chunk kernel + jnp inter-chunk scan.

    Same signature/semantics as models.ssm.ssd_chunked (b, s, h, p)...
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = s // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = dt.astype(jnp.float32) * A[None, None, :]
    xdt = (x * dt[..., None].astype(x.dtype)).reshape(b, nc, chunk, h, p)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).reshape(b, nc, chunk, h, n)
    Ch = jnp.repeat(C, rep, axis=2).reshape(b, nc, chunk, h, n)
    dAc = dA.reshape(b, nc, chunk, h)

    def per_batch(args):
        xb, dab, bb, cb = args
        return ssd_chunk(xb, dab, bb, cb, interpret=interpret_default())

    # vmap over batch: kernel grid covers (nc*h); batch handled by vmap
    y_diag, states, chunk_decay = jax.vmap(
        lambda xb, dab, bb, cb: ssd_chunk(xb, dab, bb, cb, interpret=interpret_default())
    )(xdt, dAc, Bh, Ch)
    # inter-chunk recurrence (nc steps, tiny)
    def scan_body(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry

    from jax import lax

    final, prev = lax.scan(
        scan_body,
        jnp.zeros((b, h, p, n), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev = prev.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)
    cum = jnp.cumsum(dAc.transpose(0, 3, 1, 2), axis=-1)  # (b, h, nc, Q)
    state_decay_out = jnp.exp(cum)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Ch.astype(jnp.float32), prev, state_decay_out)
    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final
