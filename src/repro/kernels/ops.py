"""Jit'd wrappers around the Pallas kernels.

On TPU the kernels run compiled (interpret=False); in this CPU container
they run in interpret mode, which executes the kernel body in Python for
correctness validation — the BlockSpec tiling is identical either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention
from repro.kernels.proxy_score import proxy_score
from repro.kernels.ssd_scan import ssd_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    return not _on_tpu()


# ----------------------------------------------------------- proxy scoring
def fold_standardizer(params):
    """Fold (x - mean)/scale into (w, b): the kernel then applies a single
    affine map.  params: LinearParams."""
    w = np.asarray(params.w) / np.asarray(params.scale)
    b = float(params.b) - float(np.asarray(params.mean) @ w)
    return w.astype(np.float32), np.float32(b)


def proxy_score_batch(params, x, threshold: float):
    """Single-proxy convenience used by the executor: returns keep mask."""
    w, b = fold_standardizer(params)
    _scores, mask = proxy_score(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(w)[:, None],
        jnp.asarray([b]),
        jnp.asarray([threshold], jnp.float32),
        interpret=interpret_default(),
    )
    return np.asarray(mask[:, 0])


def proxy_score_multi(param_list, x, thresholds):
    """Score several linear proxies in ONE fused pass (the serving engine
    evaluates a cascade's proxies together when profitable)."""
    ws, bs = zip(*(fold_standardizer(p) for p in param_list))
    w = jnp.stack([jnp.asarray(w) for w in ws], axis=1)  # (F, P)
    b = jnp.asarray(bs)
    scores, mask = proxy_score(
        jnp.asarray(x, jnp.float32), w, b, jnp.asarray(thresholds, jnp.float32),
        interpret=interpret_default(),
    )
    return np.asarray(scores), np.asarray(mask)


# -------------------------------------------------------------- attention
def attention(q, k, v, *, causal=True):
    return flash_attention(q, k, v, causal=causal, interpret=interpret_default())


# ------------------------------------------------------------------- SSD
def ssd(x, dt, A_log, B, C, D, chunk: int):
    """Full SSD forward built on the chunk kernel + jnp inter-chunk scan.

    Same signature/semantics as models.ssm.ssd_chunked (b, s, h, p)...
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = s // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = dt.astype(jnp.float32) * A[None, None, :]
    xdt = (x * dt[..., None].astype(x.dtype)).reshape(b, nc, chunk, h, p)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).reshape(b, nc, chunk, h, n)
    Ch = jnp.repeat(C, rep, axis=2).reshape(b, nc, chunk, h, n)
    dAc = dA.reshape(b, nc, chunk, h)

    def per_batch(args):
        xb, dab, bb, cb = args
        return ssd_chunk(xb, dab, bb, cb, interpret=interpret_default())

    # vmap over batch: kernel grid covers (nc*h); batch handled by vmap
    y_diag, states, chunk_decay = jax.vmap(
        lambda xb, dab, bb, cb: ssd_chunk(xb, dab, bb, cb, interpret=interpret_default())
    )(xdt, dAc, Bh, Ch)
    # inter-chunk recurrence (nc steps, tiny)
    def scan_body(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry

    from jax import lax

    final, prev = lax.scan(
        scan_body,
        jnp.zeros((b, h, p, n), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev = prev.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)
    cum = jnp.cumsum(dAc.transpose(0, 3, 1, 2), axis=-1)  # (b, h, nc, Q)
    state_decay_out = jnp.exp(cum)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Ch.astype(jnp.float32), prev, state_decay_out)
    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final
