"""Jit'd wrappers around the Pallas kernels.

On TPU the kernels run compiled (interpret=False); in this CPU container
they run in interpret mode, which executes the kernel body in Python for
correctness validation — the BlockSpec tiling is identical either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention
from repro.kernels.proxy_score import cascade_score
from repro.kernels.ssd_scan import ssd_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    return not _on_tpu()


# ----------------------------------------------------------- proxy scoring
def fold_standardizer(params):
    """Fold (x - mean)/scale into (w, b): the kernel then applies a single
    affine map.  params: LinearParams.  (Kept as the linear parity oracle's
    fold; execution paths go through the family packers.)"""
    w = np.asarray(params.w) / np.asarray(params.scale)
    b = float(params.b) - float(np.asarray(params.mean) @ w)
    return w.astype(np.float32), np.float32(b)


# Packing (standardizer fold + lowering to the depth-1 MLP form) is pure
# per parameter set, so memoize by object identity.  The cache holds a
# strong reference to the params, which keeps each id() valid for the
# lifetime of its entry; size-bounded FIFO eviction caps memory.
_PACK_CACHE: dict = {}
_PACK_CACHE_MAX = 512


def pack_proxy_cached(params):
    """Memoized ``family_of(params).pack``: repeated scoring of the same
    proxy (every microbatch of every stage) packs once."""
    from repro.core.proxy_family import family_of

    key = id(params)
    hit = _PACK_CACHE.get(key)
    if hit is not None and hit[0] is params:
        return hit[1]
    packed = family_of(params).pack(params)
    if len(_PACK_CACHE) >= _PACK_CACHE_MAX:
        _PACK_CACHE.pop(next(iter(_PACK_CACHE)))
    _PACK_CACHE[key] = (params, packed)
    return packed


_OPERAND_CACHE: dict = {}


def _kernel_operands_cached(params):
    """Device-resident (w1, b1, w2, b2) for a single proxy, memoized on
    params identity — the per-stage path packs and uploads once, not per
    microbatch."""
    from repro.core.proxy_family import cascade_kernel_operands, pack_cascade

    key = id(params)
    hit = _OPERAND_CACHE.get(key)
    if hit is not None and hit[0] is params:
        return hit[1]
    ops = tuple(jnp.asarray(a) for a in cascade_kernel_operands(
        pack_cascade([params], pack_fn=pack_proxy_cached)))
    if len(_OPERAND_CACHE) >= _PACK_CACHE_MAX:
        _OPERAND_CACHE.pop(next(iter(_OPERAND_CACHE)))
    _OPERAND_CACHE[key] = (params, ops)
    return ops


def proxy_score_batch(params, x, threshold: float):
    """Single-proxy convenience used by the per-stage kernel path: returns
    the keep mask.  Family-agnostic — params may be any registered family's."""
    w1, b1, w2, b2 = _kernel_operands_cached(params)
    _scores, mask, _pk, _cnt = cascade_score(
        jnp.asarray(x, jnp.float32), w1, b1, w2, b2,
        jnp.asarray([threshold], jnp.float32), x.shape[0],
        interpret=interpret_default(), with_scores=False,
        with_compaction=False,
    )
    return np.asarray(mask[:, 0])


class CascadeScorer:
    """Whole-cascade fused scorer (DESIGN.md §3), every proxy family.

    Packs every stage's params ONCE at construction ("plan-compile time")
    via the family registry — standardizers folded, each stage lowered to
    the packed depth-1 MLP form, the whole cascade stacked into
    bucket-padded ``(F, H, P)`` tensors kept on device — and scores record
    tiles through the fused two-pass ``cascade_score`` Pallas kernel: one
    launch yields every stage's keep mask plus on-device-compacted
    survivor index lists, for linear, MLP, and mixed cascades alike.

    Input batches are bucket-padded to a small geometric ladder of static
    shapes so ``jax.jit`` traces a handful of programs total instead of one
    per survivor count; batches larger than the top bucket are chunked.
    """

    def __init__(self, param_list, thresholds, *, block_m: int = None,
                 interpret=None, max_tile: int = 8192):
        from repro.core.proxy_family import cascade_kernel_operands, pack_cascade

        if not param_list:
            raise ValueError("CascadeScorer needs at least one proxy")
        self.packed = pack_cascade(list(param_list), pack_fn=pack_proxy_cached)
        w1, b1, w2, b2 = cascade_kernel_operands(self.packed)
        self.w1 = jnp.asarray(w1)  # (F, H*P) stacked hidden weights
        self.b1 = jnp.asarray(b1)
        self.w2 = jnp.asarray(w2)  # (H*P, P) block-diagonal readout
        self.b2 = jnp.asarray(b2)
        self.thr = jnp.asarray(np.asarray(thresholds, np.float32))
        self.families = self.packed.families
        self.n_proxies = len(param_list)
        self.n_features = int(self.w1.shape[0])
        if block_m is None:
            # auto: biggest block whose per-row VMEM footprint fits an
            # ~8MB budget (half a TPU core's VMEM; the rest covers the
            # stacked weights + double buffering) — fewer, larger blocks
            # amortize per-block launch overhead.  The footprint counts
            # the x tile, the (block_m, HPp) relu intermediate the
            # two-pass kernel materializes, and the padded score/mask/
            # compaction output columns.
            hpp = -(-(self.w1.shape[1]) // 128) * 128
            pp = -(-self.n_proxies // 128) * 128
            per_row = 4 * (self.n_features + hpp) + 9 * pp  # bytes (f32 + bool)
            budget_rows = (8 << 20) // per_row
            block_m = 256  # largest power of two within budget: tiles the
            while block_m * 2 <= min(budget_rows, max_tile):  # usual 2^k
                block_m *= 2  # batch sizes without row padding
        self.block_m = min(block_m, max_tile)
        self.interpret = interpret_default() if interpret is None else interpret
        buckets = []
        size = self.block_m
        while size < max_tile:
            buckets.append(size)
            size *= 2
        buckets.append(max_tile)
        self.buckets = tuple(buckets)
        self.max_tile = max_tile
        # stage index -> proxy column (filled by from_plan; identity default)
        self.stage_cols = list(range(self.n_proxies))

    @classmethod
    def from_plan(cls, plan, **kw):
        """Build a scorer over ALL of the plan's proxied stages (any
        family).  Returns None only when no stage carries a proxy.
        ``scorer.stage_cols[si]`` maps stage index to its proxy column, or
        None for proxy-less stages.
        """
        params, thrs, cols = [], [], []
        for stage in plan.stages:
            if stage.proxy is not None:
                cols.append(len(params))
                params.append(stage.proxy.params)
                thrs.append(stage.threshold)
            else:
                cols.append(None)
        if not params:
            return None
        scorer = cls(params, thrs, **kw)
        scorer.stage_cols = cols
        return scorer

    def covers_all(self, plan) -> bool:
        """Every proxied stage has a column — trivially true since the
        packed format covers every registered family; kept as an API
        invariant check."""
        return all(
            col is not None
            for col, stage in zip(self.stage_cols, plan.stages)
            if stage.proxy is not None
        )

    def _bucket(self, n: int) -> int:
        for size in self.buckets:
            if n <= size:
                return size
        return self.max_tile

    def _pad_tile(self, x_tile: np.ndarray) -> np.ndarray:
        n = x_tile.shape[0]
        bucket = self._bucket(n)
        if n < bucket:  # bucket-pad: static shape -> no retrace
            xp = np.zeros((bucket, x_tile.shape[1]), np.float32)
            xp[:n] = x_tile
            return xp
        return np.ascontiguousarray(x_tile, np.float32)

    def _score_tile(self, x_tile: np.ndarray, need_scores: bool,
                    need_compaction: bool = True, compact_cols=None):
        n = x_tile.shape[0]
        scores, mask, packed, counts = cascade_score(
            jnp.asarray(self._pad_tile(x_tile)), self.w1, self.b1,
            self.w2, self.b2, self.thr, n,
            block_m=self.block_m, interpret=self.interpret,
            with_scores=need_scores, with_compaction=need_compaction,
            compact_cols=compact_cols,
        )
        return (np.asarray(scores[:n]) if need_scores else None,
                np.asarray(mask[:n]),
                np.asarray(packed) if need_compaction else None,
                np.asarray(counts) if need_compaction else None)

    def score_compact(self, x: np.ndarray, *, need_scores: bool = False,
                      compact_cols=None):
        """Score every stage over ``x`` (N, F) in one fused pass per tile.

        Returns (scores (N, P) | None, masks (N, P), packed, counts) where
        ``packed[p][:counts[p]]`` are the ascending row indices surviving
        stage p's proxy gate (dense UDF batch order).  ``scores`` is only
        fetched off device when ``need_scores`` (the engines gate on masks).

        ``compact_cols`` restricts survivor-list assembly to the named
        proxy columns (the executor only consumes the first full-tile
        stage's list); unassembled entries of ``packed`` are None.  The
        per-stage survivor ``counts`` cover every column either way.
        """
        x = np.asarray(x, np.float32)
        n = x.shape[0]
        cols_sel = (tuple(range(self.n_proxies)) if compact_cols is None
                    else tuple(int(c) for c in compact_cols))
        kernel_cols = None if compact_cols is None else cols_sel
        if n <= self.max_tile:
            scores, masks, packed, counts = self._score_tile(
                x, need_scores, compact_cols=kernel_cols)
            out = [None] * self.n_proxies
            for ci, col in enumerate(cols_sel):
                out[col] = packed[ci, :counts[col]]
            return scores, masks, out, counts
        scores = np.empty((n, self.n_proxies), np.float32) if need_scores else None
        masks = np.empty((n, self.n_proxies), bool)
        parts = {col: [] for col in cols_sel}
        counts = np.zeros(self.n_proxies, np.int32)
        for start in range(0, n, self.max_tile):
            stop = min(start + self.max_tile, n)
            s, m, pk, cnt = self._score_tile(
                x[start:stop], need_scores, compact_cols=kernel_cols)
            if need_scores:
                scores[start:stop] = s
            masks[start:stop] = m
            counts += cnt
            for ci, col in enumerate(cols_sel):
                parts[col].append(pk[ci, :cnt[col]] + start)
        packed = [None] * self.n_proxies
        for col in cols_sel:
            packed[col] = (np.concatenate(parts[col]) if parts[col]
                           else np.empty(0, np.int32))
        return scores, masks, packed, counts

    def score_masks(self, x: np.ndarray) -> np.ndarray:
        """Per-stage keep masks only (N, P): skips the compaction outputs
        and their device round-trips — the serving engine's submit-time
        path gates on mask rows alone."""
        x = np.asarray(x, np.float32)
        n = x.shape[0]
        masks = np.empty((n, self.n_proxies), bool)
        for start in range(0, n, self.max_tile):
            stop = min(start + self.max_tile, n)
            _s, mask, _pk, _cnt = self._score_tile(
                x[start:stop], need_scores=False, need_compaction=False)
            masks[start:stop] = mask
        return masks

    def score_margins(self, x: np.ndarray):
        """Masks (N, P) plus per-record distance to the NEAREST stage
        threshold (N,) — the importance-audit weight signal (records near
        any proxy decision boundary are the ones whose audited labels are
        most informative).  The min-|score - thr| reduction runs on
        device, so only an (N,) vector is fetched instead of the full
        (N, P) score matrix.  The kernel does write its (N, Pp) score
        output to HBM for this path — an in-kernel margin output could
        not be narrower anyway (TPU outputs are 128-lane minimum, the
        same width as the score tile for P <= 128), and the extra
        ~512 B/row is <0.1% of HBM bandwidth at full serving rate."""
        x = np.asarray(x, np.float32)
        n = x.shape[0]
        masks = np.empty((n, self.n_proxies), bool)
        margins = np.empty(n, np.float32)
        for start in range(0, n, self.max_tile):
            stop = min(start + self.max_tile, n)
            tile = x[start:stop]
            m = tile.shape[0]
            scores, mask, _pk, _cnt = cascade_score(
                jnp.asarray(self._pad_tile(tile)), self.w1, self.b1,
                self.w2, self.b2, self.thr, m,
                block_m=self.block_m, interpret=self.interpret,
                with_scores=True, with_compaction=False,
            )
            masks[start:stop] = np.asarray(mask[:m])
            margins[start:stop] = np.asarray(
                jnp.min(jnp.abs(scores[:m] - self.thr[None, :]), axis=1))
        return masks, margins


# --------------------------------------------- scorer compile cache (serving)
# The adaptive server hot-swaps plans mid-stream and can oscillate between
# plan versions; each CascadeScorer carries packed weights + jit programs,
# so re-entering a previously compiled plan version must be a cache hit,
# not a repack + retrace.  Keyed on the packed-param identity of every
# stage — (family, params id, threshold) — so MLP-bearing plan swaps are
# cache hits exactly like linear ones; values hold strong refs to the
# params so ids stay valid.
_SCORER_CACHE: dict = {}
_SCORER_CACHE_MAX = 64


def _plan_scorer_key(plan, max_tile: int):
    from repro.core.proxy_family import family_of

    return tuple(
        (s.pred_idx,
         family_of(s.proxy.params).name if s.proxy is not None else None,
         id(s.proxy.params) if s.proxy is not None else None,
         float(s.threshold))
        for s in plan.stages
    ) + (int(max_tile),)


def cascade_scorer_for_plan(plan, *, max_tile: int = 8192):
    """Memoized ``CascadeScorer.from_plan``.

    Returns (scorer | None, cache_hit).  None means the plan has no
    proxied stage at all (nothing to fuse) — that outcome is cached too.
    """
    key = _plan_scorer_key(plan, max_tile)
    params_now = tuple(
        s.proxy.params if s.proxy is not None else None for s in plan.stages)
    hit = _SCORER_CACHE.get(key)
    if hit is not None and len(hit[0]) == len(params_now) and all(
            a is b for a, b in zip(hit[0], params_now)):
        return hit[1], True
    scorer = CascadeScorer.from_plan(plan, max_tile=max_tile)
    if len(_SCORER_CACHE) >= _SCORER_CACHE_MAX:
        _SCORER_CACHE.pop(next(iter(_SCORER_CACHE)))
    _SCORER_CACHE[key] = (params_now, scorer)
    return scorer, False


# -------------------------------------------------------------- attention
def attention(q, k, v, *, causal=True):
    return flash_attention(q, k, v, causal=causal, interpret=interpret_default())


# ------------------------------------------------------------------- SSD
def ssd(x, dt, A_log, B, C, D, chunk: int):
    """Full SSD forward built on the chunk kernel + jnp inter-chunk scan.

    Same signature/semantics as models.ssm.ssd_chunked (b, s, h, p)...
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = s // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = dt.astype(jnp.float32) * A[None, None, :]
    xdt = (x * dt[..., None].astype(x.dtype)).reshape(b, nc, chunk, h, p)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).reshape(b, nc, chunk, h, n)
    Ch = jnp.repeat(C, rep, axis=2).reshape(b, nc, chunk, h, n)
    dAc = dA.reshape(b, nc, chunk, h)

    def per_batch(args):
        xb, dab, bb, cb = args
        return ssd_chunk(xb, dab, bb, cb, interpret=interpret_default())

    # vmap over batch: kernel grid covers (nc*h); batch handled by vmap
    y_diag, states, chunk_decay = jax.vmap(
        lambda xb, dab, bb, cb: ssd_chunk(xb, dab, bb, cb, interpret=interpret_default())
    )(xdt, dAc, Bh, Ch)
    # inter-chunk recurrence (nc steps, tiny)
    def scan_body(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry

    from jax import lax

    final, prev = lax.scan(
        scan_body,
        jnp.zeros((b, h, p, n), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev = prev.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)
    cum = jnp.cumsum(dAc.transpose(0, 3, 1, 2), axis=-1)  # (b, h, nc, Q)
    state_decay_out = jnp.exp(cum)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Ch.astype(jnp.float32), prev, state_decay_out)
    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final
