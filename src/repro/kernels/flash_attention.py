"""Blockwise (flash) causal attention kernel for prefill, with GQA.

Online-softmax over KV blocks: running row-max and row-sum live in VMEM
scratch; the (Sq, Sk) score matrix is never materialized in HBM.  Block
shapes are (block_q, D) x (block_k, D) with D the head dim (128/256 —
MXU-aligned).  Grid: (batch*q_heads, Sq / block_q); the kv-block loop is a
``lax.fori_loop`` inside the kernel, bounded by the causal frontier.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, sk, scale, causal):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale  # (block_q, D)
    D = q.shape[-1]

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, D), jnp.float32)

    q_start = qi * block_q
    n_kv = sk // block_k
    if causal:
        # only kv blocks whose start <= last q position
        n_kv = jnp.minimum(n_kv, (q_start + block_q + block_k - 1) // block_k)

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.astype(jnp.float32).T, preferred_element_type=jnp.float32)
        if causal:
            qpos = q_start + jnp.arange(block_q)
            kpos = j * block_k + jnp.arange(block_k)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m, l, acc = lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret", "scale")
)
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D), H % K == 0.  Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)

    # layout: fold batch and heads into the grid's leading dim
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, D)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, D)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, sk=Sk, scale=scale, causal=causal
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Sk, D), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, Sk, D), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
