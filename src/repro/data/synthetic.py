"""Synthetic correlated record streams (Twitter / COCO / UCF101 stand-ins).

The container is offline, so we plant the experimental variable — predicate
correlation — explicitly:

* latent ``z ~ N(0, I_k)`` per record;
* features ``x = tanh(W z + eps)`` (the "unstructured content");
* each predicate column's ground truth is a quantized linear readout of z:
  ``y_j = digitize(w_j . z + eta)``.  Correlation between predicates i and j
  is controlled by the angle between w_i and w_j (shared latent directions),
  mirroring "sentiment varies by state".

The expensive ML UDFs are then *trained* (tiny JAX models) to predict y_j
from x — the UDF output defines the predicate truth at query time, exactly
as in the paper (proxies approximate UDFs, not the latent).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.query import MLUDF, Predicate, Query


@dataclass
class Dataset:
    name: str
    x: np.ndarray  # (N, F) features
    truth: np.ndarray  # (N, K) ground-truth label columns (latent readouts)
    directions: np.ndarray  # (K, k) latent readout directions
    n_classes: Sequence[int]
    # generative parameters, kept so drifted continuations of the SAME
    # process can be sampled later (make_drifting_stream)
    w_feat: Optional[np.ndarray] = None  # (k, F) latent -> feature map
    quantiles: Optional[List[np.ndarray]] = None  # per-column class bounds
    feature_noise: float = 0.8
    label_noise: float = 0.1

    @property
    def n(self) -> int:
        return self.x.shape[0]


def make_dataset(
    name: str = "twitter",
    n: int = 50_000,
    n_features: int = 64,
    n_latent: int = 16,
    n_columns: int = 4,
    n_classes: int = 4,
    correlation: float = 0.8,
    label_noise: float = 0.1,
    feature_noise: float = 0.8,
    seed: int = 0,
) -> Dataset:
    """``correlation`` in [0,1]: cosine overlap between consecutive predicate
    readout directions (1.0 -> nearly identical latent factors).
    ``feature_noise`` controls how hard the proxy task is: the paper's linear
    SVMs on text features are imperfect classifiers, which is what makes the
    accuracy->reduction trade-off (Fig. 4) non-degenerate."""
    rng = np.random.RandomState(seed)
    z = rng.randn(n, n_latent).astype(np.float32)
    W = rng.randn(n_latent, n_features).astype(np.float32) / np.sqrt(n_latent)
    x = np.tanh(z @ W + feature_noise * rng.randn(n, n_features).astype(np.float32))

    dirs = np.empty((n_columns, n_latent), np.float32)
    base = rng.randn(n_latent)
    base /= np.linalg.norm(base)
    for j in range(n_columns):
        fresh = rng.randn(n_latent)
        fresh /= np.linalg.norm(fresh)
        # orthogonalize fresh against base, then mix
        fresh = fresh - (fresh @ base) * base
        fresh /= np.linalg.norm(fresh) + 1e-9
        d = correlation * base + np.sqrt(max(1 - correlation**2, 0.0)) * fresh
        dirs[j] = d / np.linalg.norm(d)

    truth = np.empty((n, n_columns), np.int64)
    classes = []
    quantiles = []
    for j in range(n_columns):
        score = z @ dirs[j] + label_noise * rng.randn(n).astype(np.float32)
        qs = np.quantile(score, np.linspace(0, 1, n_classes + 1)[1:-1])
        truth[:, j] = np.digitize(score, qs)
        classes.append(n_classes)
        quantiles.append(qs)
    return Dataset(name=name, x=x, truth=truth, directions=dirs, n_classes=classes,
                   w_feat=W, quantiles=quantiles, feature_noise=feature_noise,
                   label_noise=label_noise)


# ------------------------------------------------------------- drift streams
@dataclass
class DriftingStream:
    """A record stream whose generative distribution shifts mid-run.

    ``x[:boundary]`` comes from the SAME process as the source dataset
    (so a plan optimized on ``ds`` samples is initially well-calibrated);
    ``x[boundary:]`` is drawn after a latent distribution shift.  The
    UDFs trained on ``ds`` still apply unchanged — the drift lives in the
    data, so what shifts at query time is the distribution of UDF
    *outputs*: per-predicate selectivities and predicate-event
    correlations, exactly the statistics a frozen plan goes stale on.
    """

    x: np.ndarray  # (n_before + n_after, F)
    boundary: int  # first row of the drifted segment
    truth: np.ndarray  # (N, K) latent-readout ground truth (reference only)
    meta: dict = field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.x.shape[0]


def make_drifting_stream(
    ds: Dataset,
    n_before: int,
    n_after: int,
    *,
    shift: float = 1.5,
    shift_dirs: Sequence[int] = (0,),
    shift_weights: Optional[Sequence[float]] = None,
    shift_targets: Optional[Dict[int, float]] = None,
    corr_gain: float = 1.0,
    seed: int = 0,
) -> DriftingStream:
    """Sample a two-segment stream from ``ds``'s generative process.

    Drift knobs (applied to the second segment's latent ``z``):

    * ``shift`` — the latent mean moves ``shift`` units along the
      (normalized) weighted sum of the readout directions named by
      ``shift_dirs`` (weights default to 1; negative weights push a
      predicate's readout DOWN): those predicates' class masses slide
      across the (frozen) quantile boundaries, i.e. **selectivity
      drift**.  Opposite-signed weights move correlated predicates in
      opposite directions — the plan-order-inverting case.
    * ``shift_targets`` — {column: desired readout-mean shift}.  Solves
      ``D mu = t`` by pseudo-inverse, so each named predicate's latent
      readout moves by EXACTLY the requested amount even when the
      directions are strongly correlated (a normalized direction sum
      cannot move correlated predicates independently — the common
      component dominates).  Overrides ``shift`` / ``shift_dirs``.
    * ``corr_gain`` — latent variance along the bisector of the first two
      readout directions is scaled by ``corr_gain``; since the covariance
      between readouts i and j under anisotropic z is d_i^T Sigma d_j,
      this changes their co-occurrence structure, i.e. **correlation
      drift** (a pure rotation would not — isotropic Gaussians are
      rotation-invariant).
    """
    if ds.w_feat is None or ds.quantiles is None:
        raise ValueError("dataset lacks generative parameters; rebuild with "
                         "make_dataset from this revision")
    rng = np.random.RandomState(seed + 7919)
    k = ds.directions.shape[1]
    n_features = ds.w_feat.shape[1]

    def sample(n: int, drifted: bool):
        z = rng.randn(n, k).astype(np.float32)
        if drifted:
            if corr_gain != 1.0 and ds.directions.shape[0] >= 2:
                u = ds.directions[0] + ds.directions[1]
                u = u / (np.linalg.norm(u) + 1e-9)
                z = z + (corr_gain - 1.0) * (z @ u)[:, None] * u[None, :]
            if shift_targets:
                cols = sorted(shift_targets)
                D = ds.directions[cols]  # (m, k)
                t = np.asarray([shift_targets[c] for c in cols], np.float64)
                mu, *_ = np.linalg.lstsq(D, t, rcond=None)
                z = z + mu.astype(np.float32)[None, :]
            else:
                weights = ([1.0] * len(shift_dirs) if shift_weights is None
                           else list(shift_weights))
                mu = np.zeros(k, np.float32)
                for d, wgt in zip(shift_dirs, weights):
                    mu += np.float32(wgt) * ds.directions[d]
                nrm = np.linalg.norm(mu)
                if nrm > 0:
                    z = z + shift * (mu / nrm)[None, :]
        x = np.tanh(z @ ds.w_feat
                    + ds.feature_noise * rng.randn(n, n_features).astype(np.float32))
        truth = np.empty((n, ds.directions.shape[0]), np.int64)
        for j in range(ds.directions.shape[0]):
            score = z @ ds.directions[j] + ds.label_noise * rng.randn(n).astype(np.float32)
            truth[:, j] = np.digitize(score, ds.quantiles[j])
        return x.astype(np.float32), truth

    x1, t1 = sample(n_before, False)
    x2, t2 = sample(n_after, True)
    return DriftingStream(
        x=np.concatenate([x1, x2]), boundary=n_before,
        truth=np.concatenate([t1, t2]),
        meta={"shift": shift, "shift_dirs": tuple(shift_dirs),
              "shift_weights": None if shift_weights is None else tuple(shift_weights),
              "shift_targets": dict(shift_targets) if shift_targets else None,
              "corr_gain": corr_gain, "seed": seed},
    )


def make_sharded_drifting_streams(
    ds: Dataset,
    n_hosts: int,
    n_before: int,
    n_after: int,
    *,
    shift_targets: Dict[int, float],
    corr_gain: float = 1.0,
    drift_skew: float = 0.3,
    boundary_jitter: float = 0.0,
    shift: float = 1.5,
    skew_corr: bool = False,
    seed: int = 0,
) -> List[DriftingStream]:
    """Per-host drifting shards of the SAME underlying population drift —
    the multi-host serving workload (DESIGN.md §6).

    Every shard drifts in the same direction, but the magnitude each host
    observes is skewed: host k's shift targets are scaled by
    ``1 + drift_skew * g_k`` with ``g_k`` spread symmetrically in
    [-1, 1] (and each shard gets its own sampling seed).  That is exactly
    why a per-host swap decision is statistically noisy — the lightly-hit
    shards' detectors fire late or not at all — and what the quorum vote
    averages over.  ``boundary_jitter`` additionally staggers each
    shard's drift onset by up to that fraction of ``n_before``
    (de-synchronized detection, the harder consensus case).

    ``n_before`` / ``n_after`` are PER-SHARD lengths; shards are disjoint
    samples (per-shard seeds), as if a load balancer hash-partitioned one
    stream.

    A **correlation-only** fleet drift (the cross-host kappa² pooling
    workload, DESIGN.md §6) is ``shift_targets={}`` with ``shift=0.0``
    and ``corr_gain > 1``: no predicate's marginal selectivity moves, so
    per-host detectors have nothing loud to fire on, while the label
    co-occurrence structure shifts everywhere.  ``skew_corr=True``
    additionally spreads the correlation magnitude across shards with
    the same ``drift_skew`` scaling used for selectivity targets.
    """
    if n_hosts < 1:
        raise ValueError("n_hosts must be >= 1")
    rng = np.random.RandomState(seed + 104729)
    gains = (np.linspace(-1.0, 1.0, n_hosts) if n_hosts > 1
             else np.zeros(1))
    streams = []
    for k in range(n_hosts):
        scale = 1.0 + drift_skew * float(gains[k])
        targets_k = {c: t * scale for c, t in shift_targets.items()}
        gain_k = (1.0 + (corr_gain - 1.0) * scale if skew_corr
                  else corr_gain)
        jitter = int(boundary_jitter * n_before * (rng.random_sample() - 0.5) * 2)
        nb = max(1, n_before + jitter)
        stream = make_drifting_stream(
            ds, nb, n_after + (n_before - nb),
            shift_targets=targets_k, corr_gain=gain_k,
            shift=shift * scale, seed=seed + 7 * k + 1,
        )
        stream.meta["host"] = k
        stream.meta["drift_scale"] = scale
        stream.meta["corr_gain"] = gain_k
        streams.append(stream)
    return streams


# --------------------------------------------------------------------- UDFs
def _train_udf_model(x, y, n_classes: int, hidden: int, depth: int, seed: int,
                     steps: int = 400):
    """Train a small-but-real MLP classifier (the expensive UDF body)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, depth + 1)
    F = x.shape[1]
    dims = [F] + [hidden] * depth + [n_classes]
    params = [
        (jax.random.normal(ks[i], (dims[i], dims[i + 1])) / jnp.sqrt(dims[i]),
         jnp.zeros(dims[i + 1]))
        for i in range(len(dims) - 1)
    ]

    def logits_fn(p, xx):
        h = xx
        for w, b in p[:-1]:
            h = jax.nn.relu(h @ w + b)
        w, b = p[-1]
        return h @ w + b

    xj = jnp.asarray(x)
    yj = jnp.asarray(y)

    def loss_fn(p):
        lg = logits_fn(p, xj)
        return jnp.mean(
            jax.nn.logsumexp(lg, axis=-1) - jnp.take_along_axis(lg, yj[:, None], 1)[:, 0]
        )

    @jax.jit
    def run(p0):
        def step(carry, _):
            p, m = carry
            g = jax.grad(loss_fn)(p)
            m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
            p = jax.tree.map(lambda pp, mm: pp - 0.05 * mm, p, m)
            return (p, m), None

        m0 = jax.tree.map(jnp.zeros_like, p0)
        (p, _), _ = jax.lax.scan(step, (p0, m0), None, length=steps)
        return p

    params = run(params)
    predict = jax.jit(lambda xx: jnp.argmax(logits_fn(params, xx), axis=-1))
    return params, predict, logits_fn


def make_udfs(
    ds: Dataset,
    *,
    hidden: int = 256,
    depth: int = 4,
    train_rows: int = 8_000,
    seed: int = 0,
    cost_scale: Dict[int, float] = None,
    declared_cost_ms: Optional[float] = None,
) -> List[MLUDF]:
    """Train one UDF per label column and profile its per-record cost.

    ``cost_scale``: optional per-column multiplier emulating heavier models
    (geotagger vs sentiment vs YOLO) by widening the body.
    ``declared_cost_ms``: override the profiled per-record cost in the COST
    MODEL (the paper's UDFs are 20ms+/record CPU NLP/YOLO models; our bodies
    are small JAX MLPs, so wall-profiled costs understate the proxy/UDF cost
    ratio by ~100x.  Declared costs restore the paper's regime for the
    cost-model metrics; wall-clock metrics always use real execution.)
    """
    udfs = []
    rng = np.random.RandomState(seed)
    idx = rng.choice(ds.n, min(train_rows, ds.n), replace=False)
    for j in range(ds.truth.shape[1]):
        scale = 1.0 if not cost_scale else cost_scale.get(j, 1.0)
        h = int(hidden * scale)
        _params, predict, _ = _train_udf_model(
            ds.x[idx], ds.truth[idx, j], ds.n_classes[j], h, depth, seed + j
        )
        # profile per-record cost (ms) on a jitted batch
        probe = jnp.asarray(ds.x[:2048])
        predict(probe).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            predict(probe).block_until_ready()
        per_record_ms = (time.perf_counter() - t0) / 3 / probe.shape[0] * 1e3

        def fn(xx, _predict=predict):
            return np.asarray(_predict(jnp.asarray(xx, jnp.float32)))

        acc = float(np.mean(fn(ds.x[idx]) == ds.truth[idx, j]))
        cost = per_record_ms if declared_cost_ms is None else declared_cost_ms * scale
        udfs.append(
            MLUDF(name=f"{ds.name}.udf{j}", fn=fn, cost=cost,
                  n_classes=ds.n_classes[j])
        )
        udfs[-1].train_accuracy = acc
    return udfs


def make_query(
    ds: Dataset,
    udfs: Sequence[MLUDF],
    *,
    columns: Sequence[int],
    target_selectivity: float = 0.4,
    accuracy_target: float = 0.9,
    align_positive: bool = True,
    seed: int = 0,
) -> Query:
    """Build a conjunctive query over ``columns`` whose per-predicate
    selectivity is ~``target_selectivity``.

    ``align_positive``: choose later predicates' value sets to be POSITIVELY
    associated with the conjunction of the earlier ones (the paper's
    "state='CA' AND sentiment=positive" scenario — correlated columns alone
    do not imply correlated predicate *events*; the lift ordering does)."""
    rng = np.random.RandomState(seed)
    sample = ds.x[: min(ds.n, 20_000)]
    preds = []
    prefix_mask = np.ones(sample.shape[0], bool)
    for j in columns:
        labels = udfs[j](sample)
        vals, counts = np.unique(labels, return_counts=True)
        fracs = counts / counts.sum()
        if align_positive and preds and prefix_mask.any():
            cond = np.asarray(
                [np.mean(labels[prefix_mask] == v) for v in vals]
            )
            lift = cond / np.maximum(fracs, 1e-9)
            order = np.argsort(-lift)  # most positively-associated first
        else:
            order = rng.permutation(len(vals))
        chosen, tot = [], 0.0
        for i in order:
            if tot >= target_selectivity:
                break
            chosen.append(int(vals[i]))
            tot += fracs[i]
        pred = Predicate(udf=udfs[j], values=frozenset(chosen))
        preds.append(pred)
        prefix_mask &= pred.evaluate(labels)
    return Query(predicates=preds, accuracy_target=accuracy_target)
