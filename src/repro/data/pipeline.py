"""Sharded, resumable streaming input pipeline.

Each host reads a disjoint shard of the record stream (host_id/num_hosts
striping), prefetches ahead of the device, and exposes a CURSOR that the
checkpointer persists — restart resumes mid-epoch with no duplicated or
dropped records (deterministic for a fixed seed).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class Cursor:
    epoch: int = 0
    position: int = 0  # index within this host's shard order

    def as_dict(self):
        return {"epoch": self.epoch, "position": self.position}

    @classmethod
    def from_dict(cls, d):
        return cls(epoch=int(d["epoch"]), position=int(d["position"]))


class ShardedStream:
    """Deterministic shuffled stream over an array-backed dataset."""

    def __init__(self, data: np.ndarray, *, host_id: int = 0, num_hosts: int = 1,
                 batch: int = 32, seed: int = 0, cursor: Optional[Cursor] = None):
        self.data = data
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.batch = batch
        self.seed = seed
        self.cursor = cursor or Cursor()

    def _shard_order(self, epoch: int) -> np.ndarray:
        rng = np.random.RandomState((self.seed, epoch))
        perm = rng.permutation(len(self.data))
        return perm[self.host_id :: self.num_hosts]

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            order = self._shard_order(self.cursor.epoch)
            while self.cursor.position + self.batch <= len(order):
                idx = order[self.cursor.position : self.cursor.position + self.batch]
                self.cursor.position += self.batch
                yield self.data[idx]
            self.cursor.epoch += 1
            self.cursor.position = 0


class Prefetcher:
    """Background-thread prefetch (depth-bounded) around any iterator."""

    _DONE = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.it = it
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self.it:
                self.q.put(item)
        finally:
            self.q.put(self._DONE)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._DONE:
            raise StopIteration
        return item
