"""Cost model: Eq. 3.1 execution cost and Lemma-4 bounds.

C(sigma_hat_i, alpha_i) = (prod_{j<i} s_j alpha_j) * (c_hat_i + (1-r_i) c_i)

All costs are per-raw-input-record (the prefix product converts stage-local
per-record cost into raw-input units).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence


def stage_cost(prefix_frac: float, proxy_cost: float, udf_cost: float,
               reduction: float) -> float:
    return prefix_frac * (proxy_cost + (1.0 - reduction) * udf_cost)


def plan_cost(alphas: Sequence[float], reductions: Sequence[float],
              selectivities: Sequence[float], proxy_costs: Sequence[float],
              udf_costs: Sequence[float]) -> float:
    total, prefix = 0.0, 1.0
    for a, r, s, ch, c in zip(alphas, reductions, selectivities, proxy_costs, udf_costs):
        total += stage_cost(prefix, ch, c, r)
        prefix *= s * a
    return total


@dataclass
class Bounds:
    lower: float
    upper: float

    def overlaps(self, other: "Bounds") -> bool:
        return self.lower <= other.upper and other.lower <= self.upper

    @property
    def mean(self) -> float:
        return 0.5 * (self.lower + self.upper)


def node_bounds(depth: int, accuracy_target: float, proxy_cost: float,
                udf_cost: float, *, known_prefix: float = None,
                s_bounds=(0.0, 1.0), r_bounds=(0.0, 1.0)) -> Bounds:
    """Lemma 4: lower bound uses alpha^l=A, s^l, r^u; upper uses alpha^u=1,
    s^u, r^l.  ``known_prefix`` fixes the prefix product when ancestors have
    been built (update_node tightening)."""
    A = accuracy_target
    s_l, s_u = s_bounds
    r_l, r_u = r_bounds
    if known_prefix is not None:
        lo_prefix = hi_prefix = known_prefix
    else:
        lo_prefix = (s_l * A) ** depth
        hi_prefix = (s_u * 1.0) ** depth
    lower = lo_prefix * (proxy_cost + (1.0 - r_u) * udf_cost)
    upper = hi_prefix * (proxy_cost + (1.0 - r_l) * udf_cost)
    return Bounds(lower, upper)
