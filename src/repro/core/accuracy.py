"""Algorithm 1: accuracy allocation for a fixed order pi.

Searches the discretized accuracy space {alpha : prod alpha_i = A} for the
allocation minimizing sum_i C(sigma-hat_i, alpha_i).  The objective is
non-convex (Lemma 1), so the default is exhaustive enumeration of the tight
frontier of the grid; ``framework="hill"`` swaps in hill-climbing (the
paper's §6.4 configuration).

Sample reuse and classifier reuse live in ``ProxyBuilder``; this module is
the search driver.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.builder import ProxyBuilder
from repro.core.proxy import ProxyModel
from repro.util import advisory_wall_ms



def alpha_frontier(n: int, A: float, step: float = 0.02) -> np.ndarray:
    """Enumerate near-tight allocations on the grid: prod in [A, A/(1-step)).

    Cost is non-decreasing in each alpha (reduction falls as accuracy rises,
    and downstream volume grows), so the optimum of {prod = A} lies on this
    shell of the discretized space.
    """
    grid = np.arange(A, 1.0 + 1e-9, step)
    if grid[-1] < 1.0 - 1e-9:
        grid = np.append(grid, 1.0)
    out: List[Tuple[float, ...]] = []
    hi = A / (1.0 - step)

    def rec(prefix: Tuple[float, ...], prod: float):
        if len(prefix) == n:
            if A - 1e-12 <= prod < hi:
                out.append(prefix)
            return
        remaining = n - len(prefix) - 1
        for a in grid:
            p = prod * a
            # prune: even all-1.0 suffix cannot reach A
            if p < A - 1e-12:
                continue
            # prune: even all-A suffix stays >= hi -> every completion too loose
            if p * (grid[0] ** remaining) >= hi:
                continue
            rec(prefix + (float(a),), p)

    rec((), 1.0)
    if not out:
        out = [tuple([float(grid[0])] * n)]
    return np.asarray(out)


@dataclass
class Allocation:
    order: Tuple[int, ...]
    alphas: Tuple[float, ...]
    proxies: List[ProxyModel]
    reductions: List[float]
    selectivities: List[float]
    stage_costs: List[float]
    total_cost: float


def _evaluate_allocation(
    builder: ProxyBuilder, order: Sequence[int], alphas: Sequence[float]
) -> Allocation:
    """Build/fetch proxies for this (order, alphas) and cost it (Eq. 3.1)."""
    proxies: List[ProxyModel] = []
    reductions, sels, costs = [], [], []
    total, prefix_frac = 0.0, 1.0
    prefix_pp: List[Tuple[ProxyModel, float]] = []
    for i, p in enumerate(order):
        proxy, rows = builder.get_proxy(p, order[:i], prefix_pp)
        r = proxy.r_curve.reduction_for(alphas[i])
        s = builder.selectivity(p, rows) if len(rows) else 1.0
        c_udf = builder.query.predicates[p].udf.cost
        stage = prefix_frac * (proxy.cost + (1.0 - r) * c_udf)
        total += stage
        prefix_frac *= s * alphas[i]
        proxies.append(proxy)
        reductions.append(r)
        sels.append(s)
        costs.append(stage)
        prefix_pp = prefix_pp + [(proxy, alphas[i])]
    return Allocation(tuple(order), tuple(float(a) for a in alphas), proxies,
                      reductions, sels, costs, total)


def accuracy_allocation(
    builder: ProxyBuilder,
    order: Sequence[int],
    A: float,
    *,
    step: float = 0.02,
    framework: str = "exhaustive",  # | "hill"
) -> Allocation:
    t0 = advisory_wall_ms()
    lt0 = builder.stats.labeling_ms + builder.stats.training_ms
    n = len(order)
    cands = alpha_frontier(n, A, step)
    best: Optional[Allocation] = None
    if framework == "exhaustive" or len(cands) <= 8:
        for alphas in cands:
            alloc = _evaluate_allocation(builder, order, alphas)
            if best is None or alloc.total_cost < best.total_cost:
                best = alloc
    else:
        # hill climbing from the balanced allocation
        balanced = np.full(n, A ** (1.0 / n))
        start = cands[np.argmin(np.abs(cands - balanced).sum(axis=1))]
        best = _evaluate_allocation(builder, order, start)
        improved = True
        visited = {tuple(start)}
        while improved:
            improved = False
            dists = np.abs(cands - np.asarray(best.alphas)).sum(axis=1)
            for alphas in cands[np.argsort(dists)[:2 * n + 1]]:
                key = tuple(alphas)
                if key in visited:
                    continue
                visited.add(key)
                alloc = _evaluate_allocation(builder, order, alphas)
                if alloc.total_cost < best.total_cost - 1e-12:
                    best = alloc
                    improved = True
                    break
    # search time excludes labeling/training accrued inside get_proxy
    elapsed = advisory_wall_ms() - t0
    lt_delta = builder.stats.labeling_ms + builder.stats.training_ms - lt0
    builder.stats.search_ms += max(elapsed - lt_delta, 0.0)
    return best
