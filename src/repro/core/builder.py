"""ProxyBuilder: the shared construction state for online proxy building.

Implements the two reuse mechanisms that make CORE's online optimization
cheap:

* **Sample reuse** (§4.3, Theorem 1): materialized samples ``L'`` are keyed
  by the *set* of prefix sigmas (commutativity makes order irrelevant), and
  UDF labeling is lazy + memoized per (predicate, row) — each expensive UDF
  runs at most once per sample row, across the entire search.
* **Classifier reuse** (§4.4, Eq. 4.7): trained classifiers are cached per
  (predicate, prefix-set) and reused when epsilon-approximate on the new
  labeled sample (F1 as the scoring function phi).

All labeling / training / search time is accounted in ``self.stats`` so the
Table-4/5 benchmarks can decompose optimization cost.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Optional, Sequence, Tuple

import numpy as np

from repro.core.proxy import ProxyModel, train_proxy
from repro.core.proxy_family import get_family
from repro.core.query import Query
from repro.training.proxy_models import f1_score
from repro.util import advisory_wall_ms



@dataclass
class BuilderStats:
    labeling_ms: float = 0.0
    training_ms: float = 0.0
    search_ms: float = 0.0
    udf_calls: Dict[int, int] = field(default_factory=dict)
    n_trained: int = 0
    n_reused: int = 0

    @property
    def qo_ms(self) -> float:
        return self.labeling_ms + self.training_ms + self.search_ms

    def as_dict(self):
        return {
            "labeling_ms": self.labeling_ms,
            "training_ms": self.training_ms,
            "search_ms": self.search_ms,
            "qo_ms": self.qo_ms,
            "udf_calls": dict(self.udf_calls),
            "n_trained": self.n_trained,
            "n_reused": self.n_reused,
        }


class ProxyBuilder:
    def __init__(self, query: Query, x_sample: np.ndarray, *, kind: str = "svm",
                 eps: float = 0.1, seed: int = 0, reuse_samples: bool = True,
                 reuse_classifiers: bool = True):
        """``reuse_samples=False`` / ``reuse_classifiers=False`` disable the
        paper's two reuse mechanisms (§4.3 / §4.4) — used by the ablation
        benchmark to quantify what each saves.

        ``kind`` selects the proxy family per predicate: a family name or
        alias ("svm"/"linear", "mlp"/"mlp1") applies to every predicate;
        "mixed" alternates linear / mlp1 by predicate index (the CLI's
        mixed-cascade exercise path); a ``{pred_idx: family}`` dict pins
        families explicitly (how ``reoptimize`` preserves an incumbent
        plan's exact per-predicate assignment)."""
        self.query = query
        self.x = np.asarray(x_sample, np.float32)
        self.n = self.x.shape[0]
        self.kind = kind
        self.eps = eps
        self.seed = seed
        self.reuse_samples = reuse_samples
        self.reuse_classifiers = reuse_classifiers
        self.stats = BuilderStats()
        # lazy UDF labels on the optimization sample
        self._labeled: Dict[int, np.ndarray] = {}  # pred -> bool "has label" per row
        self._labels: Dict[int, np.ndarray] = {}  # pred -> sigma bool per row
        # materialized sigma-filtered samples, keyed by frozenset of preds
        self._sigma_rows: Dict[FrozenSet[int], np.ndarray] = {frozenset(): np.arange(self.n)}
        # classifier cache: (pred, frozenset(prefix), family) ->
        # (ProxyModel, phi_star).  phi_star is the scorer's F1 on the
        # sample it was trained against, recorded at insert time, so the
        # Eq.-4.7 eps-approx test does not reference row indices of any
        # particular sample — the cache stays valid when transplanted onto
        # a fresh sample via ``rebase``.  Keying on the FAMILY (not just
        # the predicate) means a builder whose kind changed, or a mixed
        # cascade, never reuses a classifier across families.
        self._proxies: Dict[Tuple[int, FrozenSet[int], str], Tuple[ProxyModel, float]] = {}

    def family_for(self, pred_idx: int) -> str:
        """Canonical family name training predicate ``pred_idx``'s proxy."""
        if isinstance(self.kind, dict):
            return get_family(self.kind.get(pred_idx, "svm")).name
        if self.kind == "mixed":
            return "linear" if pred_idx % 2 == 0 else "mlp1"
        return get_family(self.kind).name

    # ------------------------------------------------------------- labeling
    def sigma_mask(self, pred_idx: int, rows: np.ndarray) -> np.ndarray:
        """Boolean sigma outcome for ``rows``, labeling lazily via the UDF."""
        if pred_idx not in self._labeled:
            self._labeled[pred_idx] = np.zeros(self.n, bool)
            self._labels[pred_idx] = np.zeros(self.n, bool)
        if not self.reuse_samples:
            # ablation: no materialization — every request re-runs the UDF
            pred = self.query.predicates[pred_idx]
            t0 = advisory_wall_ms()
            labels = pred.udf(self.x[rows])
            self.stats.labeling_ms += advisory_wall_ms() - t0
            self.stats.udf_calls[pred_idx] = self.stats.udf_calls.get(pred_idx, 0) + len(rows)
            return pred.evaluate(labels)
        need = rows[~self._labeled[pred_idx][rows]]
        if len(need):
            pred = self.query.predicates[pred_idx]
            t0 = advisory_wall_ms()
            labels = pred.udf(self.x[need])
            self.stats.labeling_ms += advisory_wall_ms() - t0
            self.stats.udf_calls[pred_idx] = self.stats.udf_calls.get(pred_idx, 0) + len(need)
            self._labels[pred_idx][need] = pred.evaluate(labels)
            self._labeled[pred_idx][need] = True
        return self._labels[pred_idx][rows]

    def rows_after_sigmas(self, prefix: Sequence[int]) -> np.ndarray:
        """Materialized L': sample rows passing the given sigma set.

        Theorem-1 commutativity lets us key by set; construction is greedy
        from the largest materialized subset."""
        if not self.reuse_samples:
            rows = np.arange(self.n)
            for p in prefix:
                rows = rows[self.sigma_mask(p, rows)]
            return rows
        key = frozenset(prefix)
        if key in self._sigma_rows:
            return self._sigma_rows[key]
        # find best materialized subset to extend
        best = frozenset()
        for k in self._sigma_rows:
            if k <= key and len(k) > len(best):
                best = k
        rows = self._sigma_rows[best]
        for p in key - best:
            rows = rows[self.sigma_mask(p, rows)]
            best = best | {p}
            self._sigma_rows[best] = rows
        return self._sigma_rows[key]

    # ------------------------------------------------------- proxy training
    def get_proxy(
        self,
        pred_idx: int,
        prefix: Sequence[int],
        prefix_proxies: Sequence[Tuple[ProxyModel, float]] = (),
    ) -> Tuple[ProxyModel, np.ndarray]:
        """Proxy for ``pred_idx`` with input relation d = (prefix sigma-hats
        + sigmas).  ``prefix_proxies``: [(proxy, alpha)] applied to refine L.
        Returns (proxy, rows of L used)."""
        rows = self.rows_after_sigmas(prefix)
        for proxy, alpha in prefix_proxies:
            if len(rows) == 0:
                break
            rows = rows[proxy.mask(self.x[rows], alpha)]
        family = self.family_for(pred_idx)
        key = (pred_idx, frozenset(prefix), family)
        labels = self.sigma_mask(pred_idx, rows)
        if key in self._proxies and self.reuse_classifiers:
            cached, phi_star = self._proxies[key]
            # epsilon-approx test (Eq. 4.7) with phi = F1 of the cached scorer
            y_new = np.where(labels, 1.0, -1.0)
            phi_new = f1_score(cached.score(self.x[rows]), y_new) if len(rows) else phi_star
            if abs(phi_new - phi_star) <= self.eps * max(phi_star, 1e-9):
                self.stats.n_reused += 1
                return cached, rows
        t0 = advisory_wall_ms()
        proxy = train_proxy(
            self.x[rows], labels, pred_idx, tuple(prefix), kind=family,
            seed=self.seed + pred_idx,
        )
        self.stats.training_ms += advisory_wall_ms() - t0
        self.stats.n_trained += 1
        y_here = np.where(labels, 1.0, -1.0)
        phi_star = f1_score(proxy.score(self.x[rows]), y_here) if len(rows) else 0.0
        self._proxies[key] = (proxy, phi_star)
        return proxy, rows

    # ----------------------------------------------------------- adaptivity
    def export_classifiers(
        self,
    ) -> Dict[Tuple[int, FrozenSet[int], str], Tuple[ProxyModel, float]]:
        """Snapshot of the trained-classifier cache for a cross-query
        transplant (the plan cache's warm start).  Keys are query-shape-
        relative (pred index within the query, prefix set, family), so a
        same-shaped future query can adopt them; the Eq.-4.7 eps-approx
        test re-validates every entry against the new query's labels
        before it is ever reused."""
        return dict(self._proxies)

    def adopt_classifiers(
        self,
        proxies: Dict[Tuple[int, FrozenSet[int], str], Tuple[ProxyModel, float]],
    ) -> None:
        """Transplant a donor builder's classifier cache (same mechanism
        ``rebase`` uses across samples, opened up across queries)."""
        self._proxies.update(proxies)

    def rebase(
        self,
        x_new: np.ndarray,
        *,
        known_sigma: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None,
    ) -> "ProxyBuilder":
        """Fresh builder over a new optimization sample (e.g. the serving
        reservoir), carrying the trained-classifier cache forward so the
        §4.4 eps-approx reuse test can skip retraining proxies that still
        fit the drifted data.

        ``known_sigma``: pred_idx -> (known_mask (M,), sigma (M,)) boolean
        arrays pre-seeding the lazy label cache — rows the serving loop
        already ran the UDF on (audit records) are never re-labeled.
        """
        nb = ProxyBuilder(
            self.query, x_new, kind=self.kind, eps=self.eps, seed=self.seed,
            reuse_samples=self.reuse_samples,
            reuse_classifiers=self.reuse_classifiers,
        )
        nb._proxies = dict(self._proxies)
        if known_sigma:
            nb.seed_labels(known_sigma)
        return nb

    def seed_labels(
        self, known_sigma: Dict[int, Tuple[np.ndarray, np.ndarray]]
    ) -> None:
        """Pre-populate the lazy UDF-label cache with sigma outcomes already
        observed elsewhere (e.g. serving audit records): pred_idx ->
        (known_mask (n,), sigma (n,)) over THIS builder's sample rows."""
        for p, (known, sigma) in known_sigma.items():
            known = np.asarray(known, bool)
            if known.shape[0] != self.n:
                raise ValueError(
                    f"known_sigma[{p}] has {known.shape[0]} rows, sample has {self.n}")
            self._labeled[p] = known.copy()
            self._labels[p] = np.asarray(sigma, bool) & known

    # ---------------------------------------------------------- measurement
    def selectivity(self, pred_idx: int, rows: np.ndarray) -> float:
        if len(rows) == 0:
            return 1.0
        return float(np.mean(self.sigma_mask(pred_idx, rows)))

    def conditional_rows(
        self, order: Sequence[int], alphas: Sequence[float],
        proxies: Sequence[ProxyModel], upto: int,
    ) -> np.ndarray:
        """Rows passing (sigma-hat_j AND sigma_j) for j < upto."""
        rows = np.arange(self.n)
        for j in range(upto):
            if len(rows) == 0:
                return rows
            rows = rows[proxies[j].mask(self.x[rows], alphas[j])]
            rows = rows[self.sigma_mask(order[j], rows)]
        return rows
