from repro.core.query import MLUDF, PhysicalPlan, PlanStage, Predicate, Query
from repro.core.proxy import ProxyModel, RCurve, build_r_curve, train_proxy
from repro.core.builder import ProxyBuilder
from repro.core.accuracy import accuracy_allocation, alpha_frontier
from repro.core.bnb import BranchAndBound
from repro.core.api import (
    CoreSession,
    OptimizeOptions,
    QueryHandle,
    ServeConfig,
    build_plan,
    rebuild_plan,
)
from repro.core.optimizer import optimize, reoptimize
from repro.core.plan_cache import PlanCache, QueryFingerprint, WarmStart, fingerprint_query
from repro.core.baselines import ns_plan, orig_plan, pp_plan
from repro.core.executor import ExecResult, execute_plan, plan_accuracy
from repro.core.correlation import correlation_score, query_correlation

__all__ = [
    "MLUDF", "PhysicalPlan", "PlanStage", "Predicate", "Query",
    "ProxyModel", "RCurve", "build_r_curve", "train_proxy",
    "ProxyBuilder", "accuracy_allocation", "alpha_frontier",
    "BranchAndBound",
    "CoreSession", "OptimizeOptions", "QueryHandle", "ServeConfig",
    "build_plan", "rebuild_plan",
    "optimize", "reoptimize",
    "PlanCache", "QueryFingerprint", "WarmStart", "fingerprint_query",
    "ns_plan", "orig_plan", "pp_plan",
    "ExecResult", "execute_plan", "plan_accuracy",
    "correlation_score", "query_correlation",
]
