"""Deprecated optimizer entry points (PR 10 API redesign).

``optimize`` and ``reoptimize`` moved to ``core/api.py`` as
``build_plan`` / ``rebuild_plan`` with every knob collected into one
``OptimizeOptions`` dataclass.  The functions here are thin
back-compat shims: same signatures, same behavior, plus a
``DeprecationWarning``.  New internal callers are kept off them by
corelint's ``deprecated-entry-point`` rule.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.api import OptimizeOptions, build_plan, rebuild_plan
from repro.core.builder import ProxyBuilder
from repro.core.query import PhysicalPlan, Query


def optimize(
    query: Query,
    x_sample: np.ndarray,
    *,
    mode: str = "core",
    kind: str = "svm",
    step: float = 0.02,
    eps: float = 0.1,
    framework: str = "exhaustive",
    fine_grained: bool = True,
    seed: int = 0,
    builder: Optional[ProxyBuilder] = None,
    keep_state: bool = False,
    quant_dtype: Optional[str] = None,
    warm_start=None,
) -> PhysicalPlan:
    """Deprecated: use ``core.api.build_plan(query, x, OptimizeOptions(...))``."""
    warnings.warn(
        "optimize() is deprecated; use repro.core.api.build_plan(query, "
        "x_sample, OptimizeOptions(...))", DeprecationWarning, stacklevel=2)
    return build_plan(
        query, x_sample,
        OptimizeOptions(mode=mode, kind=kind, step=step, eps=eps,
                        framework=framework, fine_grained=fine_grained,
                        seed=seed, keep_state=keep_state,
                        quant_dtype=quant_dtype),
        builder=builder, warm_start=warm_start)


def reoptimize(
    plan: PhysicalPlan,
    x_sample: np.ndarray,
    *,
    known_sigma: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None,
    mode: str = "alloc",  # "alloc" (cheap re-allocation) | "bnb" (warm resume)
    step: float = 0.05,
    kind: str = "svm",
    eps: float = 0.1,
    framework: str = "exhaustive",
    seed: int = 0,
    keep_state: bool = True,
) -> PhysicalPlan:
    """Deprecated: use ``core.api.rebuild_plan(plan, x, OptimizeOptions(...))``."""
    warnings.warn(
        "reoptimize() is deprecated; use repro.core.api.rebuild_plan(plan, "
        "x_sample, OptimizeOptions(reopt=...))", DeprecationWarning,
        stacklevel=2)
    return rebuild_plan(
        plan, x_sample,
        OptimizeOptions(reopt=mode, step=step, kind=kind, eps=eps,
                        framework=framework, seed=seed,
                        keep_state=keep_state),
        known_sigma=known_sigma)
