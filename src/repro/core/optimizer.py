"""CORE optimizer entry points.

``optimize(query, x_sample, ...)`` builds proxy models ONLINE on the k%
optimization sample and returns a PhysicalPlan:

* mode="core"    — branch-and-bound over orders (Alg. 2, fine-grained tree)
                   + accuracy allocation (Alg. 1).           [the paper]
* mode="core-a"  — input order, accuracy allocation only.    [§6.5 CORE-a]
* mode="core-h"  — exhaustive order search.                  [§6.5 CORE-h]
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.accuracy import Allocation, accuracy_allocation
from repro.core.bnb import BranchAndBound, SearchTrace
from repro.core.builder import ProxyBuilder
from repro.core.query import PhysicalPlan, PlanStage, Query, all_orders


def _plan_from_allocation(query: Query, alloc: Allocation, meta: dict) -> PhysicalPlan:
    stages = []
    for i, p in enumerate(alloc.order):
        proxy = alloc.proxies[i]
        stages.append(
            PlanStage(
                pred_idx=p,
                proxy=proxy,
                alpha=alloc.alphas[i],
                threshold=proxy.r_curve.threshold_for(alloc.alphas[i]),
                est_reduction=alloc.reductions[i],
                est_selectivity=alloc.selectivities[i],
                est_cost=alloc.stage_costs[i],
            )
        )
    return PhysicalPlan(query=query, stages=stages, est_total_cost=alloc.total_cost, meta=meta)


def optimize(
    query: Query,
    x_sample: np.ndarray,
    *,
    mode: str = "core",
    kind: str = "svm",
    step: float = 0.02,
    eps: float = 0.1,
    framework: str = "exhaustive",
    fine_grained: bool = True,
    seed: int = 0,
    builder: Optional[ProxyBuilder] = None,
) -> PhysicalPlan:
    t_start = time.perf_counter()
    A = query.accuracy_target
    builder = builder or ProxyBuilder(query, x_sample, kind=kind, eps=eps, seed=seed)
    trace: Optional[SearchTrace] = None
    if mode == "core-a":
        alloc = accuracy_allocation(builder, tuple(range(query.n)), A, step=step,
                                    framework=framework)
    elif mode == "core-h":
        best = None
        for order in all_orders(query.n):
            alloc = accuracy_allocation(builder, order, A, step=step, framework=framework)
            if best is None or alloc.total_cost < best.total_cost:
                best = alloc
        alloc = best
    elif mode == "core":
        bb = BranchAndBound(builder, A, step=step, fine_grained=fine_grained,
                            framework=framework)
        alloc, trace = bb.run()
    else:
        raise ValueError(f"unknown mode {mode!r}")
    meta = {
        "mode": mode,
        "stats": builder.stats.as_dict(),
        "wall_ms": (time.perf_counter() - t_start) * 1e3,
    }
    if trace is not None:
        meta["trace"] = {
            "nodes_total": trace.nodes_total,
            "nodes_visited": trace.nodes_visited,
            "nodes_pruned_frac": trace.nodes_pruned_frac,
            "plans_pruned": trace.plans_pruned,
        }
    return _plan_from_allocation(query, alloc, meta)
