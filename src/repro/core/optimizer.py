"""CORE optimizer entry points.

``optimize(query, x_sample, ...)`` builds proxy models ONLINE on the k%
optimization sample and returns a PhysicalPlan:

* mode="core"    — branch-and-bound over orders (Alg. 2, fine-grained tree)
                   + accuracy allocation (Alg. 1).           [the paper]
* mode="core-a"  — input order, accuracy allocation only.    [§6.5 CORE-a]
* mode="core-h"  — exhaustive order search.                  [§6.5 CORE-h]

``reoptimize(plan, x_sample, ...)`` is the adaptive-serving entry point
(DESIGN.md §4): it rebuilds the plan against fresh statistics — a cheap
re-allocation on the incumbent order, or a warm-started branch-and-bound
``resume`` that reuses the previous search tree — carrying the previous
builder's trained-classifier cache forward so unchanged proxies are not
retrained.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.accuracy import Allocation, accuracy_allocation
from repro.core.bnb import BranchAndBound, SearchTrace
from repro.core.builder import ProxyBuilder
from repro.core.query import PhysicalPlan, PlanStage, Query, all_orders
from repro.util import advisory_wall_ms



def _plan_from_allocation(query: Query, alloc: Allocation, meta: dict) -> PhysicalPlan:
    stages = []
    for i, p in enumerate(alloc.order):
        proxy = alloc.proxies[i]
        stages.append(
            PlanStage(
                pred_idx=p,
                proxy=proxy,
                alpha=alloc.alphas[i],
                threshold=proxy.r_curve.threshold_for(alloc.alphas[i]),
                est_reduction=alloc.reductions[i],
                est_selectivity=alloc.selectivities[i],
                est_cost=alloc.stage_costs[i],
            )
        )
    return PhysicalPlan(query=query, stages=stages, est_total_cost=alloc.total_cost, meta=meta)


def optimize(
    query: Query,
    x_sample: np.ndarray,
    *,
    mode: str = "core",
    kind: str = "svm",
    step: float = 0.02,
    eps: float = 0.1,
    framework: str = "exhaustive",
    fine_grained: bool = True,
    seed: int = 0,
    builder: Optional[ProxyBuilder] = None,
    keep_state: bool = False,
    quant_dtype: Optional[str] = None,
    warm_start=None,
) -> PhysicalPlan:
    """``keep_state=True`` attaches the live builder (and B&B tree for
    mode="core") to ``plan.meta`` so a later ``reoptimize`` can warm-start
    instead of cold-searching — the adaptive serving loop's path.

    ``quant_dtype`` ("int8" | "fp8") stamps ``plan.meta["quant_dtype"]``:
    every scorer compiled for the plan (executor, serving install, wire
    artifact) then packs its cascade weights at that storage dtype.

    ``warm_start`` is a cross-query donor state from the plan cache
    (``plan_cache.WarmStart``: classifiers / s_stars / orders): the
    builder adopts the donor's trained-classifier cache (re-validated by
    the Eq.-4.7 eps test before any reuse), and mode="core" seeds the
    branch-and-bound tree with the donor's stale L-node measurements and
    surviving candidate set, then ``resume``s instead of cold-running."""
    t_start = advisory_wall_ms()
    A = query.accuracy_target
    builder = builder or ProxyBuilder(query, x_sample, kind=kind, eps=eps, seed=seed)
    if warm_start is not None and getattr(warm_start, "classifiers", None):
        builder.adopt_classifiers(warm_start.classifiers)
    trace: Optional[SearchTrace] = None
    bb: Optional[BranchAndBound] = None
    warmed = False
    if mode == "core-a":
        alloc = accuracy_allocation(builder, tuple(range(query.n)), A, step=step,
                                    framework=framework)
    elif mode == "core-h":
        best = None
        for order in all_orders(query.n):
            alloc = accuracy_allocation(builder, order, A, step=step, framework=framework)
            if best is None or alloc.total_cost < best.total_cost:
                best = alloc
        alloc = best
    elif mode == "core":
        bb = BranchAndBound(builder, A, step=step, fine_grained=fine_grained,
                            framework=framework)
        if warm_start is not None and getattr(warm_start, "s_stars", None):
            bb.seed_from(warm_start.s_stars,
                         orders=getattr(warm_start, "orders", None))
            alloc, trace = bb.resume()
            warmed = True
        else:
            alloc, trace = bb.run()
    else:
        raise ValueError(f"unknown mode {mode!r}")
    meta = {
        "mode": mode,
        "stats": builder.stats.as_dict(),
        "wall_ms": advisory_wall_ms() - t_start,
        "plan_version": 0,
    }
    if warmed:
        meta["warm_start"] = True
    if quant_dtype is not None and quant_dtype != "float32":
        from repro.core.proxy_family import QUANT_DTYPES

        if quant_dtype not in QUANT_DTYPES:
            raise ValueError(f"unknown quant_dtype {quant_dtype!r}")
        meta["quant_dtype"] = quant_dtype
    if trace is not None:
        meta["trace"] = _trace_dict(trace)
    if keep_state:
        meta["builder"] = builder
        if bb is not None:
            meta["bnb"] = bb
    return _plan_from_allocation(query, alloc, meta)


def _trace_dict(trace: SearchTrace) -> dict:
    return {
        "nodes_total": trace.nodes_total,
        "nodes_visited": trace.nodes_visited,
        "nodes_pruned_frac": trace.nodes_pruned_frac,
        "plans_pruned": trace.plans_pruned,
    }


def reoptimize(
    plan: PhysicalPlan,
    x_sample: np.ndarray,
    *,
    known_sigma: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None,
    mode: str = "alloc",  # "alloc" (cheap re-allocation) | "bnb" (warm resume)
    step: float = 0.05,
    kind: str = "svm",
    eps: float = 0.1,
    framework: str = "exhaustive",
    seed: int = 0,
    keep_state: bool = True,
) -> PhysicalPlan:
    """Re-optimize ``plan`` against fresh statistics (adaptive serving).

    ``x_sample`` is the new optimization sample (the serving reservoir);
    ``known_sigma`` pre-seeds UDF labels the server already observed
    (pred_idx -> (known_mask, sigma)).  ``mode="alloc"`` re-runs Algorithm 1
    on the incumbent stage order — the cheap path for pure selectivity /
    threshold drift.  ``mode="bnb"`` re-searches the order space, warm-
    starting from the previous search tree when ``plan.meta["bnb"]`` is
    present (``optimize(keep_state=True)`` or a previous reoptimize).
    """
    t_start = advisory_wall_ms()
    query = plan.query
    A = query.accuracy_target
    prev_builder: Optional[ProxyBuilder] = plan.meta.get("builder")
    prev_bnb: Optional[BranchAndBound] = plan.meta.get("bnb")
    if prev_builder is None and prev_bnb is not None:
        prev_builder = prev_bnb.builder
    if prev_builder is not None:
        builder = prev_builder.rebase(x_sample, known_sigma=known_sigma)
    else:
        # no carried builder: keep the incumbent plan's exact
        # per-predicate family assignment rather than silently reverting
        # to the default kind
        fam_map = {s.pred_idx: s.proxy.family
                   for s in plan.stages if s.proxy is not None}
        builder = ProxyBuilder(query, x_sample, kind=fam_map or kind,
                               eps=eps, seed=seed)
        if known_sigma:
            builder.seed_labels(known_sigma)
    trace: Optional[SearchTrace] = None
    warm = False
    bb: Optional[BranchAndBound] = None
    if mode == "alloc":
        alloc = accuracy_allocation(builder, plan.order, A, step=step,
                                    framework=framework)
        bb = prev_bnb  # keep the tree for a later escalation
    elif mode == "bnb":
        if prev_bnb is not None:
            bb = prev_bnb
            alloc, trace = bb.resume(builder)
            warm = True
        else:
            bb = BranchAndBound(builder, A, step=step, framework=framework)
            alloc, trace = bb.run()
    else:
        raise ValueError(f"unknown reoptimize mode {mode!r}")
    meta = {
        "mode": f"reopt-{mode}",
        "stats": builder.stats.as_dict(),
        "wall_ms": advisory_wall_ms() - t_start,
        "plan_version": int(plan.meta.get("plan_version", 0)) + 1,
        "warm_start": warm,
    }
    # a quantized incumbent stays quantized across adaptive re-plans: the
    # coordinator's reoptimize -> serialize -> quorum-swap path must ship
    # the same storage dtype it was serving, or a hot-swap would silently
    # de-quantize the fleet
    if plan.meta.get("quant_dtype"):
        meta["quant_dtype"] = plan.meta["quant_dtype"]
    if trace is not None:
        meta["trace"] = _trace_dict(trace)
    if keep_state:
        meta["builder"] = builder
        if bb is not None:
            meta["bnb"] = bb
    return _plan_from_allocation(query, alloc, meta)
