"""CORDS chi-squared correlation score (paper section 2.2).

kappa^2 = 1 / (n (min(d1,d2)-1)) * sum_ij (n_ij - n_i. n_.j / n)^2 / (n_i. n_.j / n)

i.e. Cramer's-V-squared measured on a sample (CORDS uses 10K rows).

``StreamingKappa2`` is the incremental form used by the adaptive serving
loop (DESIGN.md §4): it folds label chunks into a sparse contingency table
so the statistic is available mid-stream without re-scanning history, and
is chunking-invariant — feeding the same rows in any split yields exactly
the batch ``correlation_score`` value (property-tested).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def _kappa2_from_counts(counts: np.ndarray, n: int) -> float:
    """The CORDS statistic from a dense (d1, d2) contingency table."""
    d1, d2 = counts.shape
    if min(d1, d2) < 2 or n == 0:
        return 0.0
    row = counts.sum(axis=1, keepdims=True)
    col = counts.sum(axis=0, keepdims=True)
    expected = row * col / n
    with np.errstate(divide="ignore", invalid="ignore"):
        chi2 = np.where(expected > 0, (counts - expected) ** 2 / expected, 0.0).sum()
    return float(chi2 / (n * (min(d1, d2) - 1)))


class StreamingKappa2:
    """Incremental pairwise kappa^2 over two categorical label streams.

    ``update(col1, col2)`` folds a chunk of co-observed labels into a sparse
    (value, value) -> count table; ``value()`` densifies and applies the
    CORDS formula.  Because the statistic depends only on the accumulated
    table, any chunking of the same rows produces the identical value as
    ``correlation_score`` with sampling disabled.

    ``weights`` (optional, per-row) accumulate a WEIGHTED contingency
    table: the adaptive server's audit labels arrive importance-sampled
    toward proxy thresholds, and folding each row at its inverse audit
    propensity makes the table a Horvitz-Thompson estimate of the
    population contingency — so a shift in the score distribution alone
    (which changes the audited subset's composition, not the true label
    correlation) does not masquerade as a kappa^2 drift.
    """

    def __init__(self):
        self.counts: Dict[Tuple[int, int], float] = {}
        self.n = 0.0  # weighted mass (HT population estimate)
        self.n_rows = 0  # actual label rows folded — the statistical
        # information really available; with IPW weights ~1/audit_rate,
        # ``n`` overstates it by that factor

    def update(self, col1: np.ndarray, col2: np.ndarray,
               weights: np.ndarray = None) -> None:
        col1 = np.asarray(col1).ravel()
        col2 = np.asarray(col2).ravel()
        if len(col1) != len(col2):
            raise ValueError("label chunks must be co-observed (equal length)")
        if len(col1) == 0:
            return
        pairs = np.stack([col1.astype(np.int64), col2.astype(np.int64)], axis=1)
        uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
        if weights is None:
            sums = np.bincount(inv, minlength=len(uniq))
            total = float(len(col1))
        else:
            w = np.asarray(weights, np.float64).ravel()
            if len(w) != len(col1):
                raise ValueError("weights must be per-row")
            sums = np.bincount(inv, weights=w, minlength=len(uniq))
            total = float(w.sum())
        for (a, b), c in zip(uniq, sums):
            key = (int(a), int(b))
            self.counts[key] = self.counts.get(key, 0.0) + float(c)
        self.n += total
        self.n_rows += len(col1)

    def export(self) -> Tuple[Dict[Tuple[int, int], float], float, int]:
        """Snapshot of the weighted contingency table
        ``(counts, n, n_rows)`` — the unit of cross-host pooling: tables
        from shards of one population sum into the population's table
        (``merge_counts``).  ``n_rows`` rides along so poolers can gate
        decisions on actual label counts, not IPW-inflated mass."""
        return dict(self.counts), self.n, self.n_rows

    def merge_counts(self, counts: Dict[Tuple[int, int], float],
                     n: float, n_rows: int = 0) -> None:
        """Fold another table's exported ``(counts, n, n_rows)`` into this
        one.  Because the statistic depends only on the accumulated table,
        merging K shards' exports yields exactly the value of one tracker
        fed the union of their rows — the fleet-pooling property."""
        for key, c in counts.items():
            k = (int(key[0]), int(key[1]))
            self.counts[k] = self.counts.get(k, 0.0) + float(c)
        self.n += float(n)
        self.n_rows += int(n_rows)

    def value(self) -> float:
        if not self.counts:
            return 0.0
        v1 = sorted({a for a, _ in self.counts})
        v2 = sorted({b for _, b in self.counts})
        i1 = {v: i for i, v in enumerate(v1)}
        i2 = {v: i for i, v in enumerate(v2)}
        dense = np.zeros((len(v1), len(v2)))
        for (a, b), c in self.counts.items():
            dense[i1[a], i2[b]] = c
        return _kappa2_from_counts(dense, self.n)


def correlation_score(col1: np.ndarray, col2: np.ndarray, sample: int = 10_000,
                      seed: int = 0) -> float:
    n_total = len(col1)
    if n_total > sample:
        idx = np.random.RandomState(seed).choice(n_total, sample, replace=False)
        col1, col2 = col1[idx], col2[idx]
    n = len(col1)
    v1, inv1 = np.unique(col1, return_inverse=True)
    v2, inv2 = np.unique(col2, return_inverse=True)
    counts = np.zeros((len(v1), len(v2)))
    np.add.at(counts, (inv1, inv2), 1)
    return _kappa2_from_counts(counts, n)


def query_correlation(label_columns: np.ndarray) -> float:
    """Max pairwise kappa^2 over a query's predicate columns (n, k)."""
    k = label_columns.shape[1]
    best = 0.0
    for i in range(k):
        for j in range(i + 1, k):
            best = max(best, correlation_score(label_columns[:, i], label_columns[:, j]))
    return best
