"""CORDS chi-squared correlation score (paper section 2.2).

kappa^2 = 1 / (n (min(d1,d2)-1)) * sum_ij (n_ij - n_i. n_.j / n)^2 / (n_i. n_.j / n)

i.e. Cramer's-V-squared measured on a sample (CORDS uses 10K rows).
"""
from __future__ import annotations

import numpy as np


def correlation_score(col1: np.ndarray, col2: np.ndarray, sample: int = 10_000,
                      seed: int = 0) -> float:
    n_total = len(col1)
    if n_total > sample:
        idx = np.random.RandomState(seed).choice(n_total, sample, replace=False)
        col1, col2 = col1[idx], col2[idx]
    n = len(col1)
    v1, inv1 = np.unique(col1, return_inverse=True)
    v2, inv2 = np.unique(col2, return_inverse=True)
    d1, d2 = len(v1), len(v2)
    if min(d1, d2) < 2:
        return 0.0
    counts = np.zeros((d1, d2))
    np.add.at(counts, (inv1, inv2), 1)
    row = counts.sum(axis=1, keepdims=True)
    col = counts.sum(axis=0, keepdims=True)
    expected = row * col / n
    with np.errstate(divide="ignore", invalid="ignore"):
        chi2 = np.where(expected > 0, (counts - expected) ** 2 / expected, 0.0).sum()
    return float(chi2 / (n * (min(d1, d2) - 1)))


def query_correlation(label_columns: np.ndarray) -> float:
    """Max pairwise kappa^2 over a query's predicate columns (n, k)."""
    k = label_columns.shape[1]
    best = 0.0
    for i in range(k):
        for j in range(i + 1, k):
            best = max(best, correlation_score(label_columns[:, i], label_columns[:, j]))
    return best
