"""Query / UDF / predicate descriptors and plan representations.

An ML inference query is::

    SELECT F_1(t) AS c_1, ..., F_n(t) AS c_n FROM stream t
    WHERE c_1 IN v_1 AND ... AND c_n IN v_n      [TARGET ACCURACY A]

Each ``MLUDF`` is a row processor (one output label per input record) that
wraps an expensive model; each ``Predicate`` tests the UDF's output column.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, FrozenSet, List, Optional, Tuple

import numpy as np


@dataclass
class MLUDF:
    """An expensive ML user-defined function: features (N, F) -> labels (N,)."""

    name: str
    fn: Callable[[np.ndarray], np.ndarray]
    cost: float  # per-record execution cost (ms/record), profiled
    n_classes: int = 2

    def __call__(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(self.fn(x))


@dataclass
class Predicate:
    """``column φ value`` over the output of ``udf``."""

    udf: MLUDF
    values: FrozenSet[int]  # equality / IN-set semantics (paper's c φ v)
    name: str = ""

    def __post_init__(self):
        self.values = frozenset(self.values)
        if not self.name:
            self.name = f"{self.udf.name} IN {sorted(self.values)}"

    def evaluate(self, labels: np.ndarray) -> np.ndarray:
        mask = np.zeros(labels.shape[0], bool)
        for v in self.values:
            mask |= labels == v
        return mask


@dataclass
class Query:
    """Conjunction of predicates + query-level target accuracy A."""

    predicates: List[Predicate]
    accuracy_target: float = 0.9

    @property
    def n(self) -> int:
        return len(self.predicates)

    def names(self) -> List[str]:
        return [p.name for p in self.predicates]


@dataclass
class PlanStage:
    """One (proxy, UDF, predicate) cascade stage of a physical plan."""

    pred_idx: int  # index into the query's predicate list
    proxy: Optional[object]  # ProxyModel or None (ORIG)
    alpha: float = 1.0
    threshold: float = -np.inf  # proxy score threshold for this alpha
    # bookkeeping filled by the optimizer:
    est_reduction: float = 0.0
    est_selectivity: float = 1.0
    est_cost: float = 0.0


@dataclass
class PhysicalPlan:
    """Ordered cascade; ``stages[i]`` runs proxy_i -> UDF_i -> sigma_i."""

    query: Query
    stages: List[PlanStage]
    est_total_cost: float = 0.0
    meta: dict = field(default_factory=dict)

    @property
    def order(self) -> Tuple[int, ...]:
        return tuple(s.pred_idx for s in self.stages)

    def describe(self) -> str:
        lines = [f"plan order={self.order} est_cost={self.est_total_cost:.4f}"]
        for s in self.stages:
            p = self.query.predicates[s.pred_idx]
            proxy = "none" if s.proxy is None else f"alpha={s.alpha:.3f} r={s.est_reduction:.3f}"
            lines.append(f"  [{s.pred_idx}] {p.name}: proxy={proxy} C={s.est_cost:.4f}")
        return "\n".join(lines)


def all_orders(n: int) -> List[Tuple[int, ...]]:
    import itertools

    return list(itertools.permutations(range(n)))
