"""Algorithm 2: branch-and-bound order search (+ §5.3 fine-grained tree).

The search tree merges common prefixes of the n! candidate orders.  Each
node (a prefix ending at predicate pi_i) passes through states:

    UNVISITED --(L-phase: label, measure s*)--> LABELED
              --(M-phase: run Algorithm 1, train)--> BUILT

Bounds (Lemma 4 + §5.3 L-node rules) tighten as states advance; plans whose
[sum C^l, sum C^u] interval is dominated by a non-overlapping cheaper plan
are pruned.  With ``fine_grained=False`` the L and M phases run together
(the coarse tree of §5.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple


from repro.core.accuracy import Allocation, accuracy_allocation
from repro.core.builder import ProxyBuilder
from repro.core.cost import Bounds
from repro.util import advisory_wall_ms



@dataclass
class NodeInfo:
    state: str = "unvisited"  # unvisited | labeled | built
    s_star: float = 1.0  # selectivity measured at the L-node
    alloc: Optional[Allocation] = None  # allocation for the prefix (M-node)
    epoch: int = 0  # search epoch the state was measured in (resume support)


@dataclass
class SearchTrace:
    nodes_total: int = 0
    nodes_visited: int = 0
    plans_pruned: int = 0
    iterations: int = 0

    @property
    def nodes_pruned_frac(self) -> float:
        return 1.0 - self.nodes_visited / max(self.nodes_total, 1)


class BranchAndBound:
    def __init__(self, builder: ProxyBuilder, A: float, *, step: float = 0.02,
                 fine_grained: bool = True, framework: str = "exhaustive",
                 stale_slack: float = 0.4):
        self.builder = builder
        self.A = A
        self.step = step
        self.fine_grained = fine_grained
        self.framework = framework
        self.n = builder.query.n
        # ``stale_slack`` widens bound intervals derived from a previous
        # epoch's measurements during a warm-started ``resume`` — stale
        # L/M-node values still guide the search but cannot hard-prune a
        # plan unless they dominate it even after the widening.  Too small
        # and the resume trusts stale certainty (returns the old plan
        # without re-measuring); large values converge to a cold search.
        self.stale_slack = stale_slack
        self.epoch = 0
        import itertools

        self.orders: List[Tuple[int, ...]] = list(itertools.permutations(range(self.n)))
        self.nodes: Dict[Tuple[int, ...], NodeInfo] = {}
        for order in self.orders:
            for i in range(1, self.n + 1):
                self.nodes.setdefault(tuple(order[:i]), NodeInfo())
        self.trace = SearchTrace(nodes_total=len(self.nodes))
        # surviving candidate orders; persisted across run/resume so a
        # warm resume on unchanged stats does no re-search work
        self._Q: Optional[List[Tuple[int, ...]]] = None

    def _built(self, info: NodeInfo) -> bool:
        """Built *in the current epoch* — stale BUILT nodes only feed bounds."""
        return info.state == "built" and info.epoch == self.epoch

    # ------------------------------------------------------------- bounds
    def _plan_bounds(self, order: Tuple[int, ...]) -> Bounds:
        """Walk the plan; exact cost for BUILT prefix nodes, Lemma-4/§5.3
        bounds beyond.  Measurements from a previous epoch (after a warm
        ``resume`` under drifted stats) still contribute, but the final
        interval is widened by ``stale_slack`` so stale certainty cannot
        prune what fresh stats might prefer."""
        A = self.A
        lo_prefix = hi_prefix = 1.0
        lo_total = hi_total = 0.0
        stale = False
        # find deepest BUILT prefix with an allocation
        built_alloc: Optional[Allocation] = None
        built_depth = 0
        for i in range(self.n, 0, -1):
            info = self.nodes[tuple(order[:i])]
            if info.state == "built" and info.alloc is not None:
                built_alloc, built_depth = info.alloc, i
                stale |= info.epoch != self.epoch
                break
        for i in range(self.n):
            prefix_key = tuple(order[: i + 1])
            info = self.nodes[prefix_key]
            pred = self.builder.query.predicates[order[i]]
            c_udf = pred.udf.cost
            c_hat = 1e-4  # nominal proxy cost before built (refined after)
            if i < built_depth:
                a = built_alloc.alphas[i]
                r = built_alloc.reductions[i]
                s = built_alloc.selectivities[i]
                c_hat = built_alloc.proxies[i].cost
                c = lo_prefix * (c_hat + (1 - r) * c_udf)
                lo_total += c
                hi_total += c
                lo_prefix *= s * a
                hi_prefix = lo_prefix
            elif info.state == "labeled":
                s_star = info.s_star
                stale |= info.epoch != self.epoch
                k = 1  # unavailable prefix proxies at this node (bounded by 1 step)
                s_l = max((s_star - (1 - A) ** k) / (A**k), 0.0)
                s_u = s_star
                lo_total += lo_prefix * c_hat  # r^u = 1 discards all
                hi_total += hi_prefix * (c_hat + c_udf)  # r^l = 0
                lo_prefix *= s_l * A
                hi_prefix *= s_u * 1.0
            else:
                lo_total += lo_prefix * c_hat
                hi_total += hi_prefix * (c_hat + c_udf)
                lo_prefix *= 0.0 * A  # s^l = 0
                hi_prefix *= 1.0
        if stale:
            lo_total *= 1.0 - self.stale_slack
            hi_total *= 1.0 + self.stale_slack
        return Bounds(lo_total, hi_total)

    # -------------------------------------------------------------- phases
    def _visit(self, prefix: Tuple[int, ...]):
        info = self.nodes[prefix]
        if info.state == "unvisited" or info.epoch != self.epoch:
            # L-phase: materialize L*, measure selectivity (cheap; no
            # training).  A stale node (previous epoch) re-enters the
            # normal L->M pipeline here: its old allocation fed bounds
            # only while the node stayed UNVISITED this epoch — once the
            # fresh L-measurement lands, the wide labeled-state bounds
            # take over until the M-phase rebuilds the allocation.
            rows = self.builder.rows_after_sigmas(prefix[:-1])
            info.s_star = self.builder.selectivity(prefix[-1], rows)
            info.state = "labeled"
            info.epoch = self.epoch
            if self.fine_grained:
                self.trace.nodes_visited += 1
                return  # bounds updated; M-phase deferred (prunable before training)
        if info.state == "labeled":
            # M-phase: Algorithm 1 on the sub-order
            info.alloc = accuracy_allocation(
                self.builder, prefix, self.A, step=self.step, framework=self.framework
            )
            info.state = "built"
            info.epoch = self.epoch
            self.trace.nodes_visited += 1 if not self.fine_grained else 0

    # --------------------------------------------------------------- search
    def run(self) -> Tuple[Allocation, SearchTrace]:
        """Cold search over all orders (Algorithm 2)."""
        self._Q = list(self.orders)
        self.trace = SearchTrace(nodes_total=len(self.nodes))
        return self._search()

    def seed_from(self, s_stars: Dict[Tuple[int, ...], float],
                  orders: Optional[Sequence[Tuple[int, ...]]] = None) -> None:
        """Inject a previous search's L-node measurements — the plan
        cache's cross-query warm start (DESIGN.md §8).

        Each known prefix enters at the current epoch and then the epoch
        advances, so everything injected is *stale*: the old s* values
        guide stale-slack-widened bounds exactly like a drifted
        ``resume``, and the next ``resume()`` spends fresh L/M phases only
        on prefixes those bounds cannot prune.  ``orders`` optionally
        restores the donor search's surviving candidate set (its ``_Q``).
        Prefixes or orders that do not exist in this tree (a donor query
        of a different shape) are ignored — a bad seed can cost visits,
        never correctness, because every surviving candidate is still
        re-measured under the new builder before it can win.
        """
        for prefix, s in s_stars.items():
            info = self.nodes.get(tuple(prefix))
            if info is not None:
                info.s_star = float(s)
                info.state = "labeled"
                info.alloc = None
                info.epoch = self.epoch
        self.epoch += 1
        if orders:
            known = set(self.orders)
            survivors = [tuple(o) for o in orders if tuple(o) in known]
            if survivors:
                self._Q = survivors

    def export_state(self) -> Tuple[Dict[Tuple[int, ...], float],
                                    List[Tuple[int, ...]]]:
        """(s_stars, surviving orders) snapshot for ``seed_from`` on a
        future search — only measured (labeled/built) nodes export."""
        s_stars = {prefix: info.s_star for prefix, info in self.nodes.items()
                   if info.state != "unvisited"}
        return s_stars, list(self._Q) if self._Q is not None else []

    def resume(self, builder: Optional[ProxyBuilder] = None
               ) -> Tuple[Allocation, SearchTrace]:
        """Warm-started re-search for the adaptive serving loop.

        With ``builder=None`` (stats unchanged) the persisted candidate set
        and node states are final — the search terminates immediately with
        the identical plan and zero new L/M visits.  With a fresh builder
        (drifted stats, e.g. rebased onto the serving reservoir) the epoch
        advances: every node becomes *stale* — its old s*/allocation keeps
        guiding bounds (widened by ``stale_slack``) while the candidate set
        re-opens, so re-search only spends L/M phases on the prefixes the
        new bounds cannot prune, instead of cold-starting the whole tree.
        The trace reports only the visits this resume performed.
        """
        if builder is not None:
            self.builder = builder
            self.epoch += 1
            self._Q = list(self.orders)
        elif self._Q is None:
            self._Q = list(self.orders)
        self.trace = SearchTrace(nodes_total=len(self.nodes))
        return self._search()

    def _search(self) -> Tuple[Allocation, SearchTrace]:
        t0 = advisory_wall_ms()
        lt0 = self.builder.stats.labeling_ms + self.builder.stats.training_ms
        search0 = self.builder.stats.search_ms
        Q = self._Q
        while True:
            self.trace.iterations += 1
            bounds = {o: self._plan_bounds(o) for o in Q}
            Q.sort(key=lambda o: bounds[o].mean)
            # prune: non-overlapping intervals dominated by the best
            keep = [Q[0]]
            for o in Q[1:]:
                if any(
                    not bounds[o].overlaps(bounds[k]) and bounds[o].lower > bounds[k].upper
                    for k in keep
                ):
                    self.trace.plans_pruned += 1
                else:
                    keep.append(o)
            Q = keep
            # pick first un-built node of the head plan
            head = Q[0]
            target = None
            for i in range(1, self.n + 1):
                if not self._built(self.nodes[tuple(head[:i])]):
                    target = tuple(head[:i])
                    break
            if target is None:
                if len(Q) == 1:
                    break
                # head fully built; try other plans
                for o in Q[1:]:
                    for i in range(1, self.n + 1):
                        if not self._built(self.nodes[tuple(o[:i])]):
                            target = tuple(o[:i])
                            break
                    if target:
                        break
                if target is None:
                    break  # everything built
            if target is not None:
                self._visit(target)
        self._Q = Q
        best = Q[0]
        info = self.nodes[tuple(best)]
        alloc = info.alloc if self._built(info) else None
        if alloc is None or len(alloc.order) < self.n:
            alloc = accuracy_allocation(
                self.builder, best, self.A, step=self.step, framework=self.framework
            )
            info.alloc, info.state, info.epoch = alloc, "built", self.epoch
        elapsed = advisory_wall_ms() - t0
        lt_delta = self.builder.stats.labeling_ms + self.builder.stats.training_ms - lt0
        # add only the B&B loop overhead not already accounted by Algorithm 1
        alloc_search_delta = self.builder.stats.search_ms - search0
        self.builder.stats.search_ms += max(elapsed - lt_delta - alloc_search_delta, 0.0)
        return alloc, self.trace
