"""Proxy models (Definition 1): sigma-hat = {d, sigma, M, L, R}.

``d`` — the input relation (which prefix predicates conditioned the sample),
``sigma`` — the target predicate, ``M`` — the trained scorer,
``L`` — the labeled sample it was trained on,
``R`` — the accuracy -> reduction mapping measured on a validation split.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.proxy_family import ProxyFamily, get_family
from repro.training import proxy_models as pm

TRAIN_FRAC, TEST_FRAC = 0.6, 0.2  # 6:2:2 split as in the paper (rest = val)


@dataclass
class RCurve:
    """Accuracy->reduction mapping (Figure 4), measured on a validation set.

    ``alphas`` descending thresholds: for target accuracy a we keep the
    ceil(a * P) highest-scoring positives; the threshold is that positive's
    score; reduction = fraction of validation records scored below it.
    """

    alphas: np.ndarray  # (K,) grid
    thresholds: np.ndarray  # (K,)
    reductions: np.ndarray  # (K,)

    def threshold_for(self, alpha: float) -> float:
        i = int(np.clip(np.searchsorted(-self.alphas, -alpha), 0, len(self.alphas) - 1))
        return float(self.thresholds[i])

    def reduction_for(self, alpha: float) -> float:
        i = int(np.clip(np.searchsorted(-self.alphas, -alpha), 0, len(self.alphas) - 1))
        return float(self.reductions[i])


def build_r_curve(
    scores: np.ndarray,
    labels: np.ndarray,
    grid: Optional[np.ndarray] = None,
    conf_z: float = 1.0,
) -> RCurve:
    """scores: (N,) proxy scores on validation rows; labels: (N,) bool (sigma).

    ``conf_z``: binomial confidence margin — thresholds are chosen for
    alpha' = alpha + z*sqrt(alpha(1-alpha)/P) so the *held-out* accuracy
    meets alpha despite the finite validation sample (the validation split
    of the k% optimization sample is small; without the margin the plan's
    empirical accuracy undershoots the target)."""
    if grid is None:
        grid = np.round(np.linspace(1.0, 0.5, 51), 4)
    pos_scores = np.sort(scores[labels])[::-1]  # descending
    P = len(pos_scores)
    thresholds = np.empty(len(grid))
    reductions = np.empty(len(grid))
    sorted_all = np.sort(scores)
    for i, a in enumerate(grid):
        if P == 0:
            thr = np.inf
        else:
            a_eff = min(1.0, a + conf_z * np.sqrt(a * (1 - a) / max(P, 1)))
            keep = max(1, int(np.ceil(a_eff * P)))
            thr = pos_scores[min(keep, P) - 1]
        thresholds[i] = thr
        reductions[i] = np.searchsorted(sorted_all, thr, side="left") / max(len(scores), 1)
    return RCurve(alphas=np.asarray(grid, float), thresholds=thresholds, reductions=reductions)


@dataclass
class ProxyModel:
    """A trained proxy for predicate ``pred_idx`` conditioned on prefix ``d``.

    ``family`` is the canonical ProxyFamily name ("linear", "mlp1", ...);
    all scoring dispatch goes through the family registry — there is no
    per-kind branching anywhere downstream.
    """

    pred_idx: int
    d: Tuple[int, ...]  # prefix predicate indices (the input relation)
    family: str  # canonical ProxyFamily name
    params: object
    r_curve: RCurve
    cost: float  # per-record scoring cost (ms/record)
    train_f1: float = 0.0
    n_train: int = 0

    @property
    def family_obj(self) -> ProxyFamily:
        return get_family(self.family)

    @property
    def kind(self) -> str:
        """Legacy alias ("svm" | "mlp") kept for external callers; internal
        code dispatches on ``family``."""
        return {"linear": "svm", "mlp1": "mlp"}.get(self.family, self.family)

    def score(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(self.family_obj.score(self.params, x))

    def packed(self) -> pm.PackedProxy:
        """Folded packed form (the fused kernel's device format)."""
        return self.family_obj.pack(self.params)

    def mask(self, x: np.ndarray, alpha: float) -> np.ndarray:
        """True = keep (score >= threshold(alpha))."""
        thr = self.r_curve.threshold_for(alpha)
        return self.score(x) >= thr


def train_proxy(
    x: np.ndarray,
    sigma_labels: np.ndarray,
    pred_idx: int,
    d: Tuple[int, ...],
    kind: str = "svm",
    seed: int = 0,
    cost: Optional[float] = None,
) -> ProxyModel:
    """Train M on the labeled sample L (x + boolean sigma labels) and
    measure R on the validation split.  ``kind`` may be a canonical family
    name or a legacy alias ("svm", "mlp") — training and scoring dispatch
    through the ProxyFamily registry."""
    fam = get_family(kind)
    n = x.shape[0]
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    n_tr = max(8, int(TRAIN_FRAC * n))
    n_te = int(TEST_FRAC * n)
    idx_tr = perm[:n_tr]
    idx_val = perm[n_tr + n_te :]
    if len(idx_val) < 8:  # tiny samples: validate on train
        idx_val = idx_tr
    y = np.where(sigma_labels, 1.0, -1.0).astype(np.float32)
    xf = x.astype(np.float32)
    params = fam.train(xf[idx_tr], y[idx_tr], seed)
    scores_val = np.asarray(fam.score(params, xf[idx_val]))
    scores_tr = np.asarray(fam.score(params, xf[idx_tr]))
    curve = build_r_curve(scores_val, sigma_labels[idx_val])
    f1 = pm.f1_score(scores_tr, y[idx_tr])
    if cost is None:
        # analytic: O(F x hidden) per record; hidden folds into the packed
        # form's width so the cost model sees the family difference
        hidden = fam.pack(params).hidden
        cost = 1e-4 * x.shape[1] / 64.0 * max(1.0, hidden / 2.0)
    return ProxyModel(
        pred_idx=pred_idx, d=tuple(d), family=fam.name, params=params,
        r_curve=curve, cost=float(cost), train_f1=f1, n_train=len(idx_tr),
    )
