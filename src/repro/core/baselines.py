"""Baseline optimizers reproduced for comparison (§6.1):

* ORIG — run the query as-is (no proxies).
* NS   — NoScope-style: ONE proxy for the whole conjunction, trained on the
         raw input, inserted at the front with accuracy A.
* PP   — Probabilistic Predicates: per-predicate proxies trained on the RAW
         input (independence assumption); order + accuracies chosen with the
         same cost model but with *unconditional* selectivities and
         raw-input reduction curves — exactly the over-estimate the paper
         fixes.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.accuracy import alpha_frontier
from repro.core.builder import ProxyBuilder
from repro.core.cost import plan_cost
from repro.core.proxy import ProxyModel, train_proxy
from repro.core.query import PhysicalPlan, PlanStage, Query, all_orders
from repro.util import advisory_wall_ms



def orig_plan(query: Query) -> PhysicalPlan:
    stages = [PlanStage(pred_idx=i, proxy=None, alpha=1.0) for i in range(query.n)]
    cost = 0.0
    prefix = 1.0
    for i, p in enumerate(query.predicates):
        cost += prefix * p.udf.cost
        prefix *= 0.5  # nominal; ORIG cost is measured empirically anyway
    return PhysicalPlan(query=query, stages=stages, est_total_cost=cost,
                        meta={"mode": "orig", "stats": {}, "wall_ms": 0.0})


def ns_plan(query: Query, x_sample: np.ndarray, *, kind: str = "svm",
            seed: int = 0) -> PhysicalPlan:
    """Single conjunction proxy at the front (NoScope-style)."""
    t0 = advisory_wall_ms()
    builder = ProxyBuilder(query, x_sample, kind=kind, seed=seed)
    rows = np.arange(builder.n)
    conj = np.ones(builder.n, bool)
    for i in range(query.n):
        conj &= builder.sigma_mask(i, rows)
    t1 = advisory_wall_ms()
    # the single conjunction proxy has no per-predicate family assignment;
    # "mixed" / per-predicate maps degrade to linear (builder.family_for
    # needs a pred index)
    conj_kind = kind if isinstance(kind, str) and kind != "mixed" else "linear"
    proxy = train_proxy(builder.x, conj, pred_idx=-1, d=(), kind=conj_kind, seed=seed)
    training_ms = advisory_wall_ms() - t1
    A = query.accuracy_target
    stages = [
        PlanStage(
            pred_idx=0, proxy=proxy, alpha=A,
            threshold=proxy.r_curve.threshold_for(A),
            est_reduction=proxy.r_curve.reduction_for(A),
        )
    ] + [PlanStage(pred_idx=i, proxy=None, alpha=1.0) for i in range(1, query.n)]
    stats = builder.stats.as_dict()
    stats["training_ms"] += training_ms
    return PhysicalPlan(
        query=query, stages=stages, est_total_cost=0.0,
        meta={"mode": "ns", "stats": stats, "wall_ms": advisory_wall_ms() - t0},
    )


def pp_plan(query: Query, x_sample: np.ndarray, *, kind: str = "svm",
            step: float = 0.02, seed: int = 0) -> PhysicalPlan:
    """Probabilistic Predicates: offline-style independent proxies.

    Each proxy is trained on the raw sample (d = empty) with labels from its
    own predicate; the optimizer then assembles them assuming independence:
    s_i = unconditional selectivity, r_i = raw R-curve reduction.
    """
    t0 = advisory_wall_ms()
    builder = ProxyBuilder(query, x_sample, kind=kind, seed=seed)
    rows = np.arange(builder.n)
    proxies: List[ProxyModel] = []
    sel: List[float] = []
    for i in range(query.n):
        proxy, _ = builder.get_proxy(i, (), ())  # raw input relation
        proxies.append(proxy)
        sel.append(builder.selectivity(i, rows))
    A = query.accuracy_target
    best = None
    for order in all_orders(query.n):
        for alphas in alpha_frontier(query.n, A, step):
            reds = [proxies[p].r_curve.reduction_for(alphas[i]) for i, p in enumerate(order)]
            cost = plan_cost(
                alphas, reds, [sel[p] for p in order],
                [proxies[p].cost for p in order],
                [query.predicates[p].udf.cost for p in order],
            )
            if best is None or cost < best[0]:
                best = (cost, order, tuple(alphas), reds)
    cost, order, alphas, reds = best
    stages = [
        PlanStage(
            pred_idx=p, proxy=proxies[p], alpha=alphas[i],
            threshold=proxies[p].r_curve.threshold_for(alphas[i]),
            est_reduction=reds[i], est_selectivity=sel[p],
        )
        for i, p in enumerate(order)
    ]
    return PhysicalPlan(
        query=query, stages=stages, est_total_cost=cost,
        meta={"mode": "pp", "stats": builder.stats.as_dict(),
              "wall_ms": advisory_wall_ms() - t0},
    )
