"""Unified CORE session API (DESIGN.md §10).

The optimizer grew three entry points (``optimize`` / ``reoptimize`` /
``warm_optimize``) with ~10 keyword arguments each, and serving grew
three constructors (``CascadeServer``, ``ShardedCascadeServer``,
``ServingFrontEnd``) — all single-query-shaped.  This module is the
redesigned surface:

* ``OptimizeOptions`` — one dataclass carrying every optimizer knob.
  ``build_plan`` / ``rebuild_plan`` are the canonical build / re-build
  entry points; the old free functions remain in ``core/optimizer.py``
  as thin shims that emit ``DeprecationWarning`` (and corelint's
  ``deprecated-entry-point`` rule keeps new internal callers off them).
* ``ServeConfig`` — the serving-topology knobs.  ``CoreSession.serve``
  and the ``launch/serve.py`` CLI both consume it, so a flag and a
  programmatic call can never drift apart.
* ``CoreSession`` / ``QueryHandle`` — register N queries, optimize each
  (optionally through a shared cross-query ``PlanCache``), then
  ``serve()`` them: one registered query dispatches to the classic
  single-query stack, several to the shared multi-query engine
  (``serving/multiquery.MultiQueryEngine``) with cross-query UDF result
  dedupe, one fused stacked scorer, and weighted-fair device-time
  scheduling.

Serving modules are imported lazily inside methods: ``core`` must not
depend on ``serving`` at import time (serving already imports core).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.accuracy import Allocation, accuracy_allocation
from repro.core.bnb import BranchAndBound, SearchTrace
from repro.core.builder import ProxyBuilder
from repro.core.query import PhysicalPlan, PlanStage, Query, all_orders
from repro.util import advisory_wall_ms


@dataclass(frozen=True)
class OptimizeOptions:
    """Every optimizer knob in one place, threaded through ``build_plan``,
    ``rebuild_plan``, and ``PlanCache.optimize_query`` alike.

    ``mode`` picks the initial search ("core" | "core-a" | "core-h");
    ``reopt`` picks the re-optimization depth ``rebuild_plan`` uses
    ("alloc" = Algorithm 1 on the incumbent order, "bnb" = warm
    branch-and-bound resume).  ``kind`` is a family name or a
    per-predicate ``{pred_idx: family}`` dict.  ``keep_state=True``
    attaches the live builder (and B&B tree) to ``plan.meta`` so a later
    rebuild can warm-start.  ``quant_dtype`` ("int8" | "fp8") stamps the
    packed-cascade storage dtype onto the plan.
    """

    mode: str = "core"
    kind: object = "svm"
    step: float = 0.02
    eps: float = 0.1
    framework: str = "exhaustive"
    fine_grained: bool = True
    seed: int = 0
    keep_state: bool = False
    quant_dtype: Optional[str] = None
    reopt: str = "alloc"

    def replace(self, **kw) -> "OptimizeOptions":
        return dataclasses.replace(self, **kw)


#: ``rebuild_plan`` historically defaulted to a coarser step and kept
#: state (the adaptive loop always warm-starts the next rebuild) — the
#: shims' old defaults, preserved when no options are passed.
REBUILD_DEFAULTS = OptimizeOptions(step=0.05, keep_state=True)


def _plan_from_allocation(query: Query, alloc: Allocation, meta: dict) -> PhysicalPlan:
    stages = []
    for i, p in enumerate(alloc.order):
        proxy = alloc.proxies[i]
        stages.append(
            PlanStage(
                pred_idx=p,
                proxy=proxy,
                alpha=alloc.alphas[i],
                threshold=proxy.r_curve.threshold_for(alloc.alphas[i]),
                est_reduction=alloc.reductions[i],
                est_selectivity=alloc.selectivities[i],
                est_cost=alloc.stage_costs[i],
            )
        )
    return PhysicalPlan(query=query, stages=stages, est_total_cost=alloc.total_cost, meta=meta)


def _trace_dict(trace: SearchTrace) -> dict:
    return {
        "nodes_total": trace.nodes_total,
        "nodes_visited": trace.nodes_visited,
        "nodes_pruned_frac": trace.nodes_pruned_frac,
        "plans_pruned": trace.plans_pruned,
    }


def build_plan(
    query: Query,
    x_sample: np.ndarray,
    options: Optional[OptimizeOptions] = None,
    *,
    builder: Optional[ProxyBuilder] = None,
    warm_start=None,
) -> PhysicalPlan:
    """Build proxy models ONLINE on the optimization sample and return a
    PhysicalPlan (the canonical entry the ``optimize`` shim wraps).

    * mode="core"    — branch-and-bound over orders (Alg. 2, fine-grained
                       tree) + accuracy allocation (Alg. 1). [the paper]
    * mode="core-a"  — input order, accuracy allocation only. [§6.5 CORE-a]
    * mode="core-h"  — exhaustive order search.               [§6.5 CORE-h]

    ``warm_start`` is a cross-query donor state from the plan cache
    (``plan_cache.WarmStart``: classifiers / s_stars / orders): the
    builder adopts the donor's trained-classifier cache (re-validated by
    the Eq.-4.7 eps test before any reuse), and mode="core" seeds the
    branch-and-bound tree with the donor's stale L-node measurements and
    surviving candidate set, then ``resume``s instead of cold-running."""
    opt = options or OptimizeOptions()
    t_start = advisory_wall_ms()
    A = query.accuracy_target
    builder = builder or ProxyBuilder(query, x_sample, kind=opt.kind,
                                      eps=opt.eps, seed=opt.seed)
    if warm_start is not None and getattr(warm_start, "classifiers", None):
        builder.adopt_classifiers(warm_start.classifiers)
    trace: Optional[SearchTrace] = None
    bb: Optional[BranchAndBound] = None
    warmed = False
    if opt.mode == "core-a":
        alloc = accuracy_allocation(builder, tuple(range(query.n)), A,
                                    step=opt.step, framework=opt.framework)
    elif opt.mode == "core-h":
        best = None
        for order in all_orders(query.n):
            alloc = accuracy_allocation(builder, order, A, step=opt.step,
                                        framework=opt.framework)
            if best is None or alloc.total_cost < best.total_cost:
                best = alloc
        alloc = best
    elif opt.mode == "core":
        bb = BranchAndBound(builder, A, step=opt.step,
                            fine_grained=opt.fine_grained,
                            framework=opt.framework)
        if warm_start is not None and getattr(warm_start, "s_stars", None):
            bb.seed_from(warm_start.s_stars,
                         orders=getattr(warm_start, "orders", None))
            alloc, trace = bb.resume()
            warmed = True
        else:
            alloc, trace = bb.run()
    else:
        raise ValueError(f"unknown mode {opt.mode!r}")
    meta = {
        "mode": opt.mode,
        "stats": builder.stats.as_dict(),
        "wall_ms": advisory_wall_ms() - t_start,
        "plan_version": 0,
    }
    if warmed:
        meta["warm_start"] = True
    if opt.quant_dtype is not None and opt.quant_dtype != "float32":
        from repro.core.proxy_family import QUANT_DTYPES

        if opt.quant_dtype not in QUANT_DTYPES:
            raise ValueError(f"unknown quant_dtype {opt.quant_dtype!r}")
        meta["quant_dtype"] = opt.quant_dtype
    if trace is not None:
        meta["trace"] = _trace_dict(trace)
    if opt.keep_state:
        meta["builder"] = builder
        if bb is not None:
            meta["bnb"] = bb
    return _plan_from_allocation(query, alloc, meta)


def rebuild_plan(
    plan: PhysicalPlan,
    x_sample: np.ndarray,
    options: Optional[OptimizeOptions] = None,
    *,
    known_sigma: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None,
) -> PhysicalPlan:
    """Re-optimize ``plan`` against fresh statistics (adaptive serving;
    the canonical entry the ``reoptimize`` shim wraps).

    ``x_sample`` is the new optimization sample (the serving reservoir);
    ``known_sigma`` pre-seeds UDF labels the server already observed
    (pred_idx -> (known_mask, sigma)).  ``options.reopt`` picks depth:
    "alloc" re-runs Algorithm 1 on the incumbent stage order — the cheap
    path for pure selectivity / threshold drift — while "bnb" re-searches
    the order space, warm-starting from the previous search tree when
    ``plan.meta["bnb"]`` is present."""
    opt = options or REBUILD_DEFAULTS
    t_start = advisory_wall_ms()
    query = plan.query
    A = query.accuracy_target
    prev_builder: Optional[ProxyBuilder] = plan.meta.get("builder")
    prev_bnb: Optional[BranchAndBound] = plan.meta.get("bnb")
    if prev_builder is None and prev_bnb is not None:
        prev_builder = prev_bnb.builder
    if prev_builder is not None:
        builder = prev_builder.rebase(x_sample, known_sigma=known_sigma)
    else:
        # no carried builder: keep the incumbent plan's exact
        # per-predicate family assignment rather than silently reverting
        # to the default kind
        fam_map = {s.pred_idx: s.proxy.family
                   for s in plan.stages if s.proxy is not None}
        builder = ProxyBuilder(query, x_sample, kind=fam_map or opt.kind,
                               eps=opt.eps, seed=opt.seed)
        if known_sigma:
            builder.seed_labels(known_sigma)
    trace: Optional[SearchTrace] = None
    warm = False
    bb: Optional[BranchAndBound] = None
    if opt.reopt == "alloc":
        alloc = accuracy_allocation(builder, plan.order, A, step=opt.step,
                                    framework=opt.framework)
        bb = prev_bnb  # keep the tree for a later escalation
    elif opt.reopt == "bnb":
        if prev_bnb is not None:
            bb = prev_bnb
            alloc, trace = bb.resume(builder)
            warm = True
        else:
            bb = BranchAndBound(builder, A, step=opt.step,
                                framework=opt.framework)
            alloc, trace = bb.run()
    else:
        raise ValueError(f"unknown reoptimize mode {opt.reopt!r}")
    meta = {
        "mode": f"reopt-{opt.reopt}",
        "stats": builder.stats.as_dict(),
        "wall_ms": advisory_wall_ms() - t_start,
        "plan_version": int(plan.meta.get("plan_version", 0)) + 1,
        "warm_start": warm,
    }
    # a quantized incumbent stays quantized across adaptive re-plans: the
    # coordinator's rebuild -> serialize -> quorum-swap path must ship
    # the same storage dtype it was serving, or a hot-swap would silently
    # de-quantize the fleet
    if plan.meta.get("quant_dtype"):
        meta["quant_dtype"] = plan.meta["quant_dtype"]
    if trace is not None:
        meta["trace"] = _trace_dict(trace)
    if opt.keep_state:
        meta["builder"] = builder
        if bb is not None:
            meta["bnb"] = bb
    return _plan_from_allocation(query, alloc, meta)


# --------------------------------------------------------------- serving API


@dataclass
class ServeConfig:
    """Serving-topology knobs, shared between ``CoreSession.serve`` and
    the ``launch/serve.py`` CLI (every flag maps onto one field — a
    golden test asserts the round-trip).  ``hosts > 1`` shards across K
    simulated hosts with quorum-voted swaps; ``slo_ms`` wraps the engine
    in the deadline-aware request front end; ``queries_path`` points at
    a multi-query JSON spec served through one ``CoreSession``."""

    tile: int = 1024
    use_kernel: bool = True
    adaptive: bool = False
    hosts: int = 1
    transport: str = "inline"
    slo_ms: Optional[float] = None
    arrival_rate: Optional[float] = None
    request_rows: int = 128
    backpressure: bool = True
    seed: int = 0
    drift: bool = False
    drift_skew: float = 0.3
    kill_coordinator_at: Optional[str] = None
    straggler_host: Optional[int] = None
    plan_cache_path: Optional[str] = None
    queries_path: Optional[str] = None

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)


class QueryHandle:
    """One registered query inside a ``CoreSession``: its options, its
    optimized plan, and per-query serving stats.  ``handle.optimize()``
    builds the plan (through the session's plan cache when one is
    attached); ``handle.submit()`` routes records to this query only;
    ``handle.stats()`` reads this query's serving counters."""

    def __init__(self, session: "CoreSession", qid: int, query: Query,
                 x_sample: Optional[np.ndarray], *,
                 options: OptimizeOptions, plan_cache=None,
                 slo: Optional[float] = None):
        self.session = session
        self.qid = qid
        self.query = query
        self.x_sample = x_sample
        self.options = options
        self.plan_cache = plan_cache
        self.slo = slo
        self.plan: Optional[PhysicalPlan] = None
        self.optimize_info: Optional[dict] = None

    def optimize(self, x_sample: Optional[np.ndarray] = None, *,
                 options: Optional[OptimizeOptions] = None,
                 warm_start=None) -> PhysicalPlan:
        x = self.x_sample if x_sample is None else x_sample
        if x is None:
            raise ValueError(
                "no optimization sample: pass x_sample to register_query "
                "or to handle.optimize")
        opts = self.options if options is None else options
        if self.plan_cache is not None:
            # serving needs live builder/B&B state when keep_state is on,
            # which an exact-hit wire replay cannot carry
            plan, info = self.plan_cache.optimize_query(
                self.query, x, opts, accept_hit=not opts.keep_state)
            self.optimize_info = info
        else:
            plan = build_plan(self.query, x, opts, warm_start=warm_start)
            self.optimize_info = {"path": "cold", "trace": plan.meta.get("trace")}
        self.plan = plan
        return plan

    def submit(self, indices, rows) -> None:
        self.session.submit(indices, rows, qids=(self.qid,))

    def stats(self) -> dict:
        return self.session.query_stats(self.qid)


class CoreSession:
    """Registry of N concurrent cascade queries served as one unit.

    ``register_query`` hands out ``QueryHandle``s; ``serve()`` builds
    the serving stack once every query is registered — a single query
    dispatches to ``CascadeServer`` / ``ShardedCascadeServer`` /
    ``ServingFrontEnd`` per the config, several queries to the shared
    ``MultiQueryEngine`` (one fused stacked scorer, cross-query UDF
    dedupe, weighted-fair scheduling).  ``submit`` / ``run_stream`` /
    ``query_stats`` then route through whichever stack was built.
    """

    def __init__(self, *, options: Optional[OptimizeOptions] = None,
                 plan_cache=None, seed: int = 0):
        self.options = options or OptimizeOptions()
        self.plan_cache = plan_cache
        self.seed = seed
        self.handles: List[QueryHandle] = []
        self.server = None   # whatever serve() built
        self._multi = False

    # ------------------------------------------------------------- registry
    def register_query(self, query: Query,
                       x_sample: Optional[np.ndarray] = None, *,
                       quant_dtype: Optional[str] = None,
                       plan_cache=None, slo: Optional[float] = None,
                       options: Optional[OptimizeOptions] = None
                       ) -> QueryHandle:
        if self.server is not None:
            raise RuntimeError("register_query must precede serve()")
        opts = options or self.options
        if quant_dtype is not None:
            opts = opts.replace(quant_dtype=(
                None if quant_dtype in ("fp32", "float32") else quant_dtype))
        handle = QueryHandle(
            self, len(self.handles), query, x_sample, options=opts,
            plan_cache=self.plan_cache if plan_cache is None else plan_cache,
            slo=slo)
        self.handles.append(handle)
        return handle

    def optimize_all(self, *, keep_state: Optional[bool] = None
                     ) -> List[PhysicalPlan]:
        """Optimize every registered query that has no plan yet.
        ``keep_state=True`` forces live builder/B&B state onto the plans
        (adaptive / sharded serving warm-starts rebuilds from it)."""
        plans = []
        for h in self.handles:
            if h.plan is None:
                opts = (h.options if keep_state is None
                        else h.options.replace(keep_state=keep_state))
                h.optimize(options=opts)
            plans.append(h.plan)
        return plans

    # -------------------------------------------------------------- serving
    def serve(self, *, transport: Optional[str] = None,
              hosts: Optional[int] = None, slo: Optional[float] = None,
              config: Optional[ServeConfig] = None, policy=None,
              worker_spec=None):
        """Build the serving stack for the registered queries.  The
        keyword shortcuts override ``config`` fields; both roads lead to
        the same ``ServeConfig``.  Returns the server (also kept on
        ``self.server``); drive it with ``submit``/``run_stream`` here
        or use its native interface directly."""
        if not self.handles:
            raise RuntimeError("serve() with no registered query")
        if self.server is not None:
            raise RuntimeError("serve() already built a server")
        cfg = config or ServeConfig()
        if transport is not None:
            cfg = cfg.replace(transport=transport)
        if hosts is not None:
            cfg = cfg.replace(hosts=hosts)
        if slo is not None:
            cfg = cfg.replace(slo_ms=slo)
        needs_state = cfg.adaptive or cfg.hosts > 1
        self.optimize_all(keep_state=True if needs_state else None)
        if len(self.handles) > 1:
            if cfg.hosts > 1:
                raise ValueError(
                    "multi-query sharded serving is not wired yet "
                    "(ROADMAP follow-up); serve each tenant fleet "
                    "separately or use hosts=1")
            from repro.serving.multiquery import MultiQueryEngine

            self.server = MultiQueryEngine(
                self.handles, tile=cfg.tile, use_kernel=cfg.use_kernel,
                adaptive=cfg.adaptive, policy=policy, seed=cfg.seed,
                plan_cache=self.plan_cache)
            self._multi = True
            return self.server
        h = self.handles[0]
        slo_ms = cfg.slo_ms if cfg.slo_ms is not None else h.slo
        if cfg.hosts > 1:
            from repro.distributed.serving import ShardedCascadeServer

            self.server = ShardedCascadeServer(
                h.plan, cfg.hosts, tile=cfg.tile, seed=cfg.seed,
                policy=policy, transport=cfg.transport,
                kill_coordinator_at=cfg.kill_coordinator_at,
                straggler_host=cfg.straggler_host, worker_spec=worker_spec,
                slo_ms=slo_ms, plan_cache=h.plan_cache)
            return self.server
        from repro.serving.engine import CascadeServer

        engine = CascadeServer(
            h.plan, tile=cfg.tile, use_kernel=cfg.use_kernel,
            adaptive=cfg.adaptive, policy=policy, seed=cfg.seed,
            plan_cache=h.plan_cache)
        if slo_ms is not None:
            from repro.serving.frontend import ServingFrontEnd, SLOPolicy

            self.server = ServingFrontEnd(engine, policy=SLOPolicy(
                degrade=cfg.backpressure, shed_expired=cfg.backpressure))
        else:
            self.server = engine
        return self.server

    def submit(self, indices, rows, *, qids=None) -> None:
        if self.server is None:
            raise RuntimeError("serve() before submit()")
        if self._multi:
            self.server.submit(indices, rows, qids=qids)
        else:
            self.server.submit(indices, rows)

    def run_stream(self, x: np.ndarray, *, chunk: int = 4096):
        if self.server is None:
            self.serve()
        return self.server.run_stream(x, chunk=chunk)

    def query_stats(self, qid: int) -> dict:
        if self._multi:
            return self.server.query_stats(qid)
        if qid != 0:
            raise KeyError(f"no query {qid} in a single-query session")
        if self.server is None:
            return {}
        stats = getattr(self.server, "stats", None)
        return dict(stats.__dict__) if stats is not None else {}
