"""ProxyFamily registry and the packed device-resident parameter format.

This module replaces the stringly-typed ``kind: "svm" | "mlp"`` dispatch
that used to be scattered across the proxy stack.  A **family** owns
everything the system needs to know about one class of proxy scorer:

* how to **train** it (``train(x, y, seed)`` -> params),
* how to **score** with raw params (the reference path — used by the
  optimizer on the tiny optimization sample and by ``kernels/ref.py``
  parity oracles),
* how to **pack** params into the folded depth-1 MLP form
  (``training.proxy_models.PackedProxy``) the fused cascade kernel
  executes: ``score(x) = relu(x @ w1 + b1) @ w2 + b2`` with the feature
  standardizer folded in once at pack time.

Because linear models embed exactly (``relu(z) - relu(-z) == z``,
bit-for-bit), one packed format — and therefore ONE fused Pallas scorer —
covers every registered family; there is no per-kind execution branch left
anywhere downstream of this module.

``pack_cascade`` stacks the per-stage packed proxies of a whole plan into
bucket-padded ``(F, H, P)`` tensors (H = the hidden-width bucket, P = the
number of stages); ``unpack_cascade`` is its exact inverse per stage, and
is property-tested round-trip in ``tests/test_proxy_family.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, NamedTuple, Sequence, Tuple, Type

import jax
import numpy as np

from repro.training import proxy_models as pm
from repro.training.proxy_models import PackedProxy


@dataclass(frozen=True)
class ProxyFamily:
    """One registered proxy-model family (linear SVM, depth-1 MLP, ...)."""

    name: str
    params_cls: Type
    train: Callable[[np.ndarray, np.ndarray, int], object]  # (x, y∈{-1,1}, seed)
    score: Callable[[object, np.ndarray], np.ndarray]  # reference scorer
    pack: Callable[[object], PackedProxy]  # fold standardizer + lower to packed

    def __repr__(self) -> str:  # keep plan dumps readable
        return f"ProxyFamily({self.name!r})"


_REGISTRY: Dict[str, ProxyFamily] = {}
_BY_PARAMS: Dict[Type, ProxyFamily] = {}
_ALIASES = {"svm": "linear", "mlp": "mlp1"}


def register_family(family: ProxyFamily, *, aliases: Sequence[str] = ()) -> ProxyFamily:
    _REGISTRY[family.name] = family
    _BY_PARAMS[family.params_cls] = family
    for a in aliases:
        _ALIASES[a] = family.name
    return family


def get_family(name: str) -> ProxyFamily:
    """Resolve a family by canonical name or legacy alias ("svm", "mlp")."""
    key = _ALIASES.get(name, name)
    if key not in _REGISTRY:
        raise KeyError(f"unknown proxy family {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def family_of(params) -> ProxyFamily:
    """Family lookup by parameter type (packed caches key on this)."""
    fam = _BY_PARAMS.get(type(params))
    if fam is None:
        raise KeyError(f"no proxy family registered for params type {type(params).__name__}")
    return fam


def family_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ------------------------------------------------------- built-in families
LINEAR = register_family(
    ProxyFamily(
        name="linear",
        params_cls=pm.LinearParams,
        train=lambda x, y, seed: pm.train_linear_svm(x, y),
        score=lambda p, x: np.asarray(pm.linear_score(p, x.astype(np.float32))),
        pack=pm.pack_linear,
    ),
    aliases=("svm",),
)

MLP1 = register_family(
    ProxyFamily(
        name="mlp1",
        params_cls=pm.MLPParams,
        train=lambda x, y, seed: pm.train_mlp(x, y, jax.random.PRNGKey(seed)),
        score=lambda p, x: np.asarray(pm.mlp_score(p, x.astype(np.float32))),
        pack=pm.pack_mlp,
    ),
    aliases=("mlp",),
)


def _packed_train_unsupported(x, y, seed):
    raise TypeError(
        "the 'packed1' family is a wire/device format, not a trainable one: "
        "deserialized plans carry folded PackedProxy params whose original "
        "training-side parameterization (standardizer, raw weights) is gone. "
        "Re-optimization happens where the builder lives (the coordinator), "
        "never on a host serving a deserialized artifact."
    )


# The already-folded depth-1 form itself, registered as a first-class family
# so DESERIALIZED scorer artifacts (kernels/ops.py::deserialize_scorer) are
# indistinguishable from locally-built plans everywhere downstream: family_of
# dispatch, the pack caches, the per-stage kernel fallback, and the scorer
# compile cache all work on PackedProxy params with pack == identity.
PACKED1 = register_family(
    ProxyFamily(
        name="packed1",
        params_cls=pm.PackedProxy,
        train=_packed_train_unsupported,
        score=lambda p, x: pm.packed_score(p, np.asarray(x, np.float32)),
        pack=lambda p: p,
    ),
)


# ------------------------------------------------- cascade-level packing
# Hidden widths are padded to a small bucket ladder so the fused kernel
# compiles one program per (F, H, P) shape class, not one per cascade.
HIDDEN_BUCKETS = (2, 4, 8, 16, 32, 64, 128)


def hidden_bucket(h: int) -> int:
    for b in HIDDEN_BUCKETS:
        if h <= b:
            return b
    # beyond the ladder: round up to the next multiple of the top bucket
    top = HIDDEN_BUCKETS[-1]
    return ((h + top - 1) // top) * top


class PackedCascade(NamedTuple):
    """Whole-cascade packed parameters, bucket-padded to static shapes.

    ``w1[(f, h, p)]`` is hidden weight ``h`` of stage ``p``; hidden slots
    ``h >= hidden[p]`` are zero-padded (``relu(0 + 0) = 0`` and a zero
    readout weight keeps them inert).  ``H`` is the shared hidden bucket:
    ``hidden_bucket(max(hidden))``.
    """

    w1: np.ndarray  # (F, H, P) float32
    b1: np.ndarray  # (H, P) float32
    w2: np.ndarray  # (H, P) float32 readout
    b2: np.ndarray  # (P,) float32
    hidden: Tuple[int, ...]  # true per-stage hidden widths
    families: Tuple[str, ...]  # per-stage family names

    @property
    def n_features(self) -> int:
        return int(self.w1.shape[0])

    @property
    def H(self) -> int:
        return int(self.w1.shape[1])

    @property
    def n_stages(self) -> int:
        return int(self.w1.shape[2])


def pack_cascade(param_list: Sequence[object], *,
                 pack_fn: Callable[[object], PackedProxy] = None) -> PackedCascade:
    """Pack every stage's params (any mix of families) into one
    bucket-padded (F, H, P) tensor set.  ``pack_fn`` overrides the per-proxy
    packer (e.g. ``kernels.ops.pack_proxy_cached`` to memoize the fold)."""
    if not param_list:
        raise ValueError("pack_cascade needs at least one proxy")
    packs: List[PackedProxy] = []
    fams: List[str] = []
    for p in param_list:
        fam = family_of(p)
        packs.append(pack_fn(p) if pack_fn is not None else fam.pack(p))
        fams.append(fam.name)
    F = packs[0].w1.shape[0]
    for pk in packs:
        if pk.w1.shape[0] != F:
            raise ValueError("all cascade stages must share the feature dim")
    H = hidden_bucket(max(pk.hidden for pk in packs))
    P = len(packs)
    w1 = np.zeros((F, H, P), np.float32)
    b1 = np.zeros((H, P), np.float32)
    w2 = np.zeros((H, P), np.float32)
    b2 = np.zeros(P, np.float32)
    for p, pk in enumerate(packs):
        h = pk.hidden
        w1[:, :h, p] = pk.w1
        b1[:h, p] = pk.b1
        w2[:h, p] = pk.w2
        b2[p] = pk.b2
    return PackedCascade(w1=w1, b1=b1, w2=w2, b2=b2,
                         hidden=tuple(pk.hidden for pk in packs),
                         families=tuple(fams))


def unpack_cascade(packed: PackedCascade, col: int) -> PackedProxy:
    """Exact inverse of ``pack_cascade`` for one stage: strips the hidden
    bucket padding and returns the stage's folded PackedProxy."""
    h = packed.hidden[col]
    return PackedProxy(
        w1=np.ascontiguousarray(packed.w1[:, :h, col]),
        b1=np.ascontiguousarray(packed.b1[:h, col]),
        w2=np.ascontiguousarray(packed.w2[:h, col]),
        b2=np.float32(packed.b2[col]),
        hidden=h,
    )


def cascade_kernel_operands(packed: PackedCascade):
    """Flatten (F, H, P) -> the kernel's two-GEMM operand layout.

    Returns ``(w1 (F, H*P), b1 (H*P,), w2 (H*P, P), b2 (P,))`` in h-major
    column order (column ``h*P + p`` is hidden unit ``h`` of stage ``p``);
    ``w2`` is the block-diagonal readout matrix of the second GEMM.
    """
    F, H, P = packed.w1.shape
    w1 = np.ascontiguousarray(packed.w1.reshape(F, H * P))
    b1 = np.ascontiguousarray(packed.b1.reshape(H * P))
    w2 = np.zeros((H * P, P), np.float32)
    w2[np.arange(H * P), np.tile(np.arange(P), H)] = packed.w2.reshape(H * P)
    return w1, b1, w2, np.asarray(packed.b2, np.float32)
