"""ProxyFamily registry and the packed device-resident parameter format.

This module replaces the stringly-typed ``kind: "svm" | "mlp"`` dispatch
that used to be scattered across the proxy stack.  A **family** owns
everything the system needs to know about one class of proxy scorer:

* how to **train** it (``train(x, y, seed)`` -> params),
* how to **score** with raw params (the reference path — used by the
  optimizer on the tiny optimization sample and by ``kernels/ref.py``
  parity oracles),
* how to **pack** params into the folded depth-1 MLP form
  (``training.proxy_models.PackedProxy``) the fused cascade kernel
  executes: ``score(x) = relu(x @ w1 + b1) @ w2 + b2`` with the feature
  standardizer folded in once at pack time.

Because linear models embed exactly (``relu(z) - relu(-z) == z``,
bit-for-bit), one packed format — and therefore ONE fused Pallas scorer —
covers every registered family; there is no per-kind execution branch left
anywhere downstream of this module.

``pack_cascade`` stacks the per-stage packed proxies of a whole plan into
bucket-padded ``(F, H, P)`` tensors (H = the hidden-width bucket, P = the
number of stages); ``unpack_cascade`` is its exact inverse per stage, and
is property-tested round-trip in ``tests/test_proxy_family.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple, Type

import jax
import numpy as np

from repro.training import proxy_models as pm
from repro.training.proxy_models import PackedProxy


@dataclass(frozen=True)
class ProxyFamily:
    """One registered proxy-model family (linear SVM, depth-1 MLP, ...)."""

    name: str
    params_cls: Type
    train: Callable[[np.ndarray, np.ndarray, int], object]  # (x, y∈{-1,1}, seed)
    score: Callable[[object, np.ndarray], np.ndarray]  # reference scorer
    pack: Callable[[object], PackedProxy]  # fold standardizer + lower to packed

    def __repr__(self) -> str:  # keep plan dumps readable
        return f"ProxyFamily({self.name!r})"


_REGISTRY: Dict[str, ProxyFamily] = {}
_BY_PARAMS: Dict[Type, ProxyFamily] = {}
_ALIASES = {"svm": "linear", "mlp": "mlp1"}


def register_family(family: ProxyFamily, *, aliases: Sequence[str] = ()) -> ProxyFamily:
    _REGISTRY[family.name] = family
    _BY_PARAMS[family.params_cls] = family
    for a in aliases:
        _ALIASES[a] = family.name
    return family


def get_family(name: str) -> ProxyFamily:
    """Resolve a family by canonical name or legacy alias ("svm", "mlp")."""
    key = _ALIASES.get(name, name)
    if key not in _REGISTRY:
        raise KeyError(f"unknown proxy family {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def family_of(params) -> ProxyFamily:
    """Family lookup by parameter type (packed caches key on this)."""
    fam = _BY_PARAMS.get(type(params))
    if fam is None:
        raise KeyError(f"no proxy family registered for params type {type(params).__name__}")
    return fam


def family_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ------------------------------------------------------- built-in families
LINEAR = register_family(
    ProxyFamily(
        name="linear",
        params_cls=pm.LinearParams,
        train=lambda x, y, seed: pm.train_linear_svm(x, y),
        score=lambda p, x: np.asarray(pm.linear_score(p, x.astype(np.float32))),
        pack=pm.pack_linear,
    ),
    aliases=("svm",),
)

MLP1 = register_family(
    ProxyFamily(
        name="mlp1",
        params_cls=pm.MLPParams,
        train=lambda x, y, seed: pm.train_mlp(x, y, jax.random.PRNGKey(seed)),
        score=lambda p, x: np.asarray(pm.mlp_score(p, x.astype(np.float32))),
        pack=pm.pack_mlp,
    ),
    aliases=("mlp",),
)


def _packed_train_unsupported(x, y, seed):
    raise TypeError(
        "the 'packed1' family is a wire/device format, not a trainable one: "
        "deserialized plans carry folded PackedProxy params whose original "
        "training-side parameterization (standardizer, raw weights) is gone. "
        "Re-optimization happens where the builder lives (the coordinator), "
        "never on a host serving a deserialized artifact."
    )


# The already-folded depth-1 form itself, registered as a first-class family
# so DESERIALIZED scorer artifacts (kernels/ops.py::deserialize_scorer) are
# indistinguishable from locally-built plans everywhere downstream: family_of
# dispatch, the pack caches, the per-stage kernel fallback, and the scorer
# compile cache all work on PackedProxy params with pack == identity.
PACKED1 = register_family(
    ProxyFamily(
        name="packed1",
        params_cls=pm.PackedProxy,
        train=_packed_train_unsupported,
        score=lambda p, x: pm.packed_score(p, np.asarray(x, np.float32)),
        pack=lambda p: p,
    ),
)


# ------------------------------------------------- cascade-level packing
# Hidden widths are padded to a small bucket ladder so the fused kernel
# compiles one program per (F, H, P) shape class, not one per cascade.
HIDDEN_BUCKETS = (2, 4, 8, 16, 32, 64, 128)


def hidden_bucket(h: int) -> int:
    for b in HIDDEN_BUCKETS:
        if h <= b:
            return b
    # beyond the ladder: round up to the next multiple of the top bucket
    top = HIDDEN_BUCKETS[-1]
    return ((h + top - 1) // top) * top


class PackedCascade(NamedTuple):
    """Whole-cascade packed parameters, bucket-padded to static shapes.

    ``w1[(f, h, p)]`` is hidden weight ``h`` of stage ``p``; hidden slots
    ``h >= hidden[p]`` are zero-padded (``relu(0 + 0) = 0`` and a zero
    readout weight keeps them inert).  ``H`` is the shared hidden bucket:
    ``hidden_bucket(max(hidden))``.

    ``dtype`` names the WEIGHT storage format (DESIGN.md §3, quantized
    packed format).  ``"float32"`` is the seed format: ``w1``/``w2`` are
    fp32 and ``out_scale`` is None.  Under ``"int8"`` (weight-only
    symmetric quantization, ``quantize_cascade``) ``w1``/``w2`` hold
    integer codes and the per-column hidden scales are FOLDED away at
    quantization time — ``b1`` is pre-divided by the hidden scale and the
    hidden scale is pre-multiplied into the readout before ITS
    quantization — so execution needs exactly one dequantizing multiply:
    ``scores = (relu(x @ w1 + b1) @ w2) * out_scale + b2`` with
    ``out_scale`` the (P,) per-stage readout scales.  ``"fp8"`` is the
    simulated-e4m3 variant (values rounded to the fp8 grid, stored fp32 —
    accuracy studies on hardware without native fp8).
    """

    w1: np.ndarray  # (F, H, P) float32 | int8 codes
    b1: np.ndarray  # (H, P) float32 (scale-folded when quantized)
    w2: np.ndarray  # (H, P) float32 | int8 readout codes
    b2: np.ndarray  # (P,) float32
    hidden: Tuple[int, ...]  # true per-stage hidden widths
    families: Tuple[str, ...]  # per-stage family names
    dtype: str = "float32"  # weight storage format
    out_scale: Optional[np.ndarray] = None  # (P,) f32 readout scales (quantized only)

    @property
    def n_features(self) -> int:
        return int(self.w1.shape[0])

    @property
    def H(self) -> int:
        return int(self.w1.shape[1])

    @property
    def n_stages(self) -> int:
        return int(self.w1.shape[2])


def pack_cascade(param_list: Sequence[object], *,
                 pack_fn: Callable[[object], PackedProxy] = None) -> PackedCascade:
    """Pack every stage's params (any mix of families) into one
    bucket-padded (F, H, P) tensor set.  ``pack_fn`` overrides the per-proxy
    packer (e.g. ``kernels.ops.pack_proxy_cached`` to memoize the fold)."""
    if not param_list:
        raise ValueError("pack_cascade needs at least one proxy")
    packs: List[PackedProxy] = []
    fams: List[str] = []
    for p in param_list:
        fam = family_of(p)
        packs.append(pack_fn(p) if pack_fn is not None else fam.pack(p))
        fams.append(fam.name)
    F = packs[0].w1.shape[0]
    for pk in packs:
        if pk.w1.shape[0] != F:
            raise ValueError("all cascade stages must share the feature dim")
    H = hidden_bucket(max(pk.hidden for pk in packs))
    P = len(packs)
    w1 = np.zeros((F, H, P), np.float32)
    b1 = np.zeros((H, P), np.float32)
    w2 = np.zeros((H, P), np.float32)
    b2 = np.zeros(P, np.float32)
    for p, pk in enumerate(packs):
        h = pk.hidden
        w1[:, :h, p] = pk.w1
        b1[:h, p] = pk.b1
        w2[:h, p] = pk.w2
        b2[p] = pk.b2
    return PackedCascade(w1=w1, b1=b1, w2=w2, b2=b2,
                         hidden=tuple(pk.hidden for pk in packs),
                         families=tuple(fams))


def unpack_cascade(packed: PackedCascade, col: int) -> PackedProxy:
    """Inverse of ``pack_cascade`` for one stage: strips the hidden bucket
    padding and returns the stage's folded PackedProxy.  Exact (bit-for-bit)
    for fp32 cascades.  For a QUANTIZED cascade the returned proxy is the
    fp32 depth-1 MLP that computes the identical quantized function —
    integer codes as hidden weights, scale-folded bias, and the per-stage
    ``out_scale`` multiplied back into the readout — so reference scoring,
    regret estimation, and re-serialization of a deserialized quantized
    artifact all see exactly what the kernel computes."""
    h = packed.hidden[col]
    w1 = np.ascontiguousarray(packed.w1[:, :h, col], np.float32)
    w2 = np.ascontiguousarray(packed.w2[:h, col], np.float32)
    if packed.out_scale is not None:
        w2 = w2 * np.float32(packed.out_scale[col])
    return PackedProxy(
        w1=w1,
        b1=np.ascontiguousarray(packed.b1[:h, col]),
        w2=w2,
        b2=np.float32(packed.b2[col]),
        hidden=h,
    )


# ---------------------------------------------------- weight-only quantization
QUANT_DTYPES = ("float32", "int8", "fp8")
# bytes per weight element as MOVED by the kernel — fp8 is simulated
# (stored fp32 in this container) but modeled at its native width so the
# roofline sweep prices what real-hardware fp8 would move
QUANT_WEIGHT_BYTES = {"float32": 4, "int8": 1, "fp8": 1}


def _fp8_grid(x: np.ndarray) -> np.ndarray:
    """Round to the float8_e4m3 grid and back to fp32 (saturating — e4m3
    overflow encodes NaN, so inputs are pre-clipped to ±448)."""
    import ml_dtypes

    clipped = np.clip(x, -448.0, 448.0).astype(np.float32)
    return clipped.astype(ml_dtypes.float8_e4m3fn).astype(np.float32)


def quantize_cascade(packed: PackedCascade, dtype: str = "int8") -> PackedCascade:
    """Weight-only symmetric quantization of a packed fp32 cascade, scales
    folded so the kernel dequantizes ONCE per tile (DESIGN.md §3):

    * per hidden column ``(h, p)``: ``s1[h,p] = max|w1[:,h,p]| / 127``;
      codes ``q1 = rint(w1 / s1)``.  Because ``relu(a·s) = s·relu(a)`` for
      ``s > 0``, the column scale commutes through the relu, so it is
      folded OUT of the hidden pass — ``b1`` becomes ``b1 / s1`` and
      ``s1`` multiplies into the readout weights — leaving the hidden GEMM
      pure integer codes.
    * per stage ``p`` over the scale-folded readout ``w2' = w2 · s1``:
      ``s2[p] = max|w2'[:,p]| / 127``; codes ``q2 = rint(w2' / s2)``;
      ``s2`` survives as ``out_scale``, the single dequantizing multiply
      ``scores = (relu(x @ q1 + b1') @ q2) · s2 + b2``.

    All-zero columns (hidden bucket padding) take scale 1 so the codes
    stay zero and the fold is the identity.  The linear family's exact
    ``relu(z) - relu(-z) == z`` embedding survives quantization: the +/-
    column pair shares one max-abs, hence one scale, and ``rint`` is odd,
    so the paired codes stay exact negations (tested).

    ``dtype="fp8"`` simulates float8_e4m3: same per-column scaling (to the
    e4m3 max of 448) and fold, values rounded to the fp8 grid but STORED
    fp32 — a fidelity study for hardware this container does not have; the
    roofline model prices it at 1 byte/weight, the wire ships fp32 bytes.
    """
    if packed.dtype != "float32":
        raise ValueError(f"cascade is already quantized ({packed.dtype})")
    if dtype == "float32":
        return packed
    if dtype not in QUANT_DTYPES:
        raise ValueError(f"unknown quantization dtype {dtype!r}; "
                         f"supported: {QUANT_DTYPES}")
    w1 = np.asarray(packed.w1, np.float32)
    w2 = np.asarray(packed.w2, np.float32)
    max_q = 127.0 if dtype == "int8" else 448.0
    a1 = np.max(np.abs(w1), axis=0)  # (H, P) per-hidden-column max
    s1 = np.where(a1 > 0, a1 / max_q, 1.0).astype(np.float32)
    if dtype == "int8":
        q1 = np.clip(np.rint(w1 / s1), -127, 127).astype(np.int8)
    else:
        q1 = _fp8_grid(w1 / s1)
    b1 = (np.asarray(packed.b1, np.float32) / s1).astype(np.float32)
    w2f = (w2 * s1).astype(np.float32)  # hidden scales folded into readout
    a2 = np.max(np.abs(w2f), axis=0)  # (P,) per-stage max
    s2 = np.where(a2 > 0, a2 / max_q, 1.0).astype(np.float32)
    if dtype == "int8":
        q2 = np.clip(np.rint(w2f / s2), -127, 127).astype(np.int8)
    else:
        q2 = _fp8_grid(w2f / s2)
    return PackedCascade(
        w1=q1, b1=b1, w2=q2, b2=np.asarray(packed.b2, np.float32),
        hidden=packed.hidden, families=packed.families,
        dtype=dtype, out_scale=s2,
    )


def cascade_kernel_operands(packed: PackedCascade):
    """Flatten (F, H, P) -> the kernel's two-GEMM operand layout.

    Returns ``(w1 (F, H*P), b1 (H*P,), w2 (H*P, P), b2 (P,))`` in h-major
    column order (column ``h*P + p`` is hidden unit ``h`` of stage ``p``);
    ``w2`` is the block-diagonal readout matrix of the second GEMM.
    Weight dtypes are preserved — a quantized cascade hands the kernel
    int8 code matrices (dequantized in-register via ``out_scale``, which
    travels separately on the scorer, not through this layout).
    """
    F, H, P = packed.w1.shape
    w1 = np.ascontiguousarray(packed.w1.reshape(F, H * P))
    b1 = np.ascontiguousarray(packed.b1.reshape(H * P))
    w2 = np.zeros((H * P, P), packed.w2.dtype)
    w2[np.arange(H * P), np.tile(np.arange(P), H)] = packed.w2.reshape(H * P)
    return w1, b1, w2, np.asarray(packed.b2, np.float32)
