"""Batched cascade executor (TPU-native adaptation of the paper's
row-stream executor — see DESIGN.md §3).

Executes a PhysicalPlan over a record stream in fixed-size microbatches:
proxy scores gate each expensive UDF; survivors are compacted so the UDF
always processes dense batches.  Cost is accounted both as measured wall
time and via the per-record cost model (ms/record), which is what the
paper's figures report.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.query import PhysicalPlan, Query


@dataclass
class StageStats:
    pred_idx: int
    n_in: int = 0
    n_proxy_kept: int = 0
    n_udf: int = 0
    n_pass: int = 0
    proxy_ms: float = 0.0
    udf_ms: float = 0.0

    @property
    def empirical_reduction(self) -> float:
        return 1.0 - self.n_proxy_kept / max(self.n_in, 1)


@dataclass
class ExecResult:
    passed: np.ndarray  # indices of records returned by the plan
    stages: List[StageStats]
    wall_ms: float
    model_cost_ms: float  # per-record cost model total (paper's metric)

    def cost_per_record(self, n: int) -> float:
        return self.model_cost_ms / max(n, 1)


def execute_plan(
    plan: PhysicalPlan,
    x: np.ndarray,
    *,
    batch_size: int = 8192,
    use_kernel: bool = False,
) -> ExecResult:
    """Run the cascade over ``x`` (N, F).  Returns passing record indices."""
    n = x.shape[0]
    stages = [StageStats(pred_idx=s.pred_idx) for s in plan.stages]
    t_start = time.perf_counter()
    model_cost = 0.0
    passed: List[np.ndarray] = []

    scorer = None
    if use_kernel:
        from repro.kernels import ops as kops

        scorer = kops.proxy_score_batch

    for start in range(0, n, batch_size):
        idx = np.arange(start, min(start + batch_size, n))
        alive = idx
        for si, stage in enumerate(plan.stages):
            st = stages[si]
            st.n_in += len(alive)
            if len(alive) == 0:
                continue
            if stage.proxy is not None:
                t0 = time.perf_counter()
                if scorer is not None and stage.proxy.kind == "svm":
                    keep = scorer(stage.proxy.params, x[alive], stage.threshold)
                else:
                    keep = stage.proxy.score(x[alive]) >= stage.threshold
                st.proxy_ms += (time.perf_counter() - t0) * 1e3
                model_cost += len(alive) * stage.proxy.cost
                alive = alive[np.asarray(keep)]
            st.n_proxy_kept += len(alive)
            if len(alive) == 0:
                continue
            pred = plan.query.predicates[stage.pred_idx]
            t0 = time.perf_counter()
            labels = pred.udf(x[alive])
            st.udf_ms += (time.perf_counter() - t0) * 1e3
            model_cost += len(alive) * pred.udf.cost
            st.n_udf += len(alive)
            alive = alive[pred.evaluate(labels)]
            st.n_pass += len(alive)
        passed.append(alive)

    return ExecResult(
        passed=np.concatenate(passed) if passed else np.empty(0, np.int64),
        stages=stages,
        wall_ms=(time.perf_counter() - t_start) * 1e3,
        model_cost_ms=model_cost,
    )


def plan_accuracy(result: ExecResult, orig: ExecResult) -> float:
    """Fraction of the original query's output kept by the optimized plan
    (the paper's definition of A)."""
    orig_set = set(orig.passed.tolist())
    if not orig_set:
        return 1.0
    kept = sum(1 for i in result.passed.tolist() if i in orig_set)
    return kept / len(orig_set)
