"""Batched cascade executor (TPU-native adaptation of the paper's
row-stream executor — see DESIGN.md §3).

Executes a PhysicalPlan over a record stream in fixed-size microbatches:
proxy scores gate each expensive UDF; survivors are compacted so the UDF
always processes dense batches.  Cost is accounted both as measured wall
time and via the per-record cost model (ms/record), which is what the
paper's figures report.

Proxy scoring paths, fastest first:

  * fused   — one ``CascadeScorer`` pass per microbatch scores EVERY
              proxied stage at once, every family (params packed at
              plan-compile time, bucket-padded static shapes, on-device
              survivor compaction); later stages just index the
              precomputed masks.
  * kernel  — per-stage Pallas call (``proxy_score_batch``, any family),
              kept for parity testing via ``fused=False``.
  * reference — pure numpy/jnp ``proxy.score`` via the family registry
              (``use_kernel=False`` only — the parity/ablation oracle).

``StageStats.used_kernel`` records which path actually gated each stage so
benchmarks cannot silently compare reference runs against kernel runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.query import PhysicalPlan
from repro.util import advisory_wall_ms



@dataclass
class StageStats:
    pred_idx: int
    n_in: int = 0
    n_proxy_kept: int = 0
    n_udf: int = 0
    n_pass: int = 0
    proxy_ms: float = 0.0
    udf_ms: float = 0.0
    used_kernel: bool = False  # True iff the Pallas path produced the gate

    @property
    def empirical_reduction(self) -> float:
        return 1.0 - self.n_proxy_kept / max(self.n_in, 1)


@dataclass
class ExecResult:
    passed: np.ndarray  # indices of records returned by the plan
    stages: List[StageStats]
    wall_ms: float
    model_cost_ms: float  # per-record cost model total (paper's metric)
    fused_score_ms: float = 0.0  # wall time in the fused whole-cascade pass

    def cost_per_record(self, n: int) -> float:
        return self.model_cost_ms / max(n, 1)

    @property
    def proxy_total_ms(self) -> float:
        """Total proxy-scoring wall time (fused pass + per-stage work)."""
        return self.fused_score_ms + sum(s.proxy_ms for s in self.stages)


def execute_plan(
    plan: PhysicalPlan,
    x: np.ndarray,
    *,
    batch_size: int = 8192,
    use_kernel: bool = False,
    fused: bool = True,
) -> ExecResult:
    """Run the cascade over ``x`` (N, F).  Returns passing record indices.

    ``use_kernel=True, fused=True`` takes the fused whole-cascade scorer;
    ``fused=False`` keeps the legacy one-kernel-call-per-stage path for
    parity and ablation runs.
    """
    n = x.shape[0]
    stages = [StageStats(pred_idx=s.pred_idx) for s in plan.stages]
    t_start = advisory_wall_ms()
    model_cost = 0.0
    fused_ms = 0.0
    passed: List[np.ndarray] = []

    scorer = None
    cascade = None
    compact_cols = None
    if use_kernel:
        from repro.kernels import ops as kops

        scorer = kops.proxy_score_batch
        if fused:
            cascade = kops.CascadeScorer.from_plan(plan, max_tile=batch_size)
        if cascade is not None:
            # only the FIRST gated stage ever sees a full tile, so only its
            # packed survivor list is consumed — assemble just that column
            # instead of computing every stage's list and discarding most
            compact_cols = tuple(
                col for col in (
                    cascade.stage_cols[si]
                    for si, st_ in enumerate(plan.stages) if st_.proxy is not None
                ) if col is not None
            )[:1]

    for start in range(0, n, batch_size):
        idx = np.arange(start, min(start + batch_size, n))
        masks = packed = None
        if cascade is not None:
            t0 = advisory_wall_ms()
            _, masks, packed, _counts = cascade.score_compact(
                x[idx], compact_cols=compact_cols)
            fused_ms += advisory_wall_ms() - t0
        loc = np.arange(len(idx))  # tile-local survivor positions
        for si, stage in enumerate(plan.stages):
            st = stages[si]
            st.n_in += len(loc)
            if len(loc) == 0:
                continue
            if stage.proxy is not None:
                n_enter = len(loc)
                t0 = advisory_wall_ms()
                col = cascade.stage_cols[si] if cascade is not None else None
                if masks is not None and col is not None:
                    if len(loc) == len(idx) and packed[col] is not None:
                        # full tile: use the on-device-compacted index list
                        # (score_compact already truncated it to counts[col])
                        loc = packed[col]
                    else:
                        loc = loc[masks[loc, col]]
                    st.used_kernel = True
                elif scorer is not None:
                    keep = scorer(stage.proxy.params, x[idx[loc]], stage.threshold)
                    loc = loc[np.asarray(keep)]
                    st.used_kernel = True
                else:
                    keep = stage.proxy.score(x[idx[loc]]) >= stage.threshold
                    loc = loc[keep]
                st.proxy_ms += advisory_wall_ms() - t0
                model_cost += n_enter * stage.proxy.cost
            st.n_proxy_kept += len(loc)
            if len(loc) == 0:
                continue
            pred = plan.query.predicates[stage.pred_idx]
            alive = idx[loc]
            t0 = advisory_wall_ms()
            labels = pred.udf(x[alive])
            st.udf_ms += advisory_wall_ms() - t0
            model_cost += len(alive) * pred.udf.cost
            st.n_udf += len(alive)
            loc = loc[pred.evaluate(labels)]
            st.n_pass += len(loc)
        passed.append(idx[loc])

    return ExecResult(
        passed=np.concatenate(passed) if passed else np.empty(0, np.int64),
        stages=stages,
        wall_ms=advisory_wall_ms() - t_start,
        model_cost_ms=model_cost,
        fused_score_ms=fused_ms,
    )


def plan_accuracy(result: ExecResult, orig: ExecResult) -> float:
    """Fraction of the original query's output kept by the optimized plan
    (the paper's definition of A)."""
    orig_set = set(orig.passed.tolist())
    if not orig_set:
        return 1.0
    kept = sum(1 for i in result.passed.tolist() if i in orig_set)
    return kept / len(orig_set)
