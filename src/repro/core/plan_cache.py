"""Cross-query plan cache with similarity warm-start (DESIGN.md §8).

CORE builds its proxy models *online per query* — the whole optimizer
exists to amortize that build cost inside one query.  At production
scale most new queries resemble old ones, so the remaining hot path is
the optimizer itself.  This module closes that loop:

* **Fingerprint** — a query maps to (a) an exact-identity blake2b digest
  over its predicate identities (UDF name, literal set, declared cost,
  class count), proxy family assignment, accuracy target, and the
  cost-model constants (step/eps), and (b) a normalized *stat vector*
  [accuracy target | per-predicate selectivities | per-predicate UDF
  cost shares | pairwise kappa² correlations] fed by audited reservoir
  statistics.  The digest answers "is this literally the same query?";
  the stat vector answers "how far have its statistics drifted?".
* **Index** — an append-bounded ``OrderedDict`` keyed by digest.  Exact
  lookups and nearest-neighbor probes both refresh recency, so eviction
  at capacity drops the least-recently-HIT entry.
* **Warm start** — on a match, ``warm_optimize`` (1) transplants the
  donor's trained-classifier cache into the fresh builder (the same
  mechanism ``ProxyBuilder.rebase`` uses across samples, re-validated
  per proxy by the Eq.-4.7 eps-approx test before any reuse) and
  (2) seeds the branch-and-bound tree with the donor's stale L-node
  measurements + surviving candidate set and ``resume``s — fresh search
  effort goes only where the widened stale bounds cannot prune.
* **Fallbacks** — a nearest neighbor beyond ``similarity_threshold``,
  or whose plan order carries ``estimate_order_regret`` beyond
  ``regret_tol`` under the probe's fresh selectivities, is rejected and
  the query cold-optimizes; the cold result is written back so the miss
  pays for the next query's hit.
* **Persistence** — entries serialize as COREWIRE ``plancache`` frames
  (payload = the entry's v1/v1.2 scorer artifact, meta = the JSON stats
  sidecar), length-prefixed in one container blob, so the cache
  survives restarts and ships coordinator->fleet byte-stably.  A
  corrupt entry is skipped with a warning; the rest of the file loads.

Correctness does not depend on any similarity judgment: an exact hit
replays a plan only for a digest-identical query at (near-)identical
stats, and a warm start still trains/validates every proxy against the
*new* query's labels — a bad neighbor can cost search visits, never
accuracy.
"""
from __future__ import annotations

import hashlib
import json
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from types import SimpleNamespace
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.proxy_family import get_family
from repro.core.query import PhysicalPlan, Query
from repro.util import advisory_wall_ms


PLANCACHE_MAGIC = b"COREPLNC"
PLANCACHE_VERSION = 1


def _families_for(query: Query, kind) -> List[str]:
    """Canonical per-predicate family names, mirroring
    ``ProxyBuilder.family_for`` so fingerprints computed before building
    match fingerprints recorded from built plans."""
    out = []
    for p in range(query.n):
        if isinstance(kind, dict):
            out.append(get_family(kind.get(p, "svm")).name)
        elif kind == "mixed":
            out.append("linear" if p % 2 == 0 else "mlp1")
        else:
            out.append(get_family(kind).name)
    return out


@dataclass(frozen=True)
class QueryFingerprint:
    """Exact-identity digest + normalized drift-stat vector for a query."""

    digest: str
    stat_vec: np.ndarray
    n_predicates: int
    schema: dict  # the JSON-safe fields the digest/vector were built from

    def distance(self, other_vec: np.ndarray) -> float:
        """Mean absolute componentwise distance — every component lives
        in [0, 1] (selectivities, cost shares, kappa², accuracy target),
        so the distance does too."""
        a, b = self.stat_vec, np.asarray(other_vec, np.float64)
        if a.shape != b.shape:
            return float("inf")
        return float(np.mean(np.abs(a - b)))


def fingerprint_query(
    query: Query,
    *,
    kind="svm",
    selectivities: Optional[Dict[int, float]] = None,
    correlations: Optional[Dict[Tuple[int, int], float]] = None,
    step: float = 0.02,
    eps: float = 0.1,
) -> QueryFingerprint:
    """Fingerprint ``query`` for the plan cache.

    ``selectivities``: audited per-predicate unconditional selectivities
    (reservoir / audit-monitor estimates); missing predicates default to
    0.5 (maximum-uncertainty prior) so a stats-free probe is still
    comparable with a stats-free entry.  ``correlations``: pairwise
    kappa² values keyed ``(i, j), i < j``; missing pairs default to 0.
    """
    sels = {int(p): float(v) for p, v in (selectivities or {}).items()}
    costs = [float(p.udf.cost) for p in query.predicates]
    total_cost = sum(costs) or 1.0
    families = _families_for(query, kind)
    preds = [
        {
            "udf": p.udf.name,
            "values": sorted(int(v) for v in p.values),
            "cost": float(p.udf.cost),
            "n_classes": int(p.udf.n_classes),
        }
        for p in query.predicates
    ]
    ident = {
        "preds": preds,
        "families": families,
        "accuracy_target": float(query.accuracy_target),
        "step": float(step),
        "eps": float(eps),
    }
    digest = hashlib.blake2b(
        json.dumps(ident, sort_keys=True, separators=(",", ":")).encode(),
        digest_size=16,
    ).hexdigest()
    vec = [float(query.accuracy_target)]
    vec += [sels.get(p, 0.5) for p in range(query.n)]
    vec += [c / total_cost for c in costs]
    corr = {tuple(sorted(k)): float(v) for k, v in (correlations or {}).items()}
    for i in range(query.n):
        for j in range(i + 1, query.n):
            vec.append(corr.get((i, j), 0.0))
    return QueryFingerprint(
        digest=digest,
        stat_vec=np.asarray(vec, np.float64),
        n_predicates=query.n,
        schema={"ident": ident, "stat_vec": [float(v) for v in vec]},
    )


@dataclass
class WarmStart:
    """Donor state ``optimize(warm_start=...)`` consumes: the trained-
    classifier cache, the donor B&B's L-node measurements, and its
    surviving candidate orders."""

    classifiers: Optional[dict] = None
    s_stars: Optional[Dict[Tuple[int, ...], float]] = None
    orders: Optional[List[Tuple[int, ...]]] = None


@dataclass
class PlanCacheStats:
    hits_exact: int = 0
    hits_warm: int = 0
    misses: int = 0
    fallbacks_similarity: int = 0  # nearest neighbor too far
    fallbacks_regret: int = 0      # neighbor's order regret too high
    writes: int = 0
    evictions: int = 0
    corrupt_skipped: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass
class PlanCacheEntry:
    digest: str
    stat_vec: np.ndarray
    artifact: bytes          # COREWIRE scorer artifact (exact-hit replay)
    sidecar: dict            # JSON-safe stats sidecar (persisted in the frame)
    classifiers: Optional[dict] = None  # in-memory only: live ProxyModels
    hits: int = 0

    @property
    def n_predicates(self) -> int:
        return int(self.sidecar.get("n_predicates", 0))


def _shim_plan(sidecar: dict) -> Optional[SimpleNamespace]:
    """Duck-typed plan for ``estimate_order_regret``: stages carrying the
    cached pricing fields plus a query shim with the UDF costs — enough
    to re-price the cached ORDER under a probe's fresh selectivities
    without deserializing the artifact or holding the donor query."""
    stages = sidecar.get("stages")
    if not stages:
        return None
    shim_stages = [
        SimpleNamespace(
            pred_idx=int(s["pred_idx"]),
            alpha=float(s["alpha"]),
            est_reduction=float(s["est_reduction"]),
            est_selectivity=float(s["est_selectivity"]),
            proxy=(None if s.get("proxy_cost") is None
                   else SimpleNamespace(cost=float(s["proxy_cost"]))),
        )
        for s in stages
    ]
    preds = [SimpleNamespace(udf=SimpleNamespace(cost=float(s["udf_cost"])))
             for s in sorted(stages, key=lambda s: s["pred_idx"])]
    return SimpleNamespace(
        stages=shim_stages,
        order=tuple(s.pred_idx for s in shim_stages),
        query=SimpleNamespace(predicates=preds),
    )


class PlanCache:
    """Append-bounded fingerprint index of past optimized plans.

    ``capacity`` bounds the entry count (least-recently-hit evicts);
    ``similarity_threshold`` is the maximum stat-vector distance a
    nearest neighbor may have to warm-start; ``regret_tol`` is the
    maximum Eq.-3.1 order regret of the neighbor's plan under the
    probe's fresh selectivities; ``exact_tol`` is the distance under
    which a digest-identical entry replays as an exact HIT (skipping
    proxy training entirely) instead of warm-starting a re-search.
    """

    def __init__(self, capacity: int = 32, *,
                 similarity_threshold: float = 0.15,
                 regret_tol: float = 0.1,
                 exact_tol: float = 1e-3,
                 k_donors: int = 3):
        self.capacity = int(capacity)
        self.similarity_threshold = float(similarity_threshold)
        self.regret_tol = float(regret_tol)
        self.exact_tol = float(exact_tol)
        # distance-weighted multi-donor blending: a warm start merges the
        # k nearest same-arity entries' s* maps instead of trusting the
        # single nearest (k_donors=1 restores single-donor seeding); with
        # one entry in range the behavior is identical by construction
        self.k_donors = max(1, int(k_donors))
        self._entries: "OrderedDict[str, PlanCacheEntry]" = OrderedDict()
        self.stats = PlanCacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def digests(self) -> List[str]:
        """Entry digests in recency order (least-recently-hit first)."""
        return list(self._entries)

    # ---------------------------------------------------------------- insert
    def put(self, fp: QueryFingerprint, plan: PhysicalPlan, *,
            artifact: Optional[bytes] = None) -> Optional[PlanCacheEntry]:
        """Record an optimized plan under ``fp``.  Harvests whatever
        donor state the plan carries: the builder's classifier cache and
        the B&B tree's measurements (``optimize(keep_state=True)`` /
        ``reoptimize``); a state-less plan still caches for exact-hit
        replay.  Returns the entry, or None if the plan cannot be
        serialized (no proxied stage)."""
        from repro.kernels.ops import WireFormatError, serialize_scorer

        if artifact is None:
            try:
                artifact = serialize_scorer(plan)
            except WireFormatError:
                return None
        orders: List[List[int]] = []
        s_stars: Dict[str, float] = {}
        bb = plan.meta.get("bnb")
        if bb is not None:
            raw_s, raw_o = bb.export_state()
            s_stars = {",".join(str(i) for i in k): float(v)
                       for k, v in raw_s.items()}
            orders = [list(o) for o in raw_o]
        classifiers = None
        builder = plan.meta.get("builder")
        if builder is not None:
            classifiers = builder.export_classifiers()
        stages = [
            {
                "pred_idx": int(s.pred_idx),
                "alpha": float(s.alpha),
                "est_reduction": float(s.est_reduction),
                "est_selectivity": float(s.est_selectivity),
                "proxy_cost": None if s.proxy is None else float(s.proxy.cost),
                "udf_cost": float(plan.query.predicates[s.pred_idx].udf.cost),
            }
            for s in plan.stages
        ]
        prev = self._entries.get(fp.digest)
        sidecar = {
            "digest": fp.digest,
            "n_predicates": int(fp.n_predicates),
            "stat_vec": [float(v) for v in fp.stat_vec],
            "ident": fp.schema["ident"],
            "plan_cost": float(plan.est_total_cost),
            "plan_version": int(plan.meta.get("plan_version", 0)),
            "stages": stages,
            "orders": orders,
            "s_stars": s_stars,
            "hits": prev.hits if prev is not None else 0,
        }
        entry = PlanCacheEntry(
            digest=fp.digest, stat_vec=np.asarray(fp.stat_vec, np.float64),
            artifact=artifact, sidecar=sidecar, classifiers=classifiers,
            hits=prev.hits if prev is not None else 0,
        )
        self._entries[fp.digest] = entry
        self._entries.move_to_end(fp.digest)
        self.stats.writes += 1
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return entry

    # ---------------------------------------------------------------- lookup
    def lookup(self, fp: QueryFingerprint
               ) -> Tuple[Optional[str], Optional[PlanCacheEntry], float]:
        """(kind, entry, distance): kind is "exact" (digest match at
        ~identical stats), "warm" (nearest neighbor within the
        similarity threshold — including a digest match whose stats
        drifted), or None.  A returned entry's recency refreshes."""
        same = self._entries.get(fp.digest)
        if same is not None:
            d = fp.distance(same.stat_vec)
            if d <= self.exact_tol:
                same.hits += 1
                same.sidecar["hits"] = same.hits
                self._entries.move_to_end(fp.digest)
                return "exact", same, d
        best: Optional[PlanCacheEntry] = None
        best_d = float("inf")
        for e in self._entries.values():
            if e.n_predicates != fp.n_predicates:
                continue
            d = fp.distance(e.stat_vec)
            if d < best_d:
                best, best_d = e, d
        if best is not None and best_d <= self.similarity_threshold:
            best.hits += 1
            best.sidecar["hits"] = best.hits
            self._entries.move_to_end(best.digest)
            return "warm", best, best_d
        return None, None, best_d

    def _drop(self, digest: str) -> None:
        self._entries.pop(digest, None)

    def _neighbors(self, fp: QueryFingerprint, k: int
                   ) -> List[Tuple[PlanCacheEntry, float]]:
        """The k nearest same-arity entries within the similarity
        threshold, nearest first.  Read-only: recency bookkeeping stays
        with ``lookup`` (which already refreshed the nearest)."""
        cands = []
        for e in self._entries.values():
            if e.n_predicates != fp.n_predicates:
                continue
            d = fp.distance(e.stat_vec)
            if d <= self.similarity_threshold:
                cands.append((e, d))
        cands.sort(key=lambda ed: (ed[1], ed[0].digest))
        return cands[:k]

    @staticmethod
    def _blend_donors(donors: List[Tuple[PlanCacheEntry, float]]
                      ) -> Tuple[Dict[Tuple[int, ...], float],
                                 List[Tuple[int, ...]]]:
        """Distance-weighted merge of the donors' exported search state.

        s* maps merge per prefix as an inverse-distance weighted mean
        over the donors that measured that prefix — a far donor's stale
        selectivity nudges, a near donor's dominates.  Candidate orders
        union: every donor's surviving set stays alive, so the merged
        seed can only widen (never wrongly narrow) the re-opened search.
        """
        num: Dict[Tuple[int, ...], float] = {}
        den: Dict[Tuple[int, ...], float] = {}
        orders: List[Tuple[int, ...]] = []
        seen = set()
        for entry, dist in donors:
            w = 1.0 / (dist + 1e-6)
            for key, s in entry.sidecar.get("s_stars", {}).items():
                prefix = tuple(int(i) for i in key.split(","))
                num[prefix] = num.get(prefix, 0.0) + w * float(s)
                den[prefix] = den.get(prefix, 0.0) + w
            for o in entry.sidecar.get("orders", []):
                t = tuple(int(i) for i in o)
                if t not in seen:
                    seen.add(t)
                    orders.append(t)
        s_stars = {p: num[p] / den[p] for p in num}
        return s_stars, orders

    # ----------------------------------------------------------- optimization
    def optimize_query(
        self,
        query: Query,
        x_sample: np.ndarray,
        options=None,
        *,
        selectivities: Optional[Dict[int, float]] = None,
        correlations: Optional[Dict[Tuple[int, int], float]] = None,
        accept_hit: bool = True,
    ) -> Tuple[PhysicalPlan, dict]:
        """Cache-aware ``build_plan``: exact HIT replays the cached plan
        (no proxy training at all); similar neighbors warm-start the
        builder + B&B (distance-weighted blend of the ``k_donors``
        nearest same-arity entries); anything else cold-optimizes.
        Every non-hit result is written back.  Returns ``(plan, info)``
        where ``info`` carries {path, distance, regret, donors,
        build_ms, digest}.

        ``accept_hit=False`` forces a digest-identical match down the
        warm path — callers that need live builder/B&B state (adaptive
        serving wants ``keep_state``) cannot serve a wire-replayed plan.
        """
        from repro.core.api import OptimizeOptions, build_plan
        from repro.kernels.ops import WireFormatError, deserialize_scorer
        from repro.serving.stats import estimate_order_regret

        opts = options or OptimizeOptions()
        fp = fingerprint_query(query, kind=opts.kind,
                               selectivities=selectivities,
                               correlations=correlations,
                               step=opts.step, eps=opts.eps)
        match, entry, dist = self.lookup(fp)
        info = {"path": "cold", "digest": fp.digest,
                "distance": dist, "regret": None, "donors": 0}
        if match == "exact" and accept_hit:
            t0 = advisory_wall_ms()
            try:
                plan, scorer = deserialize_scorer(entry.artifact, query)
            except WireFormatError as e:
                warnings.warn(
                    f"plan cache entry {entry.digest} failed to replay "
                    f"({e}); dropping it and cold-optimizing",
                    RuntimeWarning, stacklevel=2)
                self._drop(entry.digest)
                self.stats.corrupt_skipped += 1
            else:
                self.stats.hits_exact += 1
                plan.meta["plan_cache"] = {
                    "path": "hit", "digest": fp.digest, "distance": dist}
                info.update(path="hit", scorer=scorer,
                            build_ms=advisory_wall_ms() - t0)
                return plan, info
        warm: Optional[WarmStart] = None
        if match in ("exact", "warm") and entry is not None:
            # price the nearest neighbor's ORDER under the probe's fresh
            # stats; high regret means the order optimum moved and the
            # donors' candidate sets would steer the search wrong — fall
            # back cold
            regret = 0.0
            shim = _shim_plan(entry.sidecar)
            best_order = None
            if shim is not None:
                regret, best_order = estimate_order_regret(
                    shim, dict(selectivities or {}))
            info["regret"] = regret
            if regret > self.regret_tol:
                self.stats.fallbacks_regret += 1
            else:
                donors = self._neighbors(fp, self.k_donors)
                if not any(e is entry for e, _ in donors):
                    # lookup's pick always participates (an exact-digest
                    # match at drifted stats may sort behind strangers)
                    donors = [(entry, dist)] + donors[:self.k_donors - 1]
                s_stars, orders = self._blend_donors(donors)
                if shim is not None and orders and best_order not in orders:
                    # fresh stats prefer an order every donor search had
                    # pruned: keep the measurements, re-open the full
                    # candidate set
                    orders = []
                info["donors"] = len(donors)
                warm = WarmStart(classifiers=entry.classifiers,
                                 s_stars=s_stars or None,
                                 orders=orders or None)
        elif match is None and dist <= 1.0:
            self.stats.fallbacks_similarity += 1
        t0 = advisory_wall_ms()
        plan = build_plan(query, x_sample, opts.replace(keep_state=True),
                          warm_start=warm)
        build_ms = advisory_wall_ms() - t0
        if warm is not None:
            self.stats.hits_warm += 1
            info["path"] = "warm"
        else:
            self.stats.misses += 1
        self.put(fp, plan)
        if not opts.keep_state:
            plan.meta.pop("builder", None)
            plan.meta.pop("bnb", None)
        plan.meta["plan_cache"] = {
            "path": info["path"], "digest": fp.digest, "distance": dist}
        info["build_ms"] = build_ms
        info["trace"] = plan.meta.get("trace")
        return plan, info

    def warm_optimize(
        self,
        query: Query,
        x_sample: np.ndarray,
        *,
        selectivities: Optional[Dict[int, float]] = None,
        correlations: Optional[Dict[Tuple[int, int], float]] = None,
        mode: str = "core",
        kind="svm",
        step: float = 0.02,
        eps: float = 0.1,
        framework: str = "exhaustive",
        fine_grained: bool = True,
        seed: int = 0,
        keep_state: bool = False,
        quant_dtype: Optional[str] = None,
        accept_hit: bool = True,
    ) -> Tuple[PhysicalPlan, dict]:
        """Deprecated: use ``optimize_query(query, x, OptimizeOptions(...))``."""
        from repro.core.api import OptimizeOptions

        warnings.warn(
            "PlanCache.warm_optimize() is deprecated; use "
            "PlanCache.optimize_query(query, x_sample, OptimizeOptions(...))",
            DeprecationWarning, stacklevel=2)
        return self.optimize_query(
            query, x_sample,
            OptimizeOptions(mode=mode, kind=kind, step=step, eps=eps,
                            framework=framework, fine_grained=fine_grained,
                            seed=seed, keep_state=keep_state,
                            quant_dtype=quant_dtype),
            selectivities=selectivities, correlations=correlations,
            accept_hit=accept_hit)

    # ------------------------------------------------------------- write-back
    def record_plan(self, plan: PhysicalPlan, *,
                    selectivities: Optional[Dict[int, float]] = None,
                    step: float = 0.02, eps: float = 0.1) -> Optional[str]:
        """Write-back hook for the serving layers: fingerprint ``plan``'s
        query from its own stage estimates (the reservoir-fresh
        selectivities a re-optimization just measured) and insert/update.
        Returns the digest, or None if the plan cannot be cached (wire
        plans carry ``packed1`` proxies that cannot seed a builder —
        recording them would poison future warm starts)."""
        fams = {s.pred_idx: s.proxy.family
                for s in plan.stages if s.proxy is not None}
        if any(f == "packed1" for f in fams.values()):
            return None
        if len(fams) < plan.query.n:
            return None
        if selectivities is None:
            selectivities = {int(s.pred_idx): float(s.est_selectivity)
                             for s in plan.stages}
        fp = fingerprint_query(plan.query, kind=fams,
                               selectivities=selectivities,
                               step=step, eps=eps)
        entry = self.put(fp, plan)
        return entry.digest if entry is not None else None

    # ------------------------------------------------------------ persistence
    def to_bytes(self) -> bytes:
        """One length-prefixed COREWIRE ``plancache`` frame per entry:

            b"COREPLNC" | u16 version | u16 pad | u32 count
            | [u64 frame_len | frame]*

        Deterministic for a given cache state (canonical-JSON sidecars,
        artifact bytes verbatim), so save -> load -> save is byte-stable.
        """
        from repro.kernels.ops import FRAME_PLANCACHE, pack_le, serialize_frame

        out = bytearray()
        out += PLANCACHE_MAGIC
        out += pack_le(PLANCACHE_VERSION, 2)
        out += pack_le(0, 2)
        out += pack_le(len(self._entries), 4)
        for i, entry in enumerate(self._entries.values()):
            frame = serialize_frame(FRAME_PLANCACHE, i, entry.artifact,
                                    meta=entry.sidecar)
            out += pack_le(len(frame), 8)
            out += frame
        return bytes(out)

    @classmethod
    def from_bytes(cls, blob: bytes, **kwargs) -> "PlanCache":
        """Inverse of ``to_bytes``.  A corrupt entry (bad frame, wrong
        kind, mangled sidecar) is skipped with a warning — one poisoned
        entry must not take down the whole cache; a corrupt container
        header raises."""
        from repro.kernels.ops import (
            FRAME_PLANCACHE,
            WireFormatError,
            deserialize_frame,
            unpack_le,
        )

        cache = cls(**kwargs)
        if blob[:len(PLANCACHE_MAGIC)] != PLANCACHE_MAGIC:
            raise ValueError("bad magic: not a plan-cache container")
        ver = unpack_le(blob, 8, 2)
        if ver != PLANCACHE_VERSION:
            raise ValueError(f"unknown plan-cache container version {ver}")
        count = unpack_le(blob, 12, 4)
        off = 16
        for _ in range(count):
            if off + 8 > len(blob):
                warnings.warn(
                    "plan-cache container truncated: missing entries "
                    "skipped", RuntimeWarning, stacklevel=2)
                break
            flen = unpack_le(blob, off, 8)
            off += 8
            frame = blob[off:off + flen]
            off += flen
            if len(frame) != flen:
                warnings.warn(
                    "plan-cache container truncated mid-entry: entry "
                    "skipped", RuntimeWarning, stacklevel=2)
                cache.stats.corrupt_skipped += 1
                break
            try:
                kind, _epoch, payload, sidecar = deserialize_frame(frame)
                if kind != FRAME_PLANCACHE:
                    raise WireFormatError(f"unexpected frame kind {kind!r}")
                digest = str(sidecar["digest"])
                vec = np.asarray(sidecar["stat_vec"], np.float64)
                hits = int(sidecar.get("hits", 0))
            except (WireFormatError, KeyError, TypeError, ValueError) as e:
                warnings.warn(
                    f"corrupt plan-cache entry skipped ({e})",
                    RuntimeWarning, stacklevel=2)
                cache.stats.corrupt_skipped += 1
                continue
            cache._entries[digest] = PlanCacheEntry(
                digest=digest, stat_vec=vec, artifact=payload,
                sidecar=dict(sidecar), classifiers=None, hits=hits)
        return cache

    def save(self, path) -> None:
        from repro.util import atomic_write_bytes

        atomic_write_bytes(path, self.to_bytes())

    @classmethod
    def load(cls, path, **kwargs) -> "PlanCache":
        from pathlib import Path

        return cls.from_bytes(Path(path).read_bytes(), **kwargs)
