"""Small cross-cutting runtime helpers.

Two invariant-enforcing utilities live here, each distilled from a bug
class this repo actually shipped (see ``analysis/corelint.py`` and
DESIGN.md §9 for the rule catalog they anchor):

* ``advisory_wall_ms`` — THE sanctioned wall-clock read for decision-path
  modules (``serving/``, ``core/``, ``distributed/``).  Everything those
  modules decide (scheduling, degrade ladders, swap escalation) runs on
  the deterministic cost-model clock; wall-clock is advisory reporting
  only.  Funneling every read through one explicitly-named helper makes
  the corelint allowlist a single function instead of a module list —
  a raw ``time.perf_counter()`` in a decision module is a lint error.
* ``atomic_write_text`` / ``atomic_write_bytes`` — same-directory temp
  file + ``os.replace`` publish, the pattern ``kernels/autotune.py``
  hardened in PR 7 after a concurrent writer tore its disk cache.  Any
  shared-path ``open(path, "w")`` outside this pattern is a lint error.
"""
from __future__ import annotations

import os
import time


def advisory_wall_ms() -> float:
    """Milliseconds from a monotonic wall clock — ADVISORY ONLY.

    The returned value may feed stats fields, log lines, and advisory
    bench columns; it must never feed a scheduling, shedding, degrade,
    or swap decision (those run on the cost-model clock so results are
    bit-reproducible and gateable — DESIGN.md §2/§7).  corelint rule
    ``wall-clock-decision`` enforces that decision-path modules read
    wall time only through this helper.
    """
    return time.perf_counter() * 1e3


def atomic_write_bytes(path, data: bytes) -> None:
    """Publish ``data`` at ``path`` atomically: write a same-directory
    temp file, then ``os.replace``.  Readers see the old content or the
    new content, never a torn prefix; a concurrent writer loses the race
    wholesale instead of interleaving.  The temp name carries the pid so
    two processes publishing the same path cannot collide on it."""
    path = os.fspath(path)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_text(path, text: str, encoding: str = "utf-8") -> None:
    """``atomic_write_bytes`` for text content."""
    atomic_write_bytes(path, text.encode(encoding))
