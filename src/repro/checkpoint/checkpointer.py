"""Sharded checkpointing: save/restore arbitrary pytrees (params, optimizer
states, data-pipeline cursors) with async writes and integrity metadata.

Layout (one directory per step):

    <dir>/step_000100/
        meta.json            # tree structure, shapes, dtypes, step, checksum
        shard_<host>.npz     # this host's array shards (np.savez_compressed)

On a real multi-host pod each host writes only the addressable shards of
its arrays; in this single-host container that degenerates to one shard
file, but the layout and the restore path are the multi-host ones.
Restore supports *resharding*: a checkpoint written for one mesh can be
loaded into a differently-sharded (or unsharded) target tree — the basis of
elastic rescaling in ``repro.distributed.fault_tolerance``.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key(i: int) -> str:
    return f"leaf_{i:05d}"


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> Path:
        leaves, treedef = _flatten(tree)
        arrays = [np.asarray(l) for l in leaves]  # device->host gather
        # numpy .npz cannot round-trip ml_dtypes (bfloat16, fp8): store the
        # raw bits as unsigned ints and the true dtype in meta
        stored = []
        for a in arrays:
            if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
                stored.append(a.view(np.uint8 if a.dtype.itemsize == 1 else np.uint16))
            else:
                stored.append(a)
        target = self.dir / f"step_{step:08d}"

        def _write():
            tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_ckpt_"))
            try:
                payload = {_key(i): a for i, a in enumerate(stored)}
                np.savez_compressed(tmp / "shard_0.npz", **payload)
                digest = hashlib.sha256()
                for a in arrays:
                    digest.update(np.ascontiguousarray(a).tobytes())
                meta = {
                    "step": step,
                    "n_leaves": len(arrays),
                    "treedef": str(treedef),
                    "shapes": [list(a.shape) for a in arrays],
                    "dtypes": [str(a.dtype) for a in arrays],
                    "sha256": digest.hexdigest(),
                    "time": time.time(),
                }
                (tmp / "meta.json").write_text(json.dumps(meta))
                if target.exists():
                    shutil.rmtree(target)
                tmp.rename(target)  # atomic publish
            finally:
                if tmp.exists():
                    shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        self.wait()
        if self.async_save and not blocking:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()
        return target

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None, *, shardings=None) -> Any:
        """Restore into the structure of ``like``; optionally apply a pytree
        of NamedShardings (resharding for a new mesh)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        target = self.dir / f"step_{step:08d}"
        meta = json.loads((target / "meta.json").read_text())
        with np.load(target / "shard_0.npz") as data:
            arrays = [data[_key(i)] for i in range(meta["n_leaves"])]
        digest = hashlib.sha256()
        for a in arrays:
            digest.update(np.ascontiguousarray(a).tobytes())
        if digest.hexdigest() != meta["sha256"]:
            raise IOError(f"checkpoint {target} failed integrity check")
        # restore ml_dtypes stored as raw uint bits
        import ml_dtypes  # noqa: F401  (registers extension dtypes)

        arrays = [
            a.view(np.dtype(dt)) if a.dtype.name != dt else a
            for a, dt in zip(arrays, meta["dtypes"])
        ]
        leaves, treedef = _flatten(like)
        if len(leaves) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves; target needs {len(leaves)}"
            )
        out = []
        sh_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(arrays)
        )
        for tgt, arr, sh in zip(leaves, arrays, sh_leaves):
            a = arr.astype(tgt.dtype) if hasattr(tgt, "dtype") and arr.dtype != tgt.dtype else arr
            if sh is not None:
                out.append(jax.device_put(a, sh))
            else:
                out.append(jax.numpy.asarray(a))
        return jax.tree_util.tree_unflatten(treedef, out)
