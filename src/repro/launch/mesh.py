"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CPU integration tests (run in a subprocess with a
    forced device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
