"""HLO-text analyzer: FLOPs / HBM bytes / collective bytes with loop
trip-count multipliers.

Why not ``compiled.cost_analysis()`` alone?  XLA's cost analysis reports the
partitioned module's costs but counts every while-loop BODY ONCE — and our
models keep the layer stack inside ``lax.scan`` (essential for multi-device
compile time), so ~100% of the real cost sits inside while bodies.  This
module parses ``compiled.as_text()`` (post-optimization, post-SPMD), builds
the computation call graph (while bodies/conditions, fusions, calls),
extracts constant trip counts from while conditions, and accumulates:

  * dot FLOPs       : 2 * prod(out_shape) * prod(contracting dims)
  * HBM bytes       : kernel-boundary traffic — for every top-level op in an
                      executed computation, output bytes + operand bytes
                      (fusions appear as single ops, so this is
                      fusion-aware); parameters/GTE/bitcast/tuple are free
  * collective bytes: by op kind (all-gather / all-reduce / reduce-scatter /
                      all-to-all / collective-permute), output-shape bytes

All values are PER-DEVICE (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9].*?\)?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*\S.*\{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|branch_computations|to_apply)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "bitcast", "tuple",
             "after-all", "iota"}


def shape_bytes(type_str: str) -> float:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # value name -> type str


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_START_RE.match(line)
        if m and "=" not in line.split("(")[0]:
            current = Computation(name=m.group(1))
            comps[current.name] = current
            if line.lstrip().startswith("ENTRY"):
                entry = current.name
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, type_str, opcode = d.group(1), d.group(2), d.group(3)
        # operands: inside the first (...) after the opcode
        after = line[d.end():]
        depth = 1
        args = []
        for i, ch in enumerate(after):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = _OPERAND_RE.findall(after[:i])
                    break
        op = Op(name=name, type_str=type_str, opcode=opcode, line=line, operands=args)
        current.ops.append(op)
        current.shapes[name] = type_str
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Max s32 constant in a while condition ~= the scan length."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant" and op.type_str.strip().startswith(("s32[]", "u32[]", "s64[]")):
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS through call edges, accumulating multipliers
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        m = mult[cname]
        for op in comp.ops:
            attrs = _CALL_ATTR_RE.findall(op.line)
            if not attrs:
                continue
            if op.opcode == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _trip_count(comps[cond]) if cond and cond in comps else 1
                if body and body in comps:
                    mult[body] += m * trips
                    if body not in seen:
                        seen.add(body)
                        order.append(body)
                continue
            for group in attrs:
                for target in re.split(r",\s*%?", group):
                    target = target.strip().lstrip("%")
                    if target in comps and target != cname:
                        mult[target] += m
                        if target not in seen:
                            seen.add(target)
                            order.append(target)
    return dict(mult)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    lhs_dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contracting = 1
    if lhs_dims_m and op.operands:
        lhs_shape = _shape_dims(comp.shapes.get(op.operands[0], ""))
        if lhs_dims_m.group(1):
            for idx in lhs_dims_m.group(1).split(","):
                i = int(idx)
                if i < len(lhs_shape):
                    contracting *= lhs_shape[i]
    return 2.0 * out_elems * contracting


@dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)
    while_trip_counts: List[int] = field(default_factory=list)
    # op-level breakdowns: (bytes*mult, opcode, op_name metadata, type, mult)
    top_collectives: List[tuple] = field(default_factory=list)
    top_hbm: List[tuple] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _op_label(op: Op) -> str:
    m = _OPNAME_RE.search(op.line)
    return m.group(1) if m else op.name


_CONTROL_OPS = {"while", "conditional", "call", "optimization-barrier"}


def _fusion_targets(comps) -> set:
    targets = set()
    for comp in comps.values():
        for op in comp.ops:
            m = re.search(r"calls=%?([\w.\-]+)", op.line)
            if m:
                targets.add(m.group(1))
            m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
            if m:
                targets.add(m.group(1))
    return targets


def _param_ops_by_index(comp: Computation):
    out = {}
    for op in comp.ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.line)
            if m:
                out[int(m.group(1))] = op
    return out


_PASSTHROUGH = {"convert", "bitcast", "copy", "reshape", "transpose"}


def _param_slice_bytes(p_name: str, target: Computation) -> float:
    """Effective read bytes of a fusion parameter, following pass-through
    chains (convert/bitcast/...) until a real consumer:

    * dynamic-slice / gather      -> the slice's bytes
    * dynamic-update-slice dest   -> 0 (in-place destination, aliased)
    * anything else               -> full parameter bytes
    Returns the max over consumer paths (conservative)."""
    full = shape_bytes(target.shapes.get(p_name, ""))
    frontier = {p_name}
    best = 0.0
    visited = set()
    while frontier:
        nxt = set()
        for o in target.ops:
            if o.name in visited:
                continue
            hits = [x for x in o.operands if x in frontier]
            if not hits:
                continue
            visited.add(o.name)
            if o.opcode in _PASSTHROUGH:
                nxt.add(o.name)
            elif o.opcode in ("dynamic-slice", "gather"):
                best = max(best, shape_bytes(o.type_str))
            elif o.opcode == "dynamic-update-slice" and o.operands and o.operands[0] in frontier:
                # destination buffer of an in-place update: pass through so a
                # later real reader is still detected
                nxt.add(o.name)
            else:
                return full
        frontier = nxt
    return best


def _fusion_hbm_bytes(op: Op, comp: Computation, comps) -> float:
    """HBM traffic of a fusion call at the kernel boundary, slice/in-place/
    pass-through aware (mirrors TPU fusion semantics where convert chains
    fuse away and donated DUS buffers update in place)."""
    m = re.search(r"calls=%?([\w.\-]+)", op.line)
    target = comps.get(m.group(1)) if m else None
    out_b = shape_bytes(op.type_str)
    if target is None:
        return out_b + sum(shape_bytes(comp.shapes.get(o, "")) for o in op.operands)
    params = _param_ops_by_index(target)
    dus_ops = [o for o in target.ops if o.opcode == "dynamic-update-slice"]
    if dus_ops:
        # output traffic ~= bytes actually written (update regions)
        out_b = sum(shape_bytes(target.shapes.get(d.operands[1], "")) for d in dus_ops
                    if len(d.operands) > 1)
    total = out_b
    for i, operand in enumerate(op.operands):
        p = params.get(i)
        full = shape_bytes(comp.shapes.get(operand, ""))
        if p is None:
            total += full
            continue
        total += min(_param_slice_bytes(p.name, target), full)
    return total


def analyze(text: str) -> HloCosts:
    comps, entry = parse_module(text)
    if entry is None:
        return HloCosts()
    mult = _multipliers(comps, entry)
    fusion_targets = _fusion_targets(comps)
    costs = HloCosts()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                if cm and cm.group(1) in comps:
                    costs.while_trip_counts.append(_trip_count(comps[cm.group(1)]))
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                costs.flops += m * _dot_flops(op, comp)
            for coll in COLLECTIVE_OPS:
                if op.opcode == coll or op.opcode == f"{coll}-start":
                    b = shape_bytes(op.type_str)
                    costs.collective_bytes[coll] = costs.collective_bytes.get(coll, 0.0) + m * b
                    costs.collective_count[coll] = costs.collective_count.get(coll, 0) + 1
                    costs.top_collectives.append(
                        (m * b, coll, _op_label(op), op.type_str[:48], m)
                    )
                    break
            # ---- kernel-boundary HBM traffic.  Only control-flow-executed
            # computations count; fusion interiors are priced at call sites.
            if cname in fusion_targets:
                continue
            if op.opcode in _FREE_OPS or op.opcode in _CONTROL_OPS:
                continue
            if op.opcode == "fusion":
                b = _fusion_hbm_bytes(op, comp, comps)
            elif op.opcode == "dynamic-slice":
                b = 2 * shape_bytes(op.type_str)
            elif op.opcode == "dynamic-update-slice":
                upd = shape_bytes(comp.shapes.get(op.operands[1], "")) if len(op.operands) > 1 else 0.0
                b = 2 * upd
            else:
                out_b = shape_bytes(op.type_str)
                in_b = sum(shape_bytes(comp.shapes.get(o, "")) for o in op.operands)
                b = out_b + in_b
            costs.hbm_bytes += m * b
            costs.top_hbm.append((m * b, op.opcode, _op_label(op), op.type_str[:48], m))
    costs.top_collectives.sort(reverse=True)
    costs.top_hbm.sort(reverse=True)
    costs.top_collectives = costs.top_collectives[:40]
    costs.top_hbm = costs.top_hbm[:40]
    return costs
