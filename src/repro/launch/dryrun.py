import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the flag above must precede ANY jax import)
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, prove memory fit, and extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --multi-pod

Results are cached as JSON under results/dryrun/<mesh>/<arch>__<shape>.json
(one file per cell, incremental; --force recomputes).
"""
import argparse
import json
import time
import traceback
from repro.util import atomic_write_text
from pathlib import Path

import jax
import numpy as np

from repro.configs import SHAPES, get_config, supports_shape
from repro.configs.registry import ARCHS
from repro.distributed.sharding import (
    batch_sharding,
    cache_sharding,
    opt_shardings,
    params_shardings,
    serve_mode_for,
)
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.models.registry import get_family, input_specs
from repro.training.train_loop import make_train_step

# TPU v5e hardware constants (per chip), per the assignment
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def build_cell(arch: str, shape_name: str, mesh, variant: str = "baseline"):
    """Returns (fn, args_abstract, in_shardings, out_shardings, donate,
    token_spec) for the cell.

    variant="opt" applies the beyond-paper §Perf optimizations on top of the
    paper-faithful baseline (see EXPERIMENTS.md §Perf): donated KV caches and
    weight-stationary 2-D TP decode for the big dense archs.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    # mid-layer anchors were tried and REFUTED (see EXPERIMENTS.md SPerf)
    ctx_kw = {"token_spec": ("batch", None, None), "mid_anchors": False,
              "ep": variant == "opt", "attn_seq": variant == "opt"}
    if variant == "opt" and shape.kind == "train":
        # §Perf train iterations: Adafactor for the 100B+ archs (fits HBM),
        # deeper grad accumulation, bf16 grad accumulation (halves grad-AR)
        # deeper accumulation was tried and REFUTED: FSDP weight all-gathers
        # scale with microbatch count (+1.6TB/dev at accum=16) while Adafactor
        # already frees the memory that motivated it
        kw = {"grad_accum_dtype": "bfloat16"}
        if cfg.n_params() > 100e9:
            kw["optimizer"] = "adafactor"
        cfg = cfg.replace(**kw)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    params_abs = jax.eval_shape(lambda k: fam.init(k, cfg), key)

    if shape.kind == "train":
        from repro.training.train_loop import init_opt_state

        step = make_train_step(cfg)
        opt_abs = jax.eval_shape(lambda: init_opt_state(cfg, params_abs))
        p_sh = params_shardings(params_abs, mesh, "train")
        o_sh = opt_shardings(opt_abs, mesh, "train")
        b_sh = batch_sharding(specs, mesh)
        return (
            step,
            (params_abs, opt_abs, specs),
            (p_sh, o_sh, b_sh),
            (p_sh, o_sh, None),
            (0, 1) if variant == "opt" else None,  # donate params+opt buffers
            ctx_kw,
        )
    mode = serve_mode_for(cfg, mesh)
    p_sh = params_shardings(params_abs, mesh, mode)
    if shape.kind == "prefill":
        fn = lambda p, b: fam.prefill(p, cfg, b)  # noqa: E731
        b_sh = batch_sharding(specs, mesh)
        return fn, (params_abs, specs), (p_sh, b_sh), None, None, ctx_kw
    # decode
    fn = lambda p, c, t: fam.decode_step(p, cfg, c, t)  # noqa: E731
    cache_abs = specs["cache"]
    c_sh = cache_sharding(cache_abs, mesh)
    t_sh = batch_sharding(specs["tokens"], mesh)
    donate = None
    if variant == "opt":
        donate = (1,)  # alias the KV cache in-place
        if mode == "serve_2d":
            # weight-stationary decode: shard d_model over "data" (weights
            # never move; only the one-token activations are psum'd)
            ctx_kw["token_spec"] = ("pod", None, "data")
    return (
        fn,
        (params_abs, cache_abs, specs["tokens"]),
        (p_sh, c_sh, t_sh),
        (None, c_sh),
        donate,
        ctx_kw,
    )


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the whole step (global)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return cfg.flops_per_token(shape.seq_len, training=True) * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return cfg.flops_per_token(shape.seq_len, training=False) * tokens
    # decode: one token per sequence
    return cfg.flops_per_token(shape.seq_len, training=False) * shape.global_batch


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, force: bool = False,
             variant: str = "baseline") -> dict:
    mesh_tag = ("pod2x16x16" if multi_pod else "pod16x16") + (
        "" if variant == "baseline" else f"_{variant}"
    )
    out_dir = RESULTS_DIR / mesh_tag
    out_dir.mkdir(parents=True, exist_ok=True)
    out_file = out_dir / f"{arch}__{shape_name}.json"
    if out_file.exists() and not force:
        return json.loads(out_file.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "status": "skipped",
    }
    if not supports_shape(cfg, shape):
        rec["reason"] = "long_500k requires sub-quadratic attention (see DESIGN.md)"
        atomic_write_text(out_file, json.dumps(rec, indent=1))
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        from repro.distributed import ctx

        fn, args, in_sh, out_sh, donate, ctx_kw = build_cell(
            arch, shape_name, mesh, variant
        )
        kw = {"in_shardings": in_sh}
        if out_sh is not None:
            kw["out_shardings"] = out_sh
        if donate is not None:
            kw["donate_argnums"] = donate
        jitted = jax.jit(fn, **kw)
        with ctx.use_mesh(mesh, **ctx_kw):
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0c = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0c
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        costs = analyze(compiled.as_text())

        mf = model_flops(cfg, shape)
        per_dev_flops = costs.flops
        t_comp = per_dev_flops / PEAK_FLOPS
        t_mem = costs.hbm_bytes / HBM_BW
        t_coll = costs.total_collective_bytes / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        t_bound = max(terms.values())
        t_model = mf / (chips * PEAK_FLOPS)
        # memory-roofline floor: every live input/output byte moves exactly once
        arg_b = getattr(ma, "argument_size_in_bytes", 0) or 0
        out_b = getattr(ma, "output_size_in_bytes", 0) or 0
        alias_b = getattr(ma, "alias_size_in_bytes", 0) or 0
        mem_floor = arg_b + out_b - alias_b
        mem_eff = mem_floor / max(costs.hbm_bytes, 1.0)
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes_per_device": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes_per_device": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes_per_device": getattr(ma, "temp_size_in_bytes", None),
                "alias_bytes_per_device": getattr(ma, "alias_size_in_bytes", None),
            },
            xla_cost_analysis={
                "flops": ca.get("flops"),
                "bytes_accessed": ca.get("bytes accessed"),
            },
            hlo={
                "flops_per_device": per_dev_flops,
                "hbm_bytes_per_device": costs.hbm_bytes,
                "collective_bytes_per_device": costs.collective_bytes,
                "collective_count": costs.collective_count,
                "while_trip_counts": sorted(set(costs.while_trip_counts)),
            },
            roofline={
                "t_compute_s": t_comp,
                "t_memory_s": t_mem,
                "t_collective_s": t_coll,
                "dominant": dominant,
                "model_flops": mf,
                "model_flops_time_s": t_model,
                "useful_flops_ratio": mf / max(per_dev_flops * chips, 1.0),
                "roofline_fraction": t_model / max(t_bound, 1e-30),
                "memory_floor_bytes": mem_floor,
                "memory_efficiency": mem_eff,
            },
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    atomic_write_text(out_file, json.dumps(rec, indent=1))
    return rec


def cascade_dryrun(proxy_kind: str, *, n: int = 6000, preds: int = 3,
                   seed: int = 0) -> bool:
    """Compile-and-verify dry-run of the fused cascade scorer for one
    proxy family mix: builds a small synthetic query, optimizes a plan
    with ``--proxy-kind`` proxies, packs it through the ProxyFamily
    format, and checks the fused Pallas path end-to-end against the
    reference executor (same survivor set, every stage on the kernel).

        PYTHONPATH=src python -m repro.launch.dryrun --proxy-kind mixed
    """
    from repro.core import OptimizeOptions, build_plan, execute_plan
    from repro.data.synthetic import make_dataset, make_query, make_udfs
    from repro.kernels.ops import cascade_scorer_for_plan

    ds = make_dataset(n=n, correlation=0.9, seed=seed)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1000, seed=seed,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=list(range(preds)),
                   target_selectivity=0.5, accuracy_target=0.9, seed=seed + 1)
    k = max(800, n // 10)
    plan = build_plan(q, ds.x[:k],
                      OptimizeOptions(mode="core-a", step=0.05,
                                      kind=proxy_kind))
    print(plan.describe())
    scorer, _hit = cascade_scorer_for_plan(plan)
    packed = scorer.packed
    print(f"packed cascade: families={packed.families} hidden={packed.hidden} "
          f"(F, H, P)=({packed.n_features}, {packed.H}, {packed.n_stages}) "
          f"block_m={scorer.block_m}")
    x = ds.x[k:]
    ref = execute_plan(plan, x, use_kernel=False)
    fus = execute_plan(plan, x, use_kernel=True, fused=True)
    # boundary ties allowed: MLP standardizer folding agrees with the
    # reference to ~1e-4, so exact-threshold records may flip
    n_diff = len(set(ref.passed.tolist()) ^ set(fus.passed.tolist()))
    same = n_diff <= 3
    kernel_all = all(s.used_kernel for s in fus.stages)
    print(f"fused vs reference: disagreements={n_diff} "
          f"used_kernel={[s.used_kernel for s in fus.stages]} "
          f"fused_score_ms={fus.fused_score_ms:.1f}")
    ok = same and kernel_all
    print("cascade dry-run:", "OK" if ok else "MISMATCH")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=["baseline", "opt"])
    ap.add_argument("--proxy-kind", default=None, choices=["svm", "mlp", "mixed"],
                    help="run a fused-cascade dry-run for this proxy family "
                         "mix instead of the architecture sweep")
    args = ap.parse_args()

    if args.proxy_kind is not None:
        raise SystemExit(0 if cascade_dryrun(args.proxy_kind) else 1)

    cells = []
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a, s in cells:
            t0 = time.time()
            rec = run_cell(a, s, multi_pod=mp, force=args.force, variant=args.variant)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" dom={r['dominant']} frac={r['roofline_fraction']:.3f}"
                         f" compile={rec['compile_s']}s")
            elif status == "error":
                extra = " " + rec["error"][:120]
            print(f"[{'2x16x16' if mp else '16x16'}] {a} x {s}: {status}{extra}"
                  f" ({time.time()-t0:.1f}s)", flush=True)


if __name__ == "__main__":
    main()
