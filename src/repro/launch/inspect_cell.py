import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
"""Profiler for the dry-run: recompiles one cell and prints the top
collective / HBM contributors with op_name provenance — the 'profile' the
§Perf hillclimbing iterates on (no real-TPU timings in this container).

    PYTHONPATH=src python -m repro.launch.inspect_cell --arch X --shape Y [--multi-pod]
"""
import argparse

import jax

from repro.distributed import ctx
from repro.launch.dryrun import build_cell
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh


def inspect(arch: str, shape: str, multi_pod: bool = False, top: int = 18, variant: str = "baseline"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, out_sh, donate, ctx_kw = build_cell(arch, shape, mesh, variant)
    kw = {"in_shardings": in_sh}
    if out_sh is not None:
        kw["out_shardings"] = out_sh
    if donate is not None:
        kw["donate_argnums"] = donate
    jitted = jax.jit(fn, **kw)
    with ctx.use_mesh(mesh, **ctx_kw):
        compiled = jitted.lower(*args).compile()
    costs = analyze(compiled.as_text())
    ma = compiled.memory_analysis()
    print(f"== {arch} x {shape} ==")
    print(f"flops/dev {costs.flops:.3e}  hbm/dev {costs.hbm_bytes/1e9:.1f} GB  "
          f"coll/dev {costs.total_collective_bytes/1e9:.1f} GB  "
          f"temp {getattr(ma, 'temp_size_in_bytes', 0)/1e9:.1f} GB")
    print("-- top collectives (bytes x loop-mult) --")
    for b, kind, label, t, m in costs.top_collectives[:top]:
        print(f"  {b/1e9:10.2f} GB  {kind:19s} x{m:5.0f}  {t:40s} {label[:80]}")
    print("-- top HBM ops --")
    for b, kind, label, t, m in costs.top_hbm[:top]:
        print(f"  {b/1e9:10.2f} GB  {kind:19s} x{m:5.0f}  {t:40s} {label[:80]}")
    return costs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=18)
    ap.add_argument("--variant", default="baseline")
    a = ap.parse_args()
    inspect(a.arch, a.shape, a.multi_pod, a.top, a.variant)
