"""Training launcher: any assigned architecture, reduced (CPU) or full
(TPU pod) scale, with the full resilience substrate.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-2.7b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch llama3-405b --full \\
        --mesh 16x16   # on a real pod; CPU containers should stay reduced
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, reduced_config
from repro.data.pipeline import Cursor, ShardedStream
from repro.distributed.fault_tolerance import ResilientRunner, StragglerDetector
from repro.models.registry import make_batch
from repro.training.train_loop import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true", help="full config (pod scale)")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else reduced_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family} params~{cfg.n_params()/1e6:.1f}M "
          f"opt={cfg.optimizer} devices={len(jax.devices())}")
    step_fn = jax.jit(make_train_step(cfg, lr=args.lr))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    data = rng.randint(0, cfg.vocab_size, size=(8192, args.seq + 1)).astype(np.int32)
    stream = ShardedStream(data, batch=args.batch, seed=0)
    ck = Checkpointer(Path(args.ckpt_dir) / cfg.name, keep=3)
    start = 0
    state = (params, opt, stream.cursor.as_dict())
    if args.resume and ck.latest_step() is not None:
        start = ck.latest_step()
        state = ck.restore(state)
        stream.cursor = Cursor.from_dict(
            jax.tree.map(lambda x: int(x), state[2])
        )
        print(f"resumed from step {start}")
    it = iter(stream)

    def run_step(state, step):
        p, o, _cur = state
        if cfg.family in ("encdec", "vlm"):
            batch = make_batch(cfg, args.batch, args.seq, jax.random.PRNGKey(step))
        else:
            seqs = next(it)
            batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        p, o, m = step_fn(p, o, batch)
        if step % 10 == 0:
            print(f"  step {step}: loss {float(m['loss']):.4f}")
        return (p, o, stream.cursor.as_dict())

    runner = ResilientRunner(
        run_step,
        lambda s, st: ck.save(s, st),
        lambda: (ck.latest_step(), ck.restore(state)),
        checkpoint_every=args.ckpt_every,
        straggler=StragglerDetector(),
    )
    t0 = time.time()
    state, report = runner.run(state, args.steps, start_step=start)
    dt = time.time() - t0
    print(f"done: {report.steps_done} steps in {dt:.1f}s "
          f"({report.restarts} restarts, {report.straggler_events} stragglers)")


if __name__ == "__main__":
    main()
