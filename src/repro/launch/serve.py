"""Serving launcher: build a CORE-optimized cascade for an ML inference
query and serve a record stream with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --correlation 0.9 \\
        --accuracy 0.9 --mode core
"""
from __future__ import annotations

import argparse

from repro.core import execute_plan, ns_plan, optimize, orig_plan, plan_accuracy, pp_plan
from repro.data.synthetic import make_dataset, make_query, make_udfs
from repro.serving.engine import CascadeServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--correlation", type=float, default=0.9)
    ap.add_argument("--accuracy", type=float, default=0.9)
    ap.add_argument("--mode", default="core", choices=["core", "core-a", "core-h", "pp", "ns", "orig"])
    ap.add_argument("--preds", type=int, default=2)
    ap.add_argument("--tile", type=int, default=1024)
    ap.add_argument("--udf-cost-ms", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ds = make_dataset(n=args.n, correlation=args.correlation, seed=args.seed)
    udfs = make_udfs(ds, hidden=64, depth=2, train_rows=3000, seed=args.seed,
                     declared_cost_ms=args.udf_cost_ms)
    q = make_query(ds, udfs, columns=list(range(args.preds)),
                   target_selectivity=0.5, accuracy_target=args.accuracy,
                   seed=args.seed + 1)
    print("query:", " AND ".join(q.names()), f"A={args.accuracy}")
    k = max(1000, int(0.05 * args.n))
    if args.mode == "orig":
        plan = orig_plan(q)
    elif args.mode == "ns":
        plan = ns_plan(q, ds.x[:k])
    elif args.mode == "pp":
        plan = pp_plan(q, ds.x[:k])
    else:
        plan = optimize(q, ds.x[:k], mode=args.mode)
    print(plan.describe())

    server = CascadeServer(plan, tile=args.tile, use_kernel=True)
    stats = server.run_stream(ds.x[k:])
    orig_res = execute_plan(orig_plan(q), ds.x[k:])
    res = execute_plan(plan, ds.x[k:])
    print(f"\nserved {len(ds.x) - k} records in {stats.wall_ms:.0f} ms wall; "
          f"emitted {stats.emitted}")
    print(f"cost model: {res.cost_per_record(len(ds.x)-k):.3f} ms/rec "
          f"(ORIG {orig_res.cost_per_record(len(ds.x)-k):.3f}); "
          f"accuracy {plan_accuracy(res, orig_res):.3f}")


if __name__ == "__main__":
    main()
