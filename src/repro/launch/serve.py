"""Serving launcher: build a CORE-optimized cascade for an ML inference
query and serve a record stream with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --correlation 0.9 \\
        --accuracy 0.9 --mode core

``--drift`` serves an order-inverting drifting stream instead of held-out
rows; add ``--adaptive`` to let the server detect the drift and
re-optimize mid-stream (DESIGN.md §4).
"""
from __future__ import annotations

import argparse

from repro.core import execute_plan, ns_plan, optimize, orig_plan, pp_plan
from repro.data.synthetic import make_dataset, make_drifting_stream, make_query, make_udfs
from repro.serving.engine import CascadeServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--correlation", type=float, default=0.9)
    ap.add_argument("--accuracy", type=float, default=0.9)
    ap.add_argument("--mode", default="core", choices=["core", "core-a", "core-h", "pp", "ns", "orig"])
    ap.add_argument("--proxy-kind", default="svm", choices=["svm", "mlp", "mixed"],
                    help="proxy family per predicate: all-linear, all-MLP, "
                         "or alternating (every kind rides the fused scorer)")
    ap.add_argument("--preds", type=int, default=2)
    ap.add_argument("--tile", type=int, default=1024)
    ap.add_argument("--udf-cost-ms", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adaptive", action="store_true",
                    help="drift-triggered online re-optimization")
    ap.add_argument("--drift", action="store_true",
                    help="serve a drifting stream (selectivity + correlation shift)")
    args = ap.parse_args()

    ds = make_dataset(n=args.n, correlation=args.correlation, seed=args.seed)
    udfs = make_udfs(ds, hidden=64, depth=2, train_rows=3000, seed=args.seed,
                     declared_cost_ms=args.udf_cost_ms)
    q = make_query(ds, udfs, columns=list(range(args.preds)),
                   target_selectivity=0.5, accuracy_target=args.accuracy,
                   seed=args.seed + 1)
    print("query:", " AND ".join(q.names()), f"A={args.accuracy}")
    k = max(1000, int(0.05 * args.n))
    if args.mode == "orig":
        plan = orig_plan(q)
    elif args.mode == "ns":
        plan = ns_plan(q, ds.x[:k], kind=args.proxy_kind)
    elif args.mode == "pp":
        plan = pp_plan(q, ds.x[:k], kind=args.proxy_kind)
    else:
        plan = optimize(q, ds.x[:k], mode=args.mode, kind=args.proxy_kind,
                        keep_state=args.adaptive)
    print(plan.describe())
    if any(s.proxy is not None for s in plan.stages):
        print("proxy families:",
              " ".join(s.proxy.family for s in plan.stages if s.proxy is not None))

    if args.drift:
        stream = make_drifting_stream(
            ds, max(args.n // 4, 2000), args.n - k,
            shift_targets={c: (2.8 if c != 1 else -2.6) for c in range(args.preds)},
            corr_gain=2.5, seed=args.seed,
        )
        x_serve = stream.x
        print(f"drifting stream: {stream.n} records, boundary at "
              f"{stream.boundary}")
    else:
        x_serve = ds.x[k:]
    server = CascadeServer(plan, tile=args.tile, use_kernel=True,
                           adaptive=args.adaptive, seed=args.seed)
    stats = server.run_stream(x_serve)
    orig_res = execute_plan(orig_plan(q), x_serve)
    # accuracy of what was actually SERVED (mid-stream swaps included),
    # not a re-execution of the final plan over the whole stream
    orig_set = set(orig_res.passed.tolist())
    served_acc = (sum(1 for i in server.emitted if i in orig_set)
                  / max(len(orig_set), 1))
    print(f"\nserved {len(x_serve)} records in {stats.wall_ms:.0f} ms wall; "
          f"emitted {stats.emitted} (+{stats.rejected} rejected)")
    if args.adaptive:
        print(f"adaptive: {stats.plan_swaps} plan swap(s), "
              f"{stats.audit_records} audit records "
              f"({stats.audit_cost_ms:.0f} ms cost), reopt "
              f"{stats.reopt_ms:.0f} ms wall")
        for ev in stats.drift_events:
            print(f"  drift@{ev.at_record} [{ev.signal}] obs={ev.observed:.3f} "
                  f"exp={ev.expected:.3f} -> "
                  f"{'warm B&B' if ev.escalated else 're-allocation'} "
                  f"({ev.nodes_visited} nodes), order "
                  f"{ev.order_before} -> {ev.order_after}")
    print(f"cost model: {stats.model_cost_ms / len(x_serve):.3f} ms/rec "
          f"(ORIG {orig_res.cost_per_record(len(x_serve)):.3f}); "
          f"served accuracy {served_acc:.3f}")


if __name__ == "__main__":
    main()
