"""Serving launcher: build a CORE-optimized cascade for an ML inference
query and serve a record stream with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --correlation 0.9 \\
        --accuracy 0.9 --mode core

``--drift`` serves an order-inverting drifting stream instead of held-out
rows; add ``--adaptive`` to let the server detect the drift and
re-optimize mid-stream (DESIGN.md §4).  ``--hosts K`` (with K > 1) shards
the stream across K simulated hosts with quorum-voted global plan swaps
(DESIGN.md §6); per-shard drift magnitudes are skewed, so single-host
detectors disagree and the quorum is load-bearing.
"""
from __future__ import annotations

import argparse

from repro.core import execute_plan, ns_plan, optimize, orig_plan, pp_plan
from repro.data.synthetic import (
    make_dataset,
    make_drifting_stream,
    make_query,
    make_sharded_drifting_streams,
    make_udfs,
)
from repro.serving.engine import CascadeServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--correlation", type=float, default=0.9)
    ap.add_argument("--accuracy", type=float, default=0.9)
    ap.add_argument("--mode", default="core", choices=["core", "core-a", "core-h", "pp", "ns", "orig"])
    ap.add_argument("--proxy-kind", default="svm", choices=["svm", "mlp", "mixed"],
                    help="proxy family per predicate: all-linear, all-MLP, "
                         "or alternating (every kind rides the fused scorer)")
    ap.add_argument("--quant-dtype", default="fp32",
                    choices=["fp32", "int8", "fp8"],
                    help="weight storage dtype for the packed cascade: "
                         "int8/fp8 quantize at plan-compile time (scales "
                         "folded into the readout; masks flip only within "
                         "the calibrated threshold tolerance)")
    ap.add_argument("--preds", type=int, default=2)
    ap.add_argument("--tile", type=int, default=1024)
    ap.add_argument("--udf-cost-ms", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adaptive", action="store_true",
                    help="drift-triggered online re-optimization")
    ap.add_argument("--drift", action="store_true",
                    help="serve a drifting stream (selectivity + correlation shift)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="shard serving across K simulated hosts with "
                         "quorum-voted plan swaps (K > 1 implies adaptive)")
    ap.add_argument("--drift-skew", type=float, default=0.3,
                    help="per-shard drift magnitude skew (multi-host only)")
    ap.add_argument("--transport", default="inline",
                    choices=["inline", "thread", "process"],
                    help="multi-host transport: same-thread objects, one "
                         "worker thread per host, or one OS subprocess per "
                         "host (COREWIRE + newline-JSON control pipes)")
    ap.add_argument("--kill-coordinator-at", default=None,
                    help="failure injection: kill the primary coordinator "
                         "at 'prepare' | 'commit' | 'mid-commit' (phases "
                         "of an in-flight swap) or an integer submitted-"
                         "record count; the standby takes over on "
                         "heartbeat loss (DESIGN.md §6 failure model)")
    ap.add_argument("--straggler-host", type=int, default=None,
                    help="failure injection: this host misses the first "
                         "prepare barrier; the fleet commits without it "
                         "(serve-behind fencing) and re-syncs it on rejoin")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="serve through the SLO-aware request front end "
                         "(DESIGN.md §7): the stream becomes deadline-"
                         "carrying requests, goodput (requests/s meeting "
                         "the SLO) is reported next to raw throughput, "
                         "and backpressure degrades to cheaper plans / "
                         "sheds expired work instead of queueing forever")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="request arrivals per cost-model second (Poisson; "
                         "default ~1.3x the full plan's capacity, i.e. "
                         "mild overload so the backpressure policy has "
                         "something to do); needs --slo-ms")
    ap.add_argument("--request-rows", type=int, default=128,
                    help="records per request on the front-end path")
    ap.add_argument("--no-backpressure", action="store_true",
                    help="disable degrade + shedding on the front end "
                         "(watch the latency collapse under overload)")
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="cross-query plan cache file (DESIGN.md §8): "
                         "warm-start this query's optimization from the "
                         "most similar cached plan (exact repeats replay "
                         "with no proxy training at all), and persist "
                         "every plan this run commits — including drift "
                         "re-optimizations — back to PATH for the next run")
    args = ap.parse_args()

    ds = make_dataset(n=args.n, correlation=args.correlation, seed=args.seed)
    udfs = make_udfs(ds, hidden=64, depth=2, train_rows=3000, seed=args.seed,
                     declared_cost_ms=args.udf_cost_ms)
    q = make_query(ds, udfs, columns=list(range(args.preds)),
                   target_selectivity=0.5, accuracy_target=args.accuracy,
                   seed=args.seed + 1)
    print("query:", " AND ".join(q.names()), f"A={args.accuracy}")
    k = max(1000, int(0.05 * args.n))
    cache = None
    if args.plan_cache and args.mode in ("core", "core-a", "core-h"):
        import os

        from repro.core import PlanCache

        cache = (PlanCache.load(args.plan_cache)
                 if os.path.exists(args.plan_cache) else PlanCache())
        print(f"plan cache: {args.plan_cache} ({len(cache)} entries)")
    if args.mode == "orig":
        plan = orig_plan(q)
    elif args.mode == "ns":
        plan = ns_plan(q, ds.x[:k], kind=args.proxy_kind)
    elif args.mode == "pp":
        plan = pp_plan(q, ds.x[:k], kind=args.proxy_kind)
    else:
        # K > 1 implies the adaptive loop: the coordinator's quorum
        # re-optimizations need the builder/B&B state to warm-start
        keep = args.adaptive or args.hosts > 1
        qd = None if args.quant_dtype == "fp32" else args.quant_dtype
        if cache is not None:
            # adaptive/sharded serving needs a live builder/B&B on the
            # plan, which an exact-hit wire replay cannot carry — those
            # callers take the warm path instead of the HIT fast path
            plan, info = cache.warm_optimize(
                q, ds.x[:k], mode=args.mode, kind=args.proxy_kind,
                keep_state=keep, quant_dtype=qd, accept_hit=not keep)
            print(f"plan cache: {info['path'].upper()} "
                  f"(distance {info['distance']:.4f}, "
                  f"build {info['build_ms']:.0f} ms)")
        else:
            plan = optimize(q, ds.x[:k], mode=args.mode, kind=args.proxy_kind,
                            keep_state=keep, quant_dtype=qd)
    print(plan.describe())
    if plan.meta.get("quant_dtype"):
        print(f"packed cascade weights: {plan.meta['quant_dtype']}")
    if any(s.proxy is not None for s in plan.stages):
        print("proxy families:",
              " ".join(s.proxy.family for s in plan.stages if s.proxy is not None))

    if args.hosts > 1:
        _serve_sharded(args, ds, q, plan, cache)
        _save_cache(cache, args)
        return

    if args.slo_ms is not None:
        _serve_frontend(args, ds, plan, k, cache)
        _save_cache(cache, args)
        return

    if args.drift:
        stream = make_drifting_stream(
            ds, max(args.n // 4, 2000), args.n - k,
            shift_targets={c: (2.8 if c != 1 else -2.6) for c in range(args.preds)},
            corr_gain=2.5, seed=args.seed,
        )
        x_serve = stream.x
        print(f"drifting stream: {stream.n} records, boundary at "
              f"{stream.boundary}")
    else:
        x_serve = ds.x[k:]
    server = CascadeServer(plan, tile=args.tile, use_kernel=True,
                           adaptive=args.adaptive, seed=args.seed,
                           plan_cache=cache)
    stats = server.run_stream(x_serve)
    orig_res = execute_plan(orig_plan(q), x_serve)
    # accuracy of what was actually SERVED (mid-stream swaps included),
    # not a re-execution of the final plan over the whole stream
    orig_set = set(orig_res.passed.tolist())
    served_acc = (sum(1 for i in server.emitted if i in orig_set)
                  / max(len(orig_set), 1))
    print(f"\nserved {len(x_serve)} records in {stats.wall_ms:.0f} ms wall; "
          f"emitted {stats.emitted} (+{stats.rejected} rejected)")
    if args.adaptive:
        print(f"adaptive: {stats.plan_swaps} plan swap(s), "
              f"{stats.audit_records} audit records "
              f"({stats.audit_cost_ms:.0f} ms cost), reopt "
              f"{stats.reopt_ms:.0f} ms wall")
        for ev in stats.drift_events:
            print(f"  drift@{ev.at_record} [{ev.signal}] obs={ev.observed:.3f} "
                  f"exp={ev.expected:.3f} -> "
                  f"{'warm B&B' if ev.escalated else 're-allocation'} "
                  f"({ev.nodes_visited} nodes), order "
                  f"{ev.order_before} -> {ev.order_after}")
    print(f"cost model: {stats.model_cost_ms / len(x_serve):.3f} ms/rec "
          f"(ORIG {orig_res.cost_per_record(len(x_serve)):.3f}); "
          f"served accuracy {served_acc:.3f}")
    _save_cache(cache, args)


def _save_cache(cache, args):
    """Persist the plan cache (COREPLNC container) with this run's
    write-backs so the next ``--plan-cache`` run warm-starts from them."""
    if cache is None:
        return
    cache.save(args.plan_cache)
    st = cache.stats
    print(f"plan cache saved: {len(cache)} entries -> {args.plan_cache} "
          f"({st.hits_exact} exact / {st.hits_warm} warm hits, "
          f"{st.writes} writes)")


def _serve_frontend(args, ds, plan, k, cache=None):
    """Single-host serving through the SLO-aware request front end: the
    held-out stream arrives as Poisson requests with per-request
    deadlines; goodput is reported next to raw throughput (DESIGN.md
    §7).  All timing is the cost-model clock, so runs are deterministic
    for a fixed seed."""
    import numpy as np

    from repro.serving.frontend import ServingFrontEnd, SLOPolicy

    held = ds.x[k:]
    rows_per = max(1, args.request_rows)
    n_req = len(held) // rows_per
    if n_req == 0:
        raise SystemExit(f"--request-rows {rows_per} larger than the "
                         f"held-out stream ({len(held)} rows)")
    # capacity on the cost-model clock: the plan's Eq. 3.1 estimate says
    # one request costs est_total_cost * rows_per ms at the full plan
    req_ms = plan.est_total_cost * rows_per
    rate = args.arrival_rate or 1.3 / (req_ms / 1e3)
    rng = np.random.RandomState(args.seed)
    arrivals = np.cumsum(rng.exponential(1e3 / rate, n_req))
    bp = not args.no_backpressure
    server = CascadeServer(plan, tile=args.tile, use_kernel=True,
                           seed=args.seed, plan_cache=cache)
    fe = ServingFrontEnd(server, policy=SLOPolicy(degrade=bp,
                                                  shed_expired=bp))
    for r in range(n_req):
        idx = np.arange(k + r * rows_per, k + (r + 1) * rows_per)
        fe.submit_request(idx, ds.x[idx], deadline_ms=args.slo_ms,
                          arrival_ms=float(arrivals[r]))
    st = fe.run()
    ok, msg = fe.conserved()
    lat = [r.latency_ms for r in fe.requests.values() if r.done]
    print(f"\nfront end: {st.requests_total} requests x {rows_per} rows, "
          f"SLO {args.slo_ms:.0f} ms, arrivals {rate:.2f} req/s "
          f"(backpressure {'on' if bp else 'OFF'})")
    print(f"goodput {st.goodput_rps:.2f} req/s vs throughput "
          f"{st.throughput_rps:.2f} req/s (ratio {st.goodput_ratio:.3f}); "
          f"p50/p95 latency {np.percentile(lat, 50):.0f}/"
          f"{np.percentile(lat, 95):.0f} ms")
    print(f"backpressure: {st.degrades} degrade(s), {st.restores} "
          f"restore(s), final ladder level {st.final_level}; shed "
          f"{st.records_shed} records across {st.requests_shed} "
          f"request(s) [explicit, never silent]")
    print(f"records: {st.records_submitted} submitted -> "
          f"{st.records_emitted} emitted + {st.records_rejected} "
          f"rejected; conservation {'OK' if ok else 'VIOLATED: ' + msg}")


def _serve_sharded(args, ds, q, plan, cache=None):
    """K-host sharded serving with quorum-voted swaps (DESIGN.md §6)."""
    import numpy as np

    from repro.distributed.serving import ShardedCascadeServer

    if not any(s.proxy is not None for s in plan.stages):
        raise SystemExit(
            f"--hosts {args.hosts} needs a proxied plan: quorum swaps "
            f"broadcast the packed scorer artifact, which mode="
            f"{args.mode!r} does not produce")

    K = args.hosts
    per_host = max(args.n // (2 * K), 1500)
    if args.drift:
        streams = make_sharded_drifting_streams(
            ds, K, max(per_host // 4, 500), per_host,
            shift_targets={c: (2.8 if c != 1 else -2.6)
                           for c in range(args.preds)},
            corr_gain=2.5, drift_skew=args.drift_skew, seed=args.seed,
        )
        xs = [s.x for s in streams]
        print(f"{K} drifting shards x {[s.n for s in streams]} records, "
              f"drift scales "
              f"{[round(s.meta['drift_scale'], 2) for s in streams]}")
    else:
        k0 = max(1000, int(0.05 * args.n))
        held = ds.x[k0:]
        xs = [held[i::K] for i in range(K)]
        print(f"{K} shards x {[len(x) for x in xs]} held-out records")
    from repro.serving.stats import AdaptivePolicy

    # demo-scale detector sensitivity: per-shard streams are short, so the
    # default (production-length) CUSUM/audit budgets would never freeze a
    # baseline before the stream ends
    policy = AdaptivePolicy(audit_rate=0.03, threshold=50.0,
                            min_reservoir=128, cooldown_records=1024,
                            reservoir_capacity=512)
    kill_at = args.kill_coordinator_at
    if kill_at is not None and kill_at not in ("prepare", "commit",
                                               "mid-commit"):
        kill_at = int(kill_at)
    worker_spec = None
    if args.transport == "process":
        worker_spec = {
            "dataset": dict(n=args.n, correlation=args.correlation,
                            seed=args.seed),
            "udfs": dict(hidden=64, depth=2, train_rows=3000,
                         seed=args.seed, declared_cost_ms=args.udf_cost_ms),
            "query": dict(columns=list(range(args.preds)),
                          target_selectivity=0.5,
                          accuracy_target=args.accuracy, seed=args.seed + 1),
        }
    srv = ShardedCascadeServer(plan, K, tile=args.tile, seed=args.seed,
                               policy=policy, transport=args.transport,
                               kill_coordinator_at=kill_at,
                               straggler_host=args.straggler_host,
                               worker_spec=worker_spec,
                               slo_ms=args.slo_ms,
                               plan_cache=cache)
    stats = srv.run_streams(xs)
    x_all = np.concatenate(xs)
    orig_res = execute_plan(orig_plan(q), x_all)
    orig_set = set(orig_res.passed.tolist())
    emitted_global = [i for host in srv.emitted for i in host]
    served_acc = (sum(1 for i in emitted_global if i in orig_set)
                  / max(len(orig_set), 1))
    print(f"\nserved {stats.submitted} records on {K} hosts in "
          f"{stats.wall_ms:.0f} ms wall; emitted {stats.emitted} "
          f"(+{stats.rejected} rejected)")
    print(f"consensus: {stats.votes_cast} votes -> "
          f"{stats.swaps_committed} quorum swap(s) "
          f"(+{stats.swaps_aborted} aborted), final epoch "
          f"{stats.final_epoch}, protocol overhead "
          f"{stats.consensus_ms_total:.1f} ms total")
    if stats.frontend_stats:
        shed = sum(f.records_shed for f in stats.frontend_stats)
        print(f"request front end: fleet goodput ratio "
              f"{stats.fleet_goodput_ratio:.3f} at SLO {args.slo_ms:.0f} ms "
              f"(shed-only backpressure; {shed} record(s) shed)")
    if stats.failovers or stats.fences or stats.resyncs or stats.pooled_swaps:
        print(f"fault tolerance: {stats.failovers} failover(s) "
              f"({stats.failover_resolution or 'n/a'}), {stats.fences} "
              f"fence(s), {stats.resyncs} re-sync(s), "
              f"{stats.pooled_swaps} pooled-kappa² swap(s)")
    for r in stats.swap_log:
        extra = f", fenced {r.fenced}" if r.fenced else ""
        print(f"  epoch {r.epoch} [{r.initiated_by}]: voters {r.voters} "
              f"[{', '.join(r.signals)}] -> {r.mode} on {r.merged_rows} "
              f"merged reservoir rows (reopt {r.reopt_ms:.0f} ms, "
              f"consensus {r.consensus_ms:.1f} ms{extra})")
    cp = stats.critical_path_cost_ms
    print(f"cost model: critical path {cp / max(stats.submitted, 1):.3f} "
          f"ms/rec aggregate ({stats.aggregate_rows_per_cost_s:.0f} rows/s; "
          f"ORIG {orig_res.cost_per_record(len(x_all)):.3f} ms/rec); "
          f"served accuracy {served_acc:.3f}")


if __name__ == "__main__":
    main()
