"""Serving launcher: build a CORE-optimized cascade for an ML inference
query and serve a record stream with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --correlation 0.9 \\
        --accuracy 0.9 --mode core

``--drift`` serves an order-inverting drifting stream instead of held-out
rows; add ``--adaptive`` to let the server detect the drift and
re-optimize mid-stream (DESIGN.md §4).  ``--hosts K`` (with K > 1) shards
the stream across K simulated hosts with quorum-voted global plan swaps
(DESIGN.md §6); per-shard drift magnitudes are skewed, so single-host
detectors disagree and the quorum is load-bearing.  ``--queries
spec.json`` registers SEVERAL concurrent queries in one ``CoreSession``
(DESIGN.md §10): shared fused scoring, cross-query UDF dedupe, and
weighted-fair device-time scheduling.

Every CLI flag maps onto a typed config field via ``FLAG_MAP`` — the
parser is a thin veneer over ``(WorkloadConfig, OptimizeOptions,
ServeConfig)``, and tests/test_api.py round-trips every flag through
``config_from_args`` so the CLI can never drift from the session API.
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass
from typing import Optional

from repro.core import (
    CoreSession,
    OptimizeOptions,
    ServeConfig,
    build_plan,
    execute_plan,
    ns_plan,
    orig_plan,
    pp_plan,
)
from repro.data.synthetic import (
    make_dataset,
    make_drifting_stream,
    make_query,
    make_sharded_drifting_streams,
    make_udfs,
)
from repro.serving.engine import CascadeServer


@dataclass
class WorkloadConfig:
    """Launch-local knobs: the synthetic dataset/query the launcher
    builds (not part of the session API — a real deployment brings its
    own records and UDFs)."""

    n: int = 20_000
    correlation: float = 0.9
    accuracy: float = 0.9
    preds: int = 2
    udf_cost_ms: float = 20.0
    mode: str = "core"  # includes the non-CORE baselines pp/ns/orig
    seed: int = 0


@dataclass
class LaunchConfig:
    workload: WorkloadConfig
    optimize: OptimizeOptions
    serve: ServeConfig


# argparse dest -> (config section, field).  Golden-tested: every parser
# action must appear here, and every non-default flag value must survive
# the round trip into its config field (tests/test_api.py).
FLAG_MAP = {
    "n": ("workload", "n"),
    "correlation": ("workload", "correlation"),
    "accuracy": ("workload", "accuracy"),
    "preds": ("workload", "preds"),
    "udf_cost_ms": ("workload", "udf_cost_ms"),
    "mode": ("workload", "mode"),
    "proxy_kind": ("optimize", "kind"),
    "quant_dtype": ("optimize", "quant_dtype"),
    "tile": ("serve", "tile"),
    "seed": ("serve", "seed"),
    "adaptive": ("serve", "adaptive"),
    "drift": ("serve", "drift"),
    "hosts": ("serve", "hosts"),
    "drift_skew": ("serve", "drift_skew"),
    "transport": ("serve", "transport"),
    "kill_coordinator_at": ("serve", "kill_coordinator_at"),
    "straggler_host": ("serve", "straggler_host"),
    "slo_ms": ("serve", "slo_ms"),
    "arrival_rate": ("serve", "arrival_rate"),
    "request_rows": ("serve", "request_rows"),
    "no_backpressure": ("serve", "backpressure"),  # inverted, see below
    "plan_cache": ("serve", "plan_cache_path"),
    "queries": ("serve", "queries_path"),
}

# flags whose config field is the NEGATION of the CLI switch
_INVERTED = {"no_backpressure"}


def build_arg_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--correlation", type=float, default=0.9)
    ap.add_argument("--accuracy", type=float, default=0.9)
    ap.add_argument("--mode", default="core", choices=["core", "core-a", "core-h", "pp", "ns", "orig"])
    ap.add_argument("--proxy-kind", default="svm", choices=["svm", "mlp", "mixed"],
                    help="proxy family per predicate: all-linear, all-MLP, "
                         "or alternating (every kind rides the fused scorer)")
    ap.add_argument("--quant-dtype", default="fp32",
                    choices=["fp32", "int8", "fp8"],
                    help="weight storage dtype for the packed cascade: "
                         "int8/fp8 quantize at plan-compile time (scales "
                         "folded into the readout; masks flip only within "
                         "the calibrated threshold tolerance)")
    ap.add_argument("--preds", type=int, default=2)
    ap.add_argument("--tile", type=int, default=1024)
    ap.add_argument("--udf-cost-ms", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adaptive", action="store_true",
                    help="drift-triggered online re-optimization")
    ap.add_argument("--drift", action="store_true",
                    help="serve a drifting stream (selectivity + correlation shift)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="shard serving across K simulated hosts with "
                         "quorum-voted plan swaps (K > 1 implies adaptive)")
    ap.add_argument("--drift-skew", type=float, default=0.3,
                    help="per-shard drift magnitude skew (multi-host only)")
    ap.add_argument("--transport", default="inline",
                    choices=["inline", "thread", "process"],
                    help="multi-host transport: same-thread objects, one "
                         "worker thread per host, or one OS subprocess per "
                         "host (COREWIRE + newline-JSON control pipes)")
    ap.add_argument("--kill-coordinator-at", default=None,
                    help="failure injection: kill the primary coordinator "
                         "at 'prepare' | 'commit' | 'mid-commit' (phases "
                         "of an in-flight swap) or an integer submitted-"
                         "record count; the standby takes over on "
                         "heartbeat loss (DESIGN.md §6 failure model)")
    ap.add_argument("--straggler-host", type=int, default=None,
                    help="failure injection: this host misses the first "
                         "prepare barrier; the fleet commits without it "
                         "(serve-behind fencing) and re-syncs it on rejoin")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="serve through the SLO-aware request front end "
                         "(DESIGN.md §7): the stream becomes deadline-"
                         "carrying requests, goodput (requests/s meeting "
                         "the SLO) is reported next to raw throughput, "
                         "and backpressure degrades to cheaper plans / "
                         "sheds expired work instead of queueing forever")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="request arrivals per cost-model second (Poisson; "
                         "default ~1.3x the full plan's capacity, i.e. "
                         "mild overload so the backpressure policy has "
                         "something to do); needs --slo-ms")
    ap.add_argument("--request-rows", type=int, default=128,
                    help="records per request on the front-end path")
    ap.add_argument("--no-backpressure", action="store_true",
                    help="disable degrade + shedding on the front end "
                         "(watch the latency collapse under overload)")
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="cross-query plan cache file (DESIGN.md §8): "
                         "warm-start this query's optimization from the "
                         "most similar cached plan (exact repeats replay "
                         "with no proxy training at all), and persist "
                         "every plan this run commits — including drift "
                         "re-optimizations — back to PATH for the next run")
    ap.add_argument("--queries", default=None, metavar="SPEC.JSON",
                    help="multi-query session (DESIGN.md §10): JSON list "
                         "of query specs ({columns, accuracy?, seed?, "
                         "slo_ms?, quant_dtype?}) all registered in one "
                         "CoreSession — shared fused scoring, cross-query "
                         "UDF dedupe, weighted-fair scheduling.  Overrides "
                         "--preds/--accuracy for the query shapes")
    return ap


def config_from_args(args: argparse.Namespace) -> LaunchConfig:
    """Fold the parsed namespace into the typed config triple.  The CLI
    owns no state of its own: every dest routes through ``FLAG_MAP``."""
    sections = {"workload": {}, "optimize": {}, "serve": {}}
    for dest, (section, fld) in FLAG_MAP.items():
        val = getattr(args, dest)
        if dest in _INVERTED:
            val = not val
        sections[section][fld] = val
    # normalize: "fp32" means full precision, i.e. no quantization pass
    if sections["optimize"].get("quant_dtype") in ("fp32", "float32"):
        sections["optimize"]["quant_dtype"] = None
    # the optimizer only sees CORE modes; baselines stay workload-level
    if sections["workload"]["mode"] in ("core", "core-a", "core-h"):
        sections["optimize"]["mode"] = sections["workload"]["mode"]
    # one --seed feeds all three sections (the golden test pins it to
    # serve; workload/optimize inherit)
    seed = sections["serve"]["seed"]
    sections["workload"]["seed"] = seed
    sections["optimize"]["seed"] = seed
    return LaunchConfig(
        workload=WorkloadConfig(**sections["workload"]),
        optimize=OptimizeOptions(**sections["optimize"]),
        serve=ServeConfig(**sections["serve"]),
    )


def main():
    args = build_arg_parser().parse_args()
    cfg = config_from_args(args)
    wl, opt, sv = cfg.workload, cfg.optimize, cfg.serve

    ds = make_dataset(n=wl.n, correlation=wl.correlation, seed=wl.seed)
    udfs = make_udfs(ds, hidden=64, depth=2, train_rows=3000, seed=wl.seed,
                     declared_cost_ms=wl.udf_cost_ms)
    k = max(1000, int(0.05 * wl.n))
    cache = None
    if sv.plan_cache_path and wl.mode in ("core", "core-a", "core-h"):
        import os

        from repro.core import PlanCache

        cache = (PlanCache.load(sv.plan_cache_path)
                 if os.path.exists(sv.plan_cache_path) else PlanCache())
        print(f"plan cache: {sv.plan_cache_path} ({len(cache)} entries)")

    if sv.queries_path is not None:
        _serve_multiquery(cfg, ds, udfs, k, cache)
        _save_cache(cache, sv)
        return

    q = make_query(ds, udfs, columns=list(range(wl.preds)),
                   target_selectivity=0.5, accuracy_target=wl.accuracy,
                   seed=wl.seed + 1)
    print("query:", " AND ".join(q.names()), f"A={wl.accuracy}")
    if wl.mode == "orig":
        plan = orig_plan(q)
    elif wl.mode == "ns":
        plan = ns_plan(q, ds.x[:k], kind=opt.kind)
    elif wl.mode == "pp":
        plan = pp_plan(q, ds.x[:k], kind=opt.kind)
    else:
        # K > 1 implies the adaptive loop: the coordinator's quorum
        # re-optimizations need the builder/B&B state to warm-start
        keep = sv.adaptive or sv.hosts > 1
        build_opts = opt.replace(keep_state=keep)
        if cache is not None:
            # adaptive/sharded serving needs a live builder/B&B on the
            # plan, which an exact-hit wire replay cannot carry — those
            # callers take the warm path instead of the HIT fast path
            plan, info = cache.optimize_query(
                q, ds.x[:k], build_opts, accept_hit=not keep)
            print(f"plan cache: {info['path'].upper()} "
                  f"(distance {info['distance']:.4f}, "
                  f"build {info['build_ms']:.0f} ms)")
        else:
            plan = build_plan(q, ds.x[:k], build_opts)
    print(plan.describe())
    if plan.meta.get("quant_dtype"):
        print(f"packed cascade weights: {plan.meta['quant_dtype']}")
    if any(s.proxy is not None for s in plan.stages):
        print("proxy families:",
              " ".join(s.proxy.family for s in plan.stages if s.proxy is not None))

    if sv.hosts > 1:
        _serve_sharded(cfg, ds, q, plan, cache)
        _save_cache(cache, sv)
        return

    if sv.slo_ms is not None:
        _serve_frontend(cfg, ds, plan, k, cache)
        _save_cache(cache, sv)
        return

    if sv.drift:
        stream = make_drifting_stream(
            ds, max(wl.n // 4, 2000), wl.n - k,
            shift_targets={c: (2.8 if c != 1 else -2.6) for c in range(wl.preds)},
            corr_gain=2.5, seed=wl.seed,
        )
        x_serve = stream.x
        print(f"drifting stream: {stream.n} records, boundary at "
              f"{stream.boundary}")
    else:
        x_serve = ds.x[k:]
    server = CascadeServer(plan, tile=sv.tile, use_kernel=sv.use_kernel,
                           adaptive=sv.adaptive, seed=sv.seed,
                           plan_cache=cache)
    stats = server.run_stream(x_serve)
    orig_res = execute_plan(orig_plan(q), x_serve)
    # accuracy of what was actually SERVED (mid-stream swaps included),
    # not a re-execution of the final plan over the whole stream
    orig_set = set(orig_res.passed.tolist())
    served_acc = (sum(1 for i in server.emitted if i in orig_set)
                  / max(len(orig_set), 1))
    print(f"\nserved {len(x_serve)} records in {stats.wall_ms:.0f} ms wall; "
          f"emitted {stats.emitted} (+{stats.rejected} rejected)")
    if sv.adaptive:
        print(f"adaptive: {stats.plan_swaps} plan swap(s), "
              f"{stats.audit_records} audit records "
              f"({stats.audit_cost_ms:.0f} ms cost), reopt "
              f"{stats.reopt_ms:.0f} ms wall")
        for ev in stats.drift_events:
            print(f"  drift@{ev.at_record} [{ev.signal}] obs={ev.observed:.3f} "
                  f"exp={ev.expected:.3f} -> "
                  f"{'warm B&B' if ev.escalated else 're-allocation'} "
                  f"({ev.nodes_visited} nodes), order "
                  f"{ev.order_before} -> {ev.order_after}")
    print(f"cost model: {stats.model_cost_ms / len(x_serve):.3f} ms/rec "
          f"(ORIG {orig_res.cost_per_record(len(x_serve)):.3f}); "
          f"served accuracy {served_acc:.3f}")
    _save_cache(cache, sv)


def _save_cache(cache, sv: ServeConfig):
    """Persist the plan cache (COREPLNC container) with this run's
    write-backs so the next ``--plan-cache`` run warm-starts from them."""
    if cache is None:
        return
    cache.save(sv.plan_cache_path)
    st = cache.stats
    print(f"plan cache saved: {len(cache)} entries -> {sv.plan_cache_path} "
          f"({st.hits_exact} exact / {st.hits_warm} warm hits, "
          f"{st.writes} writes)")


def _load_query_specs(path: str):
    import json

    with open(path) as f:
        specs = json.load(f)
    if not isinstance(specs, list) or not specs:
        raise SystemExit(f"--queries {path}: expected a non-empty JSON "
                         f"list of query specs")
    for i, spec in enumerate(specs):
        if "columns" not in spec:
            raise SystemExit(f"--queries {path}: spec #{i} missing "
                             f"'columns'")
    return specs


def _serve_multiquery(cfg: LaunchConfig, ds, udfs, k: int, cache=None):
    """N concurrent queries through one CoreSession (DESIGN.md §10):
    shared block-diagonal fused scoring, cross-query UDF dedupe, and
    Eq. 3.1-weighted fair scheduling across the tenants."""
    wl, opt, sv = cfg.workload, cfg.optimize, cfg.serve
    specs = _load_query_specs(sv.queries_path)
    session = CoreSession(options=opt, plan_cache=cache, seed=sv.seed)
    queries = []
    for i, spec in enumerate(specs):
        q = make_query(ds, udfs, columns=[int(c) for c in spec["columns"]],
                       target_selectivity=float(spec.get("selectivity", 0.5)),
                       accuracy_target=float(spec.get("accuracy", wl.accuracy)),
                       seed=int(spec.get("seed", wl.seed + 1 + i)))
        h = session.register_query(
            q, ds.x[:k],
            quant_dtype=spec.get("quant_dtype", opt.quant_dtype),
            slo=spec.get("slo_ms"))
        queries.append(q)
        print(f"q{h.qid}: {' AND '.join(q.names())} "
              f"A={spec.get('accuracy', wl.accuracy)}")
    eng = session.serve(config=sv)
    x_serve = ds.x[k:]
    session.run_stream(x_serve)
    st = eng.session_stats()
    ok, msg = eng.conserved()
    ded = st["dedupe"]
    print(f"\nsession: {st['queries']} queries over {len(x_serve)} records; "
          f"conservation {'OK' if ok else 'VIOLATED: ' + msg}")
    print(f"shared scorer: {st['shared_cols']} packed columns "
          f"({st['stacked_cols_saved']} deduped), {st['restacks']} "
          f"restack(s)")
    print(f"UDF dedupe: {ded['hits']} hits / {ded['misses']} misses "
          f"(rate {ded['hit_rate']:.3f}), {ded['saved_cost_ms']:.0f} ms "
          f"cost saved")
    sched = st["scheduler"]
    for h in session.handles:
        qs = eng.query_stats(h.qid)
        print(f"  q{h.qid}: emitted {qs['emitted']} "
              f"(+{qs['rejected']} rejected), cost "
              f"{qs['model_cost_ms']:.0f} ms, weight {qs['weight']:.2f}, "
              f"served {qs['served_cost_ms']:.0f} ms device time")
    # served-accuracy audit per tenant, same recipe as the 1-query path
    for h, q in zip(session.handles, queries):
        orig_set = set(execute_plan(orig_plan(q), x_serve).passed.tolist())
        srv = eng.servers[h.qid]
        acc = (sum(1 for i in srv.emitted if i in orig_set)
               / max(len(orig_set), 1))
        print(f"  q{h.qid} served accuracy {acc:.3f}")
    print(f"scheduler: {sched['grants']} service quanta, "
          f"total {st['model_cost_ms']:.0f} ms model cost")


def _serve_frontend(cfg: LaunchConfig, ds, plan, k, cache=None):
    """Single-host serving through the SLO-aware request front end: the
    held-out stream arrives as Poisson requests with per-request
    deadlines; goodput is reported next to raw throughput (DESIGN.md
    §7).  All timing is the cost-model clock, so runs are deterministic
    for a fixed seed."""
    import numpy as np

    from repro.serving.frontend import ServingFrontEnd, SLOPolicy

    sv = cfg.serve
    held = ds.x[k:]
    rows_per = max(1, sv.request_rows)
    n_req = len(held) // rows_per
    if n_req == 0:
        raise SystemExit(f"--request-rows {rows_per} larger than the "
                         f"held-out stream ({len(held)} rows)")
    # capacity on the cost-model clock: the plan's Eq. 3.1 estimate says
    # one request costs est_total_cost * rows_per ms at the full plan
    req_ms = plan.est_total_cost * rows_per
    rate = sv.arrival_rate or 1.3 / (req_ms / 1e3)
    rng = np.random.RandomState(sv.seed)
    arrivals = np.cumsum(rng.exponential(1e3 / rate, n_req))
    bp = sv.backpressure
    server = CascadeServer(plan, tile=sv.tile, use_kernel=sv.use_kernel,
                           seed=sv.seed, plan_cache=cache)
    fe = ServingFrontEnd(server, policy=SLOPolicy(degrade=bp,
                                                  shed_expired=bp))
    for r in range(n_req):
        idx = np.arange(k + r * rows_per, k + (r + 1) * rows_per)
        fe.submit_request(idx, ds.x[idx], deadline_ms=sv.slo_ms,
                          arrival_ms=float(arrivals[r]))
    st = fe.run()
    ok, msg = fe.conserved()
    lat = [r.latency_ms for r in fe.requests.values() if r.done]
    print(f"\nfront end: {st.requests_total} requests x {rows_per} rows, "
          f"SLO {sv.slo_ms:.0f} ms, arrivals {rate:.2f} req/s "
          f"(backpressure {'on' if bp else 'OFF'})")
    print(f"goodput {st.goodput_rps:.2f} req/s vs throughput "
          f"{st.throughput_rps:.2f} req/s (ratio {st.goodput_ratio:.3f}); "
          f"p50/p95 latency {np.percentile(lat, 50):.0f}/"
          f"{np.percentile(lat, 95):.0f} ms")
    print(f"backpressure: {st.degrades} degrade(s), {st.restores} "
          f"restore(s), final ladder level {st.final_level}; shed "
          f"{st.records_shed} records across {st.requests_shed} "
          f"request(s) [explicit, never silent]")
    print(f"records: {st.records_submitted} submitted -> "
          f"{st.records_emitted} emitted + {st.records_rejected} "
          f"rejected; conservation {'OK' if ok else 'VIOLATED: ' + msg}")


def _serve_sharded(cfg: LaunchConfig, ds, q, plan, cache=None):
    """K-host sharded serving with quorum-voted swaps (DESIGN.md §6)."""
    import numpy as np

    from repro.distributed.serving import ShardedCascadeServer

    wl, sv = cfg.workload, cfg.serve
    if not any(s.proxy is not None for s in plan.stages):
        raise SystemExit(
            f"--hosts {sv.hosts} needs a proxied plan: quorum swaps "
            f"broadcast the packed scorer artifact, which mode="
            f"{wl.mode!r} does not produce")

    K = sv.hosts
    per_host = max(wl.n // (2 * K), 1500)
    if sv.drift:
        streams = make_sharded_drifting_streams(
            ds, K, max(per_host // 4, 500), per_host,
            shift_targets={c: (2.8 if c != 1 else -2.6)
                           for c in range(wl.preds)},
            corr_gain=2.5, drift_skew=sv.drift_skew, seed=sv.seed,
        )
        xs = [s.x for s in streams]
        print(f"{K} drifting shards x {[s.n for s in streams]} records, "
              f"drift scales "
              f"{[round(s.meta['drift_scale'], 2) for s in streams]}")
    else:
        k0 = max(1000, int(0.05 * wl.n))
        held = ds.x[k0:]
        xs = [held[i::K] for i in range(K)]
        print(f"{K} shards x {[len(x) for x in xs]} held-out records")
    from repro.serving.stats import AdaptivePolicy

    # demo-scale detector sensitivity: per-shard streams are short, so the
    # default (production-length) CUSUM/audit budgets would never freeze a
    # baseline before the stream ends
    policy = AdaptivePolicy(audit_rate=0.03, threshold=50.0,
                            min_reservoir=128, cooldown_records=1024,
                            reservoir_capacity=512)
    kill_at = sv.kill_coordinator_at
    if kill_at is not None and kill_at not in ("prepare", "commit",
                                               "mid-commit"):
        kill_at = int(kill_at)
    worker_spec = None
    if sv.transport == "process":
        worker_spec = {
            "dataset": dict(n=wl.n, correlation=wl.correlation,
                            seed=wl.seed),
            "udfs": dict(hidden=64, depth=2, train_rows=3000,
                         seed=wl.seed, declared_cost_ms=wl.udf_cost_ms),
            "query": dict(columns=list(range(wl.preds)),
                          target_selectivity=0.5,
                          accuracy_target=wl.accuracy, seed=wl.seed + 1),
        }
    srv = ShardedCascadeServer(plan, K, tile=sv.tile, seed=sv.seed,
                               policy=policy, transport=sv.transport,
                               kill_coordinator_at=kill_at,
                               straggler_host=sv.straggler_host,
                               worker_spec=worker_spec,
                               slo_ms=sv.slo_ms,
                               plan_cache=cache)
    stats = srv.run_streams(xs)
    x_all = np.concatenate(xs)
    orig_res = execute_plan(orig_plan(q), x_all)
    orig_set = set(orig_res.passed.tolist())
    emitted_global = [i for host in srv.emitted for i in host]
    served_acc = (sum(1 for i in emitted_global if i in orig_set)
                  / max(len(orig_set), 1))
    print(f"\nserved {stats.submitted} records on {K} hosts in "
          f"{stats.wall_ms:.0f} ms wall; emitted {stats.emitted} "
          f"(+{stats.rejected} rejected)")
    print(f"consensus: {stats.votes_cast} votes -> "
          f"{stats.swaps_committed} quorum swap(s) "
          f"(+{stats.swaps_aborted} aborted), final epoch "
          f"{stats.final_epoch}, protocol overhead "
          f"{stats.consensus_ms_total:.1f} ms total")
    if stats.frontend_stats:
        shed = sum(f.records_shed for f in stats.frontend_stats)
        print(f"request front end: fleet goodput ratio "
              f"{stats.fleet_goodput_ratio:.3f} at SLO {sv.slo_ms:.0f} ms "
              f"(shed-only backpressure; {shed} record(s) shed)")
    if stats.failovers or stats.fences or stats.resyncs or stats.pooled_swaps:
        print(f"fault tolerance: {stats.failovers} failover(s) "
              f"({stats.failover_resolution or 'n/a'}), {stats.fences} "
              f"fence(s), {stats.resyncs} re-sync(s), "
              f"{stats.pooled_swaps} pooled-kappa² swap(s)")
    for r in stats.swap_log:
        extra = f", fenced {r.fenced}" if r.fenced else ""
        print(f"  epoch {r.epoch} [{r.initiated_by}]: voters {r.voters} "
              f"[{', '.join(r.signals)}] -> {r.mode} on {r.merged_rows} "
              f"merged reservoir rows (reopt {r.reopt_ms:.0f} ms, "
              f"consensus {r.consensus_ms:.1f} ms{extra})")
    cp = stats.critical_path_cost_ms
    print(f"cost model: critical path {cp / max(stats.submitted, 1):.3f} "
          f"ms/rec aggregate ({stats.aggregate_rows_per_cost_s:.0f} rows/s; "
          f"ORIG {orig_res.cost_per_record(len(x_all)):.3f} ms/rec); "
          f"served accuracy {served_acc:.3f}")


if __name__ == "__main__":
    main()
