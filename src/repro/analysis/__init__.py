"""Static analysis & protocol checking (DESIGN.md §9).

Two tools live here, both wired into the tier-1 CI lint lane
(``scripts/ci.sh --lane lint``):

* ``corelint`` — an AST-based invariant lint suite whose rules are
  distilled from bugs this repo actually shipped and fixed (the
  ``id()``-keyed scorer cache, wall-clock nearly feeding scheduling,
  torn autotune disk writes, dropped IPW weights, ...).  See
  ``corelint.RULES`` for the catalog, each entry carrying the historical
  bug it descends from.
* ``protocol_check`` — an explicit-state model checker that exhaustively
  enumerates small-fleet interleavings of the two-phase swap /
  standby-failover / straggler-fence protocol in
  ``distributed/consensus.py``, asserting the invariants the PR 4/5/7
  tests only sample.
"""
from repro.analysis.corelint import (
    RULES,
    LintReport,
    Violation,
    load_baseline,
    run_corelint,
    write_baseline,
)

__all__ = [
    "RULES",
    "LintReport",
    "Violation",
    "load_baseline",
    "run_corelint",
    "write_baseline",
]
