"""Explicit-state model checker for the two-phase swap protocol.

``distributed/consensus.py`` + ``distributed/serving.py`` implement a
two-phase quorum plan swap (DriftVote quorum -> SwapPrepare/SwapAck
barrier -> SwapCommit) with straggler fencing (serve-behind + re-sync),
NACK/deadline aborts that re-arm voting, and a standby coordinator that
resolves in-flight swaps on primary death.  The PR 4/5/7 tests SAMPLE
interleavings of that machine; this module enumerates ALL of them within
small bounds and asserts the invariants on every reachable state.

The model
---------
States are immutable tuples (hosts, coordinator, in-flight messages,
committed-epoch log, budgets); transitions mirror the real code paths
one message delivery / protocol event at a time:

* ``vote``/``propose`` — quorum voting and proposal (artifact ids are
  fresh integers, so two rounds of the SAME epoch number are
  distinguishable — exactly what ``SwapPrepare.attempt`` encodes).
* ``deliver_prepare``/``deliver_ack``/``deliver_commit`` — asynchronous
  message delivery, blocked while a host's link is down.
* ``deadline`` — the transport ack deadline fires for a silent host,
  resolved with either straggler policy (``fence`` or ``nack``).
* ``crash`` — primary dies; the standby's ``take_over`` resolution runs
  against the probed fleet (complete if any host installed or every
  active host acked, abort otherwise).
* ``heal``/``rejoin`` — the straggler's link recovers; the driver's
  rejoin path re-admits it (direct when its epoch is current, via
  COREWIRE re-sync when behind).

Bounds (defaults): K ≤ 3 hosts, ≤ 2 proposals (two in-flight epochs),
1 crash, 1 fence/deadline event.  ~10^4-10^5 states, sub-second BFS.

Invariants (checked on EVERY reachable state/transition):

* **I1 serve-only-acked** — a host only ever installs an (epoch,
  artifact) it staged+acked itself, or received via re-sync of a
  committed artifact; and that pair was committed by a coordinator.
* **I2 monotonic-epochs** — a host's committed epoch never decreases.
* **I3 abort-re-arms** — witness: a re-proposal is reachable after an
  abort (voting was re-armed, the fleet is not wedged).
* **I4 fence-survives-abort** — the fence set is preserved across
  aborts (checked in the abort transition + reachability witness).
* **I5 one-artifact-per-epoch** — at most one artifact is ever
  committed for a given epoch (collapses "at most one primary per
  epoch": two live coordinators would commit divergent artifacts).

``legacy_acks=True`` re-enables the pre-fix ``offer_ack`` semantics
(epoch-only matching: no fenced-host check, no attempt nonce).  The
checker then finds, in a few thousand states, the stale-ack trace this
PR fixed: fence a staged host, abort, let it rejoin at the same epoch
number still holding its round-1 staged artifact, and its round-1 ack —
still in flight — closes the round-2 barrier, committing artifact A to
the fleet while the rejoined host installs artifact B.  The CLI runs
both modes and fails if the strict model violates anything OR the
legacy model fails to reproduce the bug (the checker must keep teeth).
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Tuple

# ---------------------------------------------------------------------------
# State representation (all immutable / hashable)
# ---------------------------------------------------------------------------

# Host: (epoch, artifact, staged, voted, silent, acked, resynced)
#   staged:   None | (epoch, artifact, attempt)
#   acked:    frozenset[(epoch, artifact)] — pairs this host staged+acked
#   resynced: frozenset[(epoch, artifact)] — pairs installed via re-sync
Host = Tuple[int, int, Optional[Tuple[int, int, int]], bool, bool,
             FrozenSet[Tuple[int, int]], FrozenSet[Tuple[int, int]]]

# Coordinator: (alive, epoch, artifact, attempt, pending, acks, votes,
#               fenced, proposals)
#   pending: None | (epoch, artifact, attempt)
Coord = Tuple[bool, int, int, int, Optional[Tuple[int, int, int]],
              FrozenSet[int], FrozenSet[int], FrozenSet[int], int]

# Messages in flight:
#   prepares: frozenset[(host, epoch, artifact, attempt)]
#   acks:     frozenset[(host, epoch, attempt, ok)]
#   commits:  frozenset[(host, epoch, attempt)]
Msgs = Tuple[FrozenSet[tuple], FrozenSet[tuple], FrozenSet[tuple]]

# flags: (aborted_once, fence_survived_abort, promoted)
State = Tuple[Tuple[Host, ...], Coord, Msgs,
              FrozenSet[Tuple[int, int]],  # committed (epoch, artifact)
              Tuple[int, int],             # budgets (fences, crashes)
              int,                         # next artifact id
              Tuple[bool, bool, bool]]


@dataclass
class CheckConfig:
    n_hosts: int = 3
    max_proposals: int = 2  # ≤2 in-flight epochs
    max_fences: int = 1
    max_crashes: int = 1
    legacy_acks: bool = False  # pre-fix offer_ack (epoch-only matching)


class InvariantViolation(Exception):
    def __init__(self, invariant: str, detail: str, trace: List[str]):
        self.invariant = invariant
        self.detail = detail
        self.trace = trace
        super().__init__(f"{invariant}: {detail}")


@dataclass
class CheckResult:
    states_explored: int
    transitions: int
    violation: Optional[InvariantViolation]
    witnesses: Dict[str, bool] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.violation is None and all(self.witnesses.values())


def _initial_state(cfg: CheckConfig) -> State:
    host: Host = (0, 0, None, False, False, frozenset(), frozenset())
    coord: Coord = (True, 0, 0, 0, None, frozenset(), frozenset(),
                    frozenset(), 0)
    msgs: Msgs = (frozenset(), frozenset(), frozenset())
    return ((host,) * cfg.n_hosts, coord, msgs, frozenset(),
            (cfg.max_fences, cfg.max_crashes), 1, (False, False, False))


def _quorum(active: int) -> int:
    return active // 2 + 1


# ---------------------------------------------------------------------------
# Transition helpers (pure: State -> State)
# ---------------------------------------------------------------------------


def _set_host(hosts: Tuple[Host, ...], i: int, h: Host) -> Tuple[Host, ...]:
    return hosts[:i] + (h,) + hosts[i + 1:]


def _coord_abort(state: State, cfg: CheckConfig) -> State:
    """NACK / deadline-nack / takeover abort: drop staged + re-arm voting
    on every reachable host, clear the round.  Fences SURVIVE (I4)."""
    hosts, coord, msgs, committed, budgets, nart, flags = state
    alive, cepoch, cart, catt, pending, acks, votes, fenced, props = coord
    new_hosts = []
    for h in hosts:
        epoch, art, staged, voted, silent, ackset, rsset = h
        if silent:  # unreachable: the abort never arrives — staged survives
            new_hosts.append(h)
        else:
            new_hosts.append((epoch, art, None, False, silent, ackset, rsset))
    new_coord: Coord = (alive, cepoch, cart, catt, None, frozenset(),
                        frozenset(), fenced, props)
    if fenced != coord[7]:  # pragma: no cover - structural I4 guard
        raise AssertionError("abort must not clear fences")
    new_flags = (True, flags[1] or bool(fenced), flags[2])
    return (tuple(new_hosts), new_coord, msgs, committed, budgets, nart,
            new_flags)


def _coord_maybe_commit(state: State, cfg: CheckConfig,
                        trace: List[str]) -> State:
    """All active hosts acked -> commit: log the (epoch, artifact), send
    commit messages to the barrier, clear the round."""
    hosts, coord, msgs, committed, budgets, nart, flags = state
    alive, cepoch, cart, catt, pending, acks, votes, fenced, props = coord
    active = frozenset(range(cfg.n_hosts)) - fenced
    if pending is None or not active or not active <= acks:
        return state
    pepoch, part, patt = pending
    # the real broadcast loop skips fenced + unreachable hosts (they
    # catch up via re-sync); it is synchronous — _successors gates the
    # next round on the in-flight commit set draining, and only a crash
    # can interrupt it (dropping the undelivered commits)
    reachable = {i for i in active if not hosts[i][4]}
    committed = committed | {(pepoch, part)}
    # I5: at most one artifact may ever be committed for an epoch
    by_epoch: Dict[int, set] = {}
    for e, a in committed:
        by_epoch.setdefault(e, set()).add(a)
    for e, arts in by_epoch.items():
        if len(arts) > 1:
            raise InvariantViolation(
                "I5-one-artifact-per-epoch",
                f"epoch {e} committed with artifacts {sorted(arts)}", trace)
    prepares, ackmsgs, commits = msgs
    commits = commits | {(i, pepoch, patt) for i in reachable}
    new_coord: Coord = (alive, pepoch, part, catt, None, frozenset(),
                        frozenset(), fenced, props)
    return (hosts, new_coord, (prepares, ackmsgs, commits), committed,
            budgets, nart, flags)


def _install(state: State, i: int, epoch: int, art: int, via: str,
             trace: List[str]) -> State:
    """Install a committed plan on host ``i``, checking I1 + I2."""
    hosts, coord, msgs, committed, budgets, nart, flags = state
    hepoch, hart, staged, voted, silent, ackset, rsset = hosts[i]
    if epoch <= hepoch:
        raise InvariantViolation(
            "I2-monotonic-epochs",
            f"host {i} at epoch {hepoch} told to install epoch {epoch}",
            trace)
    if (epoch, art) not in committed:
        raise InvariantViolation(
            "I1-serve-only-acked",
            f"host {i} installs ({epoch}, a{art}) which no coordinator "
            "committed", trace)
    if via == "resync":
        rsset = rsset | {(epoch, art)}
    elif (epoch, art) not in ackset:
        raise InvariantViolation(
            "I1-serve-only-acked",
            f"host {i} installs ({epoch}, a{art}) it never acked "
            f"(acked={sorted(ackset)})", trace)
    new_host: Host = (epoch, art, None, False, silent, ackset, rsset)
    return (_set_host(hosts, i, new_host), coord, msgs, committed, budgets,
            nart, flags)


# ---------------------------------------------------------------------------
# Successor enumeration
# ---------------------------------------------------------------------------


def _successors(state: State, cfg: CheckConfig,
                trace: List[str]):
    hosts, coord, msgs, committed, budgets, nart, flags = state
    alive, cepoch, cart, catt, pending, acks, votes, fenced, props = coord
    prepares, ackmsgs, commits = msgs
    fence_budget, crash_budget = budgets
    active = frozenset(range(cfg.n_hosts)) - fenced

    # round_open: the synchronous commit broadcast of the previous round
    # has drained (or was cut short by a crash) — only then does the
    # driver loop reach the vote / rejoin / propose paths again
    round_open = pending is None and not commits

    # -- vote: host offers a drift vote for the coordinator's epoch
    if alive and round_open:
        for i, h in enumerate(hosts):
            hepoch, hart, staged, voted, silent, ackset, rsset = h
            if (not voted and not silent and i not in fenced
                    and hepoch == cepoch):
                nh = (hepoch, hart, staged, True, silent, ackset, rsset)
                nc: Coord = (alive, cepoch, cart, catt, pending, acks,
                             votes | {i}, fenced, props)
                yield (f"vote(h{i})",
                       (_set_host(hosts, i, nh), nc, msgs, committed,
                        budgets, nart, flags))

    # -- propose: quorum reached, broadcast prepares for a fresh artifact
    if (alive and round_open and props < cfg.max_proposals
            and active and len(votes & active) >= _quorum(len(active))):
        art = nart
        att = catt + 1
        newp = (cepoch + 1, art, att)
        nc = (alive, cepoch, cart, att, newp, frozenset(), frozenset(),
              fenced, props + 1)
        nprep = prepares | {(i, cepoch + 1, art, att) for i in active}
        yield (f"propose(e{cepoch + 1},a{art})",
               (hosts, nc, (nprep, ackmsgs, commits), committed, budgets,
                nart + 1, flags))

    # -- deliver_prepare: host stages (ok) or NACKs (epoch mismatch)
    for m in prepares:
        i, pepoch, part, patt = m
        hepoch, hart, staged, voted, silent, ackset, rsset = hosts[i]
        if silent:
            continue
        ok = pepoch == hepoch + 1
        if ok:
            nh = (hepoch, hart, (pepoch, part, patt), voted, silent,
                  ackset | {(pepoch, part)}, rsset)
        else:
            nh = (hepoch, hart, None, voted, silent, ackset, rsset)
        nmsgs = (prepares - {m}, ackmsgs | {(i, pepoch, patt, ok)}, commits)
        yield (f"deliver_prepare(h{i},e{pepoch},a{part})",
               (_set_host(hosts, i, nh), coord, nmsgs, committed, budgets,
                nart, flags))

    # -- deliver_ack: the coordinator's offer_ack
    for m in ackmsgs:
        i, aepoch, aatt, ok = m
        hepoch, hart, staged, voted, silent, ackset, rsset = hosts[i]
        if silent or not alive:
            continue
        nmsgs = (prepares, ackmsgs - {m}, commits)
        ns: State = (hosts, coord, nmsgs, committed, budgets, nart, flags)
        label = f"deliver_ack(h{i},e{aepoch},t{aatt},{'ok' if ok else 'nack'})"
        if pending is None or aepoch != pending[0]:
            yield (label, ns)  # inert: not the pending epoch
            continue
        if not cfg.legacy_acks:
            if i in fenced or aatt != pending[2]:
                yield (label, ns)  # inert: fenced host / stale attempt
                continue
        if not ok:
            yield (label, _coord_abort(ns, cfg))
            continue
        nc = (alive, cepoch, cart, catt, pending, acks | {i}, votes, fenced,
              props)
        ns = (hosts, nc, nmsgs, committed, budgets, nart, flags)
        yield (label, _coord_maybe_commit(ns, cfg, trace + [label]))

    # -- deadline: a host the barrier is still waiting on went silent
    if alive and pending is not None and fence_budget > 0:
        for i, h in enumerate(hosts):
            if i in fenced or i in acks:
                continue
            hepoch, hart, staged, voted, silent, ackset, rsset = h
            # straggler policy "fence": exclude it, commit without it
            nh = (hepoch, hart, staged, voted, True, ackset, rsset)
            nfenced = fenced | {i}
            nacks = acks if cfg.legacy_acks else acks - {i}
            nc = (alive, cepoch, cart, catt, pending, nacks,
                  votes - {i}, nfenced, props)
            ns = (_set_host(hosts, i, nh), nc, msgs, committed,
                  (fence_budget - 1, crash_budget), nart, flags)
            label = f"deadline_fence(h{i})"
            if len(frozenset(range(cfg.n_hosts)) - nfenced) == 0:
                yield (label, _coord_abort(ns, cfg))
            else:
                yield (label, _coord_maybe_commit(ns, cfg, trace + [label]))
            # straggler policy "nack": the first straggler aborts the epoch
            nh2 = (hepoch, hart, staged, voted, True, ackset, rsset)
            ns2 = (_set_host(hosts, i, nh2), coord, msgs, committed,
                   (fence_budget - 1, crash_budget), nart, flags)
            yield (f"deadline_nack(h{i})", _coord_abort(ns2, cfg))

    # -- deliver_commit: install the staged plan (ShardHost.commit checks
    # BOTH the epoch and the attempt nonce of the staged copy; a
    # mismatch raises, which the drivers resolve by fencing for re-sync)
    for m in commits:
        i, mepoch, matt = m
        hepoch, hart, staged, voted, silent, ackset, rsset = hosts[i]
        if silent:
            continue
        nmsgs = (prepares, ackmsgs, commits - {m})
        ns = (hosts, coord, nmsgs, committed, budgets, nart, flags)
        label = f"deliver_commit(h{i},e{mepoch},t{matt})"
        if hepoch >= mepoch:
            yield (label, ns)  # duplicate/stale: idempotent
        elif (staged is not None and staged[0] == mepoch
                and (cfg.legacy_acks or staged[2] == matt)):
            yield (label, _install(ns, i, mepoch, staged[1], "commit",
                                   trace + [label]))
        else:
            # the host REFUSES the commit (ShardHost.commit raises when
            # its staged copy is missing or from a different epoch /
            # attempt — e.g. clobbered by a reordered stale prepare);
            # the drivers resolve a refused commit by fencing the host
            # for re-sync.  Refusal is an availability event, not silent
            # divergence — the serve-side invariants live in _install.
            nc = (coord[0], coord[1], coord[2], coord[3], coord[4],
                  coord[5], coord[6], coord[7] | {i}, coord[8])
            yield (label + "+refused",
                   (hosts, nc, nmsgs, committed, budgets, nart, flags))

    # -- crash: primary dies; the standby's take_over resolves the round.
    # Acks/commits are synchronous RPCs bound to the dead primary (its
    # unsent commits vanish; replies addressed to it are never read by
    # the standby) — only prepares survive in flight, because a host can
    # still process a request from its pipe after the sender died.
    if alive and crash_budget > 0:
        label = "crash+takeover"
        ns = (hosts, coord, (prepares, frozenset(), frozenset()), committed,
              (fence_budget, crash_budget - 1), nart,
              (flags[0], flags[1], True))
        yield (label, _take_over(ns, cfg, trace + [label]))

    # -- heal: a silent host's link recovers (after barrier resolution)
    if pending is None and not commits:
        for i, h in enumerate(hosts):
            hepoch, hart, staged, voted, silent, ackset, rsset = h
            if silent:
                nh = (hepoch, hart, staged, voted, False, ackset, rsset)
                yield (f"heal(h{i})",
                       (_set_host(hosts, i, nh), coord, msgs, committed,
                        budgets, nart, flags))

    # -- rejoin: driver re-admits a healed fenced host between rounds
    if alive and round_open:
        for i in fenced:
            hepoch, hart, staged, voted, silent, ackset, rsset = hosts[i]
            if silent:
                continue
            nc = (alive, cepoch, cart, catt, pending, acks, votes,
                  fenced - {i}, props)
            label = f"rejoin(h{i})"
            if hepoch >= cepoch:
                # current-epoch straggler: re-admitted directly — note it
                # may still hold a stale staged artifact (the abort never
                # reached it); only the ack checks keep that inert
                yield (label,
                       (hosts, nc, msgs, committed, budgets, nart, flags))
            else:
                # behind: COREWIRE re-sync installs the committed artifact
                ns = (hosts, nc, msgs, committed, budgets, nart, flags)
                yield (label + "+resync",
                       _install(ns, i, cepoch, cart, "resync",
                                trace + [label]))


def _take_over(state: State, cfg: CheckConfig, trace: List[str]) -> State:
    """Standby promotion (consensus.StandbyCoordinator.take_over): the
    mirror equals the primary's protocol state (deltas are piggybacked on
    the same transport); silent hosts are unreachable probes."""
    hosts, coord, msgs, committed, budgets, nart, flags = state
    alive, cepoch, cart, catt, pending, acks, votes, fenced, props = coord
    unreachable = {i for i, h in enumerate(hosts) if h[4]}
    nfenced = fenced | unreachable
    # the promoted coordinator resumes ABOVE every attempt the dead
    # primary issued (mirrored via the prepare deltas)
    ncoord: Coord = (True, cepoch, cart, catt, pending, acks, frozenset(),
                     nfenced, props)
    ns: State = (hosts, ncoord, msgs, committed, budgets, nart, flags)
    if pending is not None:
        pepoch, part, patt = pending
        reach_active = [i for i in range(cfg.n_hosts)
                        if i not in unreachable and i not in fenced]
        installed = any(hosts[i][0] >= pepoch for i in reach_active)
        all_acked = set(reach_active) <= set(acks)
        if installed or all_acked:
            # complete: re-broadcast the commit; a reachable active host
            # that never staged is fenced for re-sync
            committed = committed | {(pepoch, part)}
            ns = (hosts, ncoord, msgs, committed, budgets, nart, flags)
            for i in reach_active:
                hepoch, hart, staged, voted, silent, ackset, rsset = \
                    ns[0][i]
                if hepoch >= pepoch:
                    continue
                if (staged is not None and staged[0] == pepoch
                        and (cfg.legacy_acks or staged[2] == patt)):
                    ns = _install(ns, i, pepoch, staged[1], "commit", trace)
                else:
                    h2, c2, m2, cm2, b2, na2, f2 = ns
                    c2 = (c2[0], c2[1], c2[2], c2[3], c2[4], c2[5], c2[6],
                          c2[7] | {i}, c2[8])
                    ns = (h2, c2, m2, cm2, b2, na2, f2)
            hosts2, c2, m2, cm2, b2, na2, f2 = ns
            c2 = (True, pepoch, part, c2[3], None, frozenset(), frozenset(),
                  c2[7], c2[8])
            ns = (hosts2, c2, m2, cm2, b2, na2, f2)
        else:
            ns = _coord_abort(ns, cfg)
    else:
        # idle takeover still re-arms voting on reachable hosts (the dead
        # primary's collected votes died with it)
        ns = _coord_abort(ns, cfg)
        h2, c2, m2, cm2, b2, na2, (_a, _f, _p) = ns
        ns = (h2, c2, m2, cm2, b2, na2, (flags[0], flags[1], True))
    # fence reachable hosts still behind the resolved epoch
    hosts2, c2, m2, cm2, b2, na2, f2 = ns
    behind = frozenset(
        i for i in range(cfg.n_hosts)
        if hosts2[i][0] < c2[1] and i not in c2[7])
    c2 = (c2[0], c2[1], c2[2], c2[3], c2[4], c2[5], c2[6], c2[7] | behind,
          c2[8])
    return (hosts2, c2, m2, cm2, b2, na2, f2)


# ---------------------------------------------------------------------------
# BFS exploration
# ---------------------------------------------------------------------------


def check(cfg: Optional[CheckConfig] = None) -> CheckResult:
    cfg = cfg or CheckConfig()
    init = _initial_state(cfg)
    seen = {init}
    # parent pointers for minimal counterexample traces
    parent: Dict[State, Tuple[Optional[State], str]] = {init: (None, "init")}
    queue = deque([init])
    transitions = 0
    witnesses = {
        "commit-reachable": False,
        "abort-reachable": False,
        "I3-repropose-after-abort": False,
        "I4-fence-survives-abort": False,
        "failover-reachable": False,
    }

    def trace_to(s: State) -> List[str]:
        out: List[str] = []
        cur: Optional[State] = s
        while cur is not None:
            prev, label = parent[cur]
            out.append(label)
            cur = prev
        return list(reversed(out))[1:]  # drop "init"

    violation: Optional[InvariantViolation] = None
    try:
        while queue:
            state = queue.popleft()
            hosts, coord, msgs, committed, budgets, nart, flags = state
            if committed:
                witnesses["commit-reachable"] = True
            if flags[0]:
                witnesses["abort-reachable"] = True
                if coord[4] is not None:
                    witnesses["I3-repropose-after-abort"] = True
            if flags[1]:
                witnesses["I4-fence-survives-abort"] = True
            if flags[2]:
                witnesses["failover-reachable"] = True
            for label, nxt in _successors(state, cfg, trace_to(state)):
                transitions += 1
                if nxt not in seen:
                    seen.add(nxt)
                    parent[nxt] = (state, label)
                    queue.append(nxt)
    except InvariantViolation as e:
        violation = e
    return CheckResult(states_explored=len(seen), transitions=transitions,
                       violation=violation, witnesses=witnesses)


def main(argv=None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        description="exhaustively check the swap/failover/fence protocol")
    parser.add_argument("--hosts", type=int, default=3)
    parser.add_argument("--proposals", type=int, default=2)
    parser.add_argument("--json", action="store_true")
    parser.add_argument(
        "--skip-legacy", action="store_true",
        help="skip the legacy-mode run that must reproduce the stale-ack bug")
    args = parser.parse_args(argv)

    strict = check(CheckConfig(n_hosts=args.hosts,
                               max_proposals=args.proposals))
    report = {
        "states_explored": strict.states_explored,
        "transitions": strict.transitions,
        "invariants_ok": strict.violation is None,
        "witnesses": strict.witnesses,
    }
    ok = strict.ok
    if strict.violation is not None:
        report["violation"] = {
            "invariant": strict.violation.invariant,
            "detail": strict.violation.detail,
            "trace": strict.violation.trace,
        }
    if not args.skip_legacy:
        legacy = check(CheckConfig(n_hosts=args.hosts,
                                   max_proposals=args.proposals,
                                   legacy_acks=True))
        report["legacy_bug_reproduced"] = legacy.violation is not None
        if legacy.violation is not None:
            report["legacy_violation"] = {
                "invariant": legacy.violation.invariant,
                "detail": legacy.violation.detail,
                "trace": legacy.violation.trace,
            }
        else:
            ok = False  # the checker lost its teeth
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print(f"protocol_check: {report['states_explored']} states, "
              f"{report['transitions']} transitions")
        if strict.violation is not None:
            print(f"  VIOLATION {strict.violation.invariant}: "
                  f"{strict.violation.detail}")
            for step in strict.violation.trace:
                print(f"    {step}")
        for name, hit in strict.witnesses.items():
            print(f"  witness {name}: {'ok' if hit else 'MISSING'}")
        if "legacy_bug_reproduced" in report:
            print(f"  legacy stale-ack bug reproduced: "
                  f"{report['legacy_bug_reproduced']}")
            if report["legacy_bug_reproduced"]:
                v = report["legacy_violation"]
                print(f"    {v['invariant']}: {v['detail']}")
                for step in v["trace"]:
                    print(f"      {step}")
    return 0 if ok else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
