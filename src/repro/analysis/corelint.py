"""corelint — AST-based invariant lint for this repo (DESIGN.md §9).

Every rule here is distilled from a bug class this repo actually shipped
and later fixed; the ``origin`` field on each rule names the incident.
The engine is deliberately small: parse each file once, hand the tree to
every rule whose path scope matches, collect ``Violation``s, subtract
per-line ``# corelint: disable=RULE`` suppressions and the checked-in
JSON baseline, and report what is left.  CI (``scripts/ci.sh --lane
lint``) gates the leftover count to zero.

Suppression syntax (same line or the line directly above)::

    t0 = time.perf_counter()  # corelint: disable=wall-clock-decision
    # corelint: disable=identity-cache-key,unseeded-randomness
    key = id(params)

Baseline file: ``{"path/to/file.py": {"rule-id": count}}`` — masks the
first ``count`` findings per (path, rule), so historical findings do not
fail CI while any NEW finding in the same file still does.  The goal
state (and the checked-in state) is an EMPTY baseline: every historical
finding was either fixed or carries an explicit, justified suppression.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path, PurePosixPath
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

# --------------------------------------------------------------------------
# Core datatypes
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str  # repo-relative, posix separators
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class FileContext:
    """Everything a rule gets to look at for one file."""

    relpath: str  # posix repo-relative path
    tree: ast.Module
    lines: Sequence[str]

    @property
    def segments(self) -> Tuple[str, ...]:
        return PurePosixPath(self.relpath).parts

    @property
    def filename(self) -> str:
        return PurePosixPath(self.relpath).name


@dataclass
class Rule:
    id: str
    summary: str
    origin: str  # the historical bug this rule descends from
    applies: Callable[[FileContext], bool]
    check: Callable[[FileContext], List[Tuple[int, str]]]


@dataclass
class LintReport:
    violations: List[Violation] = field(default_factory=list)
    suppressed: int = 0
    baselined: int = 0
    files_scanned: int = 0
    parse_errors: List[str] = field(default_factory=list)


# --------------------------------------------------------------------------
# Shared AST helpers
# --------------------------------------------------------------------------

#: Path segments whose modules make scheduling / persistence / protocol
#: decisions.  Matching on segments (not prefixes) lets the lint fixture
#: tree under tests/lint_fixtures/serving/ exercise the same scopes.
DECISION_SEGMENTS = frozenset({"serving", "core", "distributed"})


def _in_decision_scope(ctx: FileContext) -> bool:
    return bool(DECISION_SEGMENTS & set(ctx.segments[:-1]))


def _name_of(node: ast.AST) -> Optional[str]:
    """Dotted name of an expression, e.g. ``np.random.seed`` -> that string."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _enclosing_scopes(tree: ast.Module) -> Dict[ast.AST, ast.AST]:
    """Map every node to its innermost enclosing function (or the module)."""
    owner: Dict[ast.AST, ast.AST] = {}

    def walk(node: ast.AST, scope: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            owner[child] = scope
            inner = scope
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner = child
            walk(child, inner)

    walk(tree, tree)
    return owner


def _is_tempy(node: ast.AST) -> bool:
    """Heuristic: does this path expression look like a temp-file path?"""
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id.lower().startswith(("tmp", "temp")):
            return True
        if isinstance(n, ast.Attribute) and n.attr.lower().startswith(("tmp", "temp")):
            return True
        if isinstance(n, ast.Constant) and isinstance(n.value, str) and ".tmp" in n.value:
            return True
    return False


def _scope_has_atomic_publish(scope: ast.AST) -> bool:
    """True if the scope ends with an atomic publish: ``os.replace(...)``
    or ``<tempy>.replace/rename(...)`` (pathlib spelling)."""
    for n in ast.walk(scope):
        if not isinstance(n, ast.Call) or not isinstance(n.func, ast.Attribute):
            continue
        fn = n.func
        if fn.attr == "replace" and isinstance(fn.value, ast.Name) and fn.value.id == "os":
            return True
        if fn.attr in ("replace", "rename") and _is_tempy(fn.value):
            return True
    return False


# --------------------------------------------------------------------------
# Rule: wall-clock-decision
# --------------------------------------------------------------------------

_WALL_CLOCK_ATTRS = frozenset(
    {"perf_counter", "perf_counter_ns", "monotonic", "monotonic_ns", "time", "time_ns"}
)


def _check_wall_clock(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute):
            name = _name_of(node)
            if name and name.startswith("time.") and node.attr in _WALL_CLOCK_ATTRS:
                out.append(
                    (
                        node.lineno,
                        f"raw wall-clock read `{name}` in a decision-path module; "
                        "route it through repro.util.advisory_wall_ms()",
                    )
                )
            elif name in ("datetime.now", "datetime.datetime.now", "datetime.utcnow"):
                out.append((node.lineno, f"raw wall-clock read `{name}` in a decision-path module"))
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            bad = [a.name for a in node.names if a.name in _WALL_CLOCK_ATTRS]
            if bad:
                out.append(
                    (
                        node.lineno,
                        f"importing clock function(s) {bad} from time into a decision-path "
                        "module; use repro.util.advisory_wall_ms()",
                    )
                )
    return out


# --------------------------------------------------------------------------
# Rule: identity-cache-key
# --------------------------------------------------------------------------


def _check_identity_cache_key(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "id"
            and len(node.args) == 1
            and not node.keywords
        ):
            out.append(
                (
                    node.lineno,
                    "id(obj) is an object-identity value — ids are recycled after gc, "
                    "so it must not key a cache or name an artifact; use a content "
                    "fingerprint (see core/compile_cache.py)",
                )
            )
    return out


# --------------------------------------------------------------------------
# Rule: atomic-persistence
# --------------------------------------------------------------------------

_WRITE_MODE_RE = re.compile(r"[wx]")


def _open_mode(call: ast.Call) -> Optional[str]:
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
        if isinstance(call.args[1].value, str):
            return call.args[1].value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            if isinstance(kw.value.value, str):
                return kw.value.value
    return None


def _check_atomic_persistence(ctx: FileContext) -> List[Tuple[int, str]]:
    owner = _enclosing_scopes(ctx.tree)
    out: List[Tuple[int, str]] = []
    atomic_scopes: Dict[ast.AST, bool] = {}

    def scope_ok(node: ast.AST) -> bool:
        scope = owner.get(node, ctx.tree)
        if scope not in atomic_scopes:
            atomic_scopes[scope] = _scope_has_atomic_publish(scope)
        return atomic_scopes[scope]

    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        target: Optional[ast.AST] = None
        what = ""
        if isinstance(node.func, ast.Name) and node.func.id == "open" and node.args:
            mode = _open_mode(node)
            if mode is None or not _WRITE_MODE_RE.search(mode):
                continue
            target, what = node.args[0], f'open(..., "{mode}")'
        elif isinstance(node.func, ast.Attribute) and node.func.attr in (
            "write_text",
            "write_bytes",
        ):
            target, what = node.func.value, f".{node.func.attr}(...)"
        else:
            continue
        if _is_tempy(target) or scope_ok(node):
            continue
        out.append(
            (
                node.lineno,
                f"{what} writes a shared path in place; publish via same-dir temp file "
                "+ os.replace (repro.util.atomic_write_text/bytes) so readers never "
                "see a torn file",
            )
        )
    return out


# --------------------------------------------------------------------------
# Rule: unseeded-randomness
# --------------------------------------------------------------------------

_NP_GLOBAL_RNG = frozenset(
    {
        "rand",
        "randn",
        "randint",
        "random",
        "random_sample",
        "ranf",
        "sample",
        "choice",
        "shuffle",
        "permutation",
        "normal",
        "uniform",
        "standard_normal",
        "beta",
        "binomial",
        "poisson",
        "exponential",
        "seed",
    }
)
_STDLIB_RNG = frozenset(
    {"random", "randint", "randrange", "uniform", "choice", "choices", "shuffle", "sample", "gauss"}
)


def _check_unseeded_randomness(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    imports_stdlib_random = any(
        isinstance(n, ast.Import) and any(a.name == "random" for a in n.names)
        for n in ast.walk(ctx.tree)
    )
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _name_of(node.func)
        if name is None:
            continue
        parts = name.split(".")
        if len(parts) == 3 and parts[0] in ("np", "numpy") and parts[1] == "random":
            if parts[2] in _NP_GLOBAL_RNG:
                out.append(
                    (
                        node.lineno,
                        f"`{name}` draws from the process-global numpy RNG; gated paths "
                        "must thread an explicit seeded Generator/RandomState",
                    )
                )
            elif parts[2] in ("RandomState", "default_rng") and not node.args and not node.keywords:
                out.append(
                    (node.lineno, f"`{name}()` without a seed is nondeterministic in a gated path")
                )
        elif (
            imports_stdlib_random
            and len(parts) == 2
            and parts[0] == "random"
            and parts[1] in _STDLIB_RNG
        ):
            out.append(
                (
                    node.lineno,
                    f"`{name}` uses the process-global stdlib RNG; thread an explicit "
                    "seeded random.Random",
                )
            )
    return out


# --------------------------------------------------------------------------
# Rule: wire-pack-outside-ops
# --------------------------------------------------------------------------


def _is_wire_ops_module(ctx: FileContext) -> bool:
    return ctx.filename == "ops.py" and "kernels" in ctx.segments


def _has_byteorder_arg(call: ast.Call) -> bool:
    """int.to_bytes/from_bytes carry a byteorder ("little"/"big") argument;
    container-serialization methods that merely share the name do not."""
    for arg in call.args:
        if isinstance(arg, ast.Constant) and arg.value in ("little", "big"):
            return True
    return any(kw.arg == "byteorder" for kw in call.keywords)


def _check_wire_pack(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _name_of(node.func)
        if name is None:
            continue
        leaf = name.rsplit(".", 1)[-1]
        if leaf in ("to_bytes", "from_bytes") and "." in name and _has_byteorder_arg(node):
            out.append(
                (
                    node.lineno,
                    f"raw integer wire packing `{name}` outside kernels/ops.py; use "
                    "ops.pack_le/unpack_le so COREWIRE field layout stays in one module",
                )
            )
        elif name.startswith("struct.") and leaf in ("pack", "unpack", "pack_into", "unpack_from"):
            out.append(
                (node.lineno, f"raw struct packing `{name}` outside kernels/ops.py (COREWIRE discipline)")
            )
    return out


# --------------------------------------------------------------------------
# Rule: wire-minor-exhaustive
# --------------------------------------------------------------------------


def _mentions_wire_minor(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id.startswith("WIRE_MINOR"):
            return True
        if isinstance(n, ast.Attribute) and n.attr.startswith("WIRE_MINOR"):
            return True
    return False


def _check_wire_minor_exhaustive(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        compares = [
            n
            for n in ast.walk(fn)
            if isinstance(n, (ast.Compare, ast.Match)) and _mentions_wire_minor(n)
        ]
        if not compares:
            continue
        if not any(isinstance(n, ast.Raise) for n in ast.walk(fn)):
            out.append(
                (
                    compares[0].lineno,
                    f"`{fn.name}` dispatches on a COREWIRE minor but never raises: an "
                    "unknown minor must fail loudly (WireFormatError), not fall through",
                )
            )
    return out


# --------------------------------------------------------------------------
# Rule: weights-travel
# --------------------------------------------------------------------------

#: ReservoirSample(indices, x, known_sigma, weights) — a call that fills
#: the first three but not `weights` silently reverts to uniform weighting
#: and un-corrects the IPW audit (the PR 4 bug).
_SAMPLE_CTORS = {"ReservoirSample": 4}


def _check_weights_travel(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _name_of(node.func)
        if name is None:
            continue
        leaf = name.rsplit(".", 1)[-1]
        if leaf not in _SAMPLE_CTORS:
            continue
        if any(kw.arg is None for kw in node.keywords):  # **kwargs: can't see inside
            continue
        if any(kw.arg == "weights" for kw in node.keywords):
            continue
        if len(node.args) >= _SAMPLE_CTORS[leaf]:
            continue
        out.append(
            (
                node.lineno,
                f"`{leaf}(...)` without `weights=`: IPW weights must travel with the "
                "sample or the merged audit silently reverts to uniform (PR 4 bug)",
            )
        )
    return out


# --------------------------------------------------------------------------
# Rule: host-sync-hot-path
# --------------------------------------------------------------------------


def _in_proxy_score_scope(ctx: FileContext) -> bool:
    return ctx.filename.startswith("proxy_score")


def _check_host_sync(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _name_of(node.func)
        if name is None:
            continue
        leaf = name.rsplit(".", 1)[-1]
        if leaf == "item" and not node.args and not node.keywords:
            what = f"`{name}()`"
        elif name in ("np.asarray", "numpy.asarray", "np.array", "numpy.array", "jax.device_get"):
            what = f"`{name}(...)`"
        elif leaf == "block_until_ready":
            what = f"`{name}()`"
        else:
            continue
        out.append(
            (
                node.lineno,
                f"{what} forces a device→host sync inside the scoring hot path; keep "
                "values on device until the survivor gather",
            )
        )
    return out


# --------------------------------------------------------------------------
# Rule: print-in-protocol
# --------------------------------------------------------------------------


def _check_print_in_protocol(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "print"
        ):
            file_kw = next((kw for kw in node.keywords if kw.arg == "file"), None)
            if file_kw is not None and _name_of(file_kw.value) != "sys.stdout":
                continue
            out.append(
                (
                    node.lineno,
                    "print() to stdout inside a distributed protocol module: the process "
                    "transport multiplexes stdout pipes for RPC framing — stray prints "
                    "corrupt it; write to sys.stderr or a logger",
                )
            )
    return out


# --------------------------------------------------------------------------
# Rule: deprecated-entry-point
# --------------------------------------------------------------------------

#: The PR-10 API redesign left ``optimize`` / ``reoptimize`` /
#: ``warm_optimize`` as DeprecationWarning shims for external callers;
#: INTERNAL code must use the ``core.api`` surface.  ``optimize`` is only
#: flagged as a bare name: the attribute form (``handle.optimize()``) is
#: the NEW session API, while ``cache.warm_optimize()`` /
#: ``x.reoptimize()`` have no non-deprecated reading.
_DEPRECATED_BARE = frozenset({"optimize", "reoptimize", "warm_optimize"})
_DEPRECATED_ATTR = frozenset({"reoptimize", "warm_optimize"})
_API_REPLACEMENT = {
    "optimize": "repro.core.api.build_plan(query, x, OptimizeOptions(...))",
    "reoptimize": "repro.core.api.rebuild_plan(plan, x, options)",
    "warm_optimize": "PlanCache.optimize_query(query, x, options)",
}


def _in_entry_point_scope(ctx: FileContext) -> bool:
    """Decision-path modules plus the launch veneers (the CLI is where a
    stray deprecated call would teach users the old surface)."""
    return bool((DECISION_SEGMENTS | {"launch"}) & set(ctx.segments[:-1]))


def _check_deprecated_entry_point(ctx: FileContext) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Name) and node.func.id in _DEPRECATED_BARE:
            leaf = node.func.id
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr in _DEPRECATED_ATTR):
            leaf = node.func.attr
        else:
            continue
        out.append(
            (
                node.lineno,
                f"`{leaf}()` is a deprecated shim kept for external callers "
                f"only; internal code must call "
                f"{_API_REPLACEMENT[leaf]}",
            )
        )
    return out


# --------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------

RULES: List[Rule] = [
    Rule(
        id="wall-clock-decision",
        summary="no raw wall-clock reads in decision-path modules",
        origin="PR 7: wall-clock fused_score_ms nearly fed scheduling; decisions must run "
        "on the cost-model clock (advisory_wall_ms is the one sanctioned read)",
        applies=_in_decision_scope,
        check=_check_wall_clock,
    ),
    Rule(
        id="identity-cache-key",
        summary="no id()/object-identity cache keys or artifact names",
        origin="PR 4: id()-keyed scorer compile cache returned a stale kernel after gc "
        "recycled the address; caches must key on content fingerprints",
        applies=lambda ctx: True,
        check=_check_identity_cache_key,
    ),
    Rule(
        id="atomic-persistence",
        summary="shared-path writes must publish via temp file + os.replace",
        origin="PR 7: concurrent autotune runs tore the shared disk cache mid-write; "
        "kernels/autotune.py now publishes atomically and so must every shared path",
        applies=lambda ctx: True,
        check=_check_atomic_persistence,
    ),
    Rule(
        id="unseeded-randomness",
        summary="no process-global / unseeded RNG in gated paths",
        origin="gated benches and tier-1 tests must be bit-reproducible; a module-level "
        "np.random call made BENCH_components.json drift run-to-run",
        applies=_in_decision_scope,
        check=_check_unseeded_randomness,
    ),
    Rule(
        id="wire-pack-outside-ops",
        summary="COREWIRE byte packing lives only in kernels/ops.py",
        origin="PR 8: COREPLNC hand-packed container fields; two packers drifted on "
        "endianness assumptions until unified behind ops helpers",
        applies=lambda ctx: not _is_wire_ops_module(ctx),
        check=_check_wire_pack,
    ),
    Rule(
        id="wire-minor-exhaustive",
        summary="COREWIRE minor dispatch must raise on unknown minors",
        origin="PR 6: COREWIRE v1.2 added the quant minor; a silent fall-through would "
        "deserialize quantized payloads as fp32 garbage instead of failing",
        applies=lambda ctx: True,
        check=_check_wire_minor_exhaustive,
    ),
    Rule(
        id="weights-travel",
        summary="reservoir/audit samples must carry their IPW weights",
        origin="PR 4: Reservoir.sample() dropped IPW weights; the merged audit silently "
        "reverted to uniform weighting and biased selectivity estimates",
        applies=lambda ctx: True,
        check=_check_weights_travel,
    ),
    Rule(
        id="host-sync-hot-path",
        summary="no device→host syncs inside the fused scoring kernel path",
        origin="PR 1: per-stage host bouncing was the original 3-6x slowdown the fused "
        "kernel removed; .item()/np.asarray in proxy_score.py reintroduces it",
        applies=_in_proxy_score_scope,
        check=_check_host_sync,
    ),
    Rule(
        id="print-in-protocol",
        summary="no stdout prints in distributed protocol modules",
        origin="PR 5: the one-host-per-subprocess transport frames RPCs over pipes; a "
        "debug print interleaved with a reply and desynced the channel",
        applies=lambda ctx: "distributed" in ctx.segments[:-1],
        check=_check_print_in_protocol,
    ),
    Rule(
        id="deprecated-entry-point",
        summary="internal code must not call the deprecated optimizer shims",
        origin="PR 10: the api_redesign left optimize/reoptimize/warm_optimize as "
        "DeprecationWarning shims; an internal caller silently keeps the old kwarg "
        "surface alive and the shims can never be retired",
        applies=_in_entry_point_scope,
        check=_check_deprecated_entry_point,
    ),
]

RULE_IDS = frozenset(r.id for r in RULES)


# --------------------------------------------------------------------------
# Suppressions
# --------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(r"#\s*corelint:\s*disable=([\w\-,\s]+)")


def _suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {tok.strip() for tok in m.group(1).split(",") if tok.strip()}
    return out


def _is_suppressed(rule_id: str, line: int, supp: Dict[int, Set[str]]) -> bool:
    for ln in (line, line - 1):
        ids = supp.get(ln)
        if ids and (rule_id in ids or "all" in ids):
            return True
    return False


# --------------------------------------------------------------------------
# Baseline
# --------------------------------------------------------------------------


def load_baseline(path) -> Dict[str, Dict[str, int]]:
    p = Path(path)
    if not p.exists():
        return {}
    data = json.loads(p.read_text())
    return {str(f): {str(r): int(c) for r, c in rules.items()} for f, rules in data.items()}


def write_baseline(path, violations: Iterable[Violation]) -> Dict[str, Dict[str, int]]:
    counts: Dict[str, Dict[str, int]] = {}
    for v in violations:
        counts.setdefault(v.path, {})
        counts[v.path][v.rule] = counts[v.path].get(v.rule, 0) + 1
    payload = json.dumps(counts, indent=2, sort_keys=True) + "\n"
    # Import here (not module level) so corelint has no repro-runtime deps
    # when vendored into other tooling.
    from repro.util import atomic_write_text

    atomic_write_text(path, payload)
    return counts


def apply_baseline(
    violations: List[Violation], baseline: Dict[str, Dict[str, int]]
) -> Tuple[List[Violation], int]:
    """Mask the first N findings per (path, rule); return (new, masked)."""
    budget = {
        (path, rule): count for path, rules in baseline.items() for rule, count in rules.items()
    }
    fresh: List[Violation] = []
    masked = 0
    for v in sorted(violations, key=lambda v: (v.path, v.rule, v.line)):
        key = (v.path, v.rule)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            masked += 1
        else:
            fresh.append(v)
    return fresh, masked


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def lint_source(
    source: str, relpath: str, enabled: Optional[Set[str]] = None
) -> Tuple[List[Violation], int]:
    """Lint one file's source text; returns (violations, suppressed_count)."""
    tree = ast.parse(source, filename=relpath)
    lines = source.splitlines()
    ctx = FileContext(relpath=relpath, tree=tree, lines=lines)
    supp = _suppressions(lines)
    violations: List[Violation] = []
    suppressed = 0
    for rule in RULES:
        if enabled is not None and rule.id not in enabled:
            continue
        if not rule.applies(ctx):
            continue
        for line, message in rule.check(ctx):
            if _is_suppressed(rule.id, line, supp):
                suppressed += 1
            else:
                violations.append(Violation(rule.id, relpath, line, message))
    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    return violations, suppressed


def iter_py_files(paths: Sequence[Path], root: Path) -> Iterable[Tuple[Path, str]]:
    seen: Set[Path] = set()
    for p in paths:
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            f = f.resolve()
            if f in seen or "__pycache__" in f.parts:
                continue
            seen.add(f)
            try:
                rel = f.relative_to(root).as_posix()
            except ValueError:
                rel = f.as_posix()
            yield f, rel


def run_corelint(
    paths: Sequence,
    root=None,
    baseline: Optional[Dict[str, Dict[str, int]]] = None,
    enabled: Optional[Set[str]] = None,
) -> LintReport:
    root = Path(root) if root is not None else Path.cwd()
    report = LintReport()
    all_violations: List[Violation] = []
    for f, rel in iter_py_files([Path(p) for p in paths], root):
        try:
            source = f.read_text(encoding="utf-8")
            violations, suppressed = lint_source(source, rel, enabled=enabled)
        except (SyntaxError, UnicodeDecodeError) as e:
            report.parse_errors.append(f"{rel}: {e}")
            continue
        report.files_scanned += 1
        report.suppressed += suppressed
        all_violations.extend(violations)
    if baseline:
        all_violations, masked = apply_baseline(all_violations, baseline)
        report.baselined = masked
    report.violations = all_violations
    return report
