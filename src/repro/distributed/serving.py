"""Multi-host sharded serving with quorum-voted plan swaps (DESIGN.md §6).

The input stream is sharded across K simulated hosts.  Each host runs its
OWN ``CascadeServer`` — local CUSUM detectors, importance-audit sampler,
and weighted reservoir — but local drift triggers do not swap plans:
they become ``DriftVote``s to a ``QuorumSwapCoordinator``.  On quorum the
coordinator merges every host's reservoir export (IPW weights preserved),
runs the warm-started re-optimization ONCE, and broadcasts the result as
the versioned scorer wire artifact through a two-phase (prepare/commit)
epoch swap: hosts stage + ack first, and only install once every peer has
acknowledged — no host ever serves a plan version its peers haven't seen.
In-flight records still finish under the plan version that scored them
(the engine's versioned ``_PlanState`` machinery), so record conservation
holds across global swaps exactly as it does across local ones.

Two transports share all protocol logic:

* ``transport="inline"`` — hosts are plain objects driven round-robin by
  the caller's thread; deterministic, the benchmark/test default.
* ``transport="thread"`` — each host runs in its own worker thread with a
  command queue; the coordinator talks to it only via messages.  Same
  code path as inline (``_ThreadHost`` proxies ``ShardHost``), but the
  prepare/commit barrier crosses real thread boundaries.

A real deployment would replace the transport with RPC; the protocol core
(``distributed/consensus.py``) is transport-agnostic by construction.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.query import PhysicalPlan
from repro.distributed.consensus import (
    DriftVote,
    QuorumSwapCoordinator,
    SwapAck,
    SwapCommit,
    SwapPrepare,
    SwapRecord,
)
from repro.serving.engine import CascadeServer, ServeStats
from repro.serving.stats import AdaptivePolicy, DriftEvent


@dataclass
class ShardedServeStats:
    """Aggregate view over K hosts plus the consensus layer."""

    n_hosts: int
    per_host: List[ServeStats]
    submitted_per_host: List[int]
    votes_cast: int = 0
    swaps_committed: int = 0
    swaps_aborted: int = 0
    final_epoch: int = 0
    swap_log: List[SwapRecord] = field(default_factory=list)
    wall_ms: float = 0.0

    @property
    def submitted(self) -> int:
        return sum(self.submitted_per_host)

    @property
    def emitted(self) -> int:
        return sum(s.emitted for s in self.per_host)

    @property
    def rejected(self) -> int:
        return sum(s.rejected for s in self.per_host)

    @property
    def host_cost_ms(self) -> List[float]:
        return [s.model_cost_ms for s in self.per_host]

    @property
    def critical_path_cost_ms(self) -> float:
        """Hosts run in parallel: the cost-model makespan is the slowest
        host's total, not the sum."""
        return max(self.host_cost_ms) if self.per_host else 0.0

    @property
    def aggregate_rows_per_cost_s(self) -> float:
        cp = self.critical_path_cost_ms
        return self.submitted / (cp / 1e3) if cp > 0 else 0.0

    @property
    def consensus_ms_total(self) -> float:
        return sum(r.consensus_ms for r in self.swap_log)


class ShardHost:
    """One simulated serving host: a private ``CascadeServer`` whose drift
    triggers are exported as votes, plus the two-phase staging slot."""

    def __init__(self, host_id: int, plan: PhysicalPlan, *, tile: int,
                 policy: AdaptivePolicy, seed: int, use_kernel: bool = True):
        self.host_id = host_id
        self.engine = CascadeServer(
            plan, tile=tile, use_kernel=use_kernel, adaptive=True,
            policy=policy, seed=seed)
        self.query = plan.query
        self.epoch = 0
        self._voted_epoch = -1
        self._staged: Optional[Tuple[int, PhysicalPlan, object]] = None
        self.submitted = 0
        # idx -> engine plan version current when the record was submitted
        # (None until a test enables tracking; kept off the hot path)
        self.track_versions = False
        self.submit_version: Dict[int, int] = {}

    # ------------------------------------------------------------- serving
    def submit_chunk(self, indices: np.ndarray, rows: np.ndarray) -> None:
        if self.track_versions:
            v = self.engine.plan_version
            for i in indices:
                self.submit_version[int(i)] = v
        self.engine.submit(indices, rows)
        self.engine.pump()
        self.submitted += len(rows)

    def drain(self) -> ServeStats:
        self.engine.pump(drain=True)
        st = self.engine.stats
        st.rejected = self.submitted - st.emitted
        return st

    # -------------------------------------------------------------- voting
    def poll_vote(self) -> Optional[DriftVote]:
        """Consume a pending local drift trigger into a quorum vote.
        At most one vote per served epoch; repeat triggers within the
        epoch stay parked on the engine (the eventual global install
        clears them)."""
        if self._voted_epoch == self.epoch:
            return None
        drift = self.engine.take_drift()
        if drift is None:
            return None
        signal, observed, expected = drift
        _mode, escalated = self.engine.escalation_hint()
        self._voted_epoch = self.epoch
        return DriftVote(
            host=self.host_id, epoch=self.epoch,
            event=DriftEvent(
                at_record=self.submitted, signal=signal,
                observed=float(observed), expected=float(expected),
                escalated=escalated, plan_version=self.epoch,
            ),
            reservoir=self.engine.reservoir_export(),
        )

    def reservoir_export(self):
        return self.engine.reservoir_export()

    # --------------------------------------------------------- two-phase
    def prepare(self, msg: SwapPrepare) -> SwapAck:
        """Phase 1: deserialize + stage the artifact; serve nothing new."""
        from repro.kernels.ops import deserialize_scorer

        try:
            if msg.epoch != self.epoch + 1:
                raise ValueError(
                    f"host {self.host_id} at epoch {self.epoch} cannot "
                    f"stage epoch {msg.epoch}")
            plan, scorer = deserialize_scorer(msg.artifact, self.query)
            self._staged = (msg.epoch, plan, scorer)
            return SwapAck(host=self.host_id, epoch=msg.epoch, ok=True)
        except Exception as e:  # NACK aborts the epoch coordinator-side
            self._staged = None
            return SwapAck(host=self.host_id, epoch=msg.epoch, ok=False,
                           error=str(e))

    def commit(self, msg: SwapCommit) -> None:
        """Phase 2: every peer acked — install the staged plan.  In-flight
        queue entries finish under their scoring version."""
        if self._staged is None or self._staged[0] != msg.epoch:
            raise RuntimeError(
                f"host {self.host_id}: commit for epoch {msg.epoch} "
                f"without a matching staged plan")
        _, plan, scorer = self._staged
        self.engine.install_plan(plan, scorer=scorer, version=msg.epoch)
        self.epoch = msg.epoch
        self._staged = None

    def abort(self) -> None:
        """Aborted epoch: drop the staged copy AND re-arm voting — the
        epoch number did not advance, so without the reset every host
        that voted would be locked out (`_voted_epoch == epoch`) and a
        transient NACK would permanently disable quorum swaps."""
        self._staged = None
        self._voted_epoch = -1


class _ThreadHost:
    """Thread-isolated ``ShardHost``: the host's engine lives entirely on
    its worker thread; every interaction is a (request, reply) message
    pair over queues.  API-identical to ``ShardHost``."""

    def __init__(self, host: ShardHost):
        self._host = host
        self.host_id = host.host_id
        self._req: "queue.Queue" = queue.Queue()
        self._thread = threading.Thread(
            target=self._loop, name=f"shard-host-{host.host_id}", daemon=True)
        self._thread.start()

    def _loop(self):
        while True:
            fn, args, reply = self._req.get()
            if fn is None:
                reply.put(None)
                return
            try:
                reply.put((True, fn(*args)))
            except Exception as e:  # surfaced on the caller thread
                reply.put((False, e))

    def _call(self, fn, *args):
        reply: "queue.Queue" = queue.Queue()
        self._req.put((fn, args, reply))
        ok, out = reply.get()
        if not ok:
            raise out
        return out

    @property
    def epoch(self) -> int:
        return self._host.epoch

    @property
    def submitted(self) -> int:
        return self._host.submitted

    @property
    def engine(self) -> CascadeServer:
        return self._host.engine

    @property
    def track_versions(self) -> bool:
        return self._host.track_versions

    @track_versions.setter
    def track_versions(self, v: bool) -> None:
        self._host.track_versions = v

    @property
    def submit_version(self) -> Dict[int, int]:
        return self._host.submit_version

    def submit_chunk(self, indices, rows):
        return self._call(self._host.submit_chunk, indices, rows)

    def drain(self):
        return self._call(self._host.drain)

    def poll_vote(self):
        return self._call(self._host.poll_vote)

    def reservoir_export(self):
        return self._call(self._host.reservoir_export)

    def prepare(self, msg):
        return self._call(self._host.prepare, msg)

    def commit(self, msg):
        return self._call(self._host.commit, msg)

    def abort(self):
        return self._call(self._host.abort)

    def stop(self):
        reply: "queue.Queue" = queue.Queue()
        self._req.put((None, (), reply))
        reply.get()
        self._thread.join(timeout=10)


class ShardedCascadeServer:
    """K-host sharded serving driver.

    ``plan`` should come from ``optimize(..., keep_state=True)`` so the
    coordinator's re-optimizations warm-start; hosts receive only the
    serialized artifact (builder state never fans out).  ``n_hosts=1``
    degrades to single-host serving THROUGH the consensus path (quorum of
    one), which is what the sharded benchmark uses as its baseline.
    """

    def __init__(self, plan: PhysicalPlan, n_hosts: int = 4, *,
                 tile: int = 1024, policy: Optional[AdaptivePolicy] = None,
                 quorum_frac: float = 0.5, seed: int = 0,
                 use_kernel: bool = True, transport: str = "inline",
                 max_tile: int = 8192):
        if transport not in ("inline", "thread"):
            raise ValueError(f"unknown transport {transport!r}")
        self.n_hosts = int(n_hosts)
        self.policy = policy or AdaptivePolicy()
        self.plan0 = plan
        self.query = plan.query
        self.coordinator = QuorumSwapCoordinator(
            plan, self.n_hosts, reopt_fn=self._reopt,
            quorum_frac=quorum_frac,
            choose_mode=lambda p, fresh: self.policy.choose_escalation(p, fresh)[0],
            max_tile=max_tile,
        )
        hosts = [
            ShardHost(k, plan, tile=tile, policy=self.policy,
                      seed=seed + 1000 * k, use_kernel=use_kernel)
            for k in range(self.n_hosts)
        ]
        self.transport = transport
        self.hosts: List = (
            [_ThreadHost(h) for h in hosts] if transport == "thread" else hosts)
        self.stats = ShardedServeStats(
            n_hosts=self.n_hosts,
            per_host=[h.engine.stats for h in self.hosts],
            submitted_per_host=[0] * self.n_hosts,
        )

    # ------------------------------------------------------ re-optimization
    def _reopt(self, plan: PhysicalPlan, merged, mode: str) -> PhysicalPlan:
        from repro.core.optimizer import reoptimize

        return reoptimize(plan, merged.x, known_sigma=merged.known_sigma,
                          mode=mode, step=self.policy.step)

    # ------------------------------------------------------------ protocol
    def _handle_votes(self) -> None:
        for h in self.hosts:
            vote = h.poll_vote()
            if vote is None:
                continue
            self.stats.votes_cast += 1
            if self.coordinator.offer_vote(vote):
                self._run_swap()

    def _run_swap(self) -> None:
        """Quorum reached: merge + re-optimize + two-phase broadcast."""
        voters = set(self.coordinator.voters)
        extras = [h.reservoir_export() for h in self.hosts
                  if h.host_id not in voters]
        submitted_at_quorum = sum(h.submitted for h in self.hosts)
        prepare = self.coordinator.propose(extra_reservoirs=extras)
        t0 = time.perf_counter()
        commit = None
        for h in self.hosts:
            ack = h.prepare(prepare)
            commit = self.coordinator.offer_ack(ack)
            if not ack.ok:
                break
        self.coordinator.note_prepare_ms((time.perf_counter() - t0) * 1e3)
        if commit is None:  # aborted (NACK) — drop every host's staged copy
            for h in self.hosts:
                h.abort()
            self.stats.swaps_aborted += 1
            return
        t0 = time.perf_counter()
        for h in self.hosts:
            h.commit(commit)
        self.coordinator.note_commit_ms((time.perf_counter() - t0) * 1e3)
        # the barrier is synchronous in both transports: any submissions
        # while it was open would show up here
        self.coordinator.swap_log[-1].lag_records = (
            sum(h.submitted for h in self.hosts) - submitted_at_quorum)
        self.stats.swaps_committed += 1

    # -------------------------------------------------------------- driver
    def _drive(self, streams: List[np.ndarray], idx_map: List[np.ndarray],
               chunk: int) -> ShardedServeStats:
        """Round-robin the hosts one chunk at a time, handling votes (and
        any resulting swap) at every chunk boundary."""
        t_start = time.perf_counter()
        pos = [0] * self.n_hosts
        while any(pos[k] < len(streams[k]) for k in range(self.n_hosts)):
            for k, h in enumerate(self.hosts):
                lo = pos[k]
                if lo >= len(streams[k]):
                    continue
                hi = min(lo + chunk, len(streams[k]))
                h.submit_chunk(idx_map[k][lo:hi], streams[k][lo:hi])
                pos[k] = hi
            self._handle_votes()
        for k, h in enumerate(self.hosts):
            h.drain()
            self.stats.submitted_per_host[k] = h.submitted
        self.stats.final_epoch = self.coordinator.epoch
        self.stats.swap_log = list(self.coordinator.swap_log)
        self.stats.wall_ms = (time.perf_counter() - t_start) * 1e3
        if self.transport == "thread":
            for h in self.hosts:
                h.stop()
        return self.stats

    def run_streams(self, streams: Sequence[np.ndarray], *,
                    chunk: int = 2048,
                    index_bases: Optional[Sequence[int]] = None
                    ) -> ShardedServeStats:
        """Serve one pre-sharded stream per host (lengths may differ).
        ``index_bases`` offsets each shard's global record indices so they
        stay disjoint across hosts (defaults to cumulative offsets)."""
        if len(streams) != self.n_hosts:
            raise ValueError(f"{len(streams)} streams for {self.n_hosts} hosts")
        if index_bases is None:
            index_bases, acc = [], 0
            for x in streams:
                index_bases.append(acc)
                acc += len(x)
        idx_map = [np.arange(len(x), dtype=np.int64) + base
                   for x, base in zip(streams, index_bases)]
        return self._drive([np.asarray(x) for x in streams], idx_map, chunk)

    def run_stream(self, x: np.ndarray, *, chunk: int = 2048
                   ) -> ShardedServeStats:
        """Shard one stream round-robin by contiguous chunk: chunk i goes
        to host i mod K, preserving each shard's arrival order."""
        shards: List[List[np.ndarray]] = [[] for _ in range(self.n_hosts)]
        bases: List[List[np.ndarray]] = [[] for _ in range(self.n_hosts)]
        for ci, s in enumerate(range(0, len(x), chunk)):
            k = ci % self.n_hosts
            shards[k].append(x[s:s + chunk])
            bases[k].append(np.arange(s, min(s + chunk, len(x)), dtype=np.int64))
        streams = [np.concatenate(s) if s else np.empty((0, x.shape[1]), x.dtype)
                   for s in shards]
        idx_map = [np.concatenate(b) if b else np.empty(0, np.int64)
                   for b in bases]
        return self._drive(streams, idx_map, chunk)

    @property
    def emitted(self) -> List[List[int]]:
        return [list(h.engine.emitted) for h in self.hosts]
