"""Multi-host sharded serving with quorum-voted plan swaps (DESIGN.md §6).

The input stream is sharded across K simulated hosts.  Each host runs its
OWN ``CascadeServer`` — local CUSUM detectors, importance-audit sampler,
and weighted reservoir — but local drift triggers do not swap plans:
they become ``DriftVote``s to a ``QuorumSwapCoordinator``.  On quorum the
coordinator merges every host's reservoir export (IPW weights preserved),
runs the warm-started re-optimization ONCE, and broadcasts the result as
the versioned scorer wire artifact through a two-phase (prepare/commit)
epoch swap: hosts stage + ack first, and only install once every peer has
acknowledged — no host ever serves a plan version its peers haven't seen.
In-flight records still finish under the plan version that scored them
(the engine's versioned ``_PlanState`` machinery), so record conservation
holds across global swaps exactly as it does across local ones.

Three transports share all protocol logic:

* ``transport="inline"`` — hosts are plain objects driven round-robin by
  the caller's thread; deterministic, the benchmark/test default.
* ``transport="thread"`` — each host runs in its own worker thread with a
  command queue; the coordinator talks to it only via messages.  Same
  code path as inline (``_ThreadHost`` proxies ``ShardHost``), but the
  prepare/commit barrier crosses real thread boundaries.
* ``transport="process"`` — one host per OS subprocess
  (``distributed/procworker.py``): the parent speaks a newline-delimited
  JSON control protocol over pipes, with COREWIRE blobs (artifacts,
  re-sync frames) riding base64-embedded.  The worker runs the same
  ``ShardHost`` the other transports drive — one protocol core.

Fault tolerance (DESIGN.md §6 failure model): the coordinator replicates
its state machine to a ``StandbyCoordinator`` via epoch-stamped deltas;
heartbeat loss promotes the standby, which completes or cleanly aborts
any in-flight two-phase swap.  The prepare barrier runs under an ack
deadline: silent hosts become a NACK or get FENCED (serve-behind on
their pinned epoch, excluded from quorum math, COREWIRE re-sync on
rejoin).  Hosts additionally stream their IPW kappa² contingency counts
so the coordinator pools correlation evidence fleet-wide.

A real deployment would replace the transport with RPC; the protocol core
(``distributed/consensus.py``) is transport-agnostic by construction.
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.query import PhysicalPlan
from repro.distributed.consensus import (
    DriftVote,
    QuorumSwapCoordinator,
    StandbyCoordinator,
    StateDelta,
    SwapAck,
    SwapCommit,
    SwapPrepare,
    SwapRecord,
)
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.serving.engine import CascadeServer, ServeStats
from repro.serving.stats import AdaptivePolicy, DriftEvent
from repro.util import advisory_wall_ms


@dataclass
class ShardedServeStats:
    """Aggregate view over K hosts plus the consensus layer."""

    n_hosts: int
    per_host: List[ServeStats]
    submitted_per_host: List[int]
    votes_cast: int = 0
    swaps_committed: int = 0
    swaps_aborted: int = 0
    final_epoch: int = 0
    swap_log: List[SwapRecord] = field(default_factory=list)
    wall_ms: float = 0.0
    # ----- fault tolerance -----
    failovers: int = 0
    failover_resolution: str = ""  # "completed" | "aborted" | "idle"
    standby_rearms: int = 0  # fresh standbys registered after a failover
    fences: int = 0  # hosts fenced out of a barrier (stragglers)
    resyncs: int = 0  # COREWIRE catch-up installs on rejoin
    pooled_swaps: int = 0  # swaps initiated by pooled kappa² evidence
    plan_cache_writebacks: int = 0  # committed plans recorded cross-query
    # ----- request front end (slo_ms set): per-host FrontEndStats -----
    frontend_stats: List = field(default_factory=list)

    @property
    def fleet_goodput_ratio(self) -> float:
        """Fleet-level goodput / throughput: requests that met their SLO
        over requests completed, summed across every host's front end."""
        done = sum(f.requests_done for f in self.frontend_stats)
        met = sum(f.requests_met_slo for f in self.frontend_stats)
        return met / done if done else 0.0

    @property
    def submitted(self) -> int:
        return sum(self.submitted_per_host)

    @property
    def emitted(self) -> int:
        return sum(s.emitted for s in self.per_host)

    @property
    def rejected(self) -> int:
        return sum(s.rejected for s in self.per_host)

    @property
    def host_cost_ms(self) -> List[float]:
        return [s.model_cost_ms for s in self.per_host]

    @property
    def critical_path_cost_ms(self) -> float:
        """Hosts run in parallel: the cost-model makespan is the slowest
        host's total, not the sum."""
        return max(self.host_cost_ms) if self.per_host else 0.0

    @property
    def aggregate_rows_per_cost_s(self) -> float:
        cp = self.critical_path_cost_ms
        return self.submitted / (cp / 1e3) if cp > 0 else 0.0

    @property
    def consensus_ms_total(self) -> float:
        return sum(r.consensus_ms for r in self.swap_log)


class ShardHost:
    """One simulated serving host: a private ``CascadeServer`` whose drift
    triggers are exported as votes, plus the two-phase staging slot."""

    def __init__(self, host_id: int, plan: PhysicalPlan, *, tile: int,
                 policy: AdaptivePolicy, seed: int, use_kernel: bool = True,
                 slo_ms: Optional[float] = None):
        self.host_id = host_id
        self.engine = CascadeServer(
            plan, tile=tile, use_kernel=use_kernel, adaptive=True,
            policy=policy, seed=seed)
        self.query = plan.query
        self.epoch = 0
        self._voted_epoch = -1
        # (epoch, plan, scorer, attempt) staged by phase 1, or None
        self._staged: Optional[Tuple[int, PhysicalPlan, object, int]] = None
        self.submitted = 0
        self.resyncs = 0
        # idx -> engine plan version current when the record was submitted
        # (None until a test enables tracking; kept off the hot path)
        self.track_versions = False
        self.submit_version: Dict[int, int] = {}
        # request front end (DESIGN.md §7): with an SLO every chunk
        # becomes a deadline-carrying request through the batching loop.
        # Backpressure is SHED-ONLY here: plan versions are pinned to
        # quorum epochs, so a host-local degrade install would break the
        # fleet's epoch ordering (coordinator-priced degrades are the
        # filed follow-up) — but deadline shedding and per-request
        # goodput accounting work unchanged.
        self.frontend = None
        if slo_ms is not None:
            from repro.serving.frontend import ServingFrontEnd, SLOPolicy

            self.slo_ms = float(slo_ms)
            self.frontend = ServingFrontEnd(
                self.engine, policy=SLOPolicy(degrade=False))
            # version tracking must stamp at ACTUAL engine submission —
            # the front end's batching loop can hold a chunk's tail rows
            # across an epoch install, and those legitimately run (and
            # emit) under the newer pinned version
            self.frontend.add_submit_hook(self._note_submit_versions)

    def _note_submit_versions(self, indices) -> None:
        if self.track_versions:
            v = self.engine.plan_version
            for i in indices:
                self.submit_version[int(i)] = v

    # ------------------------------------------------------------- serving
    def submit_chunk(self, indices: np.ndarray, rows: np.ndarray) -> None:
        if self.track_versions and self.frontend is None:
            v = self.engine.plan_version
            for i in indices:
                self.submit_version[int(i)] = v
        if self.frontend is not None:
            fe = self.frontend
            fe.submit_request(indices, rows, deadline_ms=self.slo_ms,
                              arrival_ms=fe.now_ms)
            fe.step()
        else:
            self.engine.submit(indices, rows)
            self.engine.pump()
        self.submitted += len(rows)

    def drain(self) -> ServeStats:
        if self.frontend is not None:
            while self.frontend.step():
                pass
            self.frontend.drain()
        else:
            self.engine.pump(drain=True)
        st = self.engine.stats
        shed = (self.frontend.stats.records_shed
                if self.frontend is not None else 0)
        st.rejected = self.submitted - st.emitted - shed
        return st

    # -------------------------------------------------------------- voting
    def poll_vote(self) -> Optional[DriftVote]:
        """Consume a pending local drift trigger into a quorum vote.
        At most one vote per served epoch; repeat triggers within the
        epoch stay parked on the engine (the eventual global install
        clears them)."""
        if self._voted_epoch == self.epoch:
            return None
        drift = self.engine.take_drift()
        if drift is None:
            return None
        signal, observed, expected = drift
        _mode, escalated = self.engine.escalation_hint()
        self._voted_epoch = self.epoch
        return DriftVote(
            host=self.host_id, epoch=self.epoch,
            event=DriftEvent(
                at_record=self.submitted, signal=signal,
                observed=float(observed), expected=float(expected),
                escalated=escalated, plan_version=self.epoch,
            ),
            reservoir=self.engine.reservoir_export(),
            kappa=self.engine.kappa_export(),
        )

    def reservoir_export(self):
        return self.engine.reservoir_export()

    def kappa_export(self):
        """Cumulative IPW contingency counts for fleet-level pooling."""
        return self.engine.kappa_export()

    # --------------------------------------------------------- two-phase
    def prepare(self, msg: SwapPrepare,
                timeout: Optional[float] = None) -> SwapAck:
        """Phase 1: deserialize + stage the artifact; serve nothing new.
        ``timeout`` is accepted for transport-API uniformity — an inline
        host cannot be silent (the deadline is enforced by the threaded /
        process transports, whose calls really can hang)."""
        from repro.kernels.ops import deserialize_scorer

        try:
            if msg.epoch != self.epoch + 1:
                raise ValueError(
                    f"host {self.host_id} at epoch {self.epoch} cannot "
                    f"stage epoch {msg.epoch}")
            plan, scorer = deserialize_scorer(msg.artifact, self.query)
            self._staged = (msg.epoch, plan, scorer, msg.attempt)
            return SwapAck(host=self.host_id, epoch=msg.epoch, ok=True,
                           attempt=msg.attempt)
        except Exception as e:  # NACK aborts the epoch coordinator-side
            self._staged = None
            return SwapAck(host=self.host_id, epoch=msg.epoch, ok=False,
                           error=str(e), attempt=msg.attempt)

    def commit(self, msg: SwapCommit) -> None:
        """Phase 2: every peer acked — install the staged plan.  In-flight
        queue entries finish under their scoring version."""
        if self._staged is None or self._staged[0] != msg.epoch \
                or self._staged[3] != msg.attempt:
            # the attempt check matters under message reordering: the
            # staged copy may be a STALE same-epoch artifact (a late
            # prepare from an aborted round overwrote the current one) —
            # installing it would diverge from what the fleet acked
            raise RuntimeError(
                f"host {self.host_id}: commit for epoch {msg.epoch} "
                f"(attempt {msg.attempt}) without a matching staged plan")
        _, plan, scorer, _ = self._staged
        self.engine.install_plan(plan, scorer=scorer, version=msg.epoch)
        self.epoch = msg.epoch
        self._staged = None

    def abort(self) -> None:
        """Aborted epoch: drop the staged copy AND re-arm voting — the
        epoch number did not advance, so without the reset every host
        that voted would be locked out (`_voted_epoch == epoch`) and a
        transient NACK would permanently disable quorum swaps."""
        self._staged = None
        self._voted_epoch = -1

    def resync(self, frame: bytes) -> int:
        """Catch-up install for a fenced host rejoining the fleet: a
        COREWIRE v1.1 re-sync frame carries the committed artifact of the
        fleet's CURRENT epoch.  Unlike ``prepare``, there is no two-phase
        dance — every active peer already acked this artifact — and the
        epoch may jump by more than one (the host serve-behinds through
        however many swaps it missed).  Returns the installed epoch."""
        from repro.kernels.ops import (
            FRAME_RESYNC,
            deserialize_frame,
            deserialize_scorer,
        )

        kind, epoch, payload, _meta = deserialize_frame(frame)
        if kind != FRAME_RESYNC:
            raise ValueError(f"host {self.host_id}: expected a resync "
                             f"frame, got {kind!r}")
        if epoch <= self.epoch:
            return self.epoch  # stale resync: already caught up
        plan, scorer = deserialize_scorer(payload, self.query)
        self.engine.install_plan(plan, scorer=scorer, version=epoch)
        self.epoch = epoch
        self._staged = None
        self._voted_epoch = -1
        self.resyncs += 1
        return self.epoch


class HostTimeout(Exception):
    """A host RPC missed its deadline (thread/process transports): the
    caller decides between NACK-on-deadline and straggler fencing."""


class _ThreadHost:
    """Thread-isolated ``ShardHost``: the host's engine lives entirely on
    its worker thread; every interaction is a (request, reply) message
    pair over queues.  API-identical to ``ShardHost``."""

    def __init__(self, host: ShardHost):
        self._host = host
        self.host_id = host.host_id
        self._req: "queue.Queue" = queue.Queue()
        self._thread = threading.Thread(
            target=self._loop, name=f"shard-host-{host.host_id}", daemon=True)
        self._thread.start()

    def _loop(self):
        while True:
            fn, args, reply = self._req.get()
            if fn is None:
                reply.put(None)
                return
            try:
                reply.put((True, fn(*args)))
            except Exception as e:  # surfaced on the caller thread
                reply.put((False, e))

    def _call(self, fn, *args, timeout: Optional[float] = None):
        reply: "queue.Queue" = queue.Queue()
        self._req.put((fn, args, reply))
        try:
            ok, out = reply.get(timeout=timeout)
        except queue.Empty:
            raise HostTimeout(
                f"host {self.host_id} silent past {timeout}s deadline")
        if not ok:
            raise out
        return out

    @property
    def epoch(self) -> int:
        return self._host.epoch

    @property
    def submitted(self) -> int:
        return self._host.submitted

    @property
    def engine(self) -> CascadeServer:
        return self._host.engine

    @property
    def track_versions(self) -> bool:
        return self._host.track_versions

    @track_versions.setter
    def track_versions(self, v: bool) -> None:
        self._host.track_versions = v

    @property
    def submit_version(self) -> Dict[int, int]:
        return self._host.submit_version

    @property
    def frontend(self):
        return self._host.frontend

    def submit_chunk(self, indices, rows):
        return self._call(self._host.submit_chunk, indices, rows)

    def drain(self):
        return self._call(self._host.drain)

    @property
    def resyncs(self) -> int:
        return self._host.resyncs

    def poll_vote(self):
        return self._call(self._host.poll_vote)

    def reservoir_export(self):
        return self._call(self._host.reservoir_export)

    def kappa_export(self):
        return self._call(self._host.kappa_export)

    def prepare(self, msg, timeout: Optional[float] = None):
        return self._call(self._host.prepare, msg, timeout=timeout)

    def commit(self, msg):
        return self._call(self._host.commit, msg)

    def abort(self):
        return self._call(self._host.abort)

    def resync(self, frame):
        return self._call(self._host.resync, frame)

    def stop(self):
        reply: "queue.Queue" = queue.Queue()
        self._req.put((None, (), reply))
        reply.get()
        self._thread.join(timeout=10)


class ShardedCascadeServer:
    """K-host sharded serving driver.

    ``plan`` should come from ``optimize(..., keep_state=True)`` so the
    coordinator's re-optimizations warm-start; hosts receive only the
    serialized artifact (builder state never fans out).  ``n_hosts=1``
    degrades to single-host serving THROUGH the consensus path (quorum of
    one), which is what the sharded benchmark uses as its baseline.

    Fault-tolerance knobs:

    * ``standby`` — maintain a ``StandbyCoordinator`` mirror (replicated
      state deltas ride a COREWIRE v1.1 frame per transition).  On
      primary heartbeat loss the standby takes over mid-epoch.
    * ``kill_coordinator_at`` — failure injection: ``"prepare"`` kills
      the primary after half the prepare broadcast (partial staging —
      takeover must ABORT), ``"commit"`` after the barrier closed but
      before the commit broadcast, ``"mid-commit"`` after one host
      installed (takeover must COMPLETE / re-sync), or an int record
      count (idle death at a chunk boundary).
    * ``straggler_host`` / ``straggler_policy`` — host made silent for
      the first prepare barrier; ``"fence"`` commits without it under
      serve-behind version fencing (re-sync on rejoin), ``"nack"``
      converts the deadline miss into an abort.
    * ``ack_deadline_s`` — the prepare barrier's per-host ack deadline
      (enforced for real by the thread/process transports).
    """

    def __init__(self, plan: PhysicalPlan, n_hosts: int = 4, *,
                 tile: int = 1024, policy: Optional[AdaptivePolicy] = None,
                 quorum_frac: float = 0.5, seed: int = 0,
                 use_kernel: bool = True, transport: str = "inline",
                 max_tile: int = 8192,
                 standby: bool = True,
                 kill_coordinator_at=None,
                 straggler_host: Optional[int] = None,
                 straggler_policy: str = "fence",
                 ack_deadline_s: float = 30.0,
                 heartbeat_rounds: float = 1.5,
                 worker_spec: Optional[dict] = None,
                 slo_ms: Optional[float] = None,
                 plan_cache=None):
        if transport not in ("inline", "thread", "process"):
            raise ValueError(f"unknown transport {transport!r}")
        if straggler_policy not in ("fence", "nack"):
            raise ValueError(f"unknown straggler policy {straggler_policy!r}")
        # one kill point, or a sequence of them: each consumed in order,
        # so a SECOND primary death after the first failover (served by
        # the re-armed standby) is injectable too
        if kill_coordinator_at is None:
            kill_points: Tuple = ()
        elif isinstance(kill_coordinator_at, (list, tuple)):
            kill_points = tuple(kill_coordinator_at)
        else:
            kill_points = (kill_coordinator_at,)
        for kp in kill_points:
            if kp not in ("prepare", "commit", "mid-commit") \
                    and not isinstance(kp, int):
                # a typo here would silently disable the failure injection —
                # a fault-tolerance test would then pass exercising nothing
                raise ValueError(
                    f"unknown kill point {kp!r}: expected "
                    f"'prepare' | 'commit' | 'mid-commit' | record count")
        self.n_hosts = int(n_hosts)
        self.policy = policy or AdaptivePolicy()
        self.plan0 = plan
        # cross-query plan cache (core.plan_cache.PlanCache): the
        # coordinator records the initial plan and every quorum-COMMITTED
        # re-optimization — aborted prepares never pollute the cache
        self.plan_cache = plan_cache
        self._last_reopt_plan: Optional[PhysicalPlan] = None
        self.query = plan.query
        self.max_tile = max_tile
        self.ack_deadline_s = float(ack_deadline_s)
        self.straggler_policy = straggler_policy
        # the injected straggler is partitioned from the coordinator from
        # the start (it still serves its shard); its link heals right
        # after the first barrier it goes missing from — see _finish_swap
        self._straggler_pending = straggler_host
        self._kill_queue: deque = deque(kill_points)
        self._silent: Set[int] = (
            set() if straggler_host is None else {int(straggler_host)})
        self._primary_alive = True
        self._round = 0
        self._swap_log_prefix: List[SwapRecord] = []
        coord_kw = dict(
            reopt_fn=self._reopt, quorum_frac=quorum_frac,
            choose_mode=lambda p, fresh: self.policy.choose_escalation(p, fresh)[0],
            max_tile=max_tile, kappa_tol=self.policy.kappa_tol,
            kappa_pool_baseline=self.policy.kappa_pool_baseline,
        )
        self._coord_kw = coord_kw  # standby re-construction after failover
        self.standby = (StandbyCoordinator(plan, self.n_hosts, **coord_kw)
                        if standby else None)
        self.coordinator = QuorumSwapCoordinator(
            plan, self.n_hosts,
            replicate=self._replicate if standby else None, **coord_kw)
        # heartbeat clock = driver rounds (deterministic in simulation);
        # a real deployment would beat on wall time
        self._hb = HeartbeatMonitor(["coordinator"],
                                    timeout=float(heartbeat_rounds),
                                    clock=lambda: float(self._round))
        self.transport = transport
        if transport == "process":
            from repro.distributed.procworker import ProcessHost
            from repro.kernels.ops import serialize_scorer

            if worker_spec is None:
                raise ValueError(
                    "transport='process' needs worker_spec: the worker "
                    "rebuilds the synthetic workload from its seeds (UDF "
                    "closures cannot travel over the pipe)")
            artifact = serialize_scorer(plan, max_tile=max_tile)
            self.hosts = [
                ProcessHost(k, spec=worker_spec, artifact=artifact,
                            tile=tile, policy=self.policy,
                            seed=seed + 1000 * k, use_kernel=use_kernel,
                            slo_ms=slo_ms)
                for k in range(self.n_hosts)
            ]
        else:
            hosts = [
                ShardHost(k, plan, tile=tile, policy=self.policy,
                          seed=seed + 1000 * k, use_kernel=use_kernel,
                          slo_ms=slo_ms)
                for k in range(self.n_hosts)
            ]
            self.hosts = (
                [_ThreadHost(h) for h in hosts] if transport == "thread"
                else hosts)
        self.stats = ShardedServeStats(
            n_hosts=self.n_hosts,
            per_host=[h.engine.stats for h in self.hosts],
            submitted_per_host=[0] * self.n_hosts,
        )
        self._record_to_cache(plan)

    # ------------------------------------------------------ re-optimization
    def _reopt(self, plan: PhysicalPlan, merged, mode: str) -> PhysicalPlan:
        from repro.core.api import REBUILD_DEFAULTS, rebuild_plan

        new_plan = rebuild_plan(
            plan, merged.x,
            REBUILD_DEFAULTS.replace(reopt=mode, step=self.policy.step),
            known_sigma=merged.known_sigma)
        # stashed, not recorded: the cache write-back waits for the quorum
        # barrier to COMMIT this plan fleet-wide (_finish_swap)
        self._last_reopt_plan = new_plan
        return new_plan

    def _record_to_cache(self, plan: Optional[PhysicalPlan]) -> None:
        if self.plan_cache is None or plan is None:
            return
        if self.plan_cache.record_plan(plan, step=self.policy.step) is not None:
            self.stats.plan_cache_writebacks += 1

    # ------------------------------------------------------- replication
    def _replicate(self, delta: StateDelta) -> None:
        """Ship one coordinator transition to the standby as a COREWIRE
        v1.1 delta frame — the same envelope a cross-machine deployment
        would piggyback on its vote/prepare traffic (serialize +
        deserialize both run, so the frame path is exercised on every
        transition of every sharded run)."""
        from repro.kernels.ops import FRAME_DELTA, deserialize_frame, serialize_frame

        frame = serialize_frame(
            FRAME_DELTA, delta.epoch, delta.artifact or b"",
            meta={"kind": delta.kind, "host": delta.host,
                  "has_artifact": delta.artifact is not None})
        kind, epoch, payload, meta = deserialize_frame(frame)
        assert kind == FRAME_DELTA
        self.standby.apply(StateDelta(
            kind=meta["kind"], epoch=epoch, host=meta["host"],
            artifact=payload if meta["has_artifact"] else None))

    # ------------------------------------------------------ failure control
    def set_silent(self, host_id: int, silent: bool = True) -> None:
        """Simulate a network partition: a silent host receives no
        coordinator RPCs (prepare/commit/poll) but keeps serving its
        local shard — exactly a straggler behind a dead link."""
        if silent:
            self._silent.add(host_id)
        else:
            self._silent.discard(host_id)

    def _kill_primary(self) -> None:
        """Failure injection: the primary stops beating and processing;
        its swap log survives (it is OUR log for reporting — a real
        deployment loses it, which is why the standby mirrors state)."""
        self._swap_log_prefix.extend(self.coordinator.swap_log)
        self._primary_alive = False

    def _consume_kill(self, point: str) -> bool:
        if self._primary_alive and self._kill_queue \
                and self._kill_queue[0] == point:
            self._kill_queue.popleft()
            self._kill_primary()
            return True
        return False

    def _failover(self) -> None:
        coord, resolution = self.standby.take_over(
            self.hosts, unreachable=set(self._silent))
        self.coordinator = coord
        self._primary_alive = True
        self._hb.beat("coordinator")
        self.stats.failovers += 1
        self.stats.failover_resolution = resolution
        # re-arm replication: a promoted coordinator must not run
        # unreplicated forever.  Register a fresh standby (in a real
        # fleet: re-elected from the active host set), replay the
        # promoted coordinator's state snapshot through the same COREWIRE
        # delta channel live deltas use, then attach it — a SECOND
        # primary loss after this failover resolves exactly like the
        # first (completes or cleanly aborts any in-flight epoch).
        self.standby = StandbyCoordinator(self.plan0, self.n_hosts,
                                          **self._coord_kw)
        for delta in coord.snapshot_deltas():
            self._replicate(delta)
        coord.replicate = self._replicate
        self.stats.standby_rearms += 1

    # ------------------------------------------------------------ protocol
    def _reachable(self, h) -> bool:
        return h.host_id not in self._silent

    def _handle_votes(self) -> None:
        fenced = self.coordinator.fenced
        for h in self.hosts:
            if not self._reachable(h) or h.host_id in fenced:
                continue
            vote = h.poll_vote()
            if vote is None:
                continue
            self.stats.votes_cast += 1
            if self.coordinator.offer_vote(vote):
                self._run_swap()

    def _sync_stats(self) -> None:
        """Periodic fleet stats sync: pool every reachable host's kappa²
        contingency counts coordinator-side; pooled drift beyond
        tolerance opens a coordinator-initiated (unvoted) swap.  Opt-in
        via ``policy.kappa_pool_baseline > 0``."""
        if self.policy.kappa_pool_baseline <= 0:
            return
        coord = self.coordinator
        for h in self.hosts:
            if not self._reachable(h) or h.host_id in coord.fenced:
                continue
            if coord.offer_stats(h.host_id, h.epoch, h.kappa_export()):
                reservoirs = [x.reservoir_export() for x in self.hosts
                              if self._reachable(x)
                              and x.host_id not in coord.fenced]
                self._finish_swap(coord.propose_pooled(reservoirs))
                return

    def _handle_rejoins(self) -> None:
        """Fenced hosts whose link healed catch up: a COREWIRE re-sync
        frame installs the fleet's committed epoch directly (every active
        peer acked that artifact when it committed), then the host
        re-enters quorum math."""
        from repro.kernels.ops import FRAME_RESYNC, serialize_frame

        coord = self.coordinator
        if not coord.fenced or coord.pending is not None:
            return
        for h in self.hosts:
            if h.host_id not in coord.fenced or not self._reachable(h):
                continue
            if h.epoch < coord.epoch:
                if coord.last_artifact is None:
                    continue  # nothing committed to sync from (shouldn't happen)
                frame = serialize_frame(FRAME_RESYNC, coord.epoch,
                                        coord.last_artifact,
                                        meta={"host": h.host_id})
                h.resync(frame)
                self.stats.resyncs += 1
            coord.mark_rejoined(h.host_id)

    def _run_swap(self) -> None:
        """Quorum reached: merge + re-optimize + two-phase broadcast."""
        voters = set(self.coordinator.voters)
        extras = [h.reservoir_export() for h in self.hosts
                  if h.host_id not in voters and self._reachable(h)
                  and h.host_id not in self.coordinator.fenced]
        self._finish_swap(self.coordinator.propose(extra_reservoirs=extras))

    def _finish_swap(self, prepare: SwapPrepare) -> None:
        """Drive one two-phase barrier: prepare broadcast under the ack
        deadline, straggler resolution, commit broadcast — with the
        failure-injection kill points threaded through."""
        coord = self.coordinator
        initiated_by = coord._pending_record.initiated_by
        submitted_at_quorum = sum(h.submitted for h in self.hosts)
        barrier = [h for h in self.hosts if h.host_id not in coord.fenced]
        t0 = advisory_wall_ms()
        commit = None
        missing: List[int] = []
        delivered = 0
        for h in barrier:
            if delivered >= (len(barrier) + 1) // 2 \
                    and self._consume_kill("prepare"):
                return  # primary died mid-prepare: some hosts staged, some not
            if not self._reachable(h):
                missing.append(h.host_id)
                continue
            try:
                # the deadline is only real where a call can hang; inline
                # hosts are same-thread (and tests monkeypatch prepare)
                ack = (h.prepare(prepare) if self.transport == "inline"
                       else h.prepare(prepare, timeout=self.ack_deadline_s))
            except HostTimeout:
                missing.append(h.host_id)
                continue
            delivered += 1
            commit = coord.offer_ack(ack)
            if not ack.ok:
                break
        if commit is None and coord.pending is not None and missing:
            # deadline expired with silent hosts: fence or NACK them
            commit = coord.resolve_prepare_deadline(missing,
                                                    self.straggler_policy)
            self.stats.fences += sum(1 for hid in missing
                                     if hid in coord.fenced)
        coord.note_prepare_ms(advisory_wall_ms() - t0)
        if commit is None:
            # aborted (NACK / nack-policy straggler): drop staged copies
            for h in barrier:
                if self._reachable(h):
                    h.abort()
            self.stats.swaps_aborted += 1
            self._heal_straggler(missing)
            return
        if self._consume_kill("commit"):
            return  # barrier closed, commit broadcast lost with the primary
        t0 = advisory_wall_ms()
        installed = 0
        for h in barrier:
            if h.host_id in coord.fenced or not self._reachable(h):
                continue
            h.commit(commit)
            installed += 1
            if installed == 1 and self._consume_kill("mid-commit"):
                return  # one host installed; the rest must catch up via standby
        coord.note_commit_ms(advisory_wall_ms() - t0)
        # the barrier is synchronous in every transport: any submissions
        # while it was open would show up here
        coord.swap_log[-1].lag_records = (
            sum(h.submitted for h in self.hosts) - submitted_at_quorum)
        self.stats.swaps_committed += 1
        self._record_to_cache(self._last_reopt_plan)
        self._last_reopt_plan = None
        if initiated_by == "pooled:kappa2":
            self.stats.pooled_swaps += 1
        self._heal_straggler(missing)

    # -------------------------------------------------------------- driver
    def _drive(self, streams: List[np.ndarray], idx_map: List[np.ndarray],
               chunk: int) -> ShardedServeStats:
        """Round-robin the hosts one chunk at a time, handling votes,
        stats pooling, straggler rejoins (and any resulting swap) at
        every chunk boundary; heartbeat loss promotes the standby."""
        t_start = advisory_wall_ms()
        pos = [0] * self.n_hosts
        while any(pos[k] < len(streams[k]) for k in range(self.n_hosts)):
            self._round += 1
            if self._primary_alive:
                self._hb.beat("coordinator")
            for k, h in enumerate(self.hosts):
                lo = pos[k]
                if lo >= len(streams[k]):
                    continue
                hi = min(lo + chunk, len(streams[k]))
                h.submit_chunk(idx_map[k][lo:hi], streams[k][lo:hi])
                pos[k] = hi
            if self._primary_alive and self._kill_queue \
                    and isinstance(self._kill_queue[0], int) \
                    and sum(h.submitted for h in self.hosts) >= self._kill_queue[0]:
                self._kill_queue.popleft()
                self._kill_primary()
            if self._primary_alive:
                self._handle_votes()
                self._sync_stats()
                self._handle_rejoins()
            elif self.standby is not None and self._hb.dead_hosts():
                self._failover()
        if not self._primary_alive and self.standby is not None:
            self._failover()  # stream ended inside the detection window
        # catch up any still-fenced reachable host before the drain: a
        # barrier (or failover) resolving on the final round otherwise
        # leaves it serving behind with no round left to re-sync it
        self._heal_straggler(list(self._silent))
        self._handle_rejoins()
        for k, h in enumerate(self.hosts):
            h.drain()
            self.stats.submitted_per_host[k] = h.submitted
            if getattr(h, "frontend", None) is not None:
                self.stats.frontend_stats.append(h.frontend.stats)
        self.stats.final_epoch = self.coordinator.epoch
        self.stats.swap_log = (list(self._swap_log_prefix)
                               + list(self.coordinator.swap_log))
        # recount from the authoritative log: a swap can commit inside the
        # coordinator while the primary died before broadcasting (the
        # standby finishes the install) — the incremental counters only
        # see barriers the DRIVER completed
        self.stats.swaps_committed = sum(
            1 for r in self.stats.swap_log if r.committed)
        self.stats.swaps_aborted = sum(
            1 for r in self.stats.swap_log if not r.committed)
        self.stats.wall_ms = advisory_wall_ms() - t_start
        if self.transport in ("thread", "process"):
            for h in self.hosts:
                h.stop()
        return self.stats

    def _heal_straggler(self, missing: List[int]) -> None:
        """The injected straggler misses exactly one barrier; once that
        barrier resolved (committed without it, or aborted), its link
        heals and the next round's rejoin path re-syncs it."""
        if self._straggler_pending is not None \
                and self._straggler_pending in missing:
            self._silent.discard(self._straggler_pending)
            self._straggler_pending = None

    def run_streams(self, streams: Sequence[np.ndarray], *,
                    chunk: int = 2048,
                    index_bases: Optional[Sequence[int]] = None
                    ) -> ShardedServeStats:
        """Serve one pre-sharded stream per host (lengths may differ).
        ``index_bases`` offsets each shard's global record indices so they
        stay disjoint across hosts (defaults to cumulative offsets)."""
        if len(streams) != self.n_hosts:
            raise ValueError(f"{len(streams)} streams for {self.n_hosts} hosts")
        if index_bases is None:
            index_bases, acc = [], 0
            for x in streams:
                index_bases.append(acc)
                acc += len(x)
        idx_map = [np.arange(len(x), dtype=np.int64) + base
                   for x, base in zip(streams, index_bases)]
        return self._drive([np.asarray(x) for x in streams], idx_map, chunk)

    def run_stream(self, x: np.ndarray, *, chunk: int = 2048
                   ) -> ShardedServeStats:
        """Shard one stream round-robin by contiguous chunk: chunk i goes
        to host i mod K, preserving each shard's arrival order."""
        shards: List[List[np.ndarray]] = [[] for _ in range(self.n_hosts)]
        bases: List[List[np.ndarray]] = [[] for _ in range(self.n_hosts)]
        for ci, s in enumerate(range(0, len(x), chunk)):
            k = ci % self.n_hosts
            shards[k].append(x[s:s + chunk])
            bases[k].append(np.arange(s, min(s + chunk, len(x)), dtype=np.int64))
        streams = [np.concatenate(s) if s else np.empty((0, x.shape[1]), x.dtype)
                   for s in shards]
        idx_map = [np.concatenate(b) if b else np.empty(0, np.int64)
                   for b in bases]
        return self._drive(streams, idx_map, chunk)

    @property
    def emitted(self) -> List[List[int]]:
        return [list(h.engine.emitted) for h in self.hosts]
