"""Quorum-voted plan-swap consensus for multi-host sharded serving
(DESIGN.md §6).

Per-host drift detection is statistically noisy: one shard's CUSUM firing
may be shard skew, not population drift.  A **global** plan swap therefore
requires a quorum of hosts to have voted drift within the same plan epoch.
This module is the transport-agnostic protocol core — explicit message
dataclasses plus a coordinator state machine with no I/O, threads, or
engine imports — so the inline driver, the thread transport, and the unit
tests all exercise the identical logic.

Protocol (one swap):

1. **VOTE** — a host whose local detector fired sends ``DriftVote`` (its
   ``DriftEvent`` payload + its weighted reservoir export).  One vote per
   host per epoch; votes carrying a stale epoch are discarded.
2. **QUORUM** — when ``quorum(K)`` distinct hosts have voted, the
   coordinator merges every known reservoir export (IPW weights
   preserved), decides escalation from the merged Horvitz-Thompson
   selectivities, runs the warm-started re-optimization ONCE, and
   serializes the resulting ``(plan, scorer)`` into the versioned wire
   artifact.
3. **PREPARE** — the artifact is broadcast with the next epoch number.
   Each host deserializes and stages it (does NOT serve it) and replies
   ``SwapAck``.
4. **COMMIT** — only after **all** hosts acked does the coordinator send
   ``SwapCommit``; hosts then atomically install the staged plan.  A
   single NACK aborts the epoch (staged plans are dropped, votes cleared).
   No host ever *serves* a plan version a peer has not acknowledged —
   the two-phase barrier is what the conservation property test leans on.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.serving.stats import (
    DriftEvent,
    ReservoirSample,
    ipw_selectivity,
    merge_reservoir_samples,
)


# ------------------------------------------------------------- messages
@dataclass
class DriftVote:
    """Host-local drift trigger escalated to the coordinator."""

    host: int
    epoch: int  # plan epoch the host was serving when its detector fired
    event: DriftEvent
    reservoir: ReservoirSample


@dataclass
class SwapPrepare:
    """Phase 1 broadcast: stage (don't serve) the new plan artifact."""

    epoch: int  # the NEW epoch being proposed
    artifact: bytes  # kernels.ops.serialize_scorer wire blob


@dataclass
class SwapAck:
    host: int
    epoch: int
    ok: bool
    error: str = ""


@dataclass
class SwapCommit:
    """Phase 2 broadcast: every host acked — install atomically."""

    epoch: int


@dataclass
class SwapRecord:
    """Coordinator-side log entry for one attempted swap."""

    epoch: int
    voters: List[int]
    signals: List[str]
    mode: str  # escalation decision ("alloc" | "bnb")
    committed: bool
    aborted_by: Optional[int] = None
    merged_rows: int = 0
    # records submitted anywhere between quorum and commit: >0 would mean
    # a host kept serving while the two-phase barrier was still open
    # (filled by the transport; the state machine cannot see submissions)
    lag_records: int = 0
    # wall-clock spent in each protocol step (re-optimization separate:
    # it is real optimizer work, not consensus overhead)
    reopt_ms: float = 0.0
    serialize_ms: float = 0.0
    prepare_ms: float = 0.0
    commit_ms: float = 0.0

    @property
    def consensus_ms(self) -> float:
        return self.serialize_ms + self.prepare_ms + self.commit_ms


def quorum(n_hosts: int, frac: float = 0.5) -> int:
    """Votes needed for a global swap: strict majority by default
    (``floor(frac * K) + 1``), never more than K, never fewer than 1."""
    return max(1, min(n_hosts, int(n_hosts * frac) + 1))


class QuorumSwapCoordinator:
    """Collects ``DriftVote``s and drives the two-phase swap.

    The coordinator owns the AUTHORITATIVE plan (with its live builder /
    B&B tree in ``plan.meta`` — hosts only ever hold deserialized
    artifacts, so re-optimization state never fans out).  ``reopt_fn``
    is injected: ``(plan, merged_sample, mode) -> new_plan`` — the
    sharded server binds it to ``core.optimizer.reoptimize``; unit tests
    bind a stub.
    """

    def __init__(self, plan, n_hosts: int, *,
                 reopt_fn: Callable[[object, ReservoirSample, str], object],
                 quorum_frac: float = 0.5,
                 choose_mode: Optional[Callable[[object, Dict[int, float]], str]] = None,
                 max_tile: int = 8192):
        self.plan = plan
        self.n_hosts = int(n_hosts)
        self.quorum_frac = float(quorum_frac)
        self.reopt_fn = reopt_fn
        self.choose_mode = choose_mode
        self.max_tile = max_tile
        self.epoch = 0  # current committed epoch
        self._votes: Dict[int, DriftVote] = {}  # host -> vote (current epoch)
        self.swap_log: List[SwapRecord] = []
        self.pending: Optional[SwapPrepare] = None
        self._pending_record: Optional[SwapRecord] = None
        self._new_plan = None
        self._acks: Dict[int, SwapAck] = {}

    # ------------------------------------------------------------ voting
    @property
    def quorum_size(self) -> int:
        return quorum(self.n_hosts, self.quorum_frac)

    @property
    def votes_pending(self) -> int:
        return len(self._votes)

    @property
    def voters(self) -> List[int]:
        return sorted(self._votes)

    def offer_vote(self, vote: DriftVote) -> bool:
        """Register one host's drift vote.  Returns True when this vote
        completes a quorum (caller should then run ``propose``).  Votes
        for a superseded epoch, duplicate votes from the same host, and
        votes arriving while a swap is already in flight are discarded."""
        if vote.epoch != self.epoch or self.pending is not None:
            return False
        if vote.host in self._votes:
            return False
        self._votes[vote.host] = vote
        return len(self._votes) >= self.quorum_size

    # ---------------------------------------------------------- proposing
    def propose(self, extra_reservoirs: Optional[List[ReservoirSample]] = None
                ) -> SwapPrepare:
        """Quorum reached: merge reservoirs, re-optimize once, serialize.

        ``extra_reservoirs``: exports pulled from hosts that did NOT vote
        — their rows are just as fresh, and the merged sample should span
        every shard, not only the drifted ones."""
        from repro.kernels.ops import serialize_scorer

        if len(self._votes) < self.quorum_size:
            raise RuntimeError(
                f"propose() before quorum: {len(self._votes)} votes < "
                f"{self.quorum_size}")
        if self.pending is not None:
            raise RuntimeError("a swap is already in flight")
        merged = merge_reservoir_samples(
            [v.reservoir for v in self._votes.values()]
            + list(extra_reservoirs or []))
        mode = self._decide_mode(merged)
        t0 = time.perf_counter()
        new_plan = self.reopt_fn(self.plan, merged, mode)
        reopt_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        artifact = serialize_scorer(new_plan, max_tile=self.max_tile)
        ser_ms = (time.perf_counter() - t0) * 1e3
        new_epoch = self.epoch + 1
        self.pending = SwapPrepare(epoch=new_epoch, artifact=artifact)
        self._pending_record = SwapRecord(
            epoch=new_epoch,
            voters=sorted(self._votes),
            signals=[v.event.signal for v in self._votes.values()],
            mode=mode, committed=False, merged_rows=merged.n_rows,
            reopt_ms=reopt_ms, serialize_ms=ser_ms,
        )
        self._new_plan = new_plan
        self._acks = {}
        return self.pending

    def _decide_mode(self, merged: ReservoirSample) -> str:
        """Escalation from the MERGED evidence: the per-host kappa²/regret
        decisions ride the votes, but the coordinator re-derives the mode
        from pooled Horvitz-Thompson selectivities so one noisy shard
        cannot force the expensive B&B path alone.  A majority of
        escalated votes still forces "bnb" (correlation-structure shifts
        are only visible host-side)."""
        if self.choose_mode is not None:
            fresh = {}
            for p in merged.known_sigma:
                sel = ipw_selectivity(merged, p, min_labels=8)
                if sel is not None:
                    fresh[p] = sel
            mode = self.choose_mode(self.plan, fresh)
        else:
            mode = "alloc"
        escalated = sum(1 for v in self._votes.values() if v.event.escalated)
        if escalated * 2 > len(self._votes):
            mode = "bnb"
        return mode

    # ------------------------------------------------------- ack / commit
    def offer_ack(self, ack: SwapAck) -> Optional[SwapCommit]:
        """Phase-1 responses.  Returns the ``SwapCommit`` once EVERY host
        has acked; a NACK aborts the epoch immediately (returns None and
        clears the in-flight state — callers observe via ``pending``)."""
        if self.pending is None or ack.epoch != self.pending.epoch:
            return None
        if not ack.ok:
            rec = self._pending_record
            rec.aborted_by = ack.host
            self.swap_log.append(rec)
            self._clear_round()
            return None
        self._acks[ack.host] = ack
        if len(self._acks) < self.n_hosts:
            return None
        commit = SwapCommit(epoch=self.pending.epoch)
        self.epoch = self.pending.epoch
        self.plan = self._new_plan
        rec = self._pending_record
        rec.committed = True
        self.swap_log.append(rec)
        self._clear_round()
        return commit

    def note_prepare_ms(self, ms: float) -> None:
        """Transport-side hook: wall time spent distributing the prepare
        + collecting acks (the state machine itself cannot see I/O)."""
        if self._pending_record is not None:
            self._pending_record.prepare_ms += ms
        elif self.swap_log:
            self.swap_log[-1].prepare_ms += ms

    def note_commit_ms(self, ms: float) -> None:
        """Transport-side hook: wall time spent distributing the commit
        and installing the staged plan on every host — the slow half of
        phase 2, invisible to the state machine."""
        if self.swap_log:
            self.swap_log[-1].commit_ms += ms

    def _clear_round(self) -> None:
        self.pending = None
        self._pending_record = None
        self._new_plan = None
        self._acks = {}
        self._votes = {}

    # ------------------------------------------------------------- stats
    @property
    def swaps_committed(self) -> int:
        return sum(1 for r in self.swap_log if r.committed)
