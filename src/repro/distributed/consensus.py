"""Quorum-voted plan-swap consensus for multi-host sharded serving
(DESIGN.md §6).

Per-host drift detection is statistically noisy: one shard's CUSUM firing
may be shard skew, not population drift.  A **global** plan swap therefore
requires a quorum of hosts to have voted drift within the same plan epoch.
This module is the transport-agnostic protocol core — explicit message
dataclasses plus a coordinator state machine with no I/O, threads, or
engine imports — so the inline driver, the thread transport, and the unit
tests all exercise the identical logic.

Protocol (one swap):

1. **VOTE** — a host whose local detector fired sends ``DriftVote`` (its
   ``DriftEvent`` payload + its weighted reservoir export).  One vote per
   host per epoch; votes carrying a stale epoch are discarded.
2. **QUORUM** — when ``quorum(K)`` distinct hosts have voted, the
   coordinator merges every known reservoir export (IPW weights
   preserved), decides escalation from the merged Horvitz-Thompson
   selectivities, runs the warm-started re-optimization ONCE, and
   serializes the resulting ``(plan, scorer)`` into the versioned wire
   artifact.
3. **PREPARE** — the artifact is broadcast with the next epoch number.
   Each host deserializes and stages it (does NOT serve it) and replies
   ``SwapAck``.
4. **COMMIT** — only after **all** hosts acked does the coordinator send
   ``SwapCommit``; hosts then atomically install the staged plan.  A
   single NACK aborts the epoch (staged plans are dropped, votes cleared).
   No host ever *serves* a plan version a peer has not acknowledged —
   the two-phase barrier is what the conservation property test leans on.

Fault tolerance (DESIGN.md §6, failure model):

* **Standby coordinator** — every state transition emits an epoch-stamped
  ``StateDelta`` through the ``replicate`` callback (the transport
  piggybacks it on the vote/prepare traffic it already carries).  A
  ``StandbyCoordinator`` mirrors the protocol state from those deltas and
  ``take_over()`` resolves an in-flight two-phase swap after primary
  loss: it COMPLETES the commit when any host already installed the new
  epoch or every active host had acked, and cleanly ABORTS otherwise.
  Optimizer warm-start state (builder / B&B tree) is deliberately NOT
  replicated — after failover, re-optimizations rebase from the seed
  plan's builder.
* **Straggler fencing** — the transport collects prepare-acks under a
  deadline; ``resolve_prepare_deadline`` converts the silent hosts into
  a NACK (policy ``"nack"``) or FENCES them (policy ``"fence"``): the
  fleet commits without them, quorum/ack arithmetic shrinks to the
  active hosts, and the fenced host keeps serving its pinned old epoch
  until a COREWIRE re-sync frame catches it up (``mark_rejoined``).
* **Cross-host kappa² pooling** — hosts stream their weighted IPW
  contingency counts (``DriftVote.kappa`` and the periodic
  ``offer_stats`` sync); the coordinator sums them into fleet-level
  ``StreamingKappa2`` tables.  The pooled table reaches statistical
  maturity ~K× sooner than any single shard's, so a correlation drift
  split evenly across shards — invisible to every local detector —
  still escalates to a B&B re-search (``propose_pooled``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.correlation import StreamingKappa2
from repro.util import advisory_wall_ms
from repro.serving.stats import (
    DriftEvent,
    ReservoirSample,
    ipw_selectivity,
    merge_reservoir_samples,
)

# host kappa export:
#   (pred_i, pred_j) -> ((label_a, label_b) -> weight, n_weighted, n_rows)
KappaExport = Dict[Tuple[int, int],
                   Tuple[Dict[Tuple[int, int], float], float, int]]


def kappa_export_to_json(kappa: Optional[KappaExport]) -> Optional[dict]:
    """Wire-friendly form of a host kappa export (tuple keys -> strings);
    the process transport's newline-delimited control protocol is JSON."""
    if kappa is None:
        return None
    return {
        f"{i},{j}": {
            "counts": [[int(a), int(b), float(c)]
                       for (a, b), c in counts.items()],
            "n": float(n), "rows": int(rows),
        }
        for (i, j), (counts, n, rows) in kappa.items()
    }


def kappa_export_from_json(obj: Optional[dict]) -> Optional[KappaExport]:
    if obj is None:
        return None
    out: KappaExport = {}
    for key, entry in obj.items():
        i, j = (int(v) for v in key.split(","))
        out[(i, j)] = (
            {(int(a), int(b)): float(c) for a, b, c in entry["counts"]},
            float(entry["n"]), int(entry["rows"]),
        )
    return out


# ------------------------------------------------------------- messages
@dataclass
class DriftVote:
    """Host-local drift trigger escalated to the coordinator."""

    host: int
    epoch: int  # plan epoch the host was serving when its detector fired
    event: DriftEvent
    reservoir: ReservoirSample
    # the host's weighted IPW contingency counts (engine.kappa_export()):
    # pooled coordinator-side so fleet-level correlation evidence exists
    # even when every per-shard kappa estimate is immature or sub-threshold
    kappa: Optional[KappaExport] = None
    # which registered query this vote concerns (multi-tenant fleets route
    # per-qid to independent epoch spaces; default 0 = single-query wire)
    qid: int = 0


@dataclass
class SwapPrepare:
    """Phase 1 broadcast: stage (don't serve) the new plan artifact."""

    epoch: int  # the NEW epoch being proposed
    artifact: bytes  # kernels.ops.serialize_scorer wire blob
    # Per-coordinator proposal nonce.  An abort keeps the epoch NUMBER
    # (the re-proposal targets the same epoch with a fresh artifact), so
    # (host, epoch) alone cannot distinguish an ack for round 1 from an
    # ack for round 2 — a stale round-1 ack still in flight after a
    # fence + abort + rejoin would count toward round 2's barrier and
    # let the rejoined host install the round-1 artifact the rest of the
    # fleet never committed (found by analysis/protocol_check.py).
    # Default 0 keeps the pre-nonce wire shape decodable.
    attempt: int = 0
    qid: int = 0  # target query (per-query epoch spaces, DESIGN.md §10)


@dataclass
class SwapAck:
    host: int
    epoch: int
    ok: bool
    error: str = ""
    attempt: int = 0  # echo of SwapPrepare.attempt (see there)
    qid: int = 0      # echo of SwapPrepare.qid


@dataclass
class SwapCommit:
    """Phase 2 broadcast: every host acked — install atomically."""

    epoch: int
    # echo of the winning SwapPrepare.attempt: a host must only install
    # a staged plan from the SAME proposal round — under message
    # reordering its staged copy can be a stale same-epoch artifact (a
    # late round-1 prepare overwrote round 2's), and an epoch-only match
    # would install a plan the fleet never committed
    attempt: int = 0
    qid: int = 0  # target query (per-query epoch spaces)


@dataclass
class StateDelta:
    """One replicated protocol transition (primary -> standby).

    Deltas are epoch-stamped and piggybacked on the message traffic the
    transport already carries; applying them in order reconstructs
    everything a standby needs to resolve an in-flight swap: who voted,
    the pending prepare (with its artifact), which hosts acked, and the
    commit/abort/fence outcomes."""

    kind: str  # "vote" | "prepare" | "ack" | "commit" | "abort" | "fence" | "rejoin"
    epoch: int
    host: Optional[int] = None
    artifact: Optional[bytes] = None
    attempt: int = 0  # prepare deltas carry the proposal nonce
    qid: int = 0      # originating query (multi-tenant standby mirrors)


@dataclass
class SwapRecord:
    """Coordinator-side log entry for one attempted swap."""

    epoch: int
    voters: List[int]
    signals: List[str]
    mode: str  # escalation decision ("alloc" | "bnb")
    committed: bool
    aborted_by: Optional[int] = None
    merged_rows: int = 0
    # hosts excluded from this epoch's barrier (straggler fencing): they
    # keep serving the previous epoch until a re-sync catches them up
    fenced: List[int] = field(default_factory=list)
    # what opened the swap: "quorum" (voted), "pooled:kappa2" (fleet-level
    # correlation evidence, no vote quorum), "failover" (standby resolved
    # an in-flight epoch after primary loss)
    initiated_by: str = "quorum"
    # records submitted anywhere between quorum and commit: >0 would mean
    # a host kept serving while the two-phase barrier was still open
    # (filled by the transport; the state machine cannot see submissions)
    lag_records: int = 0
    # wall-clock spent in each protocol step (re-optimization separate:
    # it is real optimizer work, not consensus overhead)
    reopt_ms: float = 0.0
    serialize_ms: float = 0.0
    prepare_ms: float = 0.0
    commit_ms: float = 0.0

    @property
    def consensus_ms(self) -> float:
        return self.serialize_ms + self.prepare_ms + self.commit_ms


def quorum(n_hosts: int, frac: float = 0.5) -> int:
    """Votes needed for a global swap: strict majority by default
    (``floor(frac * K) + 1``), never more than K, never fewer than 1."""
    return max(1, min(n_hosts, int(n_hosts * frac) + 1))


class QuorumSwapCoordinator:
    """Collects ``DriftVote``s and drives the two-phase swap.

    The coordinator owns the AUTHORITATIVE plan (with its live builder /
    B&B tree in ``plan.meta`` — hosts only ever hold deserialized
    artifacts, so re-optimization state never fans out).  ``reopt_fn``
    is injected: ``(plan, merged_sample, mode) -> new_plan`` — the
    sharded server binds it to ``core.api.rebuild_plan``; unit tests
    bind a stub.
    """

    def __init__(self, plan, n_hosts: int, *,
                 reopt_fn: Callable[[object, ReservoirSample, str], object],
                 quorum_frac: float = 0.5,
                 choose_mode: Optional[Callable[[object, Dict[int, float]], str]] = None,
                 max_tile: int = 8192,
                 kappa_tol: float = 0.08,
                 kappa_pool_baseline: float = 120.0,
                 replicate: Optional[Callable[[StateDelta], None]] = None):
        self.plan = plan
        self.n_hosts = int(n_hosts)
        self.quorum_frac = float(quorum_frac)
        self.reopt_fn = reopt_fn
        self.choose_mode = choose_mode
        self.max_tile = max_tile
        self.kappa_tol = float(kappa_tol)
        self.kappa_pool_baseline = float(kappa_pool_baseline)
        self.replicate = replicate
        self.epoch = 0  # current committed epoch
        self._votes: Dict[int, DriftVote] = {}  # host -> vote (current epoch)
        self.swap_log: List[SwapRecord] = []
        self.pending: Optional[SwapPrepare] = None
        self._pending_record: Optional[SwapRecord] = None
        self._new_plan = None
        self._acks: Dict[int, SwapAck] = {}
        # monotonic proposal nonce (see SwapPrepare.attempt); a promoted
        # standby seeds it from the mirrored prepare deltas
        self.attempt = 0
        # straggler fencing: hosts excluded from barriers + quorum math
        self.fenced: Set[int] = set()
        # committed artifact of the current epoch (re-sync source)
        self.last_artifact: Optional[bytes] = None
        # cross-host kappa² pooling (per current epoch)
        self._kappa_by_host: Dict[int, KappaExport] = {}
        self._kappa_baseline: Optional[Dict[Tuple[int, int], float]] = None
        self._pooled_fired = False

    def _emit(self, delta: StateDelta) -> None:
        if self.replicate is not None:
            self.replicate(delta)

    # ------------------------------------------------------------ voting
    @property
    def active_hosts(self) -> int:
        """Hosts participating in quorum/barrier math (not fenced)."""
        return self.n_hosts - len(self.fenced)

    @property
    def quorum_size(self) -> int:
        return quorum(self.active_hosts, self.quorum_frac)

    @property
    def votes_pending(self) -> int:
        return len(self._votes)

    @property
    def voters(self) -> List[int]:
        return sorted(self._votes)

    def offer_vote(self, vote: DriftVote) -> bool:
        """Register one host's drift vote.  Returns True when this vote
        completes a quorum (caller should then run ``propose``).  Votes
        for a superseded epoch, duplicate votes from the same host, votes
        from fenced hosts, and votes arriving while a swap is already in
        flight are discarded."""
        if vote.kappa is not None:
            self.offer_stats(vote.host, vote.epoch, vote.kappa)
        if vote.epoch != self.epoch or self.pending is not None:
            return False
        if vote.host in self._votes or vote.host in self.fenced:
            return False
        self._votes[vote.host] = vote
        self._emit(StateDelta(kind="vote", epoch=self.epoch, host=vote.host))
        return len(self._votes) >= self.quorum_size

    # ----------------------------------------------- cross-host kappa² pool
    def offer_stats(self, host: int, epoch: int,
                    kappa: Optional[KappaExport]) -> bool:
        """Fold one host's cumulative IPW contingency counts into the
        fleet pool (latest export wins — host tables are cumulative per
        epoch and reset on install, so no double counting).  Returns True
        when the POOLED kappa² has drifted beyond ``kappa_tol`` from the
        pooled baseline — the caller should then run ``propose_pooled``.

        The pooled table crosses the ``kappa_pool_baseline`` label count
        ~K× sooner than any single host's local guard arms, which is
        exactly why an evenly-split correlation drift is visible here
        and nowhere else.  ``kappa_pool_baseline <= 0`` disables pooled
        detection entirely (the default policy: pooling lets the
        coordinator open swaps without any vote quorum, so fleets opt
        in)."""
        if self.kappa_pool_baseline <= 0:
            return False
        if kappa is None or epoch != self.epoch or host in self.fenced:
            return False
        self._kappa_by_host[host] = kappa
        if self._kappa_baseline is not None \
                and (self._pooled_fired or self.pending is not None):
            # nothing can fire this round: skip the O(K · pairs) re-merge
            # (the per-host exports are stored; pooling resumes next call)
            return False
        pooled, n_min = self._pooled_kappa()
        if self._kappa_baseline is None:
            if pooled and n_min >= self.kappa_pool_baseline:
                self._kappa_baseline = pooled
            return False
        if n_min < 2.0 * self.kappa_pool_baseline:
            # evidence accumulated since the freeze must at least match
            # the baseline mass: small-sample kappa² estimates right
            # after an install are noisy enough to flap across the tol
            return False
        return self._pooled_shift(pooled) > self.kappa_tol

    def _pooled_kappa(self) -> Tuple[Dict[Tuple[int, int], float], float]:
        """Fleet-level kappa² per predicate pair (summed contingency
        tables) plus the smallest per-pair pooled LABEL count (actual
        audited rows — the IPW-weighted mass ``n`` overstates the
        statistical information by ~1/audit_rate)."""
        pairs = sorted({p for k in self._kappa_by_host.values() for p in k})
        pooled: Dict[Tuple[int, int], float] = {}
        n_min = float("inf")
        for pair in pairs:
            sk = StreamingKappa2()
            for export in self._kappa_by_host.values():
                entry = export.get(pair)
                if entry is not None:
                    sk.merge_counts(*entry)
            pooled[pair] = sk.value()
            n_min = min(n_min, sk.n_rows)
        return pooled, (0.0 if n_min == float("inf") else float(n_min))

    def _pooled_shift(self, pooled: Optional[Dict[Tuple[int, int], float]] = None
                      ) -> float:
        """Largest |pooled kappa² − pooled baseline| over pairs; 0 until
        the pooled baseline has frozen."""
        if self._kappa_baseline is None:
            return 0.0
        if pooled is None:
            pooled, _ = self._pooled_kappa()
        return max((abs(pooled.get(k, 0.0) - v)
                    for k, v in self._kappa_baseline.items()), default=0.0)

    def mark_fenced(self, host: int) -> None:
        """Exclude a silent host from quorum/barrier arithmetic; it keeps
        serving its pinned epoch (serve-behind) until ``mark_rejoined``."""
        if host not in self.fenced:
            self.fenced.add(host)
            self._votes.pop(host, None)
            self._kappa_by_host.pop(host, None)
            # an already-collected ack from this host no longer speaks
            # for it: the fence removed it from the barrier, and keeping
            # the ack would let it satisfy a FUTURE _maybe_commit if the
            # host is unfenced without re-preparing
            self._acks.pop(host, None)
            self._emit(StateDelta(kind="fence", epoch=self.epoch, host=host))

    def mark_rejoined(self, host: int) -> None:
        """Re-admit a fenced host after its COREWIRE re-sync installed the
        current epoch."""
        if host in self.fenced:
            self.fenced.discard(host)
            self._emit(StateDelta(kind="rejoin", epoch=self.epoch, host=host))

    # ---------------------------------------------------------- proposing
    def propose(self, extra_reservoirs: Optional[List[ReservoirSample]] = None
                ) -> SwapPrepare:
        """Quorum reached: merge reservoirs, re-optimize once, serialize.

        ``extra_reservoirs``: exports pulled from hosts that did NOT vote
        — their rows are just as fresh, and the merged sample should span
        every shard, not only the drifted ones."""
        if len(self._votes) < self.quorum_size:
            raise RuntimeError(
                f"propose() before quorum: {len(self._votes)} votes < "
                f"{self.quorum_size}")
        merged = merge_reservoir_samples(
            [v.reservoir for v in self._votes.values()]
            + list(extra_reservoirs or []))
        return self._propose(
            merged, self._decide_mode(merged), voters=sorted(self._votes),
            signals=[v.event.signal for v in self._votes.values()],
            initiated_by="quorum")

    def propose_pooled(self, reservoirs: List[ReservoirSample]) -> SwapPrepare:
        """Coordinator-initiated swap on pooled fleet evidence: the pooled
        kappa² drifted beyond tolerance while no vote quorum exists (each
        shard's local view is too weak to fire).  A correlation-structure
        shift invalidates the marginal-only regret estimate, so the mode
        is always the B&B re-search."""
        self._pooled_fired = True
        merged = merge_reservoir_samples(list(reservoirs))
        return self._propose(merged, "bnb", voters=[],
                             signals=["pooled:kappa2"],
                             initiated_by="pooled:kappa2")

    def _propose(self, merged: ReservoirSample, mode: str, *,
                 voters: List[int], signals: List[str],
                 initiated_by: str) -> SwapPrepare:
        from repro.kernels.ops import serialize_scorer

        if self.pending is not None:
            raise RuntimeError("a swap is already in flight")
        t0 = advisory_wall_ms()
        new_plan = self.reopt_fn(self.plan, merged, mode)
        reopt_ms = advisory_wall_ms() - t0
        t0 = advisory_wall_ms()
        artifact = serialize_scorer(new_plan, max_tile=self.max_tile)
        ser_ms = advisory_wall_ms() - t0
        new_epoch = self.epoch + 1
        self.attempt += 1
        self.pending = SwapPrepare(epoch=new_epoch, artifact=artifact,
                                   attempt=self.attempt)
        self._pending_record = SwapRecord(
            epoch=new_epoch, voters=voters, signals=signals,
            mode=mode, committed=False, merged_rows=merged.n_rows,
            fenced=sorted(self.fenced), initiated_by=initiated_by,
            reopt_ms=reopt_ms, serialize_ms=ser_ms,
        )
        self._new_plan = new_plan
        self._acks = {}
        self._emit(StateDelta(kind="prepare", epoch=new_epoch,
                              artifact=self.pending.artifact,
                              attempt=self.attempt))
        return self.pending

    def _decide_mode(self, merged: ReservoirSample) -> str:
        """Escalation from the MERGED evidence: the per-host kappa²/regret
        decisions ride the votes, but the coordinator re-derives the mode
        from pooled Horvitz-Thompson selectivities so one noisy shard
        cannot force the expensive B&B path alone.  A majority of
        escalated votes still forces "bnb" (correlation-structure shifts
        are only visible host-side)."""
        if self.choose_mode is not None:
            fresh = {}
            for p in merged.known_sigma:
                sel = ipw_selectivity(merged, p, min_labels=8)
                if sel is not None:
                    fresh[p] = sel
            mode = self.choose_mode(self.plan, fresh)
        else:
            mode = "alloc"
        escalated = sum(1 for v in self._votes.values() if v.event.escalated)
        if escalated * 2 > len(self._votes):
            mode = "bnb"
        elif self._pooled_shift() > self.kappa_tol:
            # pooled fleet evidence outranks the marginal-only regret
            # estimate even when no single vote carried an escalation hint
            mode = "bnb"
        return mode

    # ------------------------------------------------------- ack / commit
    def offer_ack(self, ack: SwapAck) -> Optional[SwapCommit]:
        """Phase-1 responses.  Returns the ``SwapCommit`` once every
        ACTIVE (non-fenced) host has acked; a NACK aborts the epoch
        immediately (returns None and clears the in-flight state —
        callers observe via ``pending``).

        Three classes of ack are inert (dropped without touching the
        barrier): acks for a non-pending epoch, acks from a FENCED host
        (it was excluded from the barrier when its deadline expired — a
        late ack must not re-enter quorum arithmetic, and its NACK must
        not abort an epoch it is no longer part of), and acks whose
        ``attempt`` nonce does not match the pending prepare (a stale
        response to an earlier aborted round of the same epoch number —
        see SwapPrepare.attempt)."""
        if self.pending is None or ack.epoch != self.pending.epoch:
            return None
        if ack.host in self.fenced:
            return None
        if ack.attempt != self.pending.attempt:
            return None
        if not ack.ok:
            self._abort(aborted_by=ack.host)
            return None
        self._acks[ack.host] = ack
        self._emit(StateDelta(kind="ack", epoch=ack.epoch, host=ack.host))
        return self._maybe_commit()

    def resolve_prepare_deadline(self, missing: List[int],
                                 policy: str = "fence"
                                 ) -> Optional[SwapCommit]:
        """The transport's ack deadline expired with ``missing`` hosts
        silent.  ``policy="nack"`` treats the first straggler as a NACK
        (epoch aborts fleet-wide); ``policy="fence"`` excludes the
        stragglers from the barrier — they keep serving their pinned old
        epoch and the remaining hosts commit without them (serve-behind
        version fencing; the fenced hosts catch up via re-sync)."""
        if self.pending is None or not missing:
            return None
        if policy == "nack":
            self._abort(aborted_by=missing[0])
            return None
        if policy != "fence":
            raise ValueError(f"unknown straggler policy {policy!r}")
        for host in missing:
            self.mark_fenced(host)
        if self._pending_record is not None:
            self._pending_record.fenced = sorted(
                set(self._pending_record.fenced) | set(missing))
        if self.active_hosts == 0:
            # every host went silent: nothing left to commit on — abort
            # rather than leave the epoch pending forever
            self._abort(aborted_by=missing[0])
            return None
        return self._maybe_commit()

    def _maybe_commit(self) -> Optional[SwapCommit]:
        active = set(range(self.n_hosts)) - self.fenced
        if not active or not active.issubset(self._acks):
            return None
        commit = SwapCommit(epoch=self.pending.epoch,
                            attempt=self.pending.attempt)
        self.epoch = self.pending.epoch
        self.plan = self._new_plan
        self.last_artifact = self.pending.artifact
        rec = self._pending_record
        rec.committed = True
        self.swap_log.append(rec)
        self._emit(StateDelta(kind="commit", epoch=commit.epoch,
                              artifact=self.last_artifact))
        self._clear_round()
        self._reset_epoch_stats()
        return commit

    def _abort(self, aborted_by: Optional[int]) -> None:
        rec = self._pending_record
        rec.aborted_by = aborted_by
        self.swap_log.append(rec)
        self._emit(StateDelta(kind="abort", epoch=self.pending.epoch,
                              host=aborted_by))
        self._clear_round()
        # fences deliberately SURVIVE the abort: a fenced host may be
        # several epochs behind, and only the re-sync/rejoin path may
        # re-admit it — clearing here would strand it (unfenced but
        # behind, its votes discarded on epoch mismatch forever)
        self._pooled_fired = False

    def note_prepare_ms(self, ms: float) -> None:
        """Transport-side hook: wall time spent distributing the prepare
        + collecting acks (the state machine itself cannot see I/O)."""
        if self._pending_record is not None:
            self._pending_record.prepare_ms += ms
        elif self.swap_log:
            self.swap_log[-1].prepare_ms += ms

    def note_commit_ms(self, ms: float) -> None:
        """Transport-side hook: wall time spent distributing the commit
        and installing the staged plan on every host — the slow half of
        phase 2, invisible to the state machine."""
        if self.swap_log:
            self.swap_log[-1].commit_ms += ms

    def _clear_round(self) -> None:
        self.pending = None
        self._pending_record = None
        self._new_plan = None
        self._acks = {}
        self._votes = {}

    def _reset_epoch_stats(self) -> None:
        """A committed install resets every host's streaming tables, so
        the pooled mirror restarts with the new epoch too."""
        self._kappa_by_host = {}
        self._kappa_baseline = None
        self._pooled_fired = False

    # ----------------------------------------------------- standby re-arm
    def snapshot_deltas(self) -> List[StateDelta]:
        """Serialize the CURRENT protocol state as an ordered delta replay
        — applying these to a fresh ``StandbyCoordinator`` reconstructs
        exactly the mirror an always-attached standby would hold.  Used to
        re-arm replication after a failover (the promoted coordinator
        would otherwise run unreplicated forever): register a new standby,
        replay this snapshot through the normal replication channel, then
        point ``replicate`` at it for live deltas."""
        deltas: List[StateDelta] = []
        if self.epoch > 0:
            deltas.append(StateDelta(kind="commit", epoch=self.epoch,
                                     artifact=self.last_artifact))
        for host in sorted(self.fenced):
            deltas.append(StateDelta(kind="fence", epoch=self.epoch,
                                     host=host))
        for host in sorted(self._votes):
            deltas.append(StateDelta(kind="vote", epoch=self.epoch,
                                     host=host))
        if self.pending is not None:
            deltas.append(StateDelta(kind="prepare", epoch=self.pending.epoch,
                                     artifact=self.pending.artifact,
                                     attempt=self.pending.attempt))
            for host in sorted(self._acks):
                deltas.append(StateDelta(kind="ack",
                                         epoch=self.pending.epoch, host=host))
        return deltas

    # ------------------------------------------------------------- stats
    @property
    def swaps_committed(self) -> int:
        return sum(1 for r in self.swap_log if r.committed)


class StandbyCoordinator:
    """Replicated mirror of a ``QuorumSwapCoordinator``'s protocol state.

    The primary emits ``StateDelta``s (piggybacked on the vote/prepare
    traffic); ``apply`` folds them into a mirror of the epoch, the voted
    hosts, the in-flight prepare (with its artifact), the collected acks,
    and the fence set.  On primary heartbeat loss, ``take_over`` probes
    the hosts and resolves any in-flight two-phase swap:

    * **complete** — some host already installed the proposed epoch
      (primary died mid-commit-broadcast; aborting would strand it), or
      every active host had acked (the barrier was closed; only the
      commit broadcast was lost): the standby re-broadcasts the commit.
      A host that cannot commit (never staged) is fenced for re-sync
      rather than blocking the takeover.
    * **abort** — anything less: staged copies are dropped fleet-wide and
      voting re-arms.  No host ever serves an epoch its peers have not
      acknowledged, through the failover included.

    Optimizer warm-start state is deliberately not replicated: the new
    coordinator re-optimizes from the seed plan's builder (protocol
    safety over search warmth)."""

    def __init__(self, base_plan, n_hosts: int, *,
                 reopt_fn: Callable[[object, ReservoirSample, str], object],
                 quorum_frac: float = 0.5,
                 choose_mode: Optional[Callable] = None,
                 max_tile: int = 8192,
                 kappa_tol: float = 0.08,
                 kappa_pool_baseline: float = 120.0):
        self.base_plan = base_plan
        self.n_hosts = int(n_hosts)
        self._kw = dict(reopt_fn=reopt_fn, quorum_frac=quorum_frac,
                        choose_mode=choose_mode, max_tile=max_tile,
                        kappa_tol=kappa_tol,
                        kappa_pool_baseline=kappa_pool_baseline)
        self.epoch = 0
        self.voted: Set[int] = set()
        self.fenced: Set[int] = set()
        self.pending: Optional[Tuple[int, bytes]] = None  # (epoch, artifact)
        self.pending_attempt = 0  # SwapPrepare.attempt of the mirrored prepare
        self.acks: Set[int] = set()
        self.last_artifact: Optional[bytes] = None
        self.deltas_applied = 0
        # highest proposal nonce seen in prepare deltas: the promoted
        # coordinator resumes ABOVE it so stale acks for the dead
        # primary's rounds can never match a post-failover prepare
        self.attempts_seen = 0

    def apply(self, delta: StateDelta) -> None:
        self.deltas_applied += 1
        if delta.kind == "vote":
            self.voted.add(delta.host)
        elif delta.kind == "prepare":
            self.pending = (delta.epoch, delta.artifact)
            self.pending_attempt = delta.attempt
            self.acks = set()
            self.attempts_seen = max(self.attempts_seen, delta.attempt)
        elif delta.kind == "ack":
            self.acks.add(delta.host)
        elif delta.kind == "commit":
            self.epoch = delta.epoch
            self.last_artifact = delta.artifact
            self.pending = None
            self.acks = set()
            self.voted = set()
        elif delta.kind == "abort":
            self.pending = None
            self.acks = set()
            self.voted = set()
        elif delta.kind == "fence":
            self.fenced.add(delta.host)
            self.voted.discard(delta.host)
        elif delta.kind == "rejoin":
            self.fenced.discard(delta.host)
        else:
            raise ValueError(f"unknown delta kind {delta.kind!r}")

    def take_over(self, hosts, *, unreachable: Optional[Set[int]] = None
                  ) -> Tuple[QuorumSwapCoordinator, str]:
        """Build a live coordinator from the mirrored state, resolving any
        in-flight swap against the probed host fleet.  Returns
        ``(coordinator, resolution)`` with resolution one of
        ``"completed"``, ``"aborted"``, ``"idle"``.  ``unreachable``
        hosts are skipped (still partitioned); they stay fenced."""
        unreachable = unreachable or set()
        coord = QuorumSwapCoordinator(
            self.base_plan, self.n_hosts, replicate=None, **self._kw)
        coord.epoch = self.epoch
        coord.attempt = self.attempts_seen
        coord.last_artifact = self.last_artifact
        coord.fenced = set(self.fenced) | (unreachable & set(
            h.host_id for h in hosts))
        resolution = "idle"
        reachable = [h for h in hosts if h.host_id not in unreachable]
        if self.pending is not None:
            epoch, artifact = self.pending
            active = [h for h in reachable if h.host_id not in self.fenced]
            installed = [h for h in active if h.epoch >= epoch]
            all_acked = {h.host_id for h in active}.issubset(self.acks)
            if installed or all_acked:
                for h in active:
                    if h.epoch >= epoch:
                        continue
                    try:
                        h.commit(SwapCommit(epoch=epoch,
                                            attempt=self.pending_attempt))
                    except Exception:
                        # never staged (prepare was lost with the primary):
                        # fence for re-sync instead of blocking takeover
                        coord.mark_fenced(h.host_id)
                coord.epoch = epoch
                coord.last_artifact = artifact
                coord.swap_log.append(SwapRecord(
                    epoch=epoch, voters=sorted(self.voted),
                    signals=["failover"], mode="takeover", committed=True,
                    fenced=sorted(coord.fenced), initiated_by="failover"))
                resolution = "completed"
            else:
                for h in reachable:
                    h.abort()
                coord.swap_log.append(SwapRecord(
                    epoch=epoch, voters=sorted(self.voted),
                    signals=["failover"], mode="takeover", committed=False,
                    aborted_by=-1, initiated_by="failover"))
                resolution = "aborted"
        else:
            # re-arm voting: the primary's collected votes died with it
            for h in reachable:
                h.abort()
        # hosts still behind the resolved epoch (the primary committed and
        # died before finishing the broadcast, or they were already
        # fenced): fence them so the driver's re-sync path installs the
        # committed artifact — no host ever serves an epoch its peers
        # have not acknowledged, failover included
        behind = [h for h in reachable
                  if h.epoch < coord.epoch and h.host_id not in coord.fenced]
        for h in behind:
            coord.mark_fenced(h.host_id)
        if resolution == "idle" and behind:
            resolution = "resync"
        return coord, resolution


class MultiQueryCoordinator:
    """Routes swap-protocol traffic to per-query coordinators.

    A multi-tenant fleet serves several registered queries through the
    same hosts, but their plans drift (and swap) independently.  A
    single ``QuorumSwapCoordinator`` would couple the tenants: it drops
    votes while a prepare is in flight, so one tenant's slow two-phase
    barrier would silently discard another tenant's drift evidence and
    stall its swap.  This wrapper instead holds one full coordinator —
    its own epoch space, vote set, kappa² pool, and pending barrier —
    per ``qid`` and dispatches every inbound message by its ``qid``
    field.  Outbound prepares/commits are stamped with the qid so the
    transport can deliver them to the right per-query plan slot on each
    host.

    The isolation invariant (tested in tests/test_multiquery.py): any
    interleaving of two tenants' vote → propose → ack → commit rounds
    commits both, and neither tenant's epoch ever observes the other's
    messages.
    """

    def __init__(self, plans: Dict[int, object], n_hosts: int, **kw):
        """``plans`` maps qid -> authoritative plan; ``kw`` is forwarded
        verbatim to every per-query ``QuorumSwapCoordinator`` (inject a
        per-qid ``reopt_fn`` by closing over the qid if tenants need
        different re-optimization policies)."""
        self.n_hosts = int(n_hosts)
        self._kw = dict(kw)
        self.coords: Dict[int, QuorumSwapCoordinator] = {
            int(qid): QuorumSwapCoordinator(plan, n_hosts, **kw)
            for qid, plan in plans.items()
        }

    def coord(self, qid: int) -> QuorumSwapCoordinator:
        return self.coords[int(qid)]

    def add_query(self, qid: int, plan) -> QuorumSwapCoordinator:
        """Register a tenant after construction (session-style API)."""
        qid = int(qid)
        if qid in self.coords:
            raise ValueError(f"qid {qid} already registered")
        self.coords[qid] = QuorumSwapCoordinator(
            plan, self.n_hosts, **self._kw)
        return self.coords[qid]

    @property
    def qids(self) -> List[int]:
        return sorted(self.coords)

    def epoch(self, qid: int) -> int:
        return self.coords[int(qid)].epoch

    # ------------------------------------------------------------- routing
    def offer_vote(self, vote: DriftVote) -> bool:
        """Route one host's vote to its query's coordinator.  A pending
        prepare on one qid never discards a vote for another qid."""
        return self.coords[vote.qid].offer_vote(vote)

    def propose(self, qid: int,
                extra_reservoirs: Optional[List[ReservoirSample]] = None
                ) -> SwapPrepare:
        prep = self.coords[int(qid)].propose(extra_reservoirs)
        prep.qid = int(qid)
        return prep

    def propose_pooled(self, qid: int,
                       reservoirs: List[ReservoirSample]) -> SwapPrepare:
        prep = self.coords[int(qid)].propose_pooled(reservoirs)
        prep.qid = int(qid)
        return prep

    def offer_ack(self, ack: SwapAck) -> Optional[SwapCommit]:
        commit = self.coords[ack.qid].offer_ack(ack)
        if commit is not None:
            commit.qid = ack.qid
        return commit

    def resolve_prepare_deadline(self, qid: int, missing: List[int],
                                 policy: str = "fence"
                                 ) -> Optional[SwapCommit]:
        commit = self.coords[int(qid)].resolve_prepare_deadline(
            missing, policy)
        if commit is not None:
            commit.qid = int(qid)
        return commit

    # fencing is a HOST property, not a query property: a silent host is
    # silent for every tenant it serves, so the fence fans out
    def mark_fenced(self, host: int) -> None:
        for c in self.coords.values():
            c.mark_fenced(host)

    def mark_rejoined(self, host: int) -> None:
        for c in self.coords.values():
            c.mark_rejoined(host)

    def pending_qids(self) -> List[int]:
        """Queries with a swap currently in flight (diagnostics)."""
        return sorted(q for q, c in self.coords.items()
                      if c.pending is not None)
