"""Ambient mesh context for activation sharding constraints.

Model code is mesh-agnostic: it calls ``constrain(x, spec_kind)`` which is a
no-op when no mesh is active (CPU tests, single device) and a
``with_sharding_constraint`` under the production mesh.  Without these
anchors GSPMD propagation picks pathological layouts (e.g. batch-replicated
attention) for the 256/512-device dry-run.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
# residual-stream (B, S, D) anchor: dim kinds per axis.  Default shards the
# batch; decode under 2-D tensor-parallel serving instead shards d_model so
# weights stay stationary and only (tiny) activations move.
_TOKEN_SPEC: tuple = ("batch", None, None)
# §Perf opt: also anchor the residual after EVERY sub-block (attention and
# MLP separately) — stops GSPMD drift that inserts redundant all-gathers of
# the residual in the backward pass.
_MID_ANCHORS: bool = False
# §Perf opt: expert-parallel MoE via shard_map (see models.moe.moe_apply_ep)
_EP: bool = False
# §Perf opt: sequence-shard attention scores when q-heads are not divisible
# by the TP degree (e.g. deepseek-coder's 56 heads on a 16-way model axis);
# without it GSPMD all-reduces the full (S, S) score tensor per layer.
_ATTN_SEQ: bool = False


def set_mesh(mesh: Optional[Mesh], token_spec: tuple = ("batch", None, None),
             mid_anchors: bool = False, ep: bool = False, attn_seq: bool = False):
    global _MESH, _TOKEN_SPEC, _MID_ANCHORS, _EP, _ATTN_SEQ
    _MESH = mesh
    _TOKEN_SPEC = token_spec
    _MID_ANCHORS = mid_anchors
    _EP = ep
    _ATTN_SEQ = attn_seq


def ep_enabled() -> bool:
    return _EP and _MESH is not None


def attn_seq_enabled() -> bool:
    return _ATTN_SEQ and _MESH is not None


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextmanager
def use_mesh(mesh: Mesh, token_spec: tuple = ("batch", None, None),
             mid_anchors: bool = False, ep: bool = False, attn_seq: bool = False):
    prev = (_MESH, _TOKEN_SPEC, _MID_ANCHORS, _EP, _ATTN_SEQ)
    set_mesh(mesh, token_spec, mid_anchors, ep, attn_seq)
    try:
        yield
    finally:
        set_mesh(*prev)


def _batch_axes(mesh: Mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _maybe(mesh: Mesh, dim: int, axes):
    if axes is None:
        return None
    names = (axes,) if isinstance(axes, str) else axes
    size = 1
    for a in names:
        size *= mesh.shape[a]
    return axes if dim % size == 0 else None


def constrain(x, *dim_kinds: Optional[str]):
    """Apply a sharding constraint; dim kinds: "batch" | "model" | None.

    Silently degrades per-dim when sizes don't divide the axis.
    """
    mesh = _MESH
    if mesh is None:
        return x
    spec = []
    for i, kind in enumerate(dim_kinds):
        if kind == "batch":
            spec.append(_maybe(mesh, x.shape[i], _batch_axes(mesh)))
        elif kind == "model":
            spec.append(_maybe(mesh, x.shape[i], "model"))
        elif kind == "data":
            spec.append(_maybe(mesh, x.shape[i], "data"))
        elif kind == "pod":
            spec.append(
                _maybe(mesh, x.shape[i], "pod") if "pod" in mesh.axis_names else None
            )
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_tokens(x):
    """Residual stream (B, S, D): anchored per the active token spec."""
    return constrain(x, *_TOKEN_SPEC)


def constrain_mid(x):
    """Sub-block residual anchor (only under the §Perf opt variant)."""
    if not _MID_ANCHORS:
        return x
    return constrain(x, *_TOKEN_SPEC)


def constrain_logits(x):
    """(B, S, V): batch over data axes, vocab over model."""
    return constrain(x, "batch", None, "model")
