"""Process-level transport for multi-host sharded serving (DESIGN.md §6).

One serving host per OS subprocess: the parent (``ShardedCascadeServer``
with ``transport="process"``) speaks a **newline-delimited JSON control
protocol** over the worker's stdin/stdout pipes, with COREWIRE blobs
(scorer artifacts, re-sync frames) riding base64-embedded in the control
lines.  The worker runs the *same* ``ShardHost`` the inline and thread
transports drive — all three transports share one protocol core; only
the call marshalling differs.

The worker rebuilds its synthetic workload from the seeds in the init
spec (UDFs are trained jax closures — they cannot travel over a pipe; the
generators are deterministic, so every process derives the identical
query), deserializes the initial plan from the COREWIRE artifact, and
then answers one request per line:

    {"cmd": "submit", "indices": <arr>, "rows": <arr>}
    {"cmd": "poll_vote"} / {"cmd": "reservoir_export"} / {"cmd": "kappa_export"}
    {"cmd": "prepare", "epoch": E, "artifact": <b64>}  -> {"ack": {...}}
    {"cmd": "commit", "epoch": E} / {"cmd": "abort"}
    {"cmd": "resync", "frame": <b64>}   (COREWIRE v1.1 catch-up frame)
    {"cmd": "track", "flag": true} / {"cmd": "drain"} / {"cmd": "stop"}

Every reply carries ``ok``, the host's current ``epoch``, and its
``submitted`` count, so the parent's mirror never drifts.  Worker
stdout is reserved for the protocol: ``main()`` re-points fd 1 at stderr
before the heavy imports so library prints cannot corrupt the framing.
"""
from __future__ import annotations

import base64
import json
import os
import select
import subprocess
import sys
from dataclasses import asdict
from pathlib import Path
from typing import Dict, Optional

import numpy as np


# ------------------------------------------------------------- marshalling
def enc_array(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"b64": base64.b64encode(a.tobytes()).decode("ascii"),
            "dtype": a.dtype.str, "shape": list(a.shape)}


def dec_array(d: dict) -> np.ndarray:
    a = np.frombuffer(base64.b64decode(d["b64"]), dtype=np.dtype(d["dtype"]))
    return a.reshape(d["shape"]).copy()


def enc_bytes(b: bytes) -> str:
    return base64.b64encode(b).decode("ascii")


def dec_bytes(s: str) -> bytes:
    return base64.b64decode(s)


def enc_reservoir(rs) -> dict:
    return {
        "indices": enc_array(rs.indices), "x": enc_array(rs.x),
        "weights": enc_array(rs.weights),
        "known_sigma": {str(p): [enc_array(k), enc_array(s)]
                        for p, (k, s) in rs.known_sigma.items()},
    }


def dec_reservoir(d: dict):
    from repro.serving.stats import ReservoirSample

    return ReservoirSample(
        indices=dec_array(d["indices"]), x=dec_array(d["x"]),
        weights=dec_array(d["weights"]),
        known_sigma={int(p): (dec_array(k), dec_array(s))
                     for p, (k, s) in d["known_sigma"].items()},
    )


def enc_vote(v) -> Optional[dict]:
    from repro.distributed.consensus import kappa_export_to_json

    if v is None:
        return None
    ev = asdict(v.event)
    ev["order_before"] = list(ev["order_before"])
    ev["order_after"] = list(ev["order_after"])
    return {"host": v.host, "epoch": v.epoch, "event": ev,
            "reservoir": enc_reservoir(v.reservoir),
            "kappa": kappa_export_to_json(v.kappa)}


def dec_vote(d: Optional[dict]):
    from repro.distributed.consensus import DriftVote, kappa_export_from_json
    from repro.serving.stats import DriftEvent

    if d is None:
        return None
    ev = dict(d["event"])
    ev["order_before"] = tuple(ev["order_before"])
    ev["order_after"] = tuple(ev["order_after"])
    return DriftVote(host=int(d["host"]), epoch=int(d["epoch"]),
                     event=DriftEvent(**ev),
                     reservoir=dec_reservoir(d["reservoir"]),
                     kappa=kappa_export_from_json(d["kappa"]))


# ------------------------------------------------------------- worker side
def _serve_loop(stdin, stdout) -> None:
    from repro.data.synthetic import make_dataset, make_query, make_udfs
    from repro.distributed.consensus import (
        SwapCommit,
        SwapPrepare,
        kappa_export_to_json,
    )
    from repro.distributed.serving import ShardHost
    from repro.kernels.ops import deserialize_scorer
    from repro.serving.stats import AdaptivePolicy

    host: Optional[ShardHost] = None
    for line in stdin:
        if not line.strip():
            continue
        req = json.loads(line)
        cmd = req.get("cmd")
        out: dict = {"id": req.get("id")}
        try:
            if cmd == "init":
                spec = req["spec"]
                ds = make_dataset(**spec["dataset"])
                udfs = make_udfs(ds, **spec["udfs"])
                q = make_query(ds, udfs, **spec["query"])
                plan, _scorer = deserialize_scorer(
                    dec_bytes(req["artifact"]), q)
                slo = req.get("slo_ms")
                host = ShardHost(
                    int(req["host_id"]), plan, tile=int(req["tile"]),
                    policy=AdaptivePolicy(**req["policy"]),
                    seed=int(req["seed"]),
                    use_kernel=bool(req["use_kernel"]),
                    slo_ms=None if slo is None else float(slo))
            elif cmd == "submit":
                host.submit_chunk(dec_array(req["indices"]),
                                  dec_array(req["rows"]))
            elif cmd == "poll_vote":
                out["vote"] = enc_vote(host.poll_vote())
            elif cmd == "reservoir_export":
                out["reservoir"] = enc_reservoir(host.reservoir_export())
            elif cmd == "kappa_export":
                out["kappa"] = kappa_export_to_json(host.kappa_export())
            elif cmd == "prepare":
                # attempt defaults to 0 for requests from older drivers
                ack = host.prepare(SwapPrepare(
                    epoch=int(req["epoch"]),
                    artifact=dec_bytes(req["artifact"]),
                    attempt=int(req.get("attempt", 0))))
                out["ack"] = {"host": ack.host, "epoch": ack.epoch,
                              "ok": ack.ok, "error": ack.error,
                              "attempt": ack.attempt}
            elif cmd == "commit":
                host.commit(SwapCommit(epoch=int(req["epoch"]),
                                       attempt=int(req.get("attempt", 0))))
            elif cmd == "abort":
                host.abort()
            elif cmd == "resync":
                out["epoch_installed"] = host.resync(dec_bytes(req["frame"]))
                out["resyncs"] = host.resyncs
            elif cmd == "track":
                host.track_versions = bool(req["flag"])
            elif cmd == "drain":
                st = host.drain()
                d = asdict(st)
                d["drift_events"] = []  # local events stay host-side
                out["stats"] = d
                out["emitted"] = [int(i) for i in host.engine.emitted]
                out["emitted_versions"] = [
                    int(v) for v in host.engine.emitted_versions]
                out["plan_version"] = int(host.engine.plan_version)
                out["in_flight"] = int(host.engine.in_flight())
                out["submit_version"] = [
                    [int(i), int(v)] for i, v in host.submit_version.items()]
                if host.frontend is not None:
                    # goodput accounting lives in this subprocess; the
                    # parent's fleet aggregation needs the scalars
                    out["frontend_stats"] = asdict(host.frontend.stats)
            elif cmd == "stop":
                out.update(ok=True, epoch=host.epoch if host else 0,
                           submitted=host.submitted if host else 0)
                stdout.write(json.dumps(out) + "\n")
                stdout.flush()
                return
            else:
                raise ValueError(f"unknown command {cmd!r}")
            out.update(ok=True, epoch=host.epoch if host else 0,
                       submitted=host.submitted if host else 0)
        except Exception as e:  # surfaced parent-side as an RPC error
            import traceback

            out = {"id": req.get("id"), "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc(limit=8)}
        stdout.write(json.dumps(out) + "\n")
        stdout.flush()


def main() -> None:
    # the protocol owns real-stdout; anything a library prints lands on
    # stderr so it cannot corrupt the newline framing
    proto_out = os.fdopen(os.dup(1), "w")
    os.dup2(2, 1)
    sys.stdout = sys.stderr
    _serve_loop(sys.stdin, proto_out)


# ------------------------------------------------------------- parent side
class _RemoteEngineView:
    """Parent-side mirror of a worker host's engine surface — filled at
    drain so stats aggregation and the conservation checks read process
    hosts exactly like in-process ones."""

    def __init__(self):
        from repro.serving.engine import ServeStats

        self.stats = ServeStats(stage_in=[], stage_udf_batches=[],
                                stage_kept=[], stage_proxy_ms=[],
                                stage_used_kernel=[])
        self.emitted: list = []
        self.emitted_versions: list = []
        self.plan_version = 0
        self._in_flight = 0

    def in_flight(self) -> int:
        return self._in_flight


class ProcessHost:
    """RPC proxy for one subprocess host — API-identical to ``ShardHost``
    (the same driver code runs all three transports)."""

    def __init__(self, host_id: int, *, spec: dict, artifact: bytes,
                 tile: int, policy, seed: int, use_kernel: bool = True,
                 slo_ms: Optional[float] = None,
                 init_timeout_s: float = 600.0):
        import repro

        # repro is a namespace package (__file__ is None): resolve the
        # src dir from its search path instead
        src_dir = Path(list(repro.__path__)[0]).resolve().parent
        env = dict(os.environ)
        env["PYTHONPATH"] = (str(src_dir) + os.pathsep
                             + env.get("PYTHONPATH", ""))
        self._proc = subprocess.Popen(
            [sys.executable, "-u", "-m", "repro.distributed.procworker"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True,
            env=env)
        self.host_id = int(host_id)
        self.engine = _RemoteEngineView()
        self.epoch = 0
        self.submitted = 0
        self.resyncs = 0
        self.submit_version: Dict[int, int] = {}
        # mirror of the worker-side request front end: None until a drain
        # reply carries frontend stats across the pipe (slo_ms set)
        self.frontend = None
        self._track = False
        self._req_id = 0
        self._rpc({"cmd": "init", "host_id": host_id, "spec": spec,
                   "artifact": enc_bytes(artifact), "tile": tile,
                   "policy": asdict(policy), "seed": seed,
                   "use_kernel": use_kernel,
                   "slo_ms": None if slo_ms is None else float(slo_ms)},
                  timeout=init_timeout_s)

    def _rpc(self, req: dict, timeout: Optional[float] = None) -> dict:
        from repro.distributed.serving import HostTimeout

        self._req_id += 1
        req = dict(req, id=self._req_id)
        self._proc.stdin.write(json.dumps(req) + "\n")
        self._proc.stdin.flush()
        rep = None
        while rep is None or rep.get("id") != self._req_id:
            # discard stale replies (a host that answered AFTER a prior
            # call's deadline expired): request ids keep the channel in
            # sync instead of mistaking the late line for this reply
            if timeout is not None:
                ready, _, _ = select.select(
                    [self._proc.stdout], [], [], timeout)
                if not ready:
                    raise HostTimeout(
                        f"host {self.host_id} silent past {timeout}s "
                        f"deadline ({req.get('cmd')})")
            line = self._proc.stdout.readline()
            if not line:
                raise RuntimeError(
                    f"host {self.host_id} worker exited (rc="
                    f"{self._proc.poll()}) during {req.get('cmd')!r}")
            rep = json.loads(line)
        if not rep.get("ok"):
            raise RuntimeError(
                f"host {self.host_id} {req.get('cmd')!r} failed: "
                f"{rep.get('error')}\n{rep.get('trace', '')}")
        self.epoch = int(rep.get("epoch", self.epoch))
        return rep

    # ------------------------------------------------------- ShardHost API
    @property
    def track_versions(self) -> bool:
        return self._track

    @track_versions.setter
    def track_versions(self, flag: bool) -> None:
        self._track = bool(flag)
        self._rpc({"cmd": "track", "flag": bool(flag)})

    def submit_chunk(self, indices, rows) -> None:
        self._rpc({"cmd": "submit", "indices": enc_array(np.asarray(indices)),
                   "rows": enc_array(np.asarray(rows, np.float32))})
        self.submitted += len(rows)

    def poll_vote(self):
        return dec_vote(self._rpc({"cmd": "poll_vote"}).get("vote"))

    def reservoir_export(self):
        return dec_reservoir(
            self._rpc({"cmd": "reservoir_export"})["reservoir"])

    def kappa_export(self):
        from repro.distributed.consensus import kappa_export_from_json

        return kappa_export_from_json(self._rpc({"cmd": "kappa_export"})["kappa"])

    def prepare(self, msg, timeout: Optional[float] = None):
        from repro.distributed.consensus import SwapAck

        rep = self._rpc({"cmd": "prepare", "epoch": msg.epoch,
                         "artifact": enc_bytes(msg.artifact),
                         "attempt": msg.attempt},
                        timeout=timeout)
        return SwapAck(**rep["ack"])

    def commit(self, msg) -> None:
        self._rpc({"cmd": "commit", "epoch": msg.epoch,
                   "attempt": msg.attempt})

    def abort(self) -> None:
        self._rpc({"cmd": "abort"})

    def resync(self, frame: bytes) -> int:
        rep = self._rpc({"cmd": "resync", "frame": enc_bytes(frame)})
        self.resyncs = int(rep.get("resyncs", self.resyncs + 1))
        return int(rep["epoch_installed"])

    def drain(self):
        rep = self._rpc({"cmd": "drain"})
        view = self.engine
        for k, v in rep["stats"].items():
            setattr(view.stats, k, v)
        view.emitted = list(rep["emitted"])
        view.emitted_versions = list(rep["emitted_versions"])
        view.plan_version = int(rep["plan_version"])
        view._in_flight = int(rep["in_flight"])
        self.submit_version = {int(i): int(v)
                               for i, v in rep["submit_version"]}
        if rep.get("frontend_stats") is not None:
            from types import SimpleNamespace

            from repro.serving.frontend import FrontEndStats

            self.frontend = SimpleNamespace(
                stats=FrontEndStats(**rep["frontend_stats"]))
        return view.stats

    def stop(self) -> None:
        try:
            self._rpc({"cmd": "stop"}, timeout=30.0)
        except Exception:
            pass
        try:
            self._proc.stdin.close()
        except Exception:
            pass
        try:
            self._proc.wait(timeout=30)
        except subprocess.TimeoutExpired:
            # a wedged worker must not discard the caller's completed run
            # (stop() runs inside the drain loop) or leak past cleanup
            self._proc.kill()
            self._proc.wait(timeout=10)


if __name__ == "__main__":
    main()
