"""Fault tolerance for 1000+-node runs: heartbeats, straggler detection,
checkpoint/restart, and elastic re-meshing.

On a real cluster the heartbeat transport is the coordination service
(jax.distributed / GCS); here the components are transport-agnostic and unit
tested with injected clocks and failures.  The training driver
(``launch/train.py``) wires them together:

    monitor = HeartbeatMonitor(...)        # detects dead hosts
    detector = StragglerDetector(...)      # flags slow steps -> re-shard hint
    runner = ResilientRunner(...)          # retries steps, checkpoints,
                                           # re-meshes on device-count change
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional



# ---------------------------------------------------------------- heartbeat
class HeartbeatMonitor:
    """Declares a host dead after ``timeout`` without a beat."""

    # clock is an injectable DEFAULT (every test passes a fake clock); the
    # monitor's decisions are a function of the injected clock, not of a
    # raw read at the decision site.
    def __init__(self, hosts: List[str], timeout: float = 60.0,
                 clock=time.monotonic):  # corelint: disable=wall-clock-decision
        self.timeout = timeout
        self.clock = clock
        self.last: Dict[str, float] = {h: clock() for h in hosts}

    def beat(self, host: str):
        self.last[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]

    def all_alive(self) -> bool:
        return not self.dead_hosts()


# ---------------------------------------------------------------- straggler
@dataclass
class StragglerDetector:
    """EWMA step-time tracker; a step > ``threshold`` x EWMA is a straggler.

    Mitigation on TPU pods is re-sharding around the slow host (or swapping
    in a hot spare); the detector emits the decision, the runner acts."""

    alpha: float = 0.1
    threshold: float = 2.5
    warmup: int = 5
    _ewma: float = 0.0
    _n: int = 0
    events: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._ewma = dt if self._ewma == 0 else (self._ewma + dt) / 2
            return False
        is_straggler = dt > self.threshold * self._ewma
        if is_straggler:
            self.events.append(step)
        else:
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        return is_straggler

    @property
    def ewma(self) -> float:
        return self._ewma


# ------------------------------------------------------------ elastic rerun
@dataclass
class RunnerReport:
    steps_done: int
    restarts: int
    remeshes: int
    straggler_events: int
    final_step_time_ewma: float


class ResilientRunner:
    """Drives a train loop with checkpoint/restart + elastic re-meshing.

    Parameters
    ----------
    step_fn(state, step) -> state     may raise (device loss, preemption)
    save_fn(step, state) / restore_fn(like) -> (step, state)
    remesh_fn(state, n_devices) -> state   re-shards state onto a new mesh
    device_count_fn() -> int          polled every step (elasticity signal)
    """

    def __init__(
        self,
        step_fn: Callable,
        save_fn: Callable,
        restore_fn: Callable,
        *,
        remesh_fn: Optional[Callable] = None,
        device_count_fn: Callable[[], int] = lambda: 1,
        checkpoint_every: int = 50,
        max_restarts: int = 10,
        straggler: Optional[StragglerDetector] = None,
        # injectable default, same contract as HeartbeatMonitor.clock
        clock=time.perf_counter,  # corelint: disable=wall-clock-decision
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.remesh_fn = remesh_fn
        self.device_count_fn = device_count_fn
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerDetector()
        self.clock = clock

    def run(self, state, n_steps: int, start_step: int = 0) -> tuple:
        restarts = remeshes = 0
        step = start_step
        devices = self.device_count_fn()
        while step < n_steps:
            try:
                now = self.device_count_fn()
                if now != devices and self.remesh_fn is not None:
                    state = self.remesh_fn(state, now)
                    devices = now
                    remeshes += 1
                t0 = self.clock()
                state = self.step_fn(state, step)
                self.straggler.observe(step, self.clock() - t0)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.save_fn(step, state)
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                step, state = self.restore_fn()
        self.save_fn(step, state)
        report = RunnerReport(
            steps_done=step - start_step,
            restarts=restarts,
            remeshes=remeshes,
            straggler_events=len(self.straggler.events),
            final_step_time_ewma=self.straggler.ewma,
        )
        return state, report


# ------------------------------------------------------- grad compression
def compress_int8(x, *, axis: int = -1):
    """Symmetric per-slice int8 quantization for cross-pod gradient
    all-reduce (bandwidth /4 vs fp32).  Returns (q, scale)."""
    import jax.numpy as jnp

    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    import jax.numpy as jnp

    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str):
    """int8 quantize -> psum -> dequantize with error feedback handled by
    the caller (returns the residual)."""
    import jax

    q, scale = compress_int8(x)
    deq = decompress_int8(q, scale)
    residual = x - deq
    summed = jax.lax.psum(deq, axis_name)
    return summed, residual
