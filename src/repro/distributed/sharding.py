"""Sharding rules: parameter/optimizer/cache/batch PartitionSpecs per mode.

Modes
-----
* ``train``      — ZeRO-style FSDP + TP: matmul weights shard
                   (second-to-last dim over the batch axes, last over
                   "model"); optimizer states follow params.
* ``serve_tp``   — inference TP: column-parallel weights shard their output
                   dim over "model", row-parallel their input dim; experts
                   shard over "model" (EP).
* ``serve_2d``   — big-model serving (params/chip would exceed HBM under
                   plain TP): TP plus the other matmul dim over the batch
                   axes (weight-gathered serving).  Picked automatically by
                   ``serve_mode_for``.

Every rule degrades to replication when a dim is not divisible by the axis
size (``_maybe``), so any (arch x mesh) combination lowers.
Intermediate activations are left to GSPMD propagation; the §Perf hillclimb
adds explicit constraints where propagation is weak.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

# weights whose LAST dim is the parallel (output) dim under TP
_COL_PARALLEL = {
    "wq", "wk", "wv", "wg", "wi", "wkv_a", "wkv_b", "in_proj", "wx", "wgate",
    "wa",
}
# weights whose FIRST matmul dim is the parallel (input) dim under TP
_ROW_PARALLEL = {"wo", "out_proj"}
_EXPERT_STACKED = 4  # (L, E, d, f)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, dim: int, axes):
    """axes if dim divides evenly, else None (replicate).  Single-axis
    tuples are unwrapped: PartitionSpec treats ("data",) and "data" as
    distinct entries, so specs built from batch_axes() would never compare
    equal to hand-written ones."""
    if axes is None:
        return None
    if dim % _axis_size(mesh, axes) != 0:
        return None
    if isinstance(axes, tuple) and len(axes) == 1:
        return axes[0]
    return axes


def serve_mode_for(cfg, mesh: Mesh) -> str:
    """Choose TP vs 2-D serving sharding from the per-chip footprint."""
    tp = mesh.shape["model"]
    per_chip_gb = cfg.n_params() * 2 / tp / 1e9
    return "serve_2d" if per_chip_gb > 6.0 else "serve_tp"


def param_spec(path_names: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
               mode: str) -> P:
    name = path_names[-1] if path_names else ""
    fsdp = batch_axes(mesh)
    ndim = len(shape)
    if ndim <= 1 or name in ("conv_w", "conv_b"):
        return P()
    is_expert = name in ("wg", "wi", "wo") and ndim == _EXPERT_STACKED
    if mode == "train":
        # FSDP x TP with col/row orientation: contractions stay local to the
        # "model" axis (true tensor-parallel compute); the batch axes shard
        # the other matmul dim ZeRO-style (weights all-gathered per layer).
        if name == "embed":
            # vocab over model only: a 2-D-sharded table turns the embedding
            # gather/scatter-add into SPMD "involuntary full remat"
            return P(_maybe(mesh, shape[0], "model"), None)
        if name == "lm_head":
            return P(_maybe(mesh, shape[-2], fsdp), _maybe(mesh, shape[-1], "model"))
        if is_expert:
            spec = [None] * ndim
            spec[1] = _maybe(mesh, shape[1], "model")  # EP for experts
            spec[-1] = _maybe(mesh, shape[-1], fsdp)
            return P(*spec)
        spec = [None] * ndim
        if name in _ROW_PARALLEL:
            spec[-2] = _maybe(mesh, shape[-2], "model")
            spec[-1] = _maybe(mesh, shape[-1], fsdp)
        else:
            spec[-2] = _maybe(mesh, shape[-2], fsdp)
            spec[-1] = _maybe(mesh, shape[-1], "model")
        return P(*spec)
    # serving modes
    data = fsdp if mode == "serve_2d" else None
    if name == "embed":
        return P(_maybe(mesh, shape[0], "model"),
                 _maybe(mesh, shape[1], data) if data else None)
    if name == "lm_head":
        return P(_maybe(mesh, shape[0], data) if data else None,
                 _maybe(mesh, shape[1], "model"))
    if is_expert:
        spec = [None] * ndim
        spec[1] = _maybe(mesh, shape[1], "model")  # experts over model (EP)
        return P(*spec)
    if name in _ROW_PARALLEL:
        spec = [None] * ndim
        spec[-2] = _maybe(mesh, shape[-2], "model")
        if data:
            spec[-1] = _maybe(mesh, shape[-1], data)
        return P(*spec)
    if name in _COL_PARALLEL or name == "router":
        spec = [None] * ndim
        spec[-1] = _maybe(mesh, shape[-1], "model")
        if data:
            spec[-2] = _maybe(mesh, shape[-2], data)
        return P(*spec)
    spec = [None] * ndim
    spec[-1] = _maybe(mesh, shape[-1], "model")
    return P(*spec)


def _names_of(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(k.name)
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(str(k.idx))
    return tuple(names)


def params_shardings(params_tree, mesh: Mesh, mode: str):
    """NamedSharding pytree matching ``params_tree`` (works on eval_shape
    abstract trees too)."""

    def f(path, leaf):
        spec = param_spec(_names_of(path), leaf.shape, mesh, mode)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, params_tree)


def opt_shardings(opt_state_tree, mesh: Mesh, mode: str = "train"):
    """Optimizer states (mu/nu) mirror the param rules; scalars replicate."""

    def f(path, leaf):
        names = _names_of(path)
        if len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        spec = param_spec(names, leaf.shape, mesh, mode)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, opt_state_tree)


# ------------------------------------------------------------ data / cache
def batch_sharding(batch_tree, mesh: Mesh):
    """Shard the leading (batch) dim of every input over the batch axes."""
    fsdp = batch_axes(mesh)

    def f(leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        spec = [None] * len(leaf.shape)
        spec[0] = _maybe(mesh, leaf.shape[0], fsdp)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(f, batch_tree)


def cache_sharding(cache_tree, mesh: Mesh, *, seq_axis_by_len: bool = True):
    """KV/state cache sharding for decode.

    Layout per leaf (L, B, T, ...):
      * B over the batch axes when divisible;
      * the longest remaining dim (sequence T for KV, heads/width for SSM
        state) over "model" when divisible — flash-decode style
        sequence-sharded KV.
    Scalars (pos counters) replicate.
    """
    fsdp = batch_axes(mesh)

    def f(leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        spec = [None] * len(shape)
        b_dim = 1 if len(shape) >= 2 else 0
        spec[b_dim] = _maybe(mesh, shape[b_dim], fsdp)
        # pick the largest dim after batch for the model axis
        cand = [i for i in range(len(shape)) if i > b_dim]
        if cand:
            i_big = max(cand, key=lambda i: shape[i])
            spec[i_big] = _maybe(mesh, shape[i_big], "model")
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(f, cache_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
