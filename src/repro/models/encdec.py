"""Encoder-decoder family (seamless-m4t-medium backbone).

The audio frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (B, enc_len, d_model) where
enc_len = seq_len // frame_ratio.  Encoder layers are bidirectional; decoder
layers are causal self-attention + cross-attention to the encoder memory.
RoPE replaces the original relative-position bias (TPU-idiomatic; see
DESIGN.md assumption log).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import ctx
from repro.models import layers as L


def enc_len_for(cfg, seq_len: int) -> int:
    return max(1, seq_len // cfg.encoder.frame_ratio)


def init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rms_for(cfg, cfg.d_model),
        "attn": L.init_gqa(k1, cfg),
        "ln2": L.init_rms_for(cfg, cfg.d_model),
        "mlp": L.init_mlp(k2, cfg),
    }


def init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_rms_for(cfg, cfg.d_model),
        "self_attn": L.init_gqa(k1, cfg),
        "ln_x": L.init_rms_for(cfg, cfg.d_model),
        "cross_attn": L.init_gqa(k2, cfg),
        "ln2": L.init_rms_for(cfg, cfg.d_model),
        "mlp": L.init_mlp(k3, cfg),
    }


def init(key, cfg):
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    params = L.init_embed(k_emb, cfg)
    params["enc_layers"] = L.stack_init(lambda k: init_enc_layer(k, cfg), k_enc, cfg.encoder.num_layers)
    params["dec_layers"] = L.stack_init(lambda k: init_dec_layer(k, cfg), k_dec, cfg.num_layers)
    params["enc_norm"] = L.init_rms_for(cfg, cfg.d_model)
    params["final_norm"] = L.init_rms_for(cfg, cfg.d_model)
    return params


def encode(params, cfg, frames):
    """frames: (B, E, d_model) precomputed frame embeddings."""
    B, E, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None], (B, E))

    def body(h, lp):
        hn = L.apply_norm(cfg, h, lp["ln1"])
        h = h + L.gqa_attend(lp["attn"], cfg, hn, positions, causal=False)
        hn = L.apply_norm(cfg, h, lp["ln2"])
        return h + L.mlp_apply(lp["mlp"], cfg, hn)

    x = L.scan_layers(body, frames.astype(L.param_dtype(cfg)), params["enc_layers"],
                      remat=cfg.remat)
    return L.apply_norm(cfg, x, params["enc_norm"])


def _cross_kv(lp, cfg, memory):
    """Project encoder memory to per-layer cross K/V."""
    a = cfg.attention
    B, E, _ = memory.shape
    k = (memory @ lp["wk"]).reshape(B, E, a.num_kv_heads, a.head_dim)
    v = (memory @ lp["wv"]).reshape(B, E, a.num_kv_heads, a.head_dim)
    if a.qkv_bias:
        k, v = k + lp["bk"].reshape(1, 1, a.num_kv_heads, a.head_dim), v + lp["bv"].reshape(
            1, 1, a.num_kv_heads, a.head_dim
        )
    return k, v


def _dec_layer(lp, cfg, x, positions, memory, mem_positions):
    h = L.apply_norm(cfg, x, lp["ln1"])
    x = x + L.gqa_attend(lp["self_attn"], cfg, h, positions, causal=True)
    h = L.apply_norm(cfg, x, lp["ln_x"])
    ck, cv = _cross_kv(lp["cross_attn"], cfg, memory)
    x = x + L.gqa_attend(
        lp["cross_attn"], cfg, h, positions, causal=False, rope=False,
        kv_override=(ck, cv), kv_positions=mem_positions,
    )
    h = L.apply_norm(cfg, x, lp["ln2"])
    return x + L.mlp_apply(lp["mlp"], cfg, h)


def forward(params, cfg, batch):
    tokens = batch["tokens"]
    frames = batch["frames"]
    B, S = tokens.shape
    memory = encode(params, cfg, frames)
    E = memory.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mem_positions = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None], (B, E))
    x = L.embed_tokens(params, cfg, tokens)

    def body(h, lp):
        return _dec_layer(lp, cfg, h, positions, memory, mem_positions)

    x = L.scan_layers(body, x, params["dec_layers"], remat=cfg.remat)
    x = L.apply_norm(cfg, x, params["final_norm"])
    return L.lm_logits(params, cfg, x)


def loss(params, cfg, batch):
    logits = forward(params, cfg, batch)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask")), {}


# --------------------------------------------------------------- serving
def init_cache(cfg, batch: int, max_len: int):
    a = cfg.attention
    dt = L.param_dtype(cfg)
    E = enc_len_for(cfg, max_len)
    Ld = cfg.num_layers
    return {
        "k": jnp.zeros((Ld, batch, max_len, a.num_kv_heads, a.head_dim), dt),
        "v": jnp.zeros((Ld, batch, max_len, a.num_kv_heads, a.head_dim), dt),
        "xk": jnp.zeros((Ld, batch, E, a.num_kv_heads, a.head_dim), dt),
        "xv": jnp.zeros((Ld, batch, E, a.num_kv_heads, a.head_dim), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg, batch):
    """Encode the source + run the decoder prompt, capturing caches."""
    tokens = batch["tokens"]
    frames = batch["frames"]
    B, S = tokens.shape
    a = cfg.attention
    memory = encode(params, cfg, frames)
    E = memory.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mem_positions = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None], (B, E))
    x = L.embed_tokens(params, cfg, tokens)

    def body(h, lp):
        hn = L.apply_norm(cfg, h, lp["ln1"])
        q, k, v = L.gqa_project_qkv(lp["self_attn"], cfg, hn)
        q = L.apply_rope(q, positions, a.rope_theta)
        k = L.apply_rope(k, positions, a.rope_theta)
        out = L.mha(q, k, v, causal=True, q_positions=positions, kv_positions=positions)
        h = h + out.reshape(B, S, -1) @ lp["self_attn"]["wo"]
        hn = L.apply_norm(cfg, h, lp["ln_x"])
        xk, xv = _cross_kv(lp["cross_attn"], cfg, memory)
        out = L.gqa_attend(
            lp["cross_attn"], cfg, hn, positions, causal=False, rope=False,
            kv_override=(xk, xv), kv_positions=mem_positions,
        )
        h = h + out
        hn = L.apply_norm(cfg, h, lp["ln2"])
        return ctx.constrain_tokens(h + L.mlp_apply(lp["mlp"], cfg, hn)), (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = lax.scan(body, x, params["dec_layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x[:, -1:, :])
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs, "pos": jnp.asarray(S, jnp.int32)}
    return logits[:, 0], cache


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    a = cfg.attention
    pos = cache["pos"]
    x = L.embed_tokens(params, cfg, tokens[:, None])
    E = cache["xk"].shape[2]
    mem_positions = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None], (B, E))

    def body(h, xs):
        lp, ck, cv, xk, xv = xs
        hn = L.apply_norm(cfg, h, lp["ln1"])
        out, ck, cv = L.gqa_decode(lp["self_attn"], cfg, hn, ck, cv, pos)
        h = h + out
        hn = L.apply_norm(cfg, h, lp["ln_x"])
        positions = jnp.full((B, 1), pos, jnp.int32)
        out = L.gqa_attend(
            lp["cross_attn"], cfg, hn, positions, causal=False, rope=False,
            kv_override=(xk, xv), kv_positions=mem_positions,
        )
        h = h + out
        hn = L.apply_norm(cfg, h, lp["ln2"])
        return ctx.constrain_tokens(h + L.mlp_apply(lp["mlp"], cfg, hn)), (ck, cv)

    x, (ks, vs) = lax.scan(body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x)
    return logits[:, 0], {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"], "pos": pos + 1}
