"""RecurrentGemma / Griffin hybrid family: RG-LRU recurrent blocks + local
(sliding-window) MQA in a 1:2 pattern (rec, rec, attn).

Train/prefill runs the RG-LRU linear recurrence with
``lax.associative_scan`` (parallel, O(S log S)); decode is the O(1)
recurrent step + ring-buffer window KV, which is why ``long_500k`` is
runnable for this arch.

Layers have heterogeneous structure, so the stack is an unrolled Python loop
over per-layer param dicts (26 layers, small d_model — HLO stays modest) with
optional per-layer remat.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import ctx
from repro.models import layers as L

_C = 8.0  # RG-LRU exponent scale (Griffin paper)


# --------------------------------------------------------------- RG-LRU
def init_rglru(key, cfg):
    h = cfg.hybrid
    d, w = cfg.d_model, h.lru_width
    dt = L.param_dtype(cfg)
    ks = jax.random.split(key, 6)
    # Lambda init so a = sigmoid-ish decay in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "wx": L.dense_init(ks[1], (d, w), dtype=dt),
        "wgate": L.dense_init(ks[2], (d, w), dtype=dt),
        "conv_w": L.dense_init(ks[3], (h.conv_width, w), dtype=dt) * 0.1,
        "conv_b": jnp.zeros((w,), dt),
        "wa": L.dense_init(ks[4], (w, w), dtype=dt),
        "ba": jnp.zeros((w,), jnp.float32),
        "wi": L.dense_init(ks[5], (w, w), dtype=dt),
        "bi": jnp.zeros((w,), jnp.float32),
        "lambda": lam,
        "wo": L.dense_init(jax.random.fold_in(key, 7), (w, d), dtype=dt),
    }


def _lru_gates(p, x):
    """x: (..., w) post-conv activations -> (log_a, gated_input) fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r  # (..., w)
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * xf)
    return log_a, b


def _conv1d(x, w, b):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def rglru_seq(p, cfg, x):
    """Full-sequence recurrent branch. x: (B,S,D) -> (B,S,D)."""
    gate = jax.nn.gelu((x @ p["wgate"]).astype(jnp.float32))
    xi = _conv1d(x @ p["wx"], p["conv_w"], p["conv_b"])
    log_a, bseq = _lru_gates(p, xi)
    a = jnp.exp(log_a)

    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, bseq), axis=1)
    y = (h * gate).astype(x.dtype)
    return y @ p["wo"]


def rglru_step(p, cfg, x, conv_state, h_state):
    """Single-token step. x: (B,1,D); conv_state: (B,K-1,w); h_state: (B,w)."""
    gate = jax.nn.gelu((x[:, 0] @ p["wgate"]).astype(jnp.float32))
    xi_raw = x[:, 0] @ p["wx"]
    full = jnp.concatenate([conv_state, xi_raw[:, None, :]], axis=1)
    xi = jnp.einsum("bkc,kc->bc", full, p["conv_w"]) + p["conv_b"]
    new_conv = full[:, 1:]
    log_a, b = _lru_gates(p, xi)
    h_new = jnp.exp(log_a) * h_state + b
    y = (h_new * gate).astype(x.dtype)
    return (y @ p["wo"])[:, None, :], new_conv, h_new


# --------------------------------------------------------------- blocks
def init_block(key, cfg, kind: str):
    k1, k2 = jax.random.split(key)
    p = {"ln1": L.init_rms_for(cfg, cfg.d_model), "ln2": L.init_rms_for(cfg, cfg.d_model)}
    if kind == "rec":
        p["rec"] = init_rglru(k1, cfg)
    else:
        p["attn"] = L.init_gqa(k1, cfg)
    p["mlp"] = L.init_mlp(k2, cfg)
    return p


def init(key, cfg):
    kinds = cfg.layer_kinds()
    k_emb, k_blocks = jax.random.split(key)
    params = L.init_embed(k_emb, cfg)
    keys = jax.random.split(k_blocks, cfg.num_layers)
    params["blocks"] = tuple(init_block(keys[i], cfg, kinds[i]) for i in range(cfg.num_layers))
    params["final_norm"] = L.init_rms_for(cfg, cfg.d_model)
    return params


def _block_fwd(bp, cfg, kind, x, positions):
    h = L.apply_norm(cfg, x, bp["ln1"])
    if kind == "rec":
        x = x + rglru_seq(bp["rec"], cfg, h)
    else:
        x = x + L.gqa_attend(bp["attn"], cfg, h, positions, causal=True)
    h = L.apply_norm(cfg, x, bp["ln2"])
    return ctx.constrain_tokens(x + L.mlp_apply(bp["mlp"], cfg, h))


def forward(params, cfg, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params, cfg, tokens)
    kinds = cfg.layer_kinds()
    for bp, kind in zip(params["blocks"], kinds):
        f = (lambda xx, b=bp, k=kind: _block_fwd(b, cfg, k, xx, positions))
        x = jax.checkpoint(f)(x) if cfg.remat else f(x)
    x = L.apply_norm(cfg, x, params["final_norm"])
    return L.lm_logits(params, cfg, x)


def loss(params, cfg, batch):
    logits = forward(params, cfg, batch)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask")), {}


# --------------------------------------------------------------- serving
def init_cache(cfg, batch: int, max_len: int):
    a, h = cfg.attention, cfg.hybrid
    dt = L.param_dtype(cfg)
    W = min(a.window, max_len)
    kinds = cfg.layer_kinds()
    cache = []
    for kind in kinds:
        if kind == "rec":
            cache.append(
                {
                    "conv": jnp.zeros((batch, h.conv_width - 1, h.lru_width), dt),
                    "h": jnp.zeros((batch, h.lru_width), jnp.float32),
                }
            )
        else:
            cache.append(
                {
                    "k": jnp.zeros((batch, W, a.num_kv_heads, a.head_dim), dt),
                    "v": jnp.zeros((batch, W, a.num_kv_heads, a.head_dim), dt),
                }
            )
    return {"blocks": tuple(cache), "pos": jnp.zeros((), jnp.int32)}


def prefill(params, cfg, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    a = cfg.attention
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params, cfg, tokens)
    kinds = cfg.layer_kinds()
    W = min(a.window, S)
    new_cache = []
    for bp, kind in zip(params["blocks"], kinds):
        h = L.apply_norm(cfg, x, bp["ln1"])
        if kind == "rec":
            hp = bp["rec"]
            gate = jax.nn.gelu((h @ hp["wgate"]).astype(jnp.float32))
            xi_raw = h @ hp["wx"]
            xi = _conv1d(xi_raw, hp["conv_w"], hp["conv_b"])
            log_a, bseq = _lru_gates(hp, xi)
            aa = jnp.exp(log_a)

            def combine(u, v):
                return u[0] * v[0], v[0] * u[1] + v[1]

            _, hs = lax.associative_scan(combine, (aa, bseq), axis=1)
            y = (hs * gate).astype(x.dtype)
            x = x + y @ hp["wo"]
            conv_tail = jnp.concatenate(
                [jnp.zeros((B, cfg.hybrid.conv_width - 1, xi_raw.shape[-1]), xi_raw.dtype), xi_raw],
                axis=1,
            )[:, -(cfg.hybrid.conv_width - 1) :]
            new_cache.append({"conv": conv_tail, "h": hs[:, -1]})
        else:
            q, k, v = L.gqa_project_qkv(bp["attn"], cfg, h)
            q = L.apply_rope(q, positions, a.rope_theta)
            k = L.apply_rope(k, positions, a.rope_theta)
            out = L.mha(q, k, v, causal=True, q_positions=positions, kv_positions=positions,
                        window=a.window)
            x = x + out.reshape(B, S, -1) @ bp["attn"]["wo"]
            # keep the last W positions, arranged so slot (pos % W) is correct
            kW, vW = k[:, -W:], v[:, -W:]
            if S >= W:
                shift = S % W
                idx = (jnp.arange(W) - shift) % W
                kW, vW = kW[:, idx], vW[:, idx]
            new_cache.append({"k": kW, "v": vW})
        h = L.apply_norm(cfg, x, bp["ln2"])
        x = ctx.constrain_tokens(x + L.mlp_apply(bp["mlp"], cfg, h))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x[:, -1:, :])
    return logits[:, 0], {"blocks": tuple(new_cache), "pos": jnp.asarray(S, jnp.int32)}


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    a = cfg.attention
    pos = cache["pos"]
    x = L.embed_tokens(params, cfg, tokens[:, None])
    kinds = cfg.layer_kinds()
    new_cache = []
    for bp, kind, c in zip(params["blocks"], kinds, cache["blocks"]):
        h = L.apply_norm(cfg, x, bp["ln1"])
        if kind == "rec":
            out, conv, hs = rglru_step(bp["rec"], cfg, h, c["conv"], c["h"])
            x = x + out
            new_cache.append({"conv": conv, "h": hs})
        else:
            out, ck, cv = L.gqa_decode(bp["attn"], cfg, h, c["k"], c["v"], pos, window=a.window)
            x = x + out
            new_cache.append({"k": ck, "v": cv})
        h = L.apply_norm(cfg, x, bp["ln2"])
        x = ctx.constrain_tokens(x + L.mlp_apply(bp["mlp"], cfg, h))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x)
    return logits[:, 0], {"blocks": tuple(new_cache), "pos": pos + 1}
