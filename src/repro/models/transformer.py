"""Dense decoder-only transformer (llama/qwen/deepseek-dense style).

Implements the uniform family API used by the launcher and the serving
engine:

    init(key, cfg)                       -> params
    forward(params, cfg, batch)          -> logits (B,S,V) fp32
    loss(params, cfg, batch)             -> (scalar, aux)
    init_cache(cfg, batch, max_len)      -> cache pytree
    prefill(params, cfg, batch)          -> (last_logits, cache)
    decode_step(params, cfg, cache, tok) -> (logits, cache)

The layer stack is scanned (stacked params, leading L dim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import ctx
from repro.models import layers as L


def init_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rms_for(cfg, cfg.d_model),
        "attn": L.init_gqa(k1, cfg),
        "ln2": L.init_rms_for(cfg, cfg.d_model),
        "mlp": L.init_mlp(k2, cfg),
    }


def init(key, cfg):
    k_emb, k_layers = jax.random.split(key)
    params = L.init_embed(k_emb, cfg)
    params["layers"] = L.stack_init(lambda k: init_layer(k, cfg), k_layers, cfg.num_layers)
    params["final_norm"] = L.init_rms_for(cfg, cfg.d_model)
    return params


def _layer_fwd(cfg, x, lp, positions):
    h = L.apply_norm(cfg, x, lp["ln1"])
    x = ctx.constrain_mid(x + L.gqa_attend(lp["attn"], cfg, h, positions, causal=True))
    h = L.apply_norm(cfg, x, lp["ln2"])
    x = x + L.mlp_apply(lp["mlp"], cfg, h)
    return x


def backbone(params, cfg, x, positions):
    """x: (B,S,d) embeddings -> (B,S,d) final-normed activations."""

    def body(h, lp):
        return _layer_fwd(cfg, h, lp, positions)

    x = L.scan_layers(body, x, params["layers"], remat=cfg.remat)
    return L.apply_norm(cfg, x, params["final_norm"])


def forward(params, cfg, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params, cfg, tokens)
    x = backbone(params, cfg, x, positions)
    return L.lm_logits(params, cfg, x)


def loss(params, cfg, batch):
    logits = forward(params, cfg, batch)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask")), {}


# -------------------------------------------------------------- serving
def init_cache(cfg, batch: int, max_len: int):
    a = cfg.attention
    window = a.window if a.kind == "local" else 0
    T = min(max_len, window) if window else max_len
    dt = L.param_dtype(cfg)
    shape = (cfg.num_layers, batch, T, a.num_kv_heads, a.head_dim)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg, batch):
    """Processes the full prompt, returns logits at the last position and a
    populated cache sized to the prompt (caller may re-pad)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params, cfg, tokens)
    a = cfg.attention

    cache_k = []
    cache_v = []

    def body(h, lp):
        hn = L.apply_norm(cfg, h, lp["ln1"])
        q, k, v = L.gqa_project_qkv(lp["attn"], cfg, hn)
        q = L.apply_rope(q, positions, a.rope_theta)
        k = L.apply_rope(k, positions, a.rope_theta)
        out = L.mha(q, k, v, causal=True, q_positions=positions, kv_positions=positions,
                    window=a.window if a.kind == "local" else 0)
        h = h + out.reshape(B, S, -1) @ lp["attn"]["wo"]
        hn = L.apply_norm(cfg, h, lp["ln2"])
        h = h + L.mlp_apply(lp["mlp"], cfg, hn)
        return ctx.constrain_tokens(h), (k, v)

    x, (ks, vs) = lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x[:, -1:, :])
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)}
    return logits[:, 0], cache


def decode_step(params, cfg, cache, tokens):
    """tokens: (B,) int32 -> (logits (B,V) fp32, new cache)."""
    B = tokens.shape[0]
    a = cfg.attention
    x = L.embed_tokens(params, cfg, tokens[:, None])
    pos = cache["pos"]
    window = a.window if a.kind == "local" else 0

    def body(h, xs):
        lp, ck, cv = xs
        hn = L.apply_norm(cfg, h, lp["ln1"])
        out, ck, cv = L.gqa_decode(lp["attn"], cfg, hn, ck, cv, pos, window=window)
        h = h + out
        hn = L.apply_norm(cfg, h, lp["ln2"])
        h = h + L.mlp_apply(lp["mlp"], cfg, hn)
        return ctx.constrain_tokens(h), (ck, cv)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x)
    return logits[:, 0], {"k": ks, "v": vs, "pos": pos + 1}
