"""VLM family (paligemma-3b): gemma decoder backbone with a vision-patch
prefix.  The SigLIP tower is a STUB per the assignment — ``input_specs()``
supplies precomputed patch embeddings (B, P, d_model) which are prepended to
the token embeddings.  Loss is computed on text positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T

init = T.init  # same backbone params as the dense family
init_cache = T.init_cache
decode_step = T.decode_step


def _prefixed_embeddings(params, cfg, batch):
    tokens = batch["tokens"]
    patches = batch["patches"].astype(L.param_dtype(cfg))
    B, S = tokens.shape
    P = patches.shape[1]
    tok_emb = L.embed_tokens(params, cfg, tokens)
    x = jnp.concatenate([patches, tok_emb], axis=1)
    positions = jnp.broadcast_to(jnp.arange(P + S, dtype=jnp.int32)[None], (B, P + S))
    return x, positions, P


def forward(params, cfg, batch):
    """Returns logits for the TEXT positions only: (B, S, V)."""
    x, positions, P = _prefixed_embeddings(params, cfg, batch)
    x = T.backbone(params, cfg, x, positions)
    return L.lm_logits(params, cfg, x[:, P:, :])


def loss(params, cfg, batch):
    logits = forward(params, cfg, batch)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask")), {}


def prefill(params, cfg, batch):
    """Prefill over [patches ; prompt tokens]; cache covers the full prefix."""
    from jax import lax

    a = cfg.attention
    x, positions, P = _prefixed_embeddings(params, cfg, batch)
    B, Stot, _ = x.shape

    def body(h, lp):
        hn = L.apply_norm(cfg, h, lp["ln1"])
        q, k, v = L.gqa_project_qkv(lp["attn"], cfg, hn)
        q = L.apply_rope(q, positions, a.rope_theta)
        k = L.apply_rope(k, positions, a.rope_theta)
        out = L.mha(q, k, v, causal=True, q_positions=positions, kv_positions=positions)
        h = h + out.reshape(B, Stot, -1) @ lp["attn"]["wo"]
        hn = L.apply_norm(cfg, h, lp["ln2"])
        return h + L.mlp_apply(lp["mlp"], cfg, hn), (k, v)

    x, (ks, vs) = lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x[:, -1:, :])
    return logits[:, 0], {"k": ks, "v": vs, "pos": jnp.asarray(Stot, jnp.int32)}
