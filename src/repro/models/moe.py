"""Mixture-of-Experts decoder family (qwen3-moe, deepseek-v2-lite).

Token dispatch uses the argsort-capacity scheme (static shapes, no one-hot
(tokens x experts x capacity) blow-up): tokens are sorted by assigned expert,
each expert processes a fixed-capacity (E, C, D) buffer, overflow tokens fall
back to zero contribution (standard dropping MoE; capacity_factor controls
the drop rate).  Under expert-parallel sharding the (E, C, D) buffer is
sharded on E — XLA materializes the all-to-all from the resharding.

DeepSeek-V2-Lite layers use MLA attention + (2 shared + 64 routed top-6)
experts with the first layer dense; qwen3 uses GQA(+qk-norm) + 128 routed
top-8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import ctx
from repro.models import layers as L
from repro.models import mla as MLA


# ------------------------------------------------------------ expert layer
def init_experts(key, cfg):
    m = cfg.moe
    d, f = cfg.d_model, m.expert_ff
    dt = L.param_dtype(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, m.num_experts), dtype=jnp.float32),
        "wg": L.dense_init(ks[1], (m.num_experts, d, f), dtype=dt),
        "wi": L.dense_init(ks[2], (m.num_experts, d, f), dtype=dt),
        "wo": L.dense_init(ks[3], (m.num_experts, f, d), in_axis=-2, dtype=dt),
    }
    if m.num_shared:
        p["shared"] = L.init_mlp(ks[4], cfg, d_ff=m.num_shared * m.expert_ff)
    return p


def moe_capacity(cfg, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.top_k * n_tokens / m.num_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_apply(p, cfg, x):
    """x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    N = B * S
    xt = x.reshape(N, D)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, m.top_k)  # (N, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, m.num_experts, dtype=jnp.float32), axis=1), axis=0
    )
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * m.num_experts * m.router_aux_weight

    # ---- argsort-capacity dispatch
    C = moe_capacity(cfg, N)
    flat_e = top_e.reshape(-1)  # (N*K,)
    sort_idx = jnp.argsort(flat_e)  # (N*K,)
    sorted_e = flat_e[sort_idx]
    # rank within expert group
    group_start = jnp.searchsorted(sorted_e, jnp.arange(m.num_experts))
    rank = jnp.arange(N * m.top_k) - group_start[sorted_e]
    dest = jnp.where(rank < C, sorted_e * C + rank, m.num_experts * C)  # trash row
    src_token = sort_idx // m.top_k
    buf = jnp.zeros((m.num_experts * C + 1, D), xt.dtype)
    buf = buf.at[dest].set(xt[src_token], mode="drop")
    buf = buf[:-1].reshape(m.num_experts, C, D)

    # ---- per-expert gated MLP
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    eout = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(m.num_experts * C, D)
    eout = jnp.concatenate([eout, jnp.zeros((1, D), eout.dtype)], axis=0)

    # ---- combine: weighted scatter-add back to tokens
    gathered = eout[dest]  # (N*K, D) in sorted order
    weights = top_p.reshape(-1)[sort_idx].astype(gathered.dtype)  # (N*K,)
    out = jnp.zeros((N, D), xt.dtype).at[src_token].add(gathered * weights[:, None])

    if m.num_shared:
        out = out + L.mlp_apply(p["shared"], cfg, xt)
    return out.reshape(B, S, D), aux


# ------------------------------------------------- expert-parallel shard_map
def moe_apply_ep(p, cfg, x):
    """Expert-parallel MoE via shard_map (§Perf opt variant).

    Under TP the token activations are replicated across the "model" axis,
    so dispatch needs NO collectives: each model-rank selects the tokens
    routed to ITS expert block locally, runs the expert FFN, scatter-adds a
    partial output, and a single psum over "model" combines the top-k
    contributions.  Replaces the GSPMD-chosen (N*K, D) all-reduce/all-gather
    (3.3 TB/device/layer on qwen3 x prefill_32k) with one (N_local, D) psum.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.distributed import ctx

    mesh = ctx.get_mesh()
    m = cfg.moe
    if mesh is None or "model" not in mesh.axis_names or m.num_experts % mesh.shape["model"]:
        return moe_apply(p, cfg, x)
    tp = mesh.shape["model"]
    e_local = m.num_experts // tp
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b_spec = batch_axes if x.shape[0] % int(
        __import__("numpy").prod([mesh.shape[a] for a in batch_axes])
    ) == 0 else None

    def local_fn(xl, router, wg, wi, wo):
        Bl, S, D = xl.shape
        N = Bl * S
        xt = xl.reshape(N, D)
        logits = (xt.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = lax.top_k(probs, m.top_k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        density = jnp.mean(
            jnp.sum(jax.nn.one_hot(top_e, m.num_experts, dtype=jnp.float32), axis=1), axis=0
        )
        aux = jnp.sum(density * jnp.mean(probs, axis=0)) * m.num_experts * m.router_aux_weight

        midx = lax.axis_index("model")
        flat_e = top_e.reshape(-1)
        mine = (flat_e // e_local) == midx  # assignments routed to MY experts
        local_e = jnp.where(mine, flat_e - midx * e_local, e_local)  # e_local = trash
        C = moe_capacity(cfg, N)
        sort_idx = jnp.argsort(local_e)
        sorted_e = local_e[sort_idx]
        group_start = jnp.searchsorted(sorted_e, jnp.arange(e_local))
        rank = jnp.arange(N * m.top_k) - group_start[jnp.minimum(sorted_e, e_local - 1)]
        valid = (sorted_e < e_local) & (rank < C)
        slot = jnp.where(valid, sorted_e * C + rank, e_local * C)
        src_token = sort_idx // m.top_k
        # build slot -> token map, then gather tokens DIRECTLY into the buffer
        slot_token = jnp.full((e_local * C + 1,), N, jnp.int32)
        slot_token = slot_token.at[slot].set(src_token.astype(jnp.int32), mode="drop")
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
        buf = xt_pad[slot_token[:-1]].reshape(e_local, C, D)

        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum("ecd,edf->ecf", buf, wi)
        eout = jnp.einsum("ecf,efd->ecd", h, wo).reshape(e_local * C, D)
        eout = jnp.concatenate([eout, jnp.zeros((1, D), eout.dtype)], axis=0)

        gathered = eout[slot]  # sorted-assignment order; trash slot -> zeros
        weights = top_p.reshape(-1)[sort_idx].astype(gathered.dtype)
        out = jnp.zeros((N, D), xt.dtype).at[src_token].add(
            gathered * (weights * valid.astype(gathered.dtype))[:, None]
        )
        out = lax.psum(out, "model")
        return out.reshape(Bl, S, D), lax.pmean(aux, "model")

    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(b_spec, None, None),
            P(),  # router replicated
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(b_spec, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["wg"], p["wi"], p["wo"])
    if m.num_shared:
        out = out + L.mlp_apply(p["shared"], cfg, x.reshape(-1, x.shape[-1])).reshape(x.shape)
    return out, aux


def _moe_dispatch(p, cfg, x):
    from repro.distributed import ctx

    if ctx.ep_enabled():
        return moe_apply_ep(p, cfg, x)
    return moe_apply(p, cfg, x)


# --------------------------------------------------------------- families
def _is_mla(cfg) -> bool:
    return cfg.attention.kind == "mla"


def init_moe_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    attn = MLA.init_mla(k1, cfg) if _is_mla(cfg) else L.init_gqa(k1, cfg)
    return {
        "ln1": L.init_rms_for(cfg, cfg.d_model),
        "attn": attn,
        "ln2": L.init_rms_for(cfg, cfg.d_model),
        "experts": init_experts(k2, cfg),
    }


def init_dense_layer(key, cfg):
    """Leading dense layers (deepseek-v2-lite layer 0)."""
    k1, k2 = jax.random.split(key)
    attn = MLA.init_mla(k1, cfg) if _is_mla(cfg) else L.init_gqa(k1, cfg)
    return {
        "ln1": L.init_rms_for(cfg, cfg.d_model),
        "attn": attn,
        "ln2": L.init_rms_for(cfg, cfg.d_model),
        "mlp": L.init_mlp(k2, cfg, d_ff=cfg.moe.dense_ff),
    }


def init(key, cfg):
    m = cfg.moe
    k_emb, k_dense, k_layers = jax.random.split(key, 3)
    params = L.init_embed(k_emb, cfg)
    if m.first_dense:
        params["dense_layers"] = L.stack_init(
            lambda k: init_dense_layer(k, cfg), k_dense, m.first_dense
        )
    params["layers"] = L.stack_init(
        lambda k: init_moe_layer(k, cfg), k_layers, cfg.num_layers - m.first_dense
    )
    params["final_norm"] = L.init_rms_for(cfg, cfg.d_model)
    return params


def _attend(lp, cfg, h, positions):
    if _is_mla(cfg):
        return MLA.mla_attend(lp["attn"], cfg, h, positions)
    return L.gqa_attend(lp["attn"], cfg, h, positions, causal=True)


def backbone(params, cfg, x, positions):
    m = cfg.moe
    aux_total = jnp.zeros((), jnp.float32)

    if m.first_dense:

        def dense_body(h, lp):
            hn = L.apply_norm(cfg, h, lp["ln1"])
            h = h + _attend(lp, cfg, hn, positions)
            hn = L.apply_norm(cfg, h, lp["ln2"])
            return h + L.mlp_apply(lp["mlp"], cfg, hn)

        x = L.scan_layers(dense_body, x, params["dense_layers"], remat=cfg.remat)

    def moe_body(carry, lp):
        h, aux = carry
        hn = L.apply_norm(cfg, h, lp["ln1"])
        h = h + _attend(lp, cfg, hn, positions)
        hn = L.apply_norm(cfg, h, lp["ln2"])
        mo, a = _moe_dispatch(lp["experts"], cfg, hn)
        return (ctx.constrain_tokens(h + mo), aux + a), None

    body = jax.checkpoint(moe_body) if cfg.remat else moe_body
    (x, aux_total), _ = lax.scan(lambda c, lp: body(c, lp), (x, aux_total), params["layers"])
    return L.apply_norm(cfg, x, params["final_norm"]), aux_total


def forward(params, cfg, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params, cfg, tokens)
    x, _aux = backbone(params, cfg, x, positions)
    return L.lm_logits(params, cfg, x)


def loss(params, cfg, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params, cfg, tokens)
    x, aux = backbone(params, cfg, x, positions)
    logits = L.lm_logits(params, cfg, x)
    ce = L.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce + aux, {"aux": aux, "ce": ce}


# --------------------------------------------------------------- serving
def init_cache(cfg, batch: int, max_len: int):
    a = cfg.attention
    dtype = L.param_dtype(cfg)
    m = cfg.moe
    n_moe = cfg.num_layers - m.first_dense
    if _is_mla(cfg):
        cache = {
            "ckv": jnp.zeros((n_moe, batch, max_len, a.kv_lora_rank), dtype),
            "krope": jnp.zeros((n_moe, batch, max_len, a.qk_rope_head_dim), dtype),
        }
        if m.first_dense:
            cache["dense_ckv"] = jnp.zeros((m.first_dense, batch, max_len, a.kv_lora_rank), dtype)
            cache["dense_krope"] = jnp.zeros(
                (m.first_dense, batch, max_len, a.qk_rope_head_dim), dtype
            )
    else:
        shape = (n_moe, batch, max_len, a.num_kv_heads, a.head_dim)
        cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if m.first_dense:
            cache["dense_k"] = jnp.zeros((m.first_dense,) + shape[1:], dtype)
            cache["dense_v"] = jnp.zeros((m.first_dense,) + shape[1:], dtype)
    cache["pos"] = jnp.zeros((), jnp.int32)
    return cache


def prefill(params, cfg, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    m = cfg.moe
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = L.embed_tokens(params, cfg, tokens)
    cache = {"pos": jnp.asarray(S, jnp.int32)}

    def attn_prefill(lp, h):
        if _is_mla(cfg):
            out, ckv, krope = MLA.mla_prefill(lp["attn"], cfg, h, positions)
            return out, (ckv, krope)
        a = cfg.attention
        q, k, v = L.gqa_project_qkv(lp["attn"], cfg, h)
        q = L.apply_rope(q, positions, a.rope_theta)
        k = L.apply_rope(k, positions, a.rope_theta)
        out = L.mha(q, k, v, causal=True, q_positions=positions, kv_positions=positions)
        return out.reshape(B, S, -1) @ lp["attn"]["wo"], (k, v)

    if m.first_dense:

        def dense_body(h, lp):
            hn = L.apply_norm(cfg, h, lp["ln1"])
            out, kv = attn_prefill(lp, hn)
            h = h + out
            hn = L.apply_norm(cfg, h, lp["ln2"])
            return ctx.constrain_tokens(h + L.mlp_apply(lp["mlp"], cfg, hn)), kv

        x, dkv = lax.scan(dense_body, x, params["dense_layers"])
        if _is_mla(cfg):
            cache["dense_ckv"], cache["dense_krope"] = dkv
        else:
            cache["dense_k"], cache["dense_v"] = dkv

    def moe_body(h, lp):
        hn = L.apply_norm(cfg, h, lp["ln1"])
        out, kv = attn_prefill(lp, hn)
        h = h + out
        hn = L.apply_norm(cfg, h, lp["ln2"])
        mo, _aux = _moe_dispatch(lp["experts"], cfg, hn)
        return ctx.constrain_tokens(h + mo), kv

    x, kv = lax.scan(moe_body, x, params["layers"])
    if _is_mla(cfg):
        cache["ckv"], cache["krope"] = kv
    else:
        cache["k"], cache["v"] = kv
    x = L.apply_norm(cfg, x, params["final_norm"])
    return L.lm_logits(params, cfg, x[:, -1:, :])[:, 0], cache


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    m = cfg.moe
    pos = cache["pos"]
    x = L.embed_tokens(params, cfg, tokens[:, None])
    new_cache = {"pos": pos + 1}

    def attn_decode(lp, h, entry):
        if _is_mla(cfg):
            ckv, krope = entry
            out, ckv, krope = MLA.mla_decode(lp["attn"], cfg, h, ckv, krope, pos)
            return out, (ckv, krope)
        ck, cv = entry
        out, ck, cv = L.gqa_decode(lp["attn"], cfg, h, ck, cv, pos)
        return out, (ck, cv)

    if m.first_dense:

        def dense_body(h, xs):
            lp, *entry = xs
            hn = L.apply_norm(cfg, h, lp["ln1"])
            out, entry = attn_decode(lp, hn, tuple(entry))
            h = h + out
            hn = L.apply_norm(cfg, h, lp["ln2"])
            return ctx.constrain_tokens(h + L.mlp_apply(lp["mlp"], cfg, hn)), entry

        dkeys = ("dense_ckv", "dense_krope") if _is_mla(cfg) else ("dense_k", "dense_v")
        x, dkv = lax.scan(dense_body, x, (params["dense_layers"], cache[dkeys[0]], cache[dkeys[1]]))
        new_cache[dkeys[0]], new_cache[dkeys[1]] = dkv

    def moe_body(h, xs):
        lp, *entry = xs
        hn = L.apply_norm(cfg, h, lp["ln1"])
        out, entry = attn_decode(lp, hn, tuple(entry))
        h = h + out
        hn = L.apply_norm(cfg, h, lp["ln2"])
        mo, _aux = _moe_dispatch(lp["experts"], cfg, hn)
        return ctx.constrain_tokens(h + mo), entry

    keys = ("ckv", "krope") if _is_mla(cfg) else ("k", "v")
    x, kv = lax.scan(moe_body, x, (params["layers"], cache[keys[0]], cache[keys[1]]))
    new_cache[keys[0]], new_cache[keys[1]] = kv
    x = L.apply_norm(cfg, x, params["final_norm"])
    return L.lm_logits(params, cfg, x)[:, 0], new_cache
