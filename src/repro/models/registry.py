"""Family registry + uniform batch/spec construction.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the
multi-pod dry-run lowers against these.  ``make_batch`` builds small concrete
batches for CPU smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, WorkloadShape
from repro.models import encdec, moe, rglru, ssm, transformer, vlm

FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": rglru,
    "encdec": encdec,
    "vlm": vlm,
}


def get_family(cfg: ModelConfig):
    return FAMILIES[cfg.family]


def _token_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text-token length such that the total processed sequence == seq_len."""
    if cfg.family == "vlm":
        return max(1, seq_len - cfg.encoder.num_prefix)
    return seq_len


def make_batch(cfg: ModelConfig, batch: int, seq_len: int, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    S = _token_len(cfg, seq_len)
    b = {
        "tokens": jax.random.randint(k1, (batch, S), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(k2, (batch, S), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.family == "encdec":
        E = encdec.enc_len_for(cfg, seq_len)
        b["frames"] = jax.random.normal(k3, (batch, E, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        P = cfg.encoder.num_prefix
        b["patches"] = jax.random.normal(k3, (batch, P, cfg.d_model), jnp.bfloat16)
    return b


def input_specs(cfg: ModelConfig, shape: WorkloadShape):
    """ShapeDtypeStruct stand-ins for a workload shape (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        St = _token_len(cfg, S)
        specs = {
            "tokens": sds((B, St), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = sds((B, St), jnp.int32)
        if cfg.family == "encdec":
            specs["frames"] = sds((B, encdec.enc_len_for(cfg, S), cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["patches"] = sds((B, cfg.encoder.num_prefix, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a seq_len-sized cache
    fam = get_family(cfg)
    cache = jax.eval_shape(lambda: fam.init_cache(cfg, B, S))
    return {"tokens": sds((B,), jnp.int32), "cache": cache}


def params_spec(cfg: ModelConfig, key=None):
    """Abstract params pytree (eval_shape over init; no allocation)."""
    fam = get_family(cfg)
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: fam.init(k, cfg), key)
