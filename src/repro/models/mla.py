"""Multi-head Latent Attention (DeepSeek-V2).

Prefill/train use the naive (materialized K/V) form; decode uses the
*absorbed* form that attends directly in the compressed latent space, so the
KV cache stores only (kv_lora_rank + rope_dim) per token — the arch's
signature serving optimization (93% KV reduction vs dense GQA).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L


def init_mla(key, cfg):
    a = cfg.attention
    d = cfg.d_model
    dt = L.param_dtype(cfg)
    ks = jax.random.split(key, 5)
    nope, rope_d, vh = a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim
    H = a.num_heads
    p = {
        # q: dense (V2-Lite has no q-lora)
        "wq": L.dense_init(ks[0], (d, H * (nope + rope_d)), dtype=dt),
        # joint kv down-projection: -> [c_kv (rank), k_rope (rope_d, shared)]
        "wkv_a": L.dense_init(ks[1], (d, a.kv_lora_rank + rope_d), dtype=dt),
        "kv_norm": jnp.ones((a.kv_lora_rank,), jnp.float32),
        # up-projection: rank -> per-head [k_nope, v]
        "wkv_b": L.dense_init(ks[2], (a.kv_lora_rank, H * (nope + vh)), dtype=dt),
        "wo": L.dense_init(ks[3], (H * vh, d), dtype=dt),
    }
    return p


def _project_common(p, cfg, x, positions):
    a = cfg.attention
    B, S, _ = x.shape
    H, nope, rope_d = a.num_heads, a.qk_nope_head_dim, a.qk_rope_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, a.rope_theta)
    kv_a = x @ p["wkv_a"]
    c_kv = L.rms_norm(kv_a[..., : a.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(kv_a[..., None, a.kv_lora_rank:], positions, a.rope_theta)
    return q_nope, q_rope, c_kv, k_rope[..., 0, :]


def mla_attend(p, cfg, x, positions):
    """Naive MLA for train/prefill: materialize per-head K/V."""
    a = cfg.attention
    B, S, _ = x.shape
    H, nope, rope_d, vh = a.num_heads, a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _project_common(p, cfg, x, positions)
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, nope + vh)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))], axis=-1)
    out = L.mha(q, k, v, causal=True, q_positions=positions, kv_positions=positions)
    return out.reshape(B, S, H * vh) @ p["wo"]


def mla_prefill(p, cfg, x, positions):
    """Prefill: returns output and the latent cache entries (c_kv, k_rope)."""
    out = mla_attend(p, cfg, x, positions)
    a = cfg.attention
    kv_a = x @ p["wkv_a"]
    c_kv = L.rms_norm(kv_a[..., : a.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(kv_a[..., None, a.kv_lora_rank:], positions, a.rope_theta)[..., 0, :]
    return out, c_kv, k_rope


def mla_decode(p, cfg, x, cache_ckv, cache_krope, pos):
    """Absorbed-matrix decode: attention scores/values in latent space.

    cache_ckv: (B, T, rank); cache_krope: (B, T, rope_d); x: (B, 1, d).
    """
    a = cfg.attention
    B = x.shape[0]
    H, nope, rope_d, vh = a.num_heads, a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim
    rank = a.kv_lora_rank
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _project_common(p, cfg, x, positions)
    cache_ckv = lax.dynamic_update_slice(cache_ckv, c_kv_new, (0, pos, 0))
    cache_krope = lax.dynamic_update_slice(cache_krope, k_rope_new, (0, pos, 0))
    wkv_b = p["wkv_b"].reshape(rank, H, nope + vh)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    # absorb W_uk into q: q_lat (B,1,H,rank)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    T = cache_ckv.shape[1]
    scores = jnp.einsum("bshr,btr->bhst", q_lat, cache_ckv, preferred_element_type=jnp.float32)
    scores += jnp.einsum("bshn,btn->bhst", q_rope, cache_krope, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(nope + rope_d)
    valid = (jnp.arange(T)[None, :] <= pos)[:, None, None, :]
    scores = jnp.where(valid, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs.astype(cache_ckv.dtype), cache_ckv)
    out = jnp.einsum("bshr,rhn->bshn", ctx_lat, w_uv).reshape(B, 1, H * vh)
    return out @ p["wo"], cache_ckv, cache_krope
