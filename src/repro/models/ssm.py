"""Mamba-2 (SSD — state-space duality) family.

Train/prefill use the chunked SSD block decomposition (intra-chunk quadratic
attention-like term + inter-chunk recurrence, arXiv:2405.21060 listing 1);
decode is the O(1)-state recurrent step, which is what makes ``long_500k``
feasible.  The intra-chunk einsum is the Pallas kernel target
(``repro.kernels.ssd_scan``); this module is the pure-jnp reference path used
for lowering and CPU tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import ctx
from repro.models import layers as L


def segsum_ref(x):
    """Segment-sum (Mamba-2 reference, cumsum-difference form).

    x: (..., T) -> (..., T, T); out[..., i, j] = sum_{k=j+1..i} x[..., k] on
    the lower triangle (incl. diagonal = 0), -inf above.
    """
    T = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)  # (..., T)
    diff = csum[..., :, None] - csum[..., None, :]  # [..., i, j] = sum_{j+1..i}
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A_log, B, C, D, chunk: int):
    """SSD forward.

    x: (b, s, h, p)   — per-head inputs (already gated/convolved)
    dt: (b, s, h)     — softplus'd timestep
    A_log: (h,)       — A = -exp(A_log), scalar per head
    B, C: (b, s, g, n) — input/output projections (g groups broadcast to h)
    D: (h,)           — skip connection
    Returns y: (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))  # (h,)
    dA = dt.astype(jnp.float32) * A[None, None, :]  # (b, s, h)
    xdt = x * dt[..., None].astype(x.dtype)

    # reshape into chunks
    xc = xdt.reshape(b, nc, chunk, h, p)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    dAc = dA.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # (b, h, nc, l)
    dA_cum = jnp.cumsum(dAc, axis=-1)  # (b, h, nc, l)

    rep = h // g

    def bh(t):  # broadcast groups->heads: (b, nc, l, g, n) -> (b, nc, l, h, n)
        return jnp.repeat(t, rep, axis=3)

    Bh, Ch = bh(Bc), bh(Cc)

    # 1. intra-chunk (diagonal blocks)
    Ldec = jnp.exp(segsum_ref(dAc))  # (b, h, nc, l, l)
    scores = jnp.einsum("bclhn,bcshn->bhcls", Ch.astype(jnp.float32), Bh.astype(jnp.float32))
    y_diag = jnp.einsum("bhcls,bhcls,bcshp->bclhp", scores, Ldec, xc.astype(jnp.float32))

    # 2. chunk states
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)  # (b, h, nc, l)
    states = jnp.einsum(
        "bclhn,bhcl,bclhp->bchpn", Bh.astype(jnp.float32), decay_states, xc.astype(jnp.float32)
    )  # (b, nc, h, p, n)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cum[..., -1])  # (b, h, nc)

    def scan_body(carry, inp):
        st, dec = inp  # st: (b, h, p, n), dec: (b, h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *before* this chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = lax.scan(
        scan_body,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # 4. inter-chunk output
    state_decay_out = jnp.exp(dA_cum)  # (b, h, nc, l)
    y_off = jnp.einsum(
        "bclhn,bchpn,bhcl->bclhp", Ch.astype(jnp.float32), prev_states, state_decay_out
    )

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), final


# ----------------------------------------------------------------- block
def init_layer(key, cfg):
    s = cfg.ssm
    d, di = cfg.d_model, cfg.d_inner
    h = cfg.ssm_heads
    conv_dim = di + 2 * s.ngroups * s.d_state
    dt = L.param_dtype(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * s.ngroups * s.d_state + h  # [z, x, B, C, dt]
    return {
        "norm": L.init_rms_for(cfg, d),
        "in_proj": L.dense_init(ks[0], (d, in_dim), dtype=dt),
        "conv_w": L.dense_init(ks[1], (s.d_conv, conv_dim), dtype=dt) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[2], (di, d), dtype=dt),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    di, h = cfg.d_inner, cfg.ssm_heads
    gn = s.ngroups * s.d_state
    z = proj[..., :di]
    xBC = proj[..., di : di + di + 2 * gn]
    dt = proj[..., di + di + 2 * gn :]
    return z, xBC, dt


def _conv1d(xBC, w, b):
    """Causal depthwise conv along sequence. xBC: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(K):
        out = out + pad[:, i : i + xBC.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def layer_fwd(lp, cfg, x):
    """Full-sequence (train/prefill) SSD block."""
    s = cfg.ssm
    Bsz, S, _ = x.shape
    di, h = cfg.d_inner, cfg.ssm_heads
    gn = s.ngroups * s.d_state
    hn = L.apply_norm(cfg, x, lp["norm"])
    proj = hn @ lp["in_proj"]
    z, xBC, dt_raw = _split_proj(cfg, proj)
    xBC = _conv1d(xBC, lp["conv_w"], lp["conv_b"])
    xi = xBC[..., :di].reshape(Bsz, S, h, s.head_dim)
    Bm = xBC[..., di : di + gn].reshape(Bsz, S, s.ngroups, s.d_state)
    Cm = xBC[..., di + gn :].reshape(Bsz, S, s.ngroups, s.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    y, _final = ssd_chunked(xi, dt, lp["A_log"], Bm, Cm, lp["D"], s.chunk)
    y = y.reshape(Bsz, S, di)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), lp["gate_norm"], cfg.norm_eps)
    return x + y @ lp["out_proj"]


def layer_decode(lp, cfg, x, conv_state, ssm_state):
    """Single-token recurrent step.

    conv_state: (B, d_conv-1, conv_dim); ssm_state: (B, h, p, n) fp32.
    """
    s = cfg.ssm
    Bsz = x.shape[0]
    di, h = cfg.d_inner, cfg.ssm_heads
    gn = s.ngroups * s.d_state
    hn = L.apply_norm(cfg, x, lp["norm"])
    proj = (hn @ lp["in_proj"])[:, 0]  # (B, in_dim)
    z, xBC, dt_raw = _split_proj(cfg, proj)
    # conv ring: append, apply, shift
    full = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", full, lp["conv_w"]) + lp["conv_b"]
    xBC = jax.nn.silu(conv_out)
    new_conv_state = full[:, 1:]
    xi = xBC[..., :di].reshape(Bsz, h, s.head_dim)
    Bm = xBC[..., di : di + gn].reshape(Bsz, s.ngroups, s.d_state)
    Cm = xBC[..., di + gn :].reshape(Bsz, s.ngroups, s.d_state)
    rep = h // s.ngroups
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B, h, n)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])  # (B, h)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])  # (B, h)
    xf = xi.astype(jnp.float32) * dt[..., None]
    new_state = ssm_state * decay[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xf, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch) + xi.astype(jnp.float32) * lp["D"][None, :, None]
    y = y.reshape(Bsz, 1, di)
    y = L.rms_norm(
        y.astype(x.dtype) * jax.nn.silu(z[:, None].astype(jnp.float32)).astype(x.dtype),
        lp["gate_norm"],
        cfg.norm_eps,
    )
    return x + y @ lp["out_proj"], new_conv_state, new_state


# ------------------------------------------------------------- family API
def init(key, cfg):
    k_emb, k_layers = jax.random.split(key)
    params = L.init_embed(k_emb, cfg)
    params["layers"] = L.stack_init(lambda k: init_layer(k, cfg), k_layers, cfg.num_layers)
    params["final_norm"] = L.init_rms_for(cfg, cfg.d_model)
    return params


def forward(params, cfg, batch):
    tokens = batch["tokens"]
    x = L.embed_tokens(params, cfg, tokens)

    def body(h, lp):
        return layer_fwd(lp, cfg, h)

    x = L.scan_layers(body, x, params["layers"], remat=cfg.remat)
    x = L.apply_norm(cfg, x, params["final_norm"])
    return L.lm_logits(params, cfg, x)


def loss(params, cfg, batch):
    logits = forward(params, cfg, batch)
    return L.cross_entropy(logits, batch["labels"], batch.get("loss_mask")), {}


def init_cache(cfg, batch: int, max_len: int):
    s = cfg.ssm
    conv_dim = cfg.d_inner + 2 * s.ngroups * s.d_state
    return {
        "conv": jnp.zeros((cfg.num_layers, batch, s.d_conv - 1, conv_dim), L.param_dtype(cfg)),
        "ssm": jnp.zeros((cfg.num_layers, batch, cfg.ssm_heads, s.head_dim, s.d_state), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg, batch):
    """Prefill = full forward + capture final states via per-layer decode...
    For SSM we simply run the chunked form and rebuild states; to keep memory
    bounded we recompute the final state per layer inside the scan."""
    tokens = batch["tokens"]
    Bsz, S = tokens.shape
    s = cfg.ssm
    x = L.embed_tokens(params, cfg, tokens)
    di, h = cfg.d_inner, cfg.ssm_heads
    gn = s.ngroups * s.d_state

    def body(hcar, lp):
        xin = hcar
        hn = L.apply_norm(cfg, xin, lp["norm"])
        proj = hn @ lp["in_proj"]
        z, xBC, dt_raw = _split_proj(cfg, proj)
        xBC_conv = _conv1d(xBC, lp["conv_w"], lp["conv_b"])
        xi = xBC_conv[..., :di].reshape(Bsz, S, h, s.head_dim)
        Bm = xBC_conv[..., di : di + gn].reshape(Bsz, S, s.ngroups, s.d_state)
        Cm = xBC_conv[..., di + gn :].reshape(Bsz, S, s.ngroups, s.d_state)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
        y, final = ssd_chunked(xi, dt, lp["A_log"], Bm, Cm, lp["D"], s.chunk)
        y = y.reshape(Bsz, S, di)
        y = L.rms_norm(
            y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), lp["gate_norm"], cfg.norm_eps
        )
        out = ctx.constrain_tokens(xin + y @ lp["out_proj"])
        conv_tail = jnp.concatenate(
            [jnp.zeros((Bsz, s.d_conv - 1, xBC.shape[-1]), xBC.dtype), xBC], axis=1
        )[:, -(s.d_conv - 1) :]
        return out, (conv_tail, final)

    x, (conv_states, ssm_states) = lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x[:, -1:, :])
    cache = {"conv": conv_states, "ssm": ssm_states, "pos": jnp.asarray(S, jnp.int32)}
    return logits[:, 0], cache


def decode_step(params, cfg, cache, tokens):
    x = L.embed_tokens(params, cfg, tokens[:, None])

    def body(h, xs):
        lp, conv, st = xs
        h, conv, st = layer_decode(lp, cfg, h, conv, st)
        return ctx.constrain_tokens(h), (conv, st)

    x, (conv, st) = lax.scan(body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = L.lm_logits(params, cfg, x)
    return logits[:, 0], {"conv": conv, "ssm": st, "pos": cache["pos"] + 1}
