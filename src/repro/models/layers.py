"""Shared model building blocks (pure JAX, explicit param pytrees).

Conventions
-----------
* Params are nested dicts of jnp arrays; layer stacks carry a leading ``L``
  dim and are iterated with ``lax.scan`` (small HLO, fast multi-device
  compile — essential for the 512-device dry-run).
* Matmul params stored in ``cfg.dtype`` (bf16); norms/softmax/rope run in
  fp32; attention logits accumulate in fp32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def param_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.bfloat16):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# ----------------------------------------------------------------- RMSNorm
def rms_norm(x, w, eps: float, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    scale = w.astype(jnp.float32)
    if plus_one:
        scale = scale + 1.0
    return (y * scale).astype(x.dtype)


def init_rms(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def init_rms_for(cfg, d: int):
    # gemma-style norms are stored as zeros and applied as (1 + w)
    if cfg.gemma_scaling:
        return jnp.zeros((d,), jnp.float32)
    return jnp.ones((d,), jnp.float32)


def apply_norm(cfg, x, w):
    return rms_norm(x, w, cfg.norm_eps, plus_one=cfg.gemma_scaling)


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (head_dim/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention
def _use_pallas_attention(q, k, causal, window, kv_valid) -> bool:
    """On TPU with plain-causal full-length attention, dispatch to the
    flash-attention Pallas kernel (REPRO_USE_PALLAS=0 disables)."""
    import os

    if os.environ.get("REPRO_USE_PALLAS", "1") != "1":
        return False
    if jax.default_backend() != "tpu":
        return False  # interpret mode is for tests, not the serving path
    return causal and window == 0 and kv_valid is None and q.shape[1] == k.shape[1]


def mha(q, k, v, *, causal: bool, q_positions, kv_positions, kv_valid=None,
        window: int = 0, logit_dtype=jnp.float32):
    """Grouped-query attention.

    q: (B, S, H, hd); k/v: (B, T, K, hd_k/hd_v).  H must be a multiple of K.
    ``q_positions``/``kv_positions``: (B, S) / (B, T) absolute positions used
    for causal/window masking.  ``kv_valid``: optional (B, T) bool mask for
    cache slots beyond the current length.
    """
    if _use_pallas_attention(q, k, causal, window, kv_valid) and q.shape[-1] == v.shape[-1]:
        from repro.kernels.flash_attention import flash_attention

        return flash_attention(q, k, v, causal=True, interpret=False)
    from repro.distributed import ctx

    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    if ctx.attn_seq_enabled():
        mesh = ctx.get_mesh()
        tp = mesh.shape["model"]
        if H % tp != 0 and S % tp == 0:
            # head sharding unavailable (e.g. 56 heads on a 16-way TP axis):
            # sequence-shard Q BEFORE the contraction so the (S, T) score
            # tensor is born sequence-sharded — otherwise GSPMD partially
            # shards heads and all-reduces the full score tensor per layer
            qg = ctx.constrain(qg, None, "model", None, None, None)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k, preferred_element_type=logit_dtype)
    scores = scores / math.sqrt(hd)
    mask = jnp.ones((B, S, T), bool)
    if causal:
        mask &= q_positions[:, :, None] >= kv_positions[:, None, :]
    if window:
        mask &= q_positions[:, :, None] - kv_positions[:, None, :] < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, jnp.finfo(logit_dtype).min)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, v.shape[-1])


# ------------------------------------------------------------ GQA block
def init_gqa(key, cfg, d_model: Optional[int] = None):
    a = cfg.attention
    d = d_model or cfg.d_model
    dt = param_dtype(cfg)
    ks = jax.random.split(key, 4)
    qd, kvd = a.num_heads * a.head_dim, a.num_kv_heads * a.head_dim
    p = {
        "wq": dense_init(ks[0], (d, qd), dtype=dt),
        "wk": dense_init(ks[1], (d, kvd), dtype=dt),
        "wv": dense_init(ks[2], (d, kvd), dtype=dt),
        "wo": dense_init(ks[3], (qd, d), dtype=dt),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dt)
        p["bk"] = jnp.zeros((kvd,), dt)
        p["bv"] = jnp.zeros((kvd,), dt)
    if a.qk_norm:
        p["q_norm"] = jnp.ones((a.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((a.head_dim,), jnp.float32)
    return p


def gqa_project_qkv(p, cfg, x):
    a = cfg.attention
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, a.num_heads, a.head_dim)
    k = k.reshape(B, S, a.num_kv_heads, a.head_dim)
    v = v.reshape(B, S, a.num_kv_heads, a.head_dim)
    if a.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_attend(p, cfg, x, positions, *, causal=True, rope=True,
               kv_override=None, kv_positions=None, kv_valid=None):
    """Full (training/prefill) attention.  ``kv_override``: (k, v) for
    cross-attention."""
    a = cfg.attention
    q, k, v = gqa_project_qkv(p, cfg, x)
    if kv_override is not None:
        k, v = kv_override
    if rope and kv_override is None:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    kv_pos = kv_positions if kv_positions is not None else positions
    out = mha(q, k, v, causal=causal, q_positions=positions, kv_positions=kv_pos,
              kv_valid=kv_valid, window=a.window if a.kind == "local" else 0)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"]


def gqa_decode(p, cfg, x, cache_k, cache_v, pos, *, rope=True, window: int = 0):
    """One-token decode against a preallocated KV cache.

    x: (B, 1, d); cache_k/v: (B, T, K, hd); pos: scalar int32 current length.
    Returns (out (B,1,d), new_k, new_v).
    """
    a = cfg.attention
    B = x.shape[0]
    q, k, v = gqa_project_qkv(p, cfg, x)
    positions = jnp.full((B, 1), pos, jnp.int32)
    if rope:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    T = cache_k.shape[1]
    if window and T >= window:
        # cache is sized exactly to the window -> ring buffer indexing
        slot = jnp.mod(pos, window)
        cache_k = lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
        cache_v = lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
        # ring buffer: slot i holds position pos-slot+i (i<=slot) else one
        # window earlier
        Tw = cache_k.shape[1]
        idx = jnp.arange(Tw)[None, :]
        kv_positions = jnp.where(idx <= slot, idx + (pos - slot), idx + (pos - slot) - Tw)
        kv_positions = jnp.broadcast_to(kv_positions, (B, Tw)).astype(jnp.int32)
        kv_valid = (kv_positions >= 0) & (kv_positions <= pos)
    else:
        cache_k = lax.dynamic_update_slice(cache_k, k, (0, pos, 0, 0))
        cache_v = lax.dynamic_update_slice(cache_v, v, (0, pos, 0, 0))
        kv_positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
        kv_valid = kv_positions <= pos
        kv_positions = kv_positions.astype(jnp.int32)
    out = mha(q, cache_k, cache_v, causal=False, q_positions=positions,
              kv_positions=kv_positions, kv_valid=kv_valid)
    return out.reshape(B, 1, -1) @ p["wo"], cache_k, cache_v


# --------------------------------------------------------------- gated MLP
def init_mlp(key, cfg, d_ff: Optional[int] = None, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = param_dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, (d, f), dtype=dt),
        "wi": dense_init(k2, (d, f), dtype=dt),
        "wo": dense_init(k3, (f, d), dtype=dt),
    }


def mlp_apply(p, cfg, x):
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    return (act(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


# ------------------------------------------------------------- embeddings
def init_embed(key, cfg):
    dt = param_dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embed": dense_init(k1, (cfg.vocab_size, cfg.d_model), in_axis=-1, dtype=dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype=dt)
    return p


def embed_tokens(p, cfg, tokens):
    from repro.distributed import ctx

    x = p["embed"][tokens]
    if cfg.gemma_scaling:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return ctx.constrain_tokens(x)


def lm_logits(p, cfg, x):
    from repro.distributed import ctx

    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    return ctx.constrain_logits(logits)


def cross_entropy(logits, labels, mask=None):
    """logits (B,S,V) fp32, labels (B,S) int32; mask optional (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ------------------------------------------------------------- scan helper
def scan_layers(fn, x, stacked_params, *extra, remat: bool = False, length=None):
    """Run ``fn(x, layer_params, *extra_slice) -> x`` over a stacked layer dim.

    The carry (residual stream) is re-anchored to the batch sharding every
    layer so GSPMD propagation cannot drift under the production mesh."""
    from repro.distributed import ctx

    def anchored(carry, *xs):
        return ctx.constrain_tokens(fn(carry, *xs))

    f = jax.checkpoint(anchored) if remat else anchored

    def body(carry, xs):
        return f(carry, *xs), None

    out, _ = lax.scan(body, x, (stacked_params, *extra), length=length)
    return out


def stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
