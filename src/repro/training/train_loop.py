"""Generic train step across all model families.

``make_train_step`` builds a jittable ``train_step(params, opt_state, batch)``
with gradient accumulation (``cfg.accum_steps`` microbatches via lax.scan) —
this bounds live activation memory for the 100B+-class dry-run cells.  Grads
are accumulated in fp32; the optimizer update happens once per global step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.registry import get_family
from repro.training import optim


def make_loss_fn(cfg):
    fam = get_family(cfg)

    def loss_fn(params, batch):
        l, aux = fam.loss(params, cfg, batch)
        return l, aux

    return loss_fn


def make_train_step(cfg, *, lr=1e-4, weight_decay=0.0):
    loss_fn = make_loss_fn(cfg)
    accum = max(1, cfg.accum_steps)
    acc_dtype = jnp.dtype(cfg.grad_accum_dtype)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            # reshape leading batch dim into (accum, B/accum, ...)
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

            micro = jax.tree.map(split, batch)
            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)

            def body(carry, mb):
                gsum, lsum = carry
                (l, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(acc_dtype), gsum, g)
                return (gsum, lsum + l), None

            (gsum, lsum), _ = lax.scan(body, (gzero, jnp.zeros((), jnp.float32)), micro)
            # divide in the accumulation dtype; optimizers upcast per-leaf, so
            # no full-size f32 grads tree is ever materialized
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        if cfg.optimizer == "adafactor":
            new_params, new_opt = optim.adafactor_update(params, grads, opt_state, lr=lr)
        else:
            new_params, new_opt = optim.adamw_update(
                params, grads, opt_state, lr=lr, weight_decay=weight_decay
            )
        return new_params, new_opt, {"loss": loss}

    return train_step


def init_opt_state(cfg, params):
    if cfg.optimizer == "adafactor":
        return optim.adafactor_init(params)
    return optim.adamw_init(params)


def init_train_state(cfg, key):
    fam = get_family(cfg)
    params = fam.init(key, cfg)
    return params, init_opt_state(cfg, params)
