"""Proxy-model trainers: linear SVM (hinge) and shallow NN, pure JAX.

These are the cheap classifiers M inside a proxy model sigma-hat.  Training
is a jitted full-batch GD ``lax.scan`` — milliseconds per proxy — replacing
the paper's scikit-learn / keras step.  Class imbalance is handled with
inverse-frequency loss weights (the paper re-samples; weighting is the
deterministic equivalent).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class LinearParams(NamedTuple):
    w: jnp.ndarray  # (F,)
    b: jnp.ndarray  # ()
    mean: jnp.ndarray  # (F,) feature standardization
    scale: jnp.ndarray  # (F,)


class MLPParams(NamedTuple):
    w1: jnp.ndarray
    b1: jnp.ndarray
    w2: jnp.ndarray
    b2: jnp.ndarray
    mean: jnp.ndarray
    scale: jnp.ndarray


class PackedProxy(NamedTuple):
    """Family-agnostic device format of ONE proxy: a folded depth-1 MLP.

    Every proxy family lowers to ``score(x) = relu(x @ w1 + b1) @ w2 + b2``
    with the feature standardizer already folded into ``(w1, b1)`` — this is
    the only form the fused cascade kernel understands.  ``hidden`` is the
    family's true hidden width before any cascade-level bucket padding.
    """

    w1: np.ndarray  # (F, hidden) folded hidden weights
    b1: np.ndarray  # (hidden,)
    w2: np.ndarray  # (hidden,) readout weights
    b2: np.float32  # () readout bias
    hidden: int


def pack_linear(params: LinearParams) -> PackedProxy:
    """Linear proxies pack exactly via the +/- trick: with hidden units
    ``(z, -z)`` and readout ``(+1, -1)``, ``relu(z) - relu(-z) == z``
    bit-for-bit (one term is always exactly zero), so the packed scorer is
    bit-identical to the affine scorer."""
    w = (np.asarray(params.w, np.float32)
         / np.asarray(params.scale, np.float32)).astype(np.float32)
    b = np.float32(float(params.b) - float(np.asarray(params.mean) @ w))
    w1 = np.stack([w, -w], axis=1)  # (F, 2)
    b1 = np.asarray([b, -b], np.float32)
    w2 = np.asarray([1.0, -1.0], np.float32)
    return PackedProxy(w1=w1, b1=b1, w2=w2, b2=np.float32(0.0), hidden=2)


def pack_mlp(params: MLPParams) -> PackedProxy:
    """Depth-1 MLP: fold the standardizer into the first layer —
    ``((x - mean) / scale) @ w1 == x @ (w1 / scale[:, None]) - (mean / scale) @ w1``."""
    scale = np.asarray(params.scale, np.float32)[:, None]
    w1 = (np.asarray(params.w1, np.float32) / scale).astype(np.float32)
    b1 = (np.asarray(params.b1, np.float32)
          - (np.asarray(params.mean, np.float32) / np.asarray(params.scale, np.float32))
          @ np.asarray(params.w1, np.float32)).astype(np.float32)
    return PackedProxy(
        w1=w1, b1=b1, w2=np.asarray(params.w2, np.float32),
        b2=np.float32(params.b2), hidden=int(w1.shape[1]),
    )


def packed_score(packed: PackedProxy, x: np.ndarray) -> np.ndarray:
    """Reference evaluation of the packed form (numpy, no kernel)."""
    h = np.maximum(x.astype(np.float32) @ packed.w1 + packed.b1, 0.0)
    return h @ packed.w2 + packed.b2


def _standardizer(x):
    mean = jnp.mean(x, axis=0)
    scale = jnp.std(x, axis=0) + 1e-6
    return mean, scale


@partial(jax.jit, static_argnames=("steps",))
def train_linear_svm(x, y, *, steps: int = 200, lr: float = 0.1, l2: float = 1e-4):
    """x: (N, F) float32; y: (N,) in {-1, +1}.  Returns LinearParams."""
    mean, scale = _standardizer(x)
    xs = (x - mean) / scale
    n_pos = jnp.maximum(jnp.sum(y > 0), 1)
    n_neg = jnp.maximum(jnp.sum(y < 0), 1)
    wts = jnp.where(y > 0, x.shape[0] / (2.0 * n_pos), x.shape[0] / (2.0 * n_neg))

    def loss_fn(p):
        w, b = p
        margin = y * (xs @ w + b)
        hinge = jnp.maximum(0.0, 1.0 - margin)
        return jnp.mean(wts * hinge) + l2 * jnp.sum(w * w)

    def step(carry, _):
        p, m = carry
        g = jax.grad(loss_fn)(p)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        p = jax.tree.map(lambda pp, mm: pp - lr * mm, p, m)
        return (p, m), None

    w0 = jnp.zeros(x.shape[1], jnp.float32)
    p0 = (w0, jnp.zeros((), jnp.float32))
    m0 = jax.tree.map(jnp.zeros_like, p0)
    (p, _), _ = jax.lax.scan(step, (p0, m0), None, length=steps)
    return LinearParams(w=p[0], b=p[1], mean=mean, scale=scale)


@jax.jit
def linear_score(params: LinearParams, x):
    xs = (x - params.mean) / params.scale
    return xs @ params.w + params.b


@partial(jax.jit, static_argnames=("steps", "hidden"))
def train_mlp(x, y, key, *, steps: int = 300, hidden: int = 32, lr: float = 0.05):
    """Shallow NN proxy: 1 hidden layer, BCE loss.  y in {-1, +1}."""
    mean, scale = _standardizer(x)
    xs = (x - mean) / scale
    yb = (y > 0).astype(jnp.float32)
    n_pos = jnp.maximum(jnp.sum(yb), 1.0)
    n_neg = jnp.maximum(jnp.sum(1 - yb), 1.0)
    wts = jnp.where(yb > 0, x.shape[0] / (2 * n_pos), x.shape[0] / (2 * n_neg))
    k1, k2 = jax.random.split(key)
    F = x.shape[1]
    p0 = (
        jax.random.normal(k1, (F, hidden)) / jnp.sqrt(F),
        jnp.zeros(hidden),
        jax.random.normal(k2, (hidden,)) / jnp.sqrt(hidden),
        jnp.zeros(()),
    )

    def logits_fn(p, xx):
        w1, b1, w2, b2 = p
        h = jax.nn.relu(xx @ w1 + b1)
        return h @ w2 + b2

    def loss_fn(p):
        lg = logits_fn(p, xs)
        ce = jnp.maximum(lg, 0) - lg * yb + jnp.log1p(jnp.exp(-jnp.abs(lg)))
        return jnp.mean(wts * ce)

    def step(carry, _):
        p, m = carry
        g = jax.grad(loss_fn)(p)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        p = jax.tree.map(lambda pp, mm: pp - lr * mm, p, m)
        return (p, m), None

    m0 = jax.tree.map(jnp.zeros_like, p0)
    (p, _), _ = jax.lax.scan(step, (p0, m0), None, length=steps)
    return MLPParams(w1=p[0], b1=p[1], w2=p[2], b2=p[3], mean=mean, scale=scale)


@jax.jit
def mlp_score(params: MLPParams, x):
    xs = (x - params.mean) / params.scale
    h = jax.nn.relu(xs @ params.w1 + params.b1)
    return h @ params.w2 + params.b2


def f1_score(scores: np.ndarray, y: np.ndarray, threshold: float = 0.0) -> float:
    """F1 of sign(score - threshold) vs y in {-1,+1} (used by the
    epsilon-approximate classifier-reuse test, Eq. 4.7)."""
    pred = scores >= threshold
    pos = y > 0
    tp = float(np.sum(pred & pos))
    fp = float(np.sum(pred & ~pos))
    fn = float(np.sum(~pred & pos))
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom else 1.0
