"""Pure-JAX optimizers (pytree-of-arrays state, no external deps).

AdamW is the backbone trainer; SGD(+momentum) is used by the linear-SVM
proxy trainer.  States are plain pytrees so the checkpointer and the
sharding rules treat them uniformly with params.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object  # pytree like params (fp32)
    nu: object  # pytree like params (fp32)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, *, lr=1e-4, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        update = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


class AdafactorState(NamedTuple):
    """Factored second-moment optimizer (Shazeer & Stern, 2018) — the
    memory-efficient choice for 100B+ models: ~0 extra bytes/param for
    matrices (row+col factors) vs Adam's 8."""

    step: jnp.ndarray
    vr: object  # row factors (or full v for <2D leaves)
    vc: object  # col factors (None placeholder for <2D leaves)


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def rows(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def cols(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,) * max(p.ndim, 1), jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(rows, params),
        vc=jax.tree.map(cols, params),
    )


def adafactor_update(params, grads, state: AdafactorState, *, lr=1e-4,
                     decay=0.8, eps=1e-30, clip_threshold=1.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t**(-decay)

    def upd(p, g, vr, vc):
        gf = g.astype(jnp.float32)
        g2 = jnp.square(gf) + eps
        if _factored(p):
            vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            r = vr2 / jnp.maximum(jnp.mean(vr2, axis=-1, keepdims=True), eps)
            update = gf / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc2)[..., None, :] + eps)
        else:
            vr2 = beta2 * vr + (1 - beta2) * g2
            vc2 = vc
            update = gf / (jnp.sqrt(vr2) + eps)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-12)
        scale = lr / jnp.maximum(1.0, rms / clip_threshold)
        # apply in the param dtype: no full-f32 update tree is materialized
        return (p - (scale * update).astype(p.dtype)).astype(p.dtype), vr2, vc2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_r = treedef.flatten_up_to(state.vr)
    flat_c = treedef.flatten_up_to(state.vc)
    out = [upd(p, g, r, c) for p, g, r, c in zip(flat_p, flat_g, flat_r, flat_c)]
    return (
        treedef.unflatten([o[0] for o in out]),
        AdafactorState(
            step=step,
            vr=treedef.unflatten([o[1] for o in out]),
            vc=treedef.unflatten([o[2] for o in out]),
        ),
    )


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: object


def sgd_init(params) -> SGDState:
    return SGDState(
        step=jnp.zeros((), jnp.int32),
        momentum=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    )


def sgd_update(params, grads, state: SGDState, *, lr=1e-2, momentum=0.9,
               weight_decay=0.0):
    def upd(p, g, m):
        gf = g.astype(jnp.float32)
        if weight_decay:
            gf = gf + weight_decay * p.astype(jnp.float32)
        m2 = momentum * m + gf
        return (p.astype(jnp.float32) - lr * m2).astype(p.dtype), m2

    new = jax.tree.map(upd, params, grads, state.momentum)
    new_p = jax.tree.map(lambda t: t[0], new, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], new, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, SGDState(step=state.step + 1, momentum=new_m)
