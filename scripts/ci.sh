#!/usr/bin/env bash
# Tiered CI lanes: tier-1 tests + regression gates (fused proxy scoring,
# adaptive serving, K=4 sharded serving, fault-tolerance scenarios,
# quantized cascade, SLO-aware serving front end with goodput gating,
# cross-query plan cache with similarity warm-start + multi-donor
# blending, multi-query CoreSession with shared fused scoring /
# cross-query UDF dedupe / weighted-fair scheduling).
#
#   scripts/ci.sh                          default: lint + tier1 + bench
#   scripts/ci.sh --lane fast              iteration lane (no @slow/@flaky)
#   scripts/ci.sh --lane tier1,fast        comma-separated / repeated lanes
#   scripts/ci.sh --lane bench --quick     quick benchmark workload
#   scripts/ci.sh --lane slow              only @slow/@flaky tests
#   scripts/ci.sh --lane lint              corelint + protocol model checker
#   scripts/ci.sh --lane all               lint + tier1 + bench + slow
#   scripts/ci.sh --fast                   back-compat: fast + quick bench
#
# Lanes:
#   tier1  python -m pytest -x -q          (the ROADMAP tier-1 command)
#   fast   pytest -m "not slow and not flaky"
#   bench  benchmarks/check_regression.py  (prints the gate delta table)
#   slow   pytest -m "slow or flaky"       (subprocess fleets, wall-clock)
#   lint   scripts/corelint.py (invariant lint, zero non-baselined
#          findings) + repro.analysis.protocol_check (exhaustive bounded
#          swap/failover/fence model check) + pyflakes when available
#
# Every requested lane runs even if an earlier one failed; the lane
# report at the end lists per-lane wall time and status, and the script
# exits nonzero if ANY lane failed.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

LANES=()
BENCH_ARGS=()
while [ $# -gt 0 ]; do
  case "$1" in
    --lane)
      shift
      IFS=',' read -ra _L <<<"${1:?--lane needs a value}"
      LANES+=("${_L[@]}")
      ;;
    --lane=*)
      IFS=',' read -ra _L <<<"${1#--lane=}"
      LANES+=("${_L[@]}")
      ;;
    --fast) LANES+=(fast bench); BENCH_ARGS+=(--quick) ;;
    --quick) BENCH_ARGS+=(--quick) ;;
    *) BENCH_ARGS+=("$1") ;;
  esac
  shift
done
[ ${#LANES[@]} -eq 0 ] && LANES=(lint tier1 bench)

EXPANDED=()
for lane in "${LANES[@]}"; do
  if [ "$lane" = "all" ]; then
    EXPANDED+=(lint tier1 bench slow)
  else
    EXPANDED+=("$lane")
  fi
done

NAMES=()
RCS=()
SECS=()

lint_lane() {
  python scripts/corelint.py || return 1
  python -m repro.analysis.protocol_check || return 1
  if python -c "import pyflakes" >/dev/null 2>&1; then
    # advisory: bare pyflakes has no suppression syntax, so intentional
    # side-effect imports (ml_dtypes dtype registration) would hard-fail;
    # corelint and the protocol checker are the gating checks.
    python -m pyflakes src || echo "pyflakes findings above are advisory"
  else
    # pyflakes is optional (not baked into every image); corelint and the
    # protocol checker still gate.
    echo "pyflakes unavailable; skipped"
  fi
}

run_lane() {
  local name="$1"
  shift
  echo
  echo "== lane: $name =="
  local t0=$SECONDS
  "$@"
  local rc=$?
  NAMES+=("$name")
  RCS+=("$rc")
  SECS+=("$((SECONDS - t0))")
}

for lane in "${EXPANDED[@]}"; do
  case "$lane" in
    tier1) run_lane tier1 python -m pytest -x -q ;;
    fast) run_lane fast python -m pytest -q -m "not slow and not flaky" ;;
    slow) run_lane slow python -m pytest -q -m "slow or flaky" ;;
    bench) run_lane bench python benchmarks/check_regression.py \
      ${BENCH_ARGS[@]+"${BENCH_ARGS[@]}"} ;;
    lint) run_lane lint lint_lane ;;
    *)
      echo "unknown lane: $lane (lint|tier1|fast|bench|slow|all)" >&2
      NAMES+=("$lane"); RCS+=(2); SECS+=(0)
      ;;
  esac
done

echo
echo "== lane report =="
FAILED=0
for i in "${!NAMES[@]}"; do
  if [ "${RCS[$i]}" -eq 0 ]; then
    status="OK"
  else
    status="FAIL (rc=${RCS[$i]})"
    FAILED=1
  fi
  printf '  %-8s %6ss  %s\n' "${NAMES[$i]}" "${SECS[$i]}" "$status"
done

if [ "$FAILED" -ne 0 ]; then
  echo "CI FAILED"
  exit 1
fi
echo "CI OK"
