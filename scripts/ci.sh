#!/usr/bin/env bash
# Single-entry CI: tier-1 tests + fused-proxy-throughput regression gate.
#   scripts/ci.sh           full run
#   scripts/ci.sh --quick   smaller benchmark workload
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== fused proxy-scoring regression gate =="
python benchmarks/check_regression.py "$@"

echo "CI OK"
