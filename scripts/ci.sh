#!/usr/bin/env bash
# Single-entry CI: tier-1 tests + regression gates (fused proxy scoring,
# adaptive serving, K=4 sharded serving with quorum-voted swaps).
#   scripts/ci.sh           full run
#   scripts/ci.sh --quick   smaller benchmark workload
#   scripts/ci.sh --fast    iteration lane: skip @slow tests, quick benchmarks
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=()
BENCH_ARGS=()
for a in "$@"; do
  case "$a" in
    --fast) PYTEST_ARGS+=(-m "not slow"); BENCH_ARGS+=(--quick) ;;
    *) BENCH_ARGS+=("$a") ;;
  esac
done

echo "== tier-1 tests =="
python -m pytest -x -q ${PYTEST_ARGS[@]+"${PYTEST_ARGS[@]}"}

echo "== regression gates (fused scoring + adaptive + sharded serving) =="
python benchmarks/check_regression.py ${BENCH_ARGS[@]+"${BENCH_ARGS[@]}"}

echo "CI OK"
