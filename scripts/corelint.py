#!/usr/bin/env python
"""corelint CLI — invariant lint over the repo (DESIGN.md §9).

Usage (from the repo root)::

    PYTHONPATH=src python scripts/corelint.py                # lint src/ + benchmarks/
    PYTHONPATH=src python scripts/corelint.py src/repro/core # lint a subtree
    PYTHONPATH=src python scripts/corelint.py --json         # machine-readable
    PYTHONPATH=src python scripts/corelint.py --write-baseline  # accept current findings

Exit status is 1 iff any non-baselined violation remains, so CI can gate
on it directly.  The checked-in baseline (``corelint_baseline.json``) is
intentionally empty — keep it that way by fixing or explicitly
suppressing new findings, not by re-baselining.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis.corelint import (  # noqa: E402
    RULES,
    load_baseline,
    run_corelint,
    write_baseline,
)

DEFAULT_PATHS = ["src", "benchmarks"]
DEFAULT_BASELINE = REPO_ROOT / "corelint_baseline.json"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("paths", nargs="*", help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    parser.add_argument("--baseline", default=str(DEFAULT_BASELINE), help="baseline JSON path")
    parser.add_argument("--no-baseline", action="store_true", help="report all findings unmasked")
    parser.add_argument(
        "--write-baseline", action="store_true", help="record current findings as the baseline"
    )
    parser.add_argument("--json", action="store_true", help="emit a JSON report to stdout")
    parser.add_argument(
        "--list-rules",
        "--explain",
        action="store_true",
        dest="list_rules",
        help="print the rule catalog (each rule's origin bug) and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in RULES:
            print(f"{rule.id}: {rule.summary}")
            print(f"    origin: {rule.origin}")
        return 0

    paths = [REPO_ROOT / p for p in (args.paths or DEFAULT_PATHS)]
    baseline = {} if args.no_baseline or args.write_baseline else load_baseline(args.baseline)
    report = run_corelint(paths, root=REPO_ROOT, baseline=baseline)

    if args.write_baseline:
        counts = write_baseline(args.baseline, report.violations)
        n = sum(c for rules in counts.values() for c in rules.values())
        print(f"corelint: wrote baseline with {n} finding(s) to {args.baseline}")
        return 0

    if args.json:
        print(
            json.dumps(
                {
                    "violations": [v.__dict__ for v in report.violations],
                    "suppressed": report.suppressed,
                    "baselined": report.baselined,
                    "files_scanned": report.files_scanned,
                    "parse_errors": report.parse_errors,
                },
                indent=2,
            )
        )
    else:
        for v in report.violations:
            print(v.format())
        for err in report.parse_errors:
            print(f"corelint: parse error: {err}", file=sys.stderr)
        print(
            f"corelint: {len(report.violations)} violation(s) "
            f"({report.suppressed} suppressed, {report.baselined} baselined) "
            f"across {report.files_scanned} file(s)",
            file=sys.stderr,
        )
    return 1 if report.violations or report.parse_errors else 0


if __name__ == "__main__":
    sys.exit(main())
