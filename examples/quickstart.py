"""Quickstart: optimize an ML inference query with CORE and execute it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import execute_plan, optimize, orig_plan, plan_accuracy, query_correlation
from repro.data.synthetic import make_dataset, make_query, make_udfs


def main():
    # 1. a correlated record stream + two expensive ML UDFs
    ds = make_dataset(name="tweets", n=20_000, correlation=0.9, seed=0)
    udfs = make_udfs(ds, hidden=64, depth=2, train_rows=3000, seed=0,
                     declared_cost_ms=20.0)
    print(f"dataset: {ds.n} records, predicate correlation kappa^2 = "
          f"{query_correlation(ds.truth[:, :2]):.2f}")

    # 2. the query:  SELECT .. WHERE udf0(t) IN {..} AND udf1(t) IN {..}  [A=0.9]
    query = make_query(ds, udfs, columns=[0, 1], target_selectivity=0.5,
                       accuracy_target=0.9, seed=1)
    print("query:", " AND ".join(query.names()), f" target A={query.accuracy_target}")

    # 3. CORE optimizes ONLINE on the first k% of the stream
    k = 1500
    plan = optimize(query, ds.x[:k], mode="core")
    print("\noptimized plan:")
    print(plan.describe())
    print("optimizer stats:", plan.meta["stats"])

    # 4. execute on the remaining stream; compare with ORIG
    rest = ds.x[k:]
    orig = execute_plan(orig_plan(query), rest)
    res = execute_plan(plan, rest)
    print(f"\nORIG cost: {orig.cost_per_record(len(rest)):.3f} ms/record")
    print(f"CORE cost: {res.cost_per_record(len(rest)):.3f} ms/record "
          f"({(1 - res.model_cost_ms / orig.model_cost_ms):.1%} saved)")
    print(f"empirical accuracy vs ORIG: {plan_accuracy(res, orig):.3f}")


if __name__ == "__main__":
    main()
