"""END-TO-END SERVING DRIVER: CORE-accelerated inference queries where the
expensive UDFs are REAL transformer backbones (reduced configs of the
assigned architectures), served with continuous batching.

Pipeline:
  1. build two classifier UDFs: random-projected features -> reduced
     llama-family / qwen3-moe-family backbone -> pooled head; train each for
     a few hundred steps with the pure-JAX AdamW substrate;
  2. CORE builds correlative proxy models online;
  3. the CascadeServer streams batched requests through the optimized
     cascade (proxies gate the transformer UDFs, full tiles only).

    PYTHONPATH=src python examples/transformer_udf_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import MLUDF, execute_plan, optimize, orig_plan, plan_accuracy
from repro.core.query import Predicate, Query
from repro.data.synthetic import make_dataset
from repro.models.registry import get_family
from repro.serving.engine import CascadeServer
from repro.training import optim

SEQ = 8


def make_backbone_udf(arch: str, ds, column: int, *, steps: int = 150, seed: int = 0):
    """Train `reduced(arch)` as a classifier head over the record features."""
    cfg = reduced_config(arch).replace(remat=False)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "backbone": fam.init(k1, cfg),
        "proj": jax.random.normal(k2, (ds.x.shape[1], SEQ * cfg.d_model)) * 0.05,
        "head": jax.random.normal(k3, (cfg.d_model, int(ds.truth[:, column].max()) + 1)) * 0.05,
    }
    n_classes = int(ds.truth[:, column].max()) + 1

    def logits_fn(p, x):
        h = (x @ p["proj"]).reshape(x.shape[0], SEQ, cfg.d_model).astype(jnp.bfloat16)
        # run the backbone trunk over projected "token" embeddings
        if cfg.family == "moe":
            from repro.models import moe as M

            positions = jnp.broadcast_to(jnp.arange(SEQ)[None], (x.shape[0], SEQ))
            h, _aux = M.backbone(p["backbone"], cfg, h, positions)
        else:
            from repro.models import transformer as T

            positions = jnp.broadcast_to(jnp.arange(SEQ)[None], (x.shape[0], SEQ))
            h = T.backbone(p["backbone"], cfg, h, positions)
        pooled = jnp.mean(h.astype(jnp.float32), axis=1)
        return pooled @ p["head"]

    y = jnp.asarray(ds.truth[:2000, column])
    xtr = jnp.asarray(ds.x[:2000])

    def loss_fn(p):
        lg = logits_fn(p, xtr)
        return jnp.mean(jax.nn.logsumexp(lg, 1) - jnp.take_along_axis(lg, y[:, None], 1)[:, 0])

    opt = optim.adamw_init(params)

    @jax.jit
    def step(p, o):
        l, g = jax.value_and_grad(loss_fn)(p)
        p, o = optim.adamw_update(p, g, o, lr=3e-3)
        return p, o, l

    for i in range(steps):
        params, opt, l = step(params, opt)
    acc = float(jnp.mean(jnp.argmax(logits_fn(params, xtr), -1) == y))
    print(f"  UDF[{arch}] col{column}: train loss {float(l):.3f}, acc {acc:.3f}")

    infer = jax.jit(lambda x: jnp.argmax(logits_fn(params, x), axis=-1))
    probe = jnp.asarray(ds.x[:512])
    infer(probe).block_until_ready()
    t0 = time.perf_counter()
    infer(probe).block_until_ready()
    cost_ms = (time.perf_counter() - t0) / 512 * 1e3

    def fn(x):
        # bucket-pad to power-of-two batches: the cascade produces ragged
        # survivor batches, and unpadded shapes would recompile the backbone
        # for every new size (the classic serving pitfall)
        n = x.shape[0]
        b = 256
        while b < n:
            b *= 2
        xp = np.zeros((b, x.shape[1]), np.float32)
        xp[:n] = x
        return np.asarray(infer(jnp.asarray(xp)))[:n]

    return MLUDF(name=f"{arch}:col{column}", fn=fn, cost=cost_ms, n_classes=n_classes)


def main():
    print("building correlated record stream...")
    ds = make_dataset(name="stream", n=12_000, correlation=0.92, n_classes=3,
                      feature_noise=1.0, seed=4)
    print("training transformer-backbone UDFs (pure-JAX AdamW)...")
    udf0 = make_backbone_udf("llama3-405b", ds, 0, steps=100, seed=1)  # reduced llama
    udf1 = make_backbone_udf("qwen3-moe-30b-a3b", ds, 1, steps=100, seed=2)  # reduced MoE
    q = Query(
        predicates=[
            Predicate(udf=udf0, values=frozenset({0, 1})),
            Predicate(udf=udf1, values=frozenset({0})),
        ],
        accuracy_target=0.9,
    )
    print("query:", " AND ".join(q.names()))

    k = 2000
    plan = optimize(q, ds.x[:k], mode="core")
    print(plan.describe())

    print("\nserving the remaining stream with continuous batching...")
    server = CascadeServer(plan, tile=512, use_kernel=True)
    stats = server.run_stream(ds.x[k:], chunk=2048)
    print(f"emitted {stats.emitted} / {len(ds.x) - k} records "
          f"in {stats.wall_ms:.0f} ms wall")
    print(f"UDF batches per stage: {stats.stage_udf_batches}; "
          f"stage inputs: {stats.stage_in}")

    orig = execute_plan(orig_plan(q), ds.x[k:])
    res = execute_plan(plan, ds.x[k:])
    print(f"cost model: ORIG {orig.model_cost_ms:.0f} ms -> CORE {res.model_cost_ms:.0f} ms "
          f"({1 - res.model_cost_ms / orig.model_cost_ms:.1%} saved); "
          f"accuracy {plan_accuracy(res, orig):.3f}")


if __name__ == "__main__":
    main()
