"""Fault-tolerant training of a reduced assigned architecture with the full
substrate: sharded data pipeline, AdamW, async checkpointing, simulated
preemption + restart, straggler detection.

    PYTHONPATH=src python examples/resilient_training.py [--arch mamba2-2.7b]
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import reduced_config
from repro.data.pipeline import ShardedStream
from repro.distributed.fault_tolerance import ResilientRunner, StragglerDetector
from repro.models.registry import make_batch
from repro.training.train_loop import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-67b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    fam_step = jax.jit(make_train_step(cfg, lr=1e-3))
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    tokens = rng.randint(0, cfg.vocab_size, size=(4096, 33)).astype(np.int32)
    stream = iter(ShardedStream(tokens, batch=8, seed=0))

    ckdir = tempfile.mkdtemp(prefix="ckpt_")
    ck = Checkpointer(ckdir, keep=2)
    losses = []
    fail_once = {"armed": True}

    def step_fn(state, step):
        if step == args.steps // 2 and fail_once["armed"]:
            fail_once["armed"] = False
            raise RuntimeError("simulated preemption")
        p, o = state
        seqs = next(stream)
        batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        if cfg.family == "encdec":
            batch = make_batch(cfg, 8, 32, jax.random.PRNGKey(step))
        if cfg.family == "vlm":
            batch = make_batch(cfg, 8, 40, jax.random.PRNGKey(step))
        p, o, m = fam_step(p, o, batch)
        losses.append(float(m["loss"]))
        return (p, o)

    saved = {}

    def save_fn(step, state):
        ck.save(step, state, blocking=False)
        saved["latest"] = step
        print(f"  checkpoint @ step {step}")

    def restore_fn():
        step = ck.latest_step()
        state = ck.restore((params, opt), step)
        print(f"  RESTORED from step {step}")
        return step, state

    save_fn(0, (params, opt))
    runner = ResilientRunner(
        step_fn, save_fn, restore_fn, checkpoint_every=10,
        straggler=StragglerDetector(threshold=3.0),
    )
    state, report = runner.run((params, opt), args.steps)
    print(f"\narch={args.arch}: {report.steps_done} steps, "
          f"{report.restarts} restart(s), {report.straggler_events} straggler event(s)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ewma step time {report.final_step_time_ewma*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
