"""Three-predicate video-style cascade: shows the branch-and-bound order
search (Algorithm 2) against CORE-a / CORE-h, with the optimizer-cost
decomposition (Table 5 in miniature).

    PYTHONPATH=src python examples/video_cascade.py
"""
import numpy as np

from repro.core import execute_plan, optimize, orig_plan, plan_accuracy
from repro.data.synthetic import make_dataset, make_query, make_udfs


def main():
    ds = make_dataset(name="ucf", n=10_000, n_features=96, correlation=0.95,
                      feature_noise=1.1, seed=7)
    # heterogeneous UDF costs: activity recognition >> object detection > tagger
    udfs = make_udfs(ds, hidden=48, depth=2, train_rows=2500, seed=7,
                     declared_cost_ms=100.0, cost_scale={0: 2.0, 1: 0.2, 2: 1.0, 3: 0.5})
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=8)
    print("query:", " AND ".join(q.names()))

    k = 1500
    rest = ds.x[k:]
    orig = execute_plan(orig_plan(q), rest)
    for mode in ("core-a", "core-h", "core"):
        plan = optimize(q, ds.x[:k], mode=mode, step=0.05)
        res = execute_plan(plan, rest)
        st = plan.meta["stats"]
        extra = ""
        if "trace" in plan.meta:
            tr = plan.meta["trace"]
            extra = (f" | B&B visited {tr['nodes_visited']}/{tr['nodes_total']} nodes"
                     f" ({tr['nodes_pruned_frac']:.0%} pruned)")
        print(
            f"{mode:7s} order={plan.order} exec={res.cost_per_record(len(rest)):7.3f} ms/rec "
            f"acc={plan_accuracy(res, orig):.3f} "
            f"QO: label {st['labeling_ms']:.0f}ms train {st['training_ms']:.0f}ms "
            f"search {st['search_ms']:.0f}ms{extra}"
        )
    print(f"ORIG    exec={orig.cost_per_record(len(rest)):7.3f} ms/rec")


if __name__ == "__main__":
    main()
