"""SLO-aware serving front-end benchmark (DESIGN.md §7): goodput vs raw
throughput under overload, with and without backpressure.

The workload is an offline arrival trace on the cost-model clock:
requests of ``rows_per`` records arrive Poisson at ~1.3x the full plan's
Eq. 3.1 capacity (mild sustained overload — the regime the backpressure
policy exists for), each carrying the same reference SLO.  Gated by
``check_regression.py``:

  * ``goodput_ratio`` — requests meeting the SLO / requests completed
    with backpressure ON (degrade ladder + deadline shedding), floor
    0.9: under overload the ladder sacrifices trailing cascade stages so
    almost every request still lands inside its deadline.
  * ``goodput_ratio_nobp`` — the SAME trace with backpressure OFF is the
    control: the queue grows without bound, per-request latency diverges,
    and the ratio collapses (ceiling-gated ≤ 0.5) — the gap between the
    two runs is the whole point of the front end.
  * ``frontend_conserved`` — every submitted record is exactly one of
    {emitted, rejected, explicitly shed}; ``in_flight() == 0`` after the
    drain; no shed record ever emitted.  Checked on BOTH runs and on the
    K=4 sharded run below.
  * ``frontend_sharded_swaps`` — the K=4 fleet submits through per-host
    front ends (shed-only backpressure: plan versions stay pinned to
    quorum epochs) while a drifting stream forces a quorum-voted plan
    swap: the request path and the consensus path compose, conservation
    holding across the epoch install.

Every gated number is cost-model/seeded (no wall-clock), so runs are
deterministic per host.
"""
from __future__ import annotations

import numpy as np

from repro.core import optimize
from repro.data.synthetic import (
    make_dataset,
    make_query,
    make_sharded_drifting_streams,
    make_udfs,
)
from repro.serving.engine import CascadeServer
from repro.serving.frontend import ServingFrontEnd, SLOPolicy
from repro.serving.stats import AdaptivePolicy

# reference point: deadline = SLO_FACTOR x the full plan's per-request
# Eq. 3.1 cost; arrivals at OVERLOAD x the full plan's capacity
SLO_FACTOR = 3.0
OVERLOAD = 1.3


def _workload(seed: int = 41):
    ds = make_dataset(n=12_000, n_features=64, n_columns=3, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=seed)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1200, seed=seed,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=seed + 1)
    plan = optimize(q, ds.x[:1500], mode="core", step=0.05)
    return ds, q, plan


def _arrival_trace(plan, n_req: int, rows_per: int, seed: int):
    """Poisson arrivals at OVERLOAD x capacity; deadline = SLO_FACTOR x
    the per-request full-plan cost.  Seeded -> identical every run."""
    req_ms = plan.est_total_cost * rows_per
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(req_ms / OVERLOAD, n_req))
    return arrivals, SLO_FACTOR * req_ms


def bench_frontend_goodput(*, n_req: int = 48, rows_per: int = 128,
                           seed: int = 7, tile: int = 256) -> dict:
    ds, _q, plan = _workload()
    arrivals, slo_ms = _arrival_trace(plan, n_req, rows_per, seed)
    base = 2_000  # request rows drawn past the optimizer's training slice

    def run(backpressure: bool):
        engine = CascadeServer(plan, tile=tile)
        fe = ServingFrontEnd(engine, policy=SLOPolicy(
            degrade=backpressure, shed_expired=backpressure))
        for r in range(n_req):
            idx = np.arange(base + r * rows_per, base + (r + 1) * rows_per)
            fe.submit_request(idx, ds.x[idx], deadline_ms=slo_ms,
                              arrival_ms=float(arrivals[r]))
        st = fe.run()
        ok, why = fe.conserved()
        lat = [q.latency_ms for q in fe.requests.values() if q.done]
        return fe, st, ok, why, lat

    fe_on, on, ok_on, why_on, lat_on = run(True)
    _fe, off, ok_off, why_off, lat_off = run(False)
    return {
        "n_requests": n_req,
        "rows_per_request": rows_per,
        "slo_ms": float(slo_ms),
        "arrival_rate_per_s": 1e3 * OVERLOAD / (plan.est_total_cost * rows_per),
        # ---- backpressure ON (the gated configuration) ----
        "goodput_ratio": float(on.goodput_ratio),
        "goodput_rps": float(on.goodput_rps),
        "throughput_rps": float(on.throughput_rps),
        "p95_latency_ms": float(np.percentile(lat_on, 95)),
        "degrades": on.degrades,
        "restores": on.restores,
        "records_shed": on.records_shed,
        "requests_shed": on.requests_shed,
        # ---- backpressure OFF (the collapse control) ----
        "goodput_ratio_nobp": float(off.goodput_ratio),
        "p95_latency_ms_nobp": float(np.percentile(lat_off, 95)),
        "conserved": int(ok_on and ok_off),
        "conserved_why": f"on:{why_on};off:{why_off}",
    }


def bench_frontend_sharded(*, seed: int = 41) -> dict:
    """K=4 fleet, every host submitting through a shed-only front end,
    drifting stream -> at least one quorum-voted plan swap must commit
    THROUGH the request path with conservation intact."""
    ds, q, _plan = _workload(seed)
    plan = optimize(q, ds.x[:1500], mode="core", step=0.05, keep_state=True)
    streams = make_sharded_drifting_streams(
        ds, 4, 800, 2400, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, drift_skew=0.3, seed=seed)
    from repro.distributed.serving import ShardedCascadeServer

    srv = ShardedCascadeServer(
        plan, 4, tile=256, seed=3,
        policy=AdaptivePolicy(cooldown_records=1024, min_reservoir=128,
                              threshold=50.0, audit_rate=0.03,
                              reservoir_capacity=512),
        slo_ms=1e6)  # generous SLO: the gate here is composition, not shed
    for h in srv.hosts:
        h.track_versions = True
    st = srv.run_streams([s.x for s in streams], chunk=400)
    shed = sum(f.records_shed for f in st.frontend_stats)
    conserved = st.submitted == st.emitted + st.rejected + shed
    for h in srv.hosts:
        ok, _why = h.frontend.conserved()
        conserved = conserved and ok and h.engine.in_flight() == 0
        for i, v in zip(h.engine.emitted, h.engine.emitted_versions):
            # emitted under the version current at submission — the swap
            # happened mid-request-stream, so this is the cross-check
            conserved = conserved and h.submit_version.get(i) == v
    return {
        "swaps_committed": st.swaps_committed,
        "final_epoch": st.final_epoch,
        "records_shed": shed,
        "fleet_goodput_ratio": float(st.fleet_goodput_ratio),
        "conserved": int(conserved),
    }


def run(quick: bool = True):
    from benchmarks.common import csv_row

    out = bench_frontend_goodput(n_req=32 if quick else 48)
    csv_row(
        "serving_frontend_goodput", out["goodput_ratio"],
        (
            f"nobp={out['goodput_ratio_nobp']:.2f};"
            f"slo={out['slo_ms']:.0f}ms;degr={out['degrades']};"
            f"shed={out['records_shed']};p95={out['p95_latency_ms']:.0f}ms"
        ),
    )
    sh = bench_frontend_sharded()
    csv_row(
        "serving_frontend_sharded", float(sh["swaps_committed"]),
        (
            f"epoch={sh['final_epoch']};conserved={sh['conserved']};"
            f"fleet_gr={sh['fleet_goodput_ratio']:.2f}"
        ),
    )
    out["sharded"] = sh
    return out


if __name__ == "__main__":
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    print(json.dumps(run(quick="--quick" in sys.argv[1:]), indent=2))
