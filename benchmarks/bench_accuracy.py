"""Fig. 14 + Table 6: effect of the target accuracy A on execution cost and
optimization cost."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import build_queries, build_workload, csv_row, evaluate_all


def run(quick: bool = True):
    targets = (0.90, 0.94, 0.98) if quick else (0.90, 0.92, 0.94, 0.96, 0.98)
    w = build_workload("twitter", 0.9, seed=15)
    base_q = build_queries(w, 1, n_preds=(2,), seed=16)[0]
    for A in targets:
        q = dataclasses.replace(base_q, accuracy_target=A) if dataclasses.is_dataclass(base_q) else base_q
        q.accuracy_target = A
        res = evaluate_all(w, q)
        for m in ("orig", "ns", "pp", "core"):
            csv_row(
                f"fig14_A{int(A*100)}_{m}", res[m]["cost_per_record_ms"] * 1e3,
                (
                    f"exec_ms_per_rec={res[m]['cost_per_record_ms']:.3f};"
                    f"acc={res[m]['accuracy']:.3f};qo_ms={res[m]['qo_ms']:.0f};"
                    f"qo_pct={100*res[m]['qo_ms']/max(res[m]['total_ms'],1e-9):.2f}%"
                ),
            )


if __name__ == "__main__":
    run()
