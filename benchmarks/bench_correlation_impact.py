"""Fig. 2 + Fig. 9: the impact of predicate correlation.

(a) Fig 2 — PP's OFFLINE reduction estimate for the 2nd filter vs its
    EMPIRICAL reduction after sigma-hat_1 AND sigma_1, for a strongly and a
    weakly correlated query.  Strong correlation -> overestimate.
(b) Fig 9 — average execution cost of ORIG/NS/PP/CORE over strongly vs
    weakly correlated query sets.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_queries, build_workload, csv_row, evaluate_all
from repro.core import ProxyBuilder, execute_plan, orig_plan, query_correlation


def fig2_estimate_vs_empirical(correlation: float, seed: int = 1):
    w = build_workload("twitter", correlation, seed=seed)
    queries = build_queries(w, 1, n_preds=(2,), seed=seed)
    q = queries[0]
    b = ProxyBuilder(q, w.x_opt, seed=seed)
    # PP: second proxy trained on RAW input (independence assumption)
    p1, _ = b.get_proxy(0, (), ())
    p2_raw, _ = b.get_proxy(1, (), ())
    rows = []
    x = w.x_exec[:10000]
    for alpha in (0.90, 0.95, 0.99):
        est = p2_raw.r_curve.reduction_for(alpha)
        # empirical: apply sigma-hat_1 ^ sigma_1 first, then p2_raw's threshold
        keep1 = p1.mask(x, alpha)
        labels1 = q.predicates[0].udf(x[keep1])
        sat1 = q.predicates[0].evaluate(labels1)
        x2 = x[keep1][sat1]
        thr = p2_raw.r_curve.threshold_for(alpha)
        emp = float(np.mean(p2_raw.score(x2) < thr)) if len(x2) else 0.0
        rows.append((alpha, est, emp))
    return rows


def run(quick: bool = True):
    print("# Fig 2: estimated vs empirical reduction of the 2nd PP filter")
    for corr, label in ((0.95, "strong"), (0.1, "weak")):
        for alpha, est, emp in fig2_estimate_vs_empirical(corr):
            over = est - emp
            csv_row(
                f"fig2_{label}_alpha{alpha:.2f}", 0.0,
                f"est_reduction={est:.3f};empirical={emp:.3f};overestimate={over:+.3f}",
            )

    print("# Fig 9: avg execution cost, strong vs weak correlation")
    n_q = 2 if quick else 10
    for corr, label in ((0.98, "strong"), (0.1, "weak")):
        w = build_workload("twitter", corr, seed=2)
        queries = build_queries(w, n_q, n_preds=(3,), seed=3)
        kappa = query_correlation(w.ds.truth)
        agg = {m: [] for m in ("orig", "ns", "pp", "core")}
        accs = {m: [] for m in agg}
        for q in queries:
            res = evaluate_all(w, q)
            for m in agg:
                agg[m].append(res[m]["cost_per_record_ms"])
                accs[m].append(res[m]["accuracy"])
        for m in agg:
            mean_ms = float(np.mean(agg[m]))
            red = 1 - mean_ms / float(np.mean(agg["orig"]))
            vs_pp = 1 - mean_ms / float(np.mean(agg["pp"]))
            csv_row(
                f"fig9_{label}_{m}", mean_ms * 1e3,
                (
                    f"kappa2={kappa:.2f};cost_ms_per_rec={mean_ms:.3f};"
                    f"reduction_vs_orig={red:.1%};vs_pp={vs_pp:+.1%};"
                    f"acc={np.mean(accs[m]):.3f}"
                ),
            )


if __name__ == "__main__":
    run()
