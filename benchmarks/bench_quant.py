"""Quantized-cascade benchmark: the numbers behind the quant gates.

Measures, for one mixed MLP cascade plan:

  * ``quant_fused_speedup`` — the bandwidth-bound speedup of the int8
    packed cascade over the fp32 one at a serving chunk, computed from
    the EXACT operand bytes the fused kernel streams per launch (the
    bucket-padded x tile, the lane-padded packed weights at storage
    width, the keep-mask output).  Modeled, not wall-clock: in this
    container Pallas runs in interpret mode, where timing measures the
    Python interpreter, so the byte ratio — which IS what bounds the
    kernel at serving batch sizes on real hardware — is the
    host-independent gate, and wall-clock rides along as an advisory.
  * the quant-parity gate — decision flips only within the calibrated
    threshold tolerance, bounded selectivity deltas
    (``kernels.ops.quant_parity_report``).
  * end-to-end cascade accuracy delta fp32 vs quantized through
    ``execute_plan`` (same plan, meta-stamped dtype).
  * the autotune sweep — tuned (block_m, dtype) beats the old static
    heuristic on >= 2 of 3 workload shapes, and repeat lookups hit the
    config cache instead of re-sweeping.

Run directly for a human-readable report:

    PYTHONPATH=src python benchmarks/bench_quant.py
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path

import numpy as np

SWEEP_JSON = Path(__file__).resolve().parent.parent / "results" / \
    "autotune_sweep.json"


def _ceil128(n: int) -> int:
    return -(-int(n) // 128) * 128


def serving_bytes(scorer, n_rows: int) -> int:
    """Exact bytes the masks-only serving path streams for one launch:
    bucket-padded x tile + keep-mask output + the lane-padded packed
    weights at their storage width + the f32 bias/threshold/scale rows."""
    from repro.core.proxy_family import QUANT_WEIGHT_BYTES

    hpp = _ceil128(int(scorer.w1.shape[1]))
    pp = _ceil128(scorer.n_proxies)
    wb = QUANT_WEIGHT_BYTES[scorer.dtype]
    npad = scorer._bucket(n_rows)
    return (npad * scorer.n_features * 4      # x tile (f32)
            + npad * pp                        # keep-mask output (bool)
            + scorer.n_features * hpp * wb     # w1 stacked hidden weights
            + hpp * 4                          # b1 (f32, scale-folded)
            + hpp * pp * wb                    # w2 block-diagonal readout
            + 3 * pp * 4)                      # b2 + thresholds + out_scale


def _wall_ms(scorer, x, repeats: int = 5) -> float:
    scorer.score_masks(x)  # warm the jit cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        scorer.score_masks(x)
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def bench_quant(dtype: str = "int8", chunk: int = 256,
                n_rows: int = 12_000) -> dict:
    from repro.core import execute_plan, optimize, orig_plan
    from repro.data.synthetic import make_dataset, make_query, make_udfs
    from repro.kernels import autotune
    from repro.kernels.ops import CascadeScorer, quant_parity_report

    ds = make_dataset(n=n_rows, n_columns=6, correlation=0.85, seed=7)
    udfs = make_udfs(ds, hidden=32, depth=1, train_rows=2_000, seed=7,
                     declared_cost_ms=5.0)
    q = make_query(ds, udfs, columns=[0, 1, 2, 3, 4, 5],
                   target_selectivity=0.5, seed=8)
    sample = ds.x[:2_000]
    plan_f = optimize(q, sample, mode="core-a", kind="mlp")
    plan_q = dataclasses.replace(
        plan_f, meta={**plan_f.meta, "quant_dtype": dtype})

    # same tiling for both sides: the gate compares storage width, not
    # block choice (autotune would pick the same block here anyway)
    scorer_f = CascadeScorer.from_plan(plan_f, max_tile=1024,
                                       n_rows_hint=chunk)
    scorer_q = CascadeScorer.from_plan(plan_q, max_tile=1024,
                                       n_rows_hint=chunk)
    assert scorer_f.dtype == "float32" and scorer_q.dtype == dtype
    bytes_f = serving_bytes(scorer_f, chunk)
    bytes_q = serving_bytes(scorer_q, chunk)
    speedup = bytes_f / bytes_q

    eval_x = ds.x[2_000:]
    parity = quant_parity_report(plan_f, eval_x, dtype=dtype)

    # end-to-end: same records through the full cascade (proxy gates +
    # UDF escalation), fp32 vs quantized scorer, accuracy vs exact ORIG
    truth = set(execute_plan(orig_plan(q), eval_x).passed.tolist())
    res_f = execute_plan(plan_f, eval_x)
    res_q = execute_plan(plan_q, eval_x)
    acc_f = sum(1 for i in res_f.passed.tolist() if i in truth) / max(
        len(truth), 1)
    acc_q = sum(1 for i in res_q.passed.tolist() if i in truth) / max(
        len(truth), 1)

    wall_f = _wall_ms(scorer_f, eval_x[:chunk])
    wall_q = _wall_ms(scorer_q, eval_x[:chunk])

    # autotune: sweep the three gate shapes, then prove repeat lookups
    # are cache hits (serving re-installs must skip the sweep)
    from benchmarks.roofline import SWEEP_SHAPES

    autotune.clear_autotune_cache()
    autotune.reset_autotune_stats()
    rows = autotune.sweep_table(SWEEP_SHAPES, dtypes=("float32", dtype))
    wins = {}
    for r in rows:
        wins.setdefault(r["shape"], False)
        wins[r["shape"]] |= bool(r["beats_static"])
    before = autotune.autotune_stats()
    rerun = autotune.sweep_table(SWEEP_SHAPES, dtypes=("float32", dtype))
    after = autotune.autotune_stats()
    cache_hit = (after["sweeps"] == before["sweeps"]
                 and after["hits"] >= len(rerun))

    mbu_rows = [r for r in rows
                if r["dtype"] == dtype and r["n_rows"] == chunk]
    return {
        "dtype": dtype,
        "chunk": chunk,
        "n_stages": len(plan_f.stages),
        "hp": int(scorer_f.w1.shape[1]),
        "bytes_fp32": int(bytes_f),
        "bytes_quant": int(bytes_q),
        "quant_fused_speedup": float(speedup),
        "wall_ms_fp32": wall_f,
        "wall_ms_quant": wall_q,
        "parity": parity,
        "accuracy_fp32": float(acc_f),
        "accuracy_quant": float(acc_q),
        "accuracy_delta": float(abs(acc_f - acc_q)),
        "autotune_wins": int(sum(wins.values())),
        "autotune_shapes": len(wins),
        "autotune_cache_hit": bool(cache_hit),
        "autotune_mbu": float(np.mean([r["mbu"] for r in mbu_rows])
                              if mbu_rows else 0.0),
        "sweep_rows": rows,
    }


def main():
    out = bench_quant()
    p = out["parity"]
    print(f"plan: {out['n_stages']} MLP stages, HP={out['hp']}, "
          f"chunk={out['chunk']}")
    print(f"quant_fused_speedup ({out['dtype']}): "
          f"{out['quant_fused_speedup']:.2f}x  "
          f"({out['bytes_fp32'] / 1024:.0f} KB -> "
          f"{out['bytes_quant'] / 1024:.0f} KB per launch)")
    print(f"wall-clock advisory: fp32 {out['wall_ms_fp32']:.2f} ms, "
          f"{out['dtype']} {out['wall_ms_quant']:.2f} ms (interpret mode)")
    print(f"parity: tol={p['tol']:.4f} flips={p['n_flips']}/{p['n_eval']} "
          f"within_tol={p['flips_within_tol']} "
          f"max_sel_delta={p['max_sel_delta']:.4f}")
    print(f"end-to-end accuracy: fp32 {out['accuracy_fp32']:.4f} vs "
          f"{out['dtype']} {out['accuracy_quant']:.4f} "
          f"(delta {out['accuracy_delta']:.4f})")
    print(f"autotune: beats static on {out['autotune_wins']}/"
          f"{out['autotune_shapes']} shapes, cache_hit="
          f"{out['autotune_cache_hit']}, MBU={out['autotune_mbu']:.3f}")


if __name__ == "__main__":
    import sys

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    main()
