"""Multi-query session benchmark (DESIGN.md §10): N=4 overlapping
cascade queries through one ``CoreSession`` vs 4 isolated servers.

Gated claims (``check_regression.py``):

  * ``multiquery_speedup`` >= 1.5 — the session's aggregate cost-model
    throughput over the 4-query workload beats the sum of 4 isolated
    ``CascadeServer`` runs by at least 1.5x.  The win is structural, not
    a timer artifact: identical ``(udf, value)`` predicate evaluations
    across queries are deduped through the session's UDF result cache
    (every query pair here shares at least one predicate), so the
    Eq. 3.1 cost the session pays is a strict subset of what the
    isolated servers pay;
  * ``multiquery_emissions_match`` — every query's emitted-id multiset
    is IDENTICAL to its isolated run's.  Stacked scoring rides the
    block-diagonal packed readout, so a column's score has exact-zero
    cross-query terms and the masks are bit-identical — which also
    pins served accuracy to exactly the isolated value;
  * ``multiquery_conserved`` — per-query conservation (submitted ==
    emitted + rejected, nothing in flight) holds through the shared
    scheduler;
  * ``multiquery_fairness`` — weighted-fair scheduling: min over
    tenants of (device time / weight) normalized by the max.  A starved
    tenant drives this toward 0; the WFQ virtual-clock keeps backlogged
    tenants' normalized service within a constant of each other;
  * ``multiquery_dedupe_rate`` — recorded (not floored): the UDF result
    cache hit rate over the run, the denominator of the speedup story.

All quantities ride the deterministic cost-model clock; the only wall
reads are advisory.
"""
from __future__ import annotations

import numpy as np

from repro.core import CoreSession, OptimizeOptions, execute_plan, orig_plan
from repro.data.synthetic import make_dataset, make_query, make_udfs
from repro.serving.engine import CascadeServer

#: every pair of queries overlaps on at least one predicate column, so
#: cross-query dedupe has work on each tenant's cascade tail
QUERY_COLUMNS = ([0, 1], [1, 2], [0, 2], [0, 1, 2])


def bench_multiquery(*, seed: int = 3, n: int = 8000) -> dict:
    ds = make_dataset(n=n, correlation=0.9, seed=seed)
    udfs = make_udfs(ds, hidden=24, depth=1, train_rows=1200, seed=seed,
                     declared_cost_ms=10.0)
    queries = [make_query(ds, udfs, columns=list(c), seed=11 + i)
               for i, c in enumerate(QUERY_COLUMNS)]
    x_sample = ds.x[:1200]
    x_serve = ds.x[1200:6000]

    session = CoreSession(options=OptimizeOptions(step=0.05, seed=seed))
    handles = [session.register_query(q, x_sample) for q in queries]
    eng = session.serve()
    session.run_stream(x_serve, chunk=1024)
    conserved, conserve_msg = eng.conserved()
    session_cost = eng.model_cost_ms()

    # isolated baseline: the SAME plans, one server each, no sharing —
    # the denominator of the aggregate-throughput claim
    iso_cost = 0.0
    emissions_match = True
    accuracies = []
    for h, q in zip(handles, queries):
        srv = CascadeServer(h.plan, tile=1024, use_kernel=True,
                            seed=seed + 101 * h.qid)
        st = srv.run_stream(x_serve, chunk=1024)
        iso_cost += st.model_cost_ms
        shared = eng.servers[h.qid].emitted
        emissions_match &= sorted(srv.emitted) == sorted(shared)
        orig_set = set(execute_plan(orig_plan(q), x_serve).passed.tolist())
        accuracies.append(sum(1 for i in shared if i in orig_set)
                          / max(len(orig_set), 1))

    speedup = iso_cost / max(session_cost, 1e-9)
    st = eng.session_stats()
    sched = st["scheduler"]
    norm = [sched["served_cost_ms"][h.qid] / sched["weights"][h.qid]
            for h in handles]
    fairness = min(norm) / max(max(norm), 1e-9)
    ded = st["dedupe"]
    return {
        "n_queries": len(queries),
        "speedup": float(speedup),
        "session_cost_ms": float(session_cost),
        "isolated_cost_ms": float(iso_cost),
        "conserved": bool(conserved),
        "conserve_msg": conserve_msg,
        "emissions_match": bool(emissions_match),
        "accuracies": [float(a) for a in accuracies],
        "accuracy_targets": [float(q.accuracy_target) for q in queries],
        "fairness": float(fairness),
        "dedupe_rate": float(ded["hit_rate"]),
        "dedupe_saved_cost_ms": float(ded["saved_cost_ms"]),
        "shared_cols": int(st["shared_cols"]),
        "restacks": int(st["restacks"]),
        "service_quanta": int(sched["grants"]),
        "per_query_emitted": [len(s) for s in eng.emitted],
    }


def run(quick: bool = True):
    from benchmarks.common import csv_row

    out = bench_multiquery()
    csv_row(
        "multiquery_session", float(out["speedup"]),
        (
            f"n_queries={out['n_queries']};"
            f"fairness={out['fairness']:.3f};"
            f"dedupe_rate={out['dedupe_rate']:.3f};"
            f"conserved={int(out['conserved'])};"
            f"emissions_match={int(out['emissions_match'])}"
        ),
    )
    return out


if __name__ == "__main__":
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    print(json.dumps(run(quick="--quick" in sys.argv[1:]), indent=2))
