"""CI regression gate for the fused proxy-scoring hot path, the adaptive
serving loop, K=4 sharded serving, the fault-tolerance scenarios, the
quantized packed cascade, the SLO-aware serving front end, the
cross-query plan cache (including multi-donor warm-start blending), and
the multi-query CoreSession.

Runs the components benchmark's proxy-throughput measurement, the
drifting-stream adaptive-serving benchmark, the K=4 quorum-swap fleet
benchmark, the three fault-tolerance scenarios (coordinator failover
mid-epoch, straggler fencing, pooled-kappa² escalation), the
quantized-cascade benchmark (int8 bytes-moved speedup, decision-flip
parity, autotune sweep), the serving-front-end goodput benchmark
(SLO goodput under overload with backpressure on vs the no-backpressure
collapse control, plus conservation through a K=4 quorum swap), and the
plan-cache benchmark (warm-start node reduction at equal Eq. 3.1 cost,
exact-repeat replay ratio, dissimilarity fallback, byte-stable
persistence), writes ``BENCH_components.json`` at the repo
root plus the autotune sweep table under ``results/autotune_sweep.json``
(the nightly CI artifact), prints a unified **before/after delta table**
for every gated metric (baseline recorded value vs this run, floor,
margin, status), and exits nonzero when any ENFORCED gate regresses
against the checked-in baseline
(``benchmarks/baseline_components.json``).

Gate classes:

  * architectural invariants (speedups, protocol correctness booleans) —
    host-independent, always enforced;
  * absolute wall-clock floors — host-dependent, ADVISORY unless pinned
    via the corresponding ``REGRESSION_*`` env override.

Usage:
  python benchmarks/check_regression.py [--quick] [--update-baseline]

``--update-baseline`` rewrites the ``recorded_*`` fields of
``baseline_components.json`` from this run (floors and the comment are
preserved) — the intentional re-baselining path after a known perf
change, instead of hand-editing JSON.  With the flag set, gate failures
are reported but do not fail the process.

Env overrides: REGRESSION_MIN_ROWS_PER_S, REGRESSION_MIN_SPEEDUP,
REGRESSION_MIN_MLP_SPEEDUP, REGRESSION_MIN_ADAPTIVE_SPEEDUP,
REGRESSION_MIN_SHARDED_SPEEDUP, REGRESSION_MAX_CONSENSUS_MS,
REGRESSION_MIN_QUANT_SPEEDUP, REGRESSION_MIN_GOODPUT_RATIO,
REGRESSION_MIN_MULTIQUERY_SPEEDUP.
"""
from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.bench_adaptive import bench_adaptive_throughput  # noqa: E402
from benchmarks.bench_components import (  # noqa: E402
    BENCH_JSON,
    bench_mlp_throughput,
    bench_proxy_throughput,
    write_bench_json,
)
from benchmarks.bench_multiquery import bench_multiquery  # noqa: E402
from benchmarks.bench_plan_cache import (  # noqa: E402
    bench_multidonor,
    bench_plan_cache,
)
from benchmarks.bench_quant import SWEEP_JSON, bench_quant  # noqa: E402
from benchmarks.bench_serving_frontend import (  # noqa: E402
    bench_frontend_goodput,
    bench_frontend_sharded,
)
from benchmarks.bench_sharded import (  # noqa: E402
    bench_fault_tolerance,
    bench_sharded_throughput,
)
from repro.analysis.corelint import load_baseline, run_corelint  # noqa: E402
from repro.analysis.protocol_check import CheckConfig, check  # noqa: E402
from repro.util import atomic_write_text  # noqa: E402

REPO_ROOT = Path(__file__).resolve().parent.parent
CORELINT_BASELINE = REPO_ROOT / "corelint_baseline.json"


def run_static_analysis() -> dict:
    """The lint-lane checks, as gated metrics: corelint must be clean
    (zero non-baselined findings over src/ + benchmarks/) and the strict
    swap-protocol model check must hold over a state space at least as
    large as the recorded one — a shrinking space means the enumeration
    silently lost reach, which would let a protocol regression hide."""
    lint = run_corelint([REPO_ROOT / "src", REPO_ROOT / "benchmarks"],
                        root=REPO_ROOT,
                        baseline=load_baseline(CORELINT_BASELINE))
    strict = check(CheckConfig(n_hosts=3))
    legacy = check(CheckConfig(n_hosts=3, legacy_acks=True))
    return {
        "lint_violations": len(lint.violations),
        "lint_suppressed": lint.suppressed,
        "lint_baselined": lint.baselined,
        "lint_files_scanned": lint.files_scanned,
        "protocol_safe": bool(strict.violation is None
                              and all(strict.witnesses.values())),
        "protocol_states_explored": strict.states_explored,
        "protocol_transitions": strict.transitions,
        "protocol_witnesses": strict.witnesses,
        # the checker must still FIND the pre-attempt-nonce bug, or it
        # has lost its teeth
        "protocol_teeth": bool(legacy.violation is not None),
    }

BASELINE = Path(__file__).resolve().parent / "baseline_components.json"


@dataclass
class Gate:
    """One gated metric: a current value checked against a floor (or
    ceiling), with the baseline's recorded value alongside for the
    before/after delta table."""

    name: str
    current: float
    floor: Optional[float]  # None = informational row, never fails
    recorded: Optional[float] = None  # baseline value (before)
    higher_is_better: bool = True
    enforced: bool = True  # False = advisory (warn, don't fail)
    fmt: str = "{:.2f}"
    record_key: Optional[str] = None  # baseline key --update-baseline rewrites

    @property
    def ok(self) -> bool:
        if self.floor is None:
            return True
        return (self.current >= self.floor if self.higher_is_better
                else self.current <= self.floor)

    @property
    def margin(self) -> Optional[float]:
        if self.floor is None:
            return None
        return (self.current - self.floor if self.higher_is_better
                else self.floor - self.current)

    @property
    def status(self) -> str:
        if self.floor is None:
            return "info"
        if self.ok:
            return "OK" if self.enforced else "OK (advisory)"
        return "FAIL" if self.enforced else "WARN (advisory)"


def _print_delta_table(gates: List[Gate]) -> None:
    header = (f"{'metric':<34} {'baseline':>12} {'current':>12} "
              f"{'floor':>10} {'margin':>10}  status")
    print("\n== regression gate delta table (baseline vs this run) ==")
    print(header)
    print("-" * len(header))
    for g in gates:
        def fv(v):
            return "-" if v is None else g.fmt.format(v)

        print(f"{g.name:<34} {fv(g.recorded):>12} {fv(g.current):>12} "
              f"{fv(g.floor):>10} {fv(g.margin):>10}  {g.status}")
    print("-" * len(header))


def _update_baseline(base: dict, gates: List[Gate]) -> None:
    for g in gates:
        if g.record_key:
            # count-valued gates (fmt {:.0f}) stay ints in the baseline —
            # every Gate.current is a float, so type-sniffing would churn
            # recorded counts to 2.0/1.0 on each re-baseline
            base[g.record_key] = (int(round(g.current))
                                  if g.fmt == "{:.0f}"
                                  else round(g.current, 4))
    atomic_write_text(BASELINE, json.dumps(base, indent=2) + "\n")
    print(f"baseline updated: {BASELINE} "
          f"({sum(1 for g in gates if g.record_key)} recorded values)")


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    update_baseline = "--update-baseline" in argv
    throughput = bench_proxy_throughput(n_rows=24_576 if quick else 49_152)
    mlp = bench_mlp_throughput(n_rows=24_576 if quick else 49_152)
    # deliberately NOT shrunk by --quick: the 1.3x floor is an acceptance
    # invariant of the FULL drifting stream — a shorter drifted segment
    # dilutes the stale-plan span the adaptation amortizes against
    # (measured 1.25x at n_after=18k vs 1.38x at 30k), so a quick run
    # would fail the gate without any code regression
    adaptive = bench_adaptive_throughput()
    sharded = bench_sharded_throughput(
        n_before=1_500 if quick else 2_000,
        n_after=4_000 if quick else 6_000)
    # fixed-seed fixed-size scenarios: deterministic in --quick and full
    ft = bench_fault_tolerance()
    quant = bench_quant()
    # cost-model clock + seeded trace: deterministic per host; --quick
    # shortens the trace, both lengths sit well inside the gates
    fe = bench_frontend_goodput(n_req=32 if quick else 48)
    fes = bench_frontend_sharded()
    # fixed workload + seeds: node counts and costs deterministic per
    # environment, only the hit-ratio column is wall-clock
    pc = bench_plan_cache()
    md = bench_multidonor()
    # N=4 overlapping queries, one shared session vs 4 isolated servers;
    # all gated quantities ride the cost-model clock
    mq = bench_multiquery()
    sa = run_static_analysis()
    write_bench_json(throughput, adaptive, mlp, sharded, fault_tolerance=ft,
                     quant={k: v for k, v in quant.items()
                            if k != "sweep_rows"},
                     frontend={**fe, "sharded": fes},
                     plan_cache={**pc, "multidonor": md},
                     static_analysis=sa, multiquery=mq)
    print(f"wrote {BENCH_JSON}")
    SWEEP_JSON.parent.mkdir(parents=True, exist_ok=True)
    atomic_write_text(SWEEP_JSON, json.dumps(
        {"rows": quant["sweep_rows"],
         "wins": quant["autotune_wins"],
         "shapes": quant["autotune_shapes"]}, indent=1) + "\n")
    print(f"wrote {SWEEP_JSON}")

    base = json.loads(BASELINE.read_text())
    rows_env = os.environ.get("REGRESSION_MIN_ROWS_PER_S")
    min_rows = float(rows_env) if rows_env else float(base["min_fused_rows_per_s"])
    min_speedup = float(os.environ.get(
        "REGRESSION_MIN_SPEEDUP", base["min_speedup"]))
    min_mlp = float(os.environ.get(
        "REGRESSION_MIN_MLP_SPEEDUP", base["min_mlp_speedup"]))
    min_adaptive = float(os.environ.get(
        "REGRESSION_MIN_ADAPTIVE_SPEEDUP", base["min_adaptive_speedup"]))
    min_sharded = float(os.environ.get(
        "REGRESSION_MIN_SHARDED_SPEEDUP", base["min_sharded_speedup"]))
    consensus_env = os.environ.get("REGRESSION_MAX_CONSENSUS_MS")
    max_consensus = (float(consensus_env) if consensus_env
                     else float(base["advisory_max_consensus_ms"]))
    min_quant = float(os.environ.get(
        "REGRESSION_MIN_QUANT_SPEEDUP", base["min_quant_speedup"]))
    max_quant_acc_delta = float(base["max_quant_accuracy_delta"])
    min_goodput = float(os.environ.get(
        "REGRESSION_MIN_GOODPUT_RATIO", base["min_goodput_ratio"]))
    max_goodput_nobp = float(base["max_goodput_ratio_nobp"])
    max_hit_ratio = float(base["max_plan_cache_hit_ratio"])
    min_protocol_states = float(base["recorded_protocol_states"])
    min_multiquery = float(os.environ.get(
        "REGRESSION_MIN_MULTIQUERY_SPEEDUP", base["min_multiquery_speedup"]))
    min_mq_fairness = float(base["min_multiquery_fairness"])

    worst_consensus = max(sharded["consensus_ms_per_swap"] or [0.0])
    fo, strag, pooled = (ft["failover"], ft["straggler"], ft["pooled_kappa"])
    gates = [
        # ----- fused scoring hot path -----
        Gate("fused_rows_per_s", throughput["fused_rows_per_s"], min_rows,
             base.get("recorded_fused_rows_per_s"), fmt="{:.0f}",
             enforced=bool(rows_env), record_key="recorded_fused_rows_per_s"),
        Gate("fused_speedup", throughput["speedup"], min_speedup,
             base.get("recorded_speedup"), record_key="recorded_speedup"),
        Gate("fused_used_kernel", float(all(throughput["fused_used_kernel"])),
             1.0, 1.0, fmt="{:.0f}"),
        Gate("mlp_fused_speedup", mlp["mlp_fused_speedup"], min_mlp,
             base.get("recorded_mlp_fused_speedup"),
             record_key="recorded_mlp_fused_speedup"),
        Gate("mlp_used_kernel", float(all(mlp["fused_used_kernel"])),
             1.0, 1.0, fmt="{:.0f}"),
        # ----- adaptive serving -----
        Gate("adaptive_speedup", adaptive["adaptive_speedup"], min_adaptive,
             base.get("recorded_adaptive_speedup"),
             record_key="recorded_adaptive_speedup"),
        Gate("adaptive_accuracy", adaptive["adaptive_accuracy"],
             adaptive["accuracy_target"],
             base.get("recorded_adaptive_accuracy"), fmt="{:.3f}",
             record_key="recorded_adaptive_accuracy"),
        Gate("warm_bnb_nodes", float(adaptive["warm_nodes"]),
             float(adaptive["cold_nodes"] - 1),
             base.get("recorded_warm_nodes"), higher_is_better=False,
             fmt="{:.0f}", record_key="recorded_warm_nodes"),
        Gate("adaptive_plan_swaps", float(adaptive["plan_swaps"]), 1.0,
             None, fmt="{:.0f}"),
        # ----- sharded serving -----
        Gate("sharded_speedup", sharded["sharded_speedup"], min_sharded,
             base.get("recorded_sharded_speedup"),
             record_key="recorded_sharded_speedup"),
        Gate("sharded_swaps_committed", float(sharded["swaps_committed"]),
             1.0, base.get("recorded_sharded_swaps"), fmt="{:.0f}",
             record_key="recorded_sharded_swaps"),
        Gate("sharded_conserved", float(sharded["conserved"]), 1.0, 1.0,
             fmt="{:.0f}"),
        Gate("consensus_lag_records",
             float(sharded["consensus_lag_records"]), 0.0, 0.0,
             higher_is_better=False, fmt="{:.0f}"),
        Gate("worst_consensus_ms", worst_consensus, max_consensus,
             base.get("recorded_worst_consensus_ms"),
             higher_is_better=False, fmt="{:.1f}",
             enforced=bool(consensus_env),
             record_key="recorded_worst_consensus_ms"),
        # ----- fault tolerance: coordinator failover mid-epoch -----
        Gate("failover_count", float(fo["failovers"]), 1.0,
             base.get("recorded_failover_count"), fmt="{:.0f}",
             record_key="recorded_failover_count"),
        Gate("failover_swaps_committed", float(fo["swaps_committed"]), 1.0,
             base.get("recorded_failover_swaps"), fmt="{:.0f}",
             record_key="recorded_failover_swaps"),
        Gate("failover_conserved",
             float(fo["conserved"] and fo["epochs_agree"]), 1.0, 1.0,
             fmt="{:.0f}"),
        # ----- fault tolerance: straggler fencing -----
        Gate("straggler_commits_unblocked",
             float(strag["committed_while_fenced"]), 1.0, 1.0, fmt="{:.0f}"),
        Gate("straggler_resynced", float(strag["straggler_resynced"]), 1.0,
             base.get("recorded_straggler_resyncs"), fmt="{:.0f}",
             record_key="recorded_straggler_resyncs"),
        Gate("straggler_conserved",
             float(strag["conserved"] and strag["epochs_agree"]), 1.0, 1.0,
             fmt="{:.0f}"),
        # ----- fault tolerance: pooled kappa² escalation -----
        Gate("pooled_local_votes", float(pooled["votes_cast"]), 0.0, 0.0,
             higher_is_better=False, fmt="{:.0f}"),
        Gate("pooled_swaps_committed", float(pooled["pooled_swaps"]), 1.0,
             base.get("recorded_pooled_swaps"), fmt="{:.0f}",
             record_key="recorded_pooled_swaps"),
        Gate("pooled_escalated_bnb", float(pooled["all_bnb"]), 1.0, 1.0,
             fmt="{:.0f}"),
        Gate("pooled_conserved", float(pooled["conserved"]), 1.0, 1.0,
             fmt="{:.0f}"),
        # ----- quantized packed cascade (bytes-moved model; see
        # ----- bench_quant.py for why the speedup gate is modeled) -----
        Gate("quant_fused_speedup", quant["quant_fused_speedup"], min_quant,
             base.get("recorded_quant_speedup"),
             record_key="recorded_quant_speedup"),
        Gate("quant_parity_within_tol",
             float(quant["parity"]["flips_within_tol"]), 1.0, 1.0,
             fmt="{:.0f}"),
        Gate("quant_accuracy_delta", quant["accuracy_delta"],
             max_quant_acc_delta, base.get("recorded_quant_accuracy_delta"),
             higher_is_better=False, fmt="{:.4f}",
             record_key="recorded_quant_accuracy_delta"),
        Gate("quant_sel_delta", quant["parity"]["max_sel_delta"], None,
             None, fmt="{:.4f}"),
        Gate("quant_bytes_per_launch_kb",
             quant["bytes_quant"] / 1024.0, None, None, fmt="{:.0f}"),
        Gate("quant_mbu_advisory", quant["autotune_mbu"], None, None,
             fmt="{:.3f}"),
        Gate("autotune_beats_static_shapes", float(quant["autotune_wins"]),
             2.0, base.get("recorded_autotune_wins"), fmt="{:.0f}",
             record_key="recorded_autotune_wins"),
        Gate("autotune_cache_hit", float(quant["autotune_cache_hit"]),
             1.0, 1.0, fmt="{:.0f}"),
        # ----- SLO-aware serving front end (cost-model clock; see
        # ----- bench_serving_frontend.py for the trace construction) -----
        Gate("goodput_ratio", fe["goodput_ratio"], min_goodput,
             base.get("recorded_goodput_ratio"), fmt="{:.3f}",
             record_key="recorded_goodput_ratio"),
        Gate("goodput_ratio_nobp", fe["goodput_ratio_nobp"],
             max_goodput_nobp, base.get("recorded_goodput_ratio_nobp"),
             higher_is_better=False, fmt="{:.3f}",
             record_key="recorded_goodput_ratio_nobp"),
        Gate("frontend_conserved", float(fe["conserved"]), 1.0, 1.0,
             fmt="{:.0f}"),
        Gate("frontend_p95_latency_ms", fe["p95_latency_ms"], None, None,
             fmt="{:.0f}"),
        Gate("frontend_records_shed", float(fe["records_shed"]), None, None,
             fmt="{:.0f}"),
        Gate("frontend_sharded_swaps", float(fes["swaps_committed"]), 1.0,
             base.get("recorded_frontend_sharded_swaps"), fmt="{:.0f}",
             record_key="recorded_frontend_sharded_swaps"),
        Gate("frontend_sharded_conserved", float(fes["conserved"]), 1.0,
             1.0, fmt="{:.0f}"),
        # ----- cross-query plan cache (see bench_plan_cache.py) -----
        Gate("plan_cache_warm_nodes", float(pc["warm_nodes"]),
             float(pc["cold_nodes"] - 1),
             base.get("recorded_plan_cache_warm_nodes"),
             higher_is_better=False, fmt="{:.0f}",
             record_key="recorded_plan_cache_warm_nodes"),
        Gate("plan_cache_cold_nodes", float(pc["cold_nodes"]), None,
             base.get("recorded_plan_cache_cold_nodes"), fmt="{:.0f}",
             record_key="recorded_plan_cache_cold_nodes"),
        Gate("plan_cache_same_cost", float(pc["same_cost"]), 1.0, 1.0,
             fmt="{:.0f}"),
        Gate("plan_cache_hit_build_ratio", pc["hit_build_ratio"],
             max_hit_ratio, base.get("recorded_plan_cache_hit_ratio"),
             higher_is_better=False, fmt="{:.4f}",
             record_key="recorded_plan_cache_hit_ratio"),
        Gate("plan_cache_dissimilar_cold",
             float(pc["dissimilar_cold"]
                   and pc["dissimilar_accuracy_cached"]
                   >= pc["dissimilar_accuracy_uncached"] - 1e-9),
             1.0, 1.0, fmt="{:.0f}"),
        Gate("plan_cache_roundtrip_stable", float(pc["roundtrip_stable"]),
             1.0, 1.0, fmt="{:.0f}"),
        # ----- multi-donor warm-start blending (bench_plan_cache.py) -----
        Gate("multidonor_warm_le_single",
             float(md["multi_le_single"] and md["same_cost"]
                   and md["multi_path"] == "warm"), 1.0, 1.0, fmt="{:.0f}"),
        Gate("multidonor_warm_nodes", float(md["multi_donor_nodes"]),
             float(md["single_donor_nodes"]),
             base.get("recorded_multidonor_warm_nodes"),
             higher_is_better=False, fmt="{:.0f}",
             record_key="recorded_multidonor_warm_nodes"),
        Gate("multidonor_donors_used", float(md["multi_donors_used"]),
             2.0, None, fmt="{:.0f}"),
        # ----- multi-query session (see bench_multiquery.py) -----
        Gate("multiquery_speedup", mq["speedup"], min_multiquery,
             base.get("recorded_multiquery_speedup"),
             record_key="recorded_multiquery_speedup"),
        Gate("multiquery_conserved", float(mq["conserved"]), 1.0, 1.0,
             fmt="{:.0f}"),
        Gate("multiquery_emissions_match", float(mq["emissions_match"]),
             1.0, 1.0, fmt="{:.0f}"),
        Gate("multiquery_fairness", mq["fairness"], min_mq_fairness,
             base.get("recorded_multiquery_fairness"), fmt="{:.3f}",
             record_key="recorded_multiquery_fairness"),
        Gate("multiquery_dedupe_rate", mq["dedupe_rate"], None,
             base.get("recorded_multiquery_dedupe_rate"), fmt="{:.3f}",
             record_key="recorded_multiquery_dedupe_rate"),
        # ----- static analysis & protocol checking (lint lane, gated) -----
        Gate("lint_violations", float(sa["lint_violations"]), 0.0, 0.0,
             higher_is_better=False, fmt="{:.0f}"),
        Gate("protocol_safe", float(sa["protocol_safe"]), 1.0, 1.0,
             fmt="{:.0f}"),
        Gate("protocol_states_explored",
             float(sa["protocol_states_explored"]), min_protocol_states,
             base.get("recorded_protocol_states"), fmt="{:.0f}",
             record_key="recorded_protocol_states"),
        Gate("protocol_checker_has_teeth", float(sa["protocol_teeth"]),
             1.0, 1.0, fmt="{:.0f}"),
    ]

    _print_delta_table(gates)

    failures = [
        f"{g.name} {g.fmt.format(g.current)} vs floor {g.fmt.format(g.floor)}"
        for g in gates if not g.ok and g.enforced
    ]
    for g in gates:
        if not g.ok and not g.enforced:
            print(f"WARNING (advisory, host-dependent): {g.name} "
                  f"{g.fmt.format(g.current)} vs bound "
                  f"{g.fmt.format(g.floor)}")

    if update_baseline:
        _update_baseline(base, gates)
        if failures:
            print("NOTE: gates failing while re-baselining:",
                  *failures, sep="\n  ")
        return 0
    if failures:
        print("REGRESSION:", *failures, sep="\n  ")
        return 1
    print(
        f"OK: fused {throughput['fused_rows_per_s']:.0f} rows/s "
        f"({throughput['speedup']:.2f}x over per-stage); fused-MLP "
        f"{mlp['mlp_fused_speedup']:.2f}x; adaptive drift "
        f"{adaptive['adaptive_speedup']:.2f}x, accuracy "
        f"{adaptive['adaptive_accuracy']:.3f}; sharded K="
        f"{sharded['n_hosts']} {sharded['sharded_speedup']:.2f}x, "
        f"{sharded['swaps_committed']} quorum swap(s); failover "
        f"{fo['resolution']} ({fo['swaps_committed']} committed); "
        f"straggler fenced+resynced ({strag['fences']}/"
        f"{strag['straggler_resynced']}); pooled kappa² "
        f"{pooled['pooled_swaps']} bnb swap(s) on {pooled['votes_cast']} "
        f"votes; quant {quant['quant_fused_speedup']:.2f}x bytes-moved, "
        f"parity {'OK' if quant['parity']['flips_within_tol'] else 'FAIL'}, "
        f"autotune {quant['autotune_wins']}/{quant['autotune_shapes']} "
        f"shapes; frontend goodput {fe['goodput_ratio']:.3f} "
        f"(nobp {fe['goodput_ratio_nobp']:.3f}), sharded swaps "
        f"{fes['swaps_committed']} conserved={fes['conserved']}; "
        f"plan cache warm {pc['warm_nodes']}/{pc['cold_nodes']} nodes, "
        f"hit ratio {pc['hit_build_ratio']:.4f}, "
        f"roundtrip={int(pc['roundtrip_stable'])}; multidonor "
        f"{md['multi_donor_nodes']}<={md['single_donor_nodes']} nodes "
        f"({md['multi_donors_used']} donors); multiquery N="
        f"{mq['n_queries']} {mq['speedup']:.2f}x, fairness "
        f"{mq['fairness']:.3f}, dedupe {mq['dedupe_rate']:.3f}, "
        f"conserved={int(mq['conserved'])}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
