"""CI regression gate for the fused proxy-scoring hot path and the
adaptive serving loop.

Runs the components benchmark's proxy-throughput measurement plus the
drifting-stream adaptive-serving benchmark, writes
``BENCH_components.json`` at the repo root, and exits nonzero when either
regresses against the checked-in baseline
(``benchmarks/baseline_components.json``):

  * fused/per-stage speedup below ``min_speedup`` — the architectural
    invariant: the fused path must beat one-kernel-call-per-stage
    regardless of host speed, or
  * fused throughput below an absolute rows/s floor, which is
    host-dependent and therefore ADVISORY (a warning) by default; it
    becomes enforcing when ``REGRESSION_MIN_ROWS_PER_S`` is set
    explicitly for a pinned CI host, or
  * fused-MLP/reference-MLP single-pass streaming speedup below
    ``min_mlp_speedup`` — the unified ProxyFamily scorer must beat the
    old per-stage reference path MLP proxies used to fall back to
    (warmed single pass over an unseen stream: the reference's per-shape
    retraces are a real recurring serving cost, the fused path's
    bucket-padded shapes never retrace), or
  * adaptive-vs-static cost-model speedup on the drifting stream below
    ``min_adaptive_speedup``, the adaptive plan missing the query's
    accuracy target, or the warm-started re-search failing to visit
    strictly fewer nodes than a cold branch-and-bound — all three are
    cost-model invariants, host-independent by construction, or
  * the K=4 sharded serving run (quorum-voted swaps, DESIGN.md §6)
    falling below ``min_sharded_speedup`` aggregate cost-model throughput
    over the K=1 baseline, failing to commit a quorum swap, leaking
    records (conservation), or serving ahead of the two-phase barrier
    (``consensus_lag_records != 0``) — all cost-model / protocol
    invariants, host-independent.  Wall-clock consensus overhead per swap
    is ADVISORY unless ``REGRESSION_MAX_CONSENSUS_MS`` pins it.

Usage: python benchmarks/check_regression.py [--quick]
Env overrides: REGRESSION_MIN_ROWS_PER_S, REGRESSION_MIN_SPEEDUP,
REGRESSION_MIN_MLP_SPEEDUP, REGRESSION_MIN_ADAPTIVE_SPEEDUP,
REGRESSION_MIN_SHARDED_SPEEDUP, REGRESSION_MAX_CONSENSUS_MS.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.bench_adaptive import bench_adaptive_throughput  # noqa: E402
from benchmarks.bench_components import (  # noqa: E402
    BENCH_JSON,
    bench_mlp_throughput,
    bench_proxy_throughput,
    write_bench_json,
)
from benchmarks.bench_sharded import bench_sharded_throughput  # noqa: E402

BASELINE = Path(__file__).resolve().parent / "baseline_components.json"


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    throughput = bench_proxy_throughput(n_rows=24_576 if quick else 49_152)
    mlp = bench_mlp_throughput(n_rows=24_576 if quick else 49_152)
    # deliberately NOT shrunk by --quick: the 1.3x floor is an acceptance
    # invariant of the FULL drifting stream — a shorter drifted segment
    # dilutes the stale-plan span the adaptation amortizes against
    # (measured 1.25x at n_after=18k vs 1.38x at 30k), so a quick run
    # would fail the gate without any code regression
    adaptive = bench_adaptive_throughput()
    sharded = bench_sharded_throughput(
        n_before=1_500 if quick else 2_000,
        n_after=4_000 if quick else 6_000)
    write_bench_json(throughput, adaptive, mlp, sharded)
    print(f"wrote {BENCH_JSON}")

    base = json.loads(BASELINE.read_text())
    rows_env = os.environ.get("REGRESSION_MIN_ROWS_PER_S")
    min_rows = float(rows_env) if rows_env else float(base["min_fused_rows_per_s"])
    min_speedup = float(os.environ.get(
        "REGRESSION_MIN_SPEEDUP", base["min_speedup"]))
    min_mlp = float(os.environ.get(
        "REGRESSION_MIN_MLP_SPEEDUP", base["min_mlp_speedup"]))
    min_adaptive = float(os.environ.get(
        "REGRESSION_MIN_ADAPTIVE_SPEEDUP", base["min_adaptive_speedup"]))
    min_sharded = float(os.environ.get(
        "REGRESSION_MIN_SHARDED_SPEEDUP", base["min_sharded_speedup"]))
    consensus_env = os.environ.get("REGRESSION_MAX_CONSENSUS_MS")
    max_consensus = (float(consensus_env) if consensus_env
                     else float(base["advisory_max_consensus_ms"]))

    failures = []
    if sharded["sharded_speedup"] < min_sharded:
        failures.append(
            f"K={sharded['n_hosts']} sharded/single aggregate throughput "
            f"{sharded['sharded_speedup']:.2f}x < floor {min_sharded:.2f}x"
        )
    if sharded["swaps_committed"] < 1:
        failures.append(
            "sharded serving never committed a quorum-voted plan swap")
    if not sharded["conserved"]:
        failures.append("sharded serving lost or duplicated records")
    if sharded["consensus_lag_records"] != 0:
        failures.append(
            f"{sharded['consensus_lag_records']} records served while a "
            f"two-phase swap barrier was open"
        )
    worst_consensus = max(sharded["consensus_ms_per_swap"] or [0.0])
    if worst_consensus > max_consensus:
        msg = (
            f"swap consensus overhead {worst_consensus:.1f} ms "
            f"> bound {max_consensus:.1f} ms"
        )
        if consensus_env:  # wall-clock: only enforce on a pinned host
            failures.append(msg)
        else:
            print(f"WARNING (advisory, host-dependent): {msg}")
    if mlp["mlp_fused_speedup"] < min_mlp:
        failures.append(
            f"fused-MLP/reference-MLP speedup {mlp['mlp_fused_speedup']:.2f}x "
            f"< floor {min_mlp:.2f}x"
        )
    if not all(mlp["fused_used_kernel"]):
        failures.append(
            f"fused MLP run fell off the kernel path: {mlp['fused_used_kernel']}"
        )
    if adaptive["adaptive_speedup"] < min_adaptive:
        failures.append(
            f"adaptive/static drift speedup {adaptive['adaptive_speedup']:.2f}x "
            f"< floor {min_adaptive:.2f}x"
        )
    if adaptive["adaptive_accuracy"] < adaptive["accuracy_target"]:
        failures.append(
            f"adaptive accuracy {adaptive['adaptive_accuracy']:.3f} misses "
            f"target {adaptive['accuracy_target']}"
        )
    if adaptive["warm_nodes"] >= adaptive["cold_nodes"]:
        failures.append(
            f"warm-started B&B visited {adaptive['warm_nodes']} nodes, not "
            f"strictly fewer than cold ({adaptive['cold_nodes']})"
        )
    if adaptive["plan_swaps"] < 1:
        failures.append("adaptive server never re-optimized on the drifting stream")
    if throughput["fused_rows_per_s"] < min_rows:
        msg = (
            f"fused throughput {throughput['fused_rows_per_s']:.0f} rows/s "
            f"< floor {min_rows:.0f}"
        )
        if rows_env:  # absolute floor only enforces on a pinned host
            failures.append(msg)
        else:
            print(f"WARNING (advisory, host-dependent): {msg}")
    if throughput["speedup"] < min_speedup:
        failures.append(
            f"fused/per-stage speedup {throughput['speedup']:.2f}x "
            f"< floor {min_speedup:.2f}x"
        )
    if not all(throughput["fused_used_kernel"]):
        failures.append(
            f"fused run fell off the kernel path: {throughput['fused_used_kernel']}"
        )
    if failures:
        print("REGRESSION:", *failures, sep="\n  ")
        return 1
    print(
        f"OK: fused {throughput['fused_rows_per_s']:.0f} rows/s "
        f"({throughput['speedup']:.2f}x over per-stage; floors: "
        f"{min_rows:.0f} rows/s, {min_speedup:.2f}x); fused-MLP "
        f"{mlp['mlp_fused_speedup']:.2f}x over reference (floor "
        f"{min_mlp:.2f}x); adaptive drift "
        f"{adaptive['adaptive_speedup']:.2f}x over static (floor "
        f"{min_adaptive:.2f}x), accuracy {adaptive['adaptive_accuracy']:.3f} "
        f">= {adaptive['accuracy_target']}, warm B&B "
        f"{adaptive['warm_nodes']} < cold {adaptive['cold_nodes']} nodes; "
        f"sharded K={sharded['n_hosts']} "
        f"{sharded['sharded_speedup']:.2f}x over single (floor "
        f"{min_sharded:.2f}x), {sharded['swaps_committed']} quorum "
        f"swap(s), lag {sharded['consensus_lag_records']} records, worst "
        f"consensus {worst_consensus:.1f} ms"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
