"""CI regression gate for the fused proxy-scoring hot path.

Runs the components benchmark's proxy-throughput measurement on the
synthetic dataset, writes ``BENCH_components.json`` at the repo root, and
exits nonzero when the fused path regresses against the checked-in
baseline (``benchmarks/baseline_components.json``):

  * fused/per-stage speedup below ``min_speedup`` — the architectural
    invariant: the fused path must beat one-kernel-call-per-stage
    regardless of host speed, or
  * fused throughput below an absolute rows/s floor, which is
    host-dependent and therefore ADVISORY (a warning) by default; it
    becomes enforcing when ``REGRESSION_MIN_ROWS_PER_S`` is set
    explicitly for a pinned CI host.

Usage: python benchmarks/check_regression.py [--quick]
Env overrides: REGRESSION_MIN_ROWS_PER_S, REGRESSION_MIN_SPEEDUP.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.bench_components import (  # noqa: E402
    BENCH_JSON,
    bench_proxy_throughput,
    write_bench_json,
)

BASELINE = Path(__file__).resolve().parent / "baseline_components.json"


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    throughput = bench_proxy_throughput(n_rows=24_576 if quick else 49_152)
    write_bench_json(throughput)
    print(f"wrote {BENCH_JSON}")

    base = json.loads(BASELINE.read_text())
    rows_env = os.environ.get("REGRESSION_MIN_ROWS_PER_S")
    min_rows = float(rows_env) if rows_env else float(base["min_fused_rows_per_s"])
    min_speedup = float(os.environ.get(
        "REGRESSION_MIN_SPEEDUP", base["min_speedup"]))

    failures = []
    if throughput["fused_rows_per_s"] < min_rows:
        msg = (
            f"fused throughput {throughput['fused_rows_per_s']:.0f} rows/s "
            f"< floor {min_rows:.0f}"
        )
        if rows_env:  # absolute floor only enforces on a pinned host
            failures.append(msg)
        else:
            print(f"WARNING (advisory, host-dependent): {msg}")
    if throughput["speedup"] < min_speedup:
        failures.append(
            f"fused/per-stage speedup {throughput['speedup']:.2f}x "
            f"< floor {min_speedup:.2f}x"
        )
    if not all(throughput["fused_used_kernel"]):
        failures.append(
            f"fused run fell off the kernel path: {throughput['fused_used_kernel']}"
        )
    if failures:
        print("REGRESSION:", *failures, sep="\n  ")
        return 1
    print(
        f"OK: fused {throughput['fused_rows_per_s']:.0f} rows/s "
        f"({throughput['speedup']:.2f}x over per-stage; floors: "
        f"{min_rows:.0f} rows/s, {min_speedup:.2f}x)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
