"""Fig. 10/11 + §6.3: total processing time (QO + execution) per dataset.

Reports, per dataset family (twitter/coco/ucf101 stand-ins): total times for
ORIG/NS/PP/CORE with percentiles across queries, average total-time
reduction vs ORIG (Fig 10 b/d/f), and the per-query breakdown (Fig 11).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_queries, build_workload, csv_row, evaluate_all


def run(quick: bool = True):
    n_q = 2 if quick else 10
    for name in ("twitter", "coco", "ucf101"):
        w = build_workload(name, 0.9, seed=5)
        queries = build_queries(w, n_q, seed=6)
        totals = {m: [] for m in ("orig", "ns", "pp", "core")}
        accs = {m: [] for m in totals}
        for qi, q in enumerate(queries):
            res = evaluate_all(w, q)
            for m in totals:
                totals[m].append(res[m]["total_ms"])
                accs[m].append(res[m]["accuracy"])
            csv_row(
                f"fig11_{name}_q{qi}", res["core"]["cost_per_record_ms"] * 1e3,
                ";".join(f"{m}_total_s={res[m]['total_ms']/1e3:.1f}" for m in totals),
            )
        orig_mean = np.mean(totals["orig"])
        for m in ("ns", "pp", "core"):
            arr = np.asarray(totals[m])
            red = 1 - arr.mean() / orig_mean
            csv_row(
                f"fig10_{name}_{m}", float(arr.mean()) * 1e3 / max(len(w.x_exec), 1),
                (
                    f"total_reduction_vs_orig={red:.1%};"
                    f"p1={np.percentile(arr,1)/1e3:.1f}s;median={np.median(arr)/1e3:.1f}s;"
                    f"p99={np.percentile(arr,99)/1e3:.1f}s;mean_acc={np.mean(accs[m]):.3f}"
                ),
            )


if __name__ == "__main__":
    run()
