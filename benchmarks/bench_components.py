"""Fig. 12 + Table 5: effectiveness of CORE's components.

CORE-a (accuracy allocation only, input order), CORE-h (exhaustive order
search), CORE (branch-and-bound): execution cost should be
CORE ~= CORE-h < CORE-a, with CORE's optimization cost well below CORE-h's.
Also reports the node-pruning fractions (§5.3: coarse vs fine-grained tree).

Additionally measures the fused whole-cascade proxy-scoring path
(DESIGN.md §3) against the legacy one-kernel-call-per-stage path on a
3-stage cascade and writes ``BENCH_components.json`` — the artifact
``benchmarks/check_regression.py`` gates on.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import build_queries, build_workload, csv_row, evaluate_all
from repro.core import BranchAndBound, ProxyBuilder, execute_plan, optimize
from repro.data.synthetic import make_dataset, make_query, make_udfs
from repro.util import atomic_write_text

BENCH_JSON = Path(__file__).resolve().parent.parent / "BENCH_components.json"


def bench_proxy_throughput(*, n_rows: int = 24_576, n_features: int = 64,
                           batch_size: int = 8192, repeats: int = 3,
                           seed: int = 5) -> dict:
    """Fused vs per-stage proxy-scoring throughput on a 3-stage cascade.

    Throughput is records streamed per second of proxy-scoring wall time
    (``ExecResult.proxy_total_ms``), the quantity the fused path optimizes:
    one Pallas dispatch per microbatch for ALL stages, standardizers folded
    at plan-compile time, bucket-padded static shapes.  The per-stage
    number is the legacy path (one dispatch per stage per microbatch on the
    survivor set); both paths are warmed before timing so jit tracing is
    excluded from steady-state throughput.
    """
    ds = make_dataset(n=n_rows + 4000, n_features=n_features, n_columns=4,
                      correlation=0.9, feature_noise=1.1, label_noise=0.25,
                      seed=seed)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1500, seed=seed,
                     declared_cost_ms=20.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=seed)
    plan = optimize(q, ds.x[:2000], mode="core-a", step=0.05)
    x = ds.x[4000:4000 + n_rows]

    def measure(fused: bool):
        # warmup: populate jit caches / fold caches for the measured path
        execute_plan(plan, x[:batch_size], batch_size=batch_size,
                     use_kernel=True, fused=fused)
        best = None
        for _ in range(repeats):
            res = execute_plan(plan, x, batch_size=batch_size,
                               use_kernel=True, fused=fused)
            ms = res.proxy_total_ms
            if best is None or ms < best[0]:
                best = (ms, res)
        return best

    per_ms, per_res = measure(fused=False)
    fus_ms, fus_res = measure(fused=True)
    assert set(per_res.passed.tolist()) == set(fus_res.passed.tolist()), \
        "fused and per-stage paths disagree on query output"
    assert all(s.used_kernel for s in fus_res.stages), \
        "fused run silently fell back off the kernel path"
    out = {
        "n_rows": n_rows,
        "n_features": n_features,
        "n_stages": len(plan.stages),
        "batch_size": batch_size,
        "perstage_proxy_ms": per_ms,
        "fused_proxy_ms": fus_ms,
        "perstage_rows_per_s": n_rows / (per_ms / 1e3),
        "fused_rows_per_s": n_rows / (fus_ms / 1e3),
        "speedup": per_ms / fus_ms,
        "fused_used_kernel": [s.used_kernel for s in fus_res.stages],
        "perstage_used_kernel": [s.used_kernel for s in per_res.stages],
    }
    csv_row(
        "fused_proxy_throughput", out["fused_rows_per_s"],
        (
            f"rows_per_s={out['fused_rows_per_s']:.0f};"
            f"perstage_rows_per_s={out['perstage_rows_per_s']:.0f};"
            f"speedup={out['speedup']:.2f}x"
        ),
    )
    return out


def bench_mlp_throughput(*, n_rows: int = 49_152, n_features: int = 64,
                         batch_size: int = 8192, seed: int = 7) -> dict:
    """Fused-MLP vs reference-MLP cascade proxy throughput.

    The unified ProxyFamily format put MLP proxies on the fused Pallas
    scorer (they used to silently drop to the per-stage reference path).
    Methodology differs from the linear gate deliberately: one WARMED
    SINGLE PASS over an unseen stream, because that is what serving does —
    every microbatch has fresh survivor counts, so the reference path's
    per-shape ``jax.jit`` retraces recur forever, while the fused path's
    bucket-padded static shapes never retrace (DESIGN.md §3, hidden cost
    4).  Best-of-N over identical batches would amortize exactly the cost
    the fused path is designed to remove.
    """
    ds = make_dataset(n=n_rows + 4000, n_features=n_features, n_columns=4,
                      correlation=0.9, feature_noise=1.1, label_noise=0.25,
                      seed=seed)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1500, seed=seed,
                     declared_cost_ms=20.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=seed)
    plan = optimize(q, ds.x[:2000], mode="core-a", step=0.05, kind="mlp")
    assert all(s.proxy.family == "mlp1" for s in plan.stages)
    x = ds.x[4000:4000 + n_rows]

    def measure_stream(use_kernel: bool, fused: bool):
        # warm on one microbatch (pack caches, bucket jit programs, the
        # reference path's first-shape traces), then ONE timed pass over
        # the unseen remainder — serving never sees a batch twice
        execute_plan(plan, x[:batch_size], batch_size=batch_size,
                     use_kernel=use_kernel, fused=fused)
        res = execute_plan(plan, x[batch_size:], batch_size=batch_size,
                           use_kernel=use_kernel, fused=fused)
        return res.proxy_total_ms, res

    ref_ms, ref_res = measure_stream(use_kernel=False, fused=False)
    fus_ms, fus_res = measure_stream(use_kernel=True, fused=True)
    # the fused path folds the standardizer into the first layer — a f32
    # reassociation that agrees with standardize-then-score only to ~1e-4,
    # so a record whose score sits exactly on a threshold may flip; allow
    # boundary ties but nothing that could hide a real mask bug
    diff = set(ref_res.passed.tolist()) ^ set(fus_res.passed.tolist())
    assert len(diff) <= max(3, n_rows // 1000), \
        f"fused and reference MLP paths disagree on {len(diff)} records"
    assert all(s.used_kernel for s in fus_res.stages), \
        "fused MLP run silently fell back off the kernel path"
    assert not any(s.used_kernel for s in ref_res.stages)
    n_meas = n_rows - batch_size
    out = {
        "n_rows": n_meas,
        "n_features": n_features,
        "n_stages": len(plan.stages),
        "batch_size": batch_size,
        "hidden_widths": [s.proxy.packed().hidden for s in plan.stages],
        "reference_proxy_ms": ref_ms,
        "fused_proxy_ms": fus_ms,
        "reference_rows_per_s": n_meas / (ref_ms / 1e3),
        "fused_rows_per_s": n_meas / (fus_ms / 1e3),
        "mlp_fused_speedup": ref_ms / fus_ms,
        "fused_used_kernel": [s.used_kernel for s in fus_res.stages],
    }
    csv_row(
        "mlp_fused_throughput", out["fused_rows_per_s"],
        (
            f"rows_per_s={out['fused_rows_per_s']:.0f};"
            f"reference_rows_per_s={out['reference_rows_per_s']:.0f};"
            f"speedup={out['mlp_fused_speedup']:.2f}x"
        ),
    )
    return out


def write_bench_json(throughput: dict, adaptive: dict | None = None,
                     mlp: dict | None = None, sharded: dict | None = None,
                     fault_tolerance: dict | None = None,
                     quant: dict | None = None,
                     frontend: dict | None = None,
                     plan_cache: dict | None = None,
                     static_analysis: dict | None = None,
                     multiquery: dict | None = None,
                     path: Path = BENCH_JSON) -> None:
    payload = {
        "bench": "components",
        "proxy_throughput": throughput,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    if adaptive is not None:
        payload["adaptive_drift"] = adaptive
    if mlp is not None:
        payload["mlp_proxy_throughput"] = mlp
    if sharded is not None:
        payload["sharded_serving"] = sharded
    if fault_tolerance is not None:
        payload["fault_tolerance"] = fault_tolerance
    if quant is not None:
        payload["quantized_cascade"] = quant
    if frontend is not None:
        payload["serving_frontend"] = frontend
    if plan_cache is not None:
        payload["plan_cache"] = plan_cache
    if static_analysis is not None:
        payload["static_analysis"] = static_analysis
    if multiquery is not None:
        payload["multiquery"] = multiquery
    atomic_write_text(path, json.dumps(payload, indent=2) + "\n")


def run(quick: bool = True):
    from benchmarks.bench_adaptive import bench_adaptive_throughput

    throughput = bench_proxy_throughput(
        n_rows=24_576 if quick else 98_304)
    mlp = bench_mlp_throughput(n_rows=24_576 if quick else 49_152)
    # full-size regardless of ``quick``: the gated 1.3x floor only holds
    # on the full drifted segment (see check_regression.py)
    adaptive = bench_adaptive_throughput()
    write_bench_json(throughput, adaptive, mlp)
    csv_row(
        "adaptive_drift_throughput", adaptive["adaptive_rows_per_cost_s"],
        (
            f"speedup={adaptive['adaptive_speedup']:.2f}x;"
            f"acc={adaptive['adaptive_accuracy']:.3f};"
            f"warm_nodes={adaptive['warm_nodes']};"
            f"cold_nodes={adaptive['cold_nodes']}"
        ),
    )
    n_q = 2 if quick else 6
    w = build_workload("twitter", 0.9, seed=9)
    queries = build_queries(w, n_q, n_preds=(3,), seed=10)
    agg = {m: {"exec": [], "qo": []} for m in ("core-a", "core-h", "core")}
    for q in queries:
        res = evaluate_all(w, q, modes=("orig", "core-a", "core-h", "core"))
        for m in agg:
            agg[m]["exec"].append(res[m]["cost_per_record_ms"])
            agg[m]["qo"].append(res[m]["qo_ms"])
    for m in agg:
        csv_row(
            f"fig12_{m}", float(np.mean(agg[m]["exec"])) * 1e3,
            (
                f"exec_ms_per_rec={np.mean(agg[m]['exec']):.3f};"
                f"qo_ms={np.mean(agg[m]['qo']):.0f}"
            ),
        )
    # §5.3 pruning statistics: coarse vs fine-grained trees
    for fine, label in ((False, "coarse"), (True, "fine")):
        pruned = []
        for q in queries:
            b = ProxyBuilder(q, w.x_opt, seed=0)
            bb = BranchAndBound(b, q.accuracy_target, fine_grained=fine, step=0.05)
            _, trace = bb.run()
            pruned.append(trace.nodes_pruned_frac)
        csv_row(
            f"table5_prune_{label}_tree", 0.0,
            f"nodes_pruned_frac={np.mean(pruned):.2%}",
        )

    # §4.3/§4.4 reuse ablation: what sample + classifier reuse each save
    variants = {
        "full_reuse": dict(reuse_samples=True, reuse_classifiers=True),
        "no_classifier_reuse": dict(reuse_samples=True, reuse_classifiers=False),
        "no_sample_reuse": dict(reuse_samples=False, reuse_classifiers=True),
    }
    q = queries[0]
    for label, kw in variants.items():
        b = ProxyBuilder(q, w.x_opt, seed=0, **kw)
        bb = BranchAndBound(b, q.accuracy_target, fine_grained=True, step=0.05)
        bb.run()
        st = b.stats
        csv_row(
            f"table5_ablation_{label}", st.qo_ms * 1e3,
            (
                f"labeling_ms={st.labeling_ms:.0f};training_ms={st.training_ms:.0f};"
                f"search_ms={st.search_ms:.0f};udf_calls={sum(st.udf_calls.values())};"
                f"n_trained={st.n_trained};n_reused={st.n_reused}"
            ),
        )


if __name__ == "__main__":
    run()
