"""Fig. 12 + Table 5: effectiveness of CORE's components.

CORE-a (accuracy allocation only, input order), CORE-h (exhaustive order
search), CORE (branch-and-bound): execution cost should be
CORE ~= CORE-h < CORE-a, with CORE's optimization cost well below CORE-h's.
Also reports the node-pruning fractions (§5.3: coarse vs fine-grained tree).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_queries, build_workload, csv_row, evaluate_all
from repro.core import BranchAndBound, ProxyBuilder


def run(quick: bool = True):
    n_q = 2 if quick else 6
    w = build_workload("twitter", 0.9, seed=9)
    queries = build_queries(w, n_q, n_preds=(3,), seed=10)
    agg = {m: {"exec": [], "qo": []} for m in ("core-a", "core-h", "core")}
    for q in queries:
        res = evaluate_all(w, q, modes=("orig", "core-a", "core-h", "core"))
        for m in agg:
            agg[m]["exec"].append(res[m]["cost_per_record_ms"])
            agg[m]["qo"].append(res[m]["qo_ms"])
    for m in agg:
        csv_row(
            f"fig12_{m}", float(np.mean(agg[m]["exec"])) * 1e3,
            (
                f"exec_ms_per_rec={np.mean(agg[m]['exec']):.3f};"
                f"qo_ms={np.mean(agg[m]['qo']):.0f}"
            ),
        )
    # §5.3 pruning statistics: coarse vs fine-grained trees
    for fine, label in ((False, "coarse"), (True, "fine")):
        pruned = []
        for q in queries:
            b = ProxyBuilder(q, w.x_opt, seed=0)
            bb = BranchAndBound(b, q.accuracy_target, fine_grained=fine, step=0.05)
            _, trace = bb.run()
            pruned.append(trace.nodes_pruned_frac)
        csv_row(
            f"table5_prune_{label}_tree", 0.0,
            f"nodes_pruned_frac={np.mean(pruned):.2%}",
        )

    # §4.3/§4.4 reuse ablation: what sample + classifier reuse each save
    variants = {
        "full_reuse": dict(reuse_samples=True, reuse_classifiers=True),
        "no_classifier_reuse": dict(reuse_samples=True, reuse_classifiers=False),
        "no_sample_reuse": dict(reuse_samples=False, reuse_classifiers=True),
    }
    q = queries[0]
    for label, kw in variants.items():
        b = ProxyBuilder(q, w.x_opt, seed=0, **kw)
        bb = BranchAndBound(b, q.accuracy_target, fine_grained=True, step=0.05)
        bb.run()
        st = b.stats
        csv_row(
            f"table5_ablation_{label}", st.qo_ms * 1e3,
            (
                f"labeling_ms={st.labeling_ms:.0f};training_ms={st.training_ms:.0f};"
                f"search_ms={st.search_ms:.0f};udf_calls={sum(st.udf_calls.values())};"
                f"n_trained={st.n_trained};n_reused={st.n_reused}"
            ),
        )


if __name__ == "__main__":
    run()
