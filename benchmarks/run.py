"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV per benchmark.  --full uses the
paper-scale query counts (slower); the default profile keeps the whole
suite under ~15 minutes on this container.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import (  # noqa: E402
    bench_accuracy,
    bench_components,
    bench_correlation_impact,
    bench_qo_cost,
    bench_scalability,
    bench_time_reduction,
    roofline,
)

SUITES = [
    ("correlation_impact (Fig 2, Fig 9)", bench_correlation_impact.run),
    ("time_reduction (Fig 10, Fig 11)", bench_time_reduction.run),
    ("qo_cost (Table 4)", bench_qo_cost.run),
    ("components (Fig 12, Table 5)", bench_components.run),
    ("scalability (Fig 13)", bench_scalability.run),
    ("accuracy_sweep (Fig 14, Table 6)", bench_accuracy.run),
    ("roofline (assignment g)", roofline.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale query counts")
    ap.add_argument("--only", default=None, help="substring filter on suite name")
    args = ap.parse_args()
    t_all = time.time()
    print("name,us_per_call,derived")
    for name, fn in SUITES:
        if args.only and args.only not in name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn(quick=not args.full)
        except Exception as e:  # noqa: BLE001 - a failing suite must not kill the run
            print(f"bench_error_{name},0,{type(e).__name__}: {e}")
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    print(f"# total {time.time()-t_all:.1f}s")


if __name__ == "__main__":
    main()
