"""Adaptive serving benchmark (DESIGN.md §4): drift-triggered online
re-optimization vs a frozen plan.

A drifting synthetic stream (``make_drifting_stream``) inverts the
workload mid-run: the stage the optimizer put first becomes nearly
non-selective while the back stages become highly selective, and the
latent anisotropy changes the predicate correlation structure.  The
static server keeps executing the stale plan; the adaptive server
detects the drift (CUSUM on stage keep-rates + audited selectivities),
re-optimizes on its reservoir with a warm-started branch-and-bound
``resume``, and hot-swaps the compiled scorer mid-stream.

Reported (and gated by ``check_regression.py``):

  * ``adaptive_speedup`` — static / adaptive cost-model totals over the
    whole stream (including the adaptive path's audit + reservoir-
    labeling UDF charges) — the floor is 1.3x;
  * both paths' empirical accuracy vs the full-UDF oracle (the adaptive
    plan must still meet the query's accuracy target);
  * ``warm_nodes`` < ``cold_nodes`` — the warm-started re-search must
    visit strictly fewer L/M nodes than a cold branch-and-bound on the
    same drifted sample.
"""
from __future__ import annotations

import numpy as np

from repro.core import BranchAndBound, ProxyBuilder, optimize
from repro.data.synthetic import (
    make_dataset,
    make_drifting_stream,
    make_query,
    make_udfs,
)
from repro.serving.engine import CascadeServer
from repro.serving.stats import AdaptivePolicy


def drift_scenario(*, n_before: int = 6_000, n_after: int = 30_000,
                   seed: int = 5):
    """Workload + plan + order-inverting drifted stream (shared with the
    regression gate so the gated numbers match the benchmark's)."""
    ds = make_dataset(n=20_000, n_features=64, n_columns=4, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=seed)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1500, seed=seed,
                     declared_cost_ms=20.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=seed)
    stream = make_drifting_stream(
        ds, n_before, n_after,
        shift_targets={0: 2.8, 1: -2.6, 2: 2.8}, corr_gain=2.5, seed=seed,
    )
    return ds, q, stream


def _oracle_pass(q, x: np.ndarray) -> np.ndarray:
    masks = [p.evaluate(p.udf(x)) for p in q.predicates]
    return np.flatnonzero(np.logical_and.reduce(masks))


def bench_adaptive_throughput(*, n_before: int = 6_000, n_after: int = 30_000,
                              seed: int = 5, chunk: int = 2048,
                              tile: int = 1024) -> dict:
    ds, q, stream = drift_scenario(n_before=n_before, n_after=n_after,
                                   seed=seed)
    x = stream.x
    oracle = set(_oracle_pass(q, x).tolist())

    def accuracy(emitted):
        if not oracle:
            return 1.0
        return sum(1 for i in emitted if i in oracle) / len(oracle)

    def serve(adaptive: bool):
        plan = optimize(q, ds.x[:2000], mode="core", step=0.05,
                        keep_state=True)
        srv = CascadeServer(
            plan, tile=tile, use_kernel=True, adaptive=adaptive,
            policy=AdaptivePolicy(audit_rate=0.015), seed=1,
        )
        stats = srv.run_stream(x, chunk=chunk)
        return srv, stats

    srv_s, st_s = serve(adaptive=False)
    srv_a, st_a = serve(adaptive=True)
    assert st_s.emitted + st_s.rejected == len(x)
    assert st_a.emitted + st_a.rejected == len(x)

    # warm-started vs cold re-search on the same drifted sample
    plan = optimize(q, ds.x[:2000], mode="core", step=0.05, keep_state=True)
    drifted = x[stream.boundary:stream.boundary + 2000]
    warm_builder = plan.meta["builder"].rebase(drifted)
    _, warm_trace = plan.meta["bnb"].resume(warm_builder)
    cold_builder = ProxyBuilder(q, drifted, seed=0)
    _, cold_trace = BranchAndBound(cold_builder, q.accuracy_target,
                                   step=0.05).run()

    events = [
        {"at_record": e.at_record, "signal": e.signal,
         "escalated": e.escalated, "nodes_visited": e.nodes_visited,
         "order_before": list(e.order_before),
         "order_after": list(e.order_after)}
        for e in st_a.drift_events
    ]
    return {
        "n_stream": len(x),
        "drift_boundary": stream.boundary,
        "accuracy_target": q.accuracy_target,
        "static_cost_ms": st_s.model_cost_ms,
        "adaptive_cost_ms": st_a.model_cost_ms,
        "adaptive_speedup": st_s.model_cost_ms / st_a.model_cost_ms,
        "static_rows_per_cost_s": len(x) / (st_s.model_cost_ms / 1e3),
        "adaptive_rows_per_cost_s": len(x) / (st_a.model_cost_ms / 1e3),
        "static_accuracy": accuracy(srv_s.emitted),
        "adaptive_accuracy": accuracy(srv_a.emitted),
        "plan_swaps": st_a.plan_swaps,
        "audit_cost_ms": st_a.audit_cost_ms,
        "reopt_udf_cost_ms": st_a.reopt_udf_cost_ms,
        "reopt_ms": st_a.reopt_ms,
        "drift_events": events,
        "warm_nodes": warm_trace.nodes_visited,
        "cold_nodes": cold_trace.nodes_visited,
        "final_order": list(srv_a.plan.order),
    }


def run(quick: bool = True):
    from benchmarks.common import csv_row

    out = bench_adaptive_throughput(
        n_after=18_000 if quick else 30_000)
    csv_row(
        "adaptive_drift_throughput", out["adaptive_rows_per_cost_s"],
        (
            f"speedup={out['adaptive_speedup']:.2f}x;"
            f"acc={out['adaptive_accuracy']:.3f} (A={out['accuracy_target']});"
            f"swaps={out['plan_swaps']};"
            f"warm_nodes={out['warm_nodes']};cold_nodes={out['cold_nodes']}"
        ),
    )
    return out


if __name__ == "__main__":
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    print(json.dumps(run(quick="--quick" in sys.argv[1:]), indent=2))
