"""Multi-host sharded serving benchmark (DESIGN.md §6): K=4 hosts with
quorum-voted plan swaps vs the same consensus stack at K=1.

The workload is the adaptive benchmark's order-inverting drift, sharded
with per-host skewed magnitudes (``make_sharded_drifting_streams``) —
lightly-hit shards' detectors fire late or never, so the quorum vote is
what decides the swap.  Gated by ``check_regression.py``:

  * ``sharded_speedup`` — K=4 aggregate cost-model throughput (total
    records / the SLOWEST host's cost, since hosts run in parallel) over
    the K=1 baseline's throughput, floor 2.5x.  Cost-model based, so
    host-independent: each host serves ~N/K records through the same
    cascade, and the consensus layer must not erode the near-linear
    scaling with audit or re-optimization overhead.
  * ``swaps_committed >= 1`` — the skewed per-host drifts still reach
    quorum and the two-phase swap commits.
  * ``consensus_lag_records == 0`` — the prepare/commit barrier completes
    within the same chunk round that reached quorum (no host serves ahead
    of its peers' acknowledgements); records-based, host-independent.
  * conservation — checked against ground truth, not derived counters:
    zero records left in any plan version's queues after the drain, no
    index emitted twice, shard emissions disjoint (and the artifact
    round-trip is exercised on every swap: hosts only ever install
    deserialized wire blobs).
  * ``consensus_ms`` per swap is reported and ADVISORY (wall-clock of
    serialize + prepare + commit, excluding re-optimization): it is
    host-speed-dependent, so the gate only warns unless
    ``REGRESSION_MAX_CONSENSUS_MS`` pins it for a known CI host.
"""
from __future__ import annotations

import numpy as np

from repro.core import optimize
from repro.data.synthetic import (
    make_dataset,
    make_query,
    make_sharded_drifting_streams,
    make_udfs,
)
from repro.distributed.serving import ShardedCascadeServer
from repro.serving.stats import AdaptivePolicy


def sharded_scenario(*, n_hosts: int = 4, n_before: int = 2_000,
                     n_after: int = 6_000, seed: int = 5):
    """Workload + plan + per-host skewed drifting shards (per-shard
    lengths, so total volume scales with K)."""
    ds = make_dataset(n=20_000, n_features=64, n_columns=4, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=seed)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1500, seed=seed,
                     declared_cost_ms=20.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=seed)
    streams = make_sharded_drifting_streams(
        ds, n_hosts, n_before, n_after,
        shift_targets={0: 2.8, 1: -2.6, 2: 2.8}, corr_gain=2.5,
        drift_skew=0.3, seed=seed,
    )
    return ds, q, streams


def _serve(plan_factory, streams, n_hosts: int, *, chunk: int, tile: int,
           seed: int):
    srv = ShardedCascadeServer(
        plan_factory(), n_hosts, tile=tile,
        policy=AdaptivePolicy(audit_rate=0.015), seed=seed,
    )
    stats = srv.run_streams([s.x for s in streams[:n_hosts]], chunk=chunk)
    return srv, stats


def bench_sharded_throughput(*, n_hosts: int = 4, n_before: int = 2_000,
                             n_after: int = 6_000, seed: int = 5,
                             chunk: int = 1024, tile: int = 1024) -> dict:
    ds, q, streams = sharded_scenario(
        n_hosts=n_hosts, n_before=n_before, n_after=n_after, seed=seed)

    def plan_factory():
        return optimize(q, ds.x[:2000], mode="core", step=0.05,
                        keep_state=True)

    # K=1 baseline: the same consensus stack with a quorum of one, serving
    # ONE shard's volume — throughput is rows per critical-path cost
    # second either way, so the comparison is per-host-load-invariant.
    srv1, st1 = _serve(plan_factory, streams, 1, chunk=chunk, tile=tile,
                       seed=seed)
    srvK, stK = _serve(plan_factory, streams, n_hosts, chunk=chunk,
                       tile=tile, seed=seed)

    def conserved(srv, stats) -> bool:
        # ground truth, not bookkeeping: `rejected` is DERIVED from
        # submitted - emitted, so summing it proves nothing.  What can
        # actually fail: a record stuck in a queue after the drain
        # (lost), an index emitted twice (duplicated), or emissions
        # leaking across shards.
        all_emitted: list = []
        for h in srv.hosts:
            if h.engine.in_flight() != 0:
                return False
            if len(h.engine.emitted) != len(set(h.engine.emitted)):
                return False
            all_emitted.extend(h.engine.emitted)
        return (len(all_emitted) == len(set(all_emitted))
                and len(all_emitted) <= stats.submitted)

    single = st1.aggregate_rows_per_cost_s
    sharded = stK.aggregate_rows_per_cost_s
    # consensus lag in RECORDS: submissions anywhere in the fleet while a
    # two-phase barrier was open — any nonzero value means a host served
    # ahead of an epoch its peers had not yet acknowledged
    lag = sum(r.lag_records for r in stK.swap_log if r.committed)
    return {
        "n_hosts": n_hosts,
        "per_host_records": [int(n) for n in stK.submitted_per_host],
        "single_rows_per_cost_s": single,
        "sharded_rows_per_cost_s": sharded,
        "sharded_speedup": sharded / single if single else 0.0,
        "single_swaps": st1.swaps_committed,
        "swaps_committed": stK.swaps_committed,
        "swaps_aborted": stK.swaps_aborted,
        "votes_cast": stK.votes_cast,
        "final_epoch": stK.final_epoch,
        "consensus_lag_records": lag,
        "consensus_ms_per_swap": [
            float(r.consensus_ms) for r in stK.swap_log if r.committed],
        "reopt_ms_per_swap": [
            float(r.reopt_ms) for r in stK.swap_log if r.committed],
        "merged_rows_per_swap": [
            int(r.merged_rows) for r in stK.swap_log if r.committed],
        "conserved": bool(conserved(srvK, stK) and conserved(srv1, st1)),
    }


# -------------------------------------------------- fault-tolerance gates
def _ft_workload(seed: int = 41):
    """Smaller fixed-seed workload for the fault-tolerance scenarios —
    identical in every ``--quick``/full run, so the CI bench lane is
    deterministic (inline transport + fixed seeds: no wall-clock in any
    gated quantity)."""
    ds = make_dataset(n=9_000, n_features=64, n_columns=3, correlation=0.9,
                      feature_noise=0.9, label_noise=0.2, seed=seed)
    udfs = make_udfs(ds, hidden=16, depth=1, train_rows=1200, seed=seed,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=seed + 1)
    return ds, q


def _ft_conserved(srv, stats) -> bool:
    """Ground-truth conservation INCLUDING version pinning: zero in-flight
    rows after drain, no duplicate emissions, and every emitted row
    served under the plan version current at its submission."""
    all_emitted: list = []
    for h in srv.hosts:
        if h.engine.in_flight() != 0:
            return False
        if len(h.engine.emitted) != len(set(h.engine.emitted)):
            return False
        for i, v in zip(h.engine.emitted, h.engine.emitted_versions):
            if h.submit_version.get(i) != v:
                return False
        all_emitted.extend(h.engine.emitted)
    return (len(all_emitted) == len(set(all_emitted))
            and len(all_emitted) <= stats.submitted)


def bench_fault_tolerance(*, seed: int = 41) -> dict:
    """Three gated failure scenarios (DESIGN.md §6 failure model):

    * **failover** — the primary coordinator dies after the prepare
      barrier closed but before the commit broadcast; the standby takes
      over mid-epoch and the fleet converges on the committed swap.
    * **straggler** — one host misses the prepare barrier; the fleet
      commits without it (serve-behind fencing), then re-syncs it.
    * **pooled_kappa** — a correlation-only drift split evenly across
      K=4 shards: every local detector stays quiet, but the pooled
      fleet-level kappa² crosses tolerance and escalates to B&B.
    """
    ds, q = _ft_workload(seed)
    policy_kw = dict(cooldown_records=1024, min_reservoir=128,
                     threshold=50.0, audit_rate=0.03,
                     reservoir_capacity=512)

    def plan():
        return optimize(q, ds.x[:1500], mode="core", step=0.05,
                        keep_state=True)

    def drift_streams():
        return make_sharded_drifting_streams(
            ds, 4, 800, 2400, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
            corr_gain=2.5, drift_skew=0.3, seed=seed)

    def run(srv, streams):
        for h in srv.hosts:
            h.track_versions = True
        stats = srv.run_streams([s.x for s in streams], chunk=400)
        return stats, _ft_conserved(srv, stats)

    # 1) coordinator failover mid-epoch (commit broadcast lost)
    srv = ShardedCascadeServer(plan(), 4, tile=256, seed=3,
                               policy=AdaptivePolicy(**policy_kw),
                               kill_coordinator_at="commit")
    st, conserved = run(srv, drift_streams())
    failover = {
        "failovers": st.failovers,
        "resolution": st.failover_resolution,
        "swaps_committed": st.swaps_committed,
        "resyncs": st.resyncs,
        "final_epoch": st.final_epoch,
        "epochs_agree": int(len({h.epoch for h in srv.hosts}) == 1),
        "lag_records": sum(r.lag_records for r in st.swap_log if r.committed),
        "conserved": int(conserved),
    }

    # 2) straggler fencing: silent host neither blocks nor serves unacked
    srv = ShardedCascadeServer(plan(), 4, tile=256, seed=3,
                               policy=AdaptivePolicy(**policy_kw),
                               straggler_host=2)
    st, conserved = run(srv, drift_streams())
    straggler_host = srv.hosts[2]
    fenced_commits = [r for r in st.swap_log if r.committed and r.fenced]
    straggler = {
        "swaps_committed": st.swaps_committed,
        "fences": st.fences,
        "resyncs": st.resyncs,
        "committed_while_fenced": int(bool(fenced_commits)),
        "straggler_resynced": straggler_host.resyncs,
        "final_epoch": st.final_epoch,
        "epochs_agree": int(len({h.epoch for h in srv.hosts}) == 1),
        "conserved": int(conserved),
    }

    # 3) evenly-split correlation drift: pooled kappa² must escalate while
    #    every local detector stays quiet
    pooled_streams = make_sharded_drifting_streams(
        ds, 4, 1200, 2600, shift_targets={}, shift=0.0, corr_gain=3.0,
        drift_skew=0.3, skew_corr=True, seed=seed)
    srv = ShardedCascadeServer(
        plan(), 4, tile=256, seed=3,
        policy=AdaptivePolicy(**{**policy_kw, "threshold": 200.0,
                                 "kappa_pool_baseline": 60}))
    st, conserved = run(srv, pooled_streams)
    pooled_recs = [r for r in st.swap_log
                   if r.initiated_by == "pooled:kappa2"]
    pooled = {
        "votes_cast": st.votes_cast,
        "pooled_swaps": st.pooled_swaps,
        "swaps_committed": st.swaps_committed,
        "all_bnb": int(bool(pooled_recs)
                       and all(r.mode == "bnb" for r in pooled_recs)),
        "local_escalations": sum(
            int(h.engine.escalation_hint()[1]) for h in srv.hosts),
        "conserved": int(conserved),
    }
    return {"failover": failover, "straggler": straggler,
            "pooled_kappa": pooled}


def run(quick: bool = True):
    from benchmarks.common import csv_row

    out = bench_sharded_throughput(
        n_before=1_500 if quick else 2_000,
        n_after=4_000 if quick else 6_000,
    )
    csv_row(
        "sharded_serving_throughput", out["sharded_rows_per_cost_s"],
        (
            f"speedup={out['sharded_speedup']:.2f}x;K={out['n_hosts']};"
            f"swaps={out['swaps_committed']};votes={out['votes_cast']};"
            f"lag={out['consensus_lag_records']}"
        ),
    )
    ft = bench_fault_tolerance()
    csv_row(
        "sharded_fault_tolerance", float(ft["failover"]["swaps_committed"]),
        (
            f"failover={ft['failover']['resolution']};"
            f"straggler_fences={ft['straggler']['fences']};"
            f"pooled_swaps={ft['pooled_kappa']['pooled_swaps']};"
            f"pooled_votes={ft['pooled_kappa']['votes_cast']}"
        ),
    )
    out["fault_tolerance"] = ft
    return out


if __name__ == "__main__":
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    print(json.dumps(run(quick="--quick" in sys.argv[1:]), indent=2))
