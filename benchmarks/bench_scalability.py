"""Fig. 13: scalability — total processing time vs input size."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_queries, build_workload, csv_row, evaluate_all


def run(quick: bool = True):
    sizes = (10_000, 20_000, 40_000) if quick else (10_000, 20_000, 40_000, 80_000)
    for n in sizes:
        w = build_workload("twitter", 0.9, seed=13, n_override=n)
        q = build_queries(w, 1, n_preds=(2,), seed=14)[0]
        res = evaluate_all(w, q)
        for m in ("orig", "ns", "pp", "core"):
            csv_row(
                f"fig13_n{n}_{m}",
                res[m]["total_ms"] / n * 1e3,
                f"total_s={res[m]['total_ms']/1e3:.1f};acc={res[m]['accuracy']:.3f}",
            )


if __name__ == "__main__":
    run()
