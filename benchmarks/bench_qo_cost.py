"""Table 4: decomposition of CORE's optimization cost (labeling / training /
searching) and its share of total processing time."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_queries, build_workload, csv_row, evaluate_all


def run(quick: bool = True):
    n_q = 2 if quick else 4
    for name in ("twitter", "coco", "ucf101"):
        w = build_workload(name, 0.9, seed=7)
        queries = build_queries(w, n_q, seed=8)
        rows = []
        for qi, q in enumerate(queries):
            res = evaluate_all(w, q, modes=("orig", "core"))
            st = res["core"]["stats"]
            total = res["core"]["total_ms"]
            rows.append((st, total, res["orig"]["total_ms"], res["core"]["qo_ms"], q.n))
            csv_row(
                f"table4_{name}_q{qi}", res["core"]["qo_ms"] * 1e3,
                (
                    f"n_preds={q.n};labeling_ms={st.get('labeling_ms',0):.0f};"
                    f"training_ms={st.get('training_ms',0):.0f};"
                    f"search_ms={st.get('search_ms',0):.0f};"
                    f"qo_pct={100*res['core']['qo_ms']/max(total,1e-9):.2f}%;"
                    f"reduction={(1-total/res['orig']['total_ms']):.1%}"
                ),
            )
        lab = np.mean([r[0].get("labeling_ms", 0) for r in rows])
        trn = np.mean([r[0].get("training_ms", 0) for r in rows])
        srch = np.mean([r[0].get("search_ms", 0) for r in rows])
        qo = np.mean([r[3] for r in rows])
        tot = np.mean([r[1] for r in rows])
        orig = np.mean([r[2] for r in rows])
        csv_row(
            f"table4_{name}_avg", qo * 1e3,
            (
                f"labeling_ms={lab:.0f};training_ms={trn:.0f};search_ms={srch:.0f};"
                f"qo_ms={qo:.0f};qo_pct={100*qo/max(tot,1e-9):.2f}%;"
                f"total_reduction={(1-tot/orig):.1%}"
            ),
        )


if __name__ == "__main__":
    run()
