"""Cross-query plan cache benchmark (DESIGN.md §8): warm-start vs cold
optimization, exact-repeat replay, and the dissimilarity fallback.

Four gated claims (``check_regression.py``):

  * ``plan_cache_warm_nodes`` < ``cold_nodes`` — warm-starting a SIMILAR
    query (same predicates, mildly shifted audited statistics) from a
    cached donor must visit strictly fewer branch-and-bound nodes than
    the cold search it replaces;
  * ``plan_cache_same_cost`` — the warm-started plan lands on the same
    Eq. 3.1 cost as the cold plan (within 5% — eps-approx classifier
    reuse may retrain a stage, shifting thresholds a hair);
  * ``plan_cache_hit_build_ratio`` <= 0.2 — an exact repeat is a cache
    HIT that replays the COREWIRE artifact: no sampling, no proxy
    training, no search.  The ratio is hit build wall-clock over cold
    build wall-clock (cold trains proxies, so the gap is structural, not
    a timer race);
  * ``plan_cache_dissimilar_cold`` + ``plan_cache_roundtrip_stable`` —
    a dissimilar query (different accuracy target, inverted
    selectivities) falls back to a cold optimization whose output meets
    the query's accuracy target exactly as an uncached run would, and
    the cache container round-trips byte-stably (save -> load -> save
    identical), which is what lets a coordinator ship it to a fleet.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    OptimizeOptions,
    PlanCache,
    build_plan,
    execute_plan,
    plan_accuracy,
)
from repro.data.synthetic import make_dataset, make_query, make_udfs

OPTS = OptimizeOptions(step=0.05, seed=0)


def _workload(seed: int):
    ds = make_dataset(n=6000, correlation=0.9, feature_noise=1.0, seed=seed)
    udfs = make_udfs(ds, hidden=24, depth=1, train_rows=1200, seed=seed,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], seed=seed + 1)
    return ds, udfs, q


def bench_plan_cache(*, seed: int = 21) -> dict:
    ds, udfs, q = _workload(seed)
    x = ds.x[:1200]

    cache = PlanCache()
    # ---- cold: first sight of the query, full build + search ----
    cold_plan, cold = cache.optimize_query(q, x, OPTS)
    assert cold["path"] == "cold", cold["path"]
    cold_nodes = cold["trace"]["nodes_visited"]

    # ---- exact repeat: HIT replays the wire artifact ----
    hit_plan, hit = cache.optimize_query(q, x, OPTS)
    assert hit["path"] == "hit", hit["path"]
    hit_ratio = hit["build_ms"] / max(cold["build_ms"], 1e-9)
    same_order_hit = list(hit_plan.order) == list(cold_plan.order)

    # ---- persistence BEFORE the drifted write-back refreshes stats ----
    blob = cache.to_bytes()
    roundtrip_stable = PlanCache.from_bytes(blob).to_bytes() == blob

    # ---- similar query: same predicates, mildly shifted audited stats
    # (what an engine's reservoir would report after gentle drift) ----
    sels = {0: 0.45, 1: 0.5, 2: 0.55}
    warm_plan, warm = cache.optimize_query(q, x, OPTS, selectivities=sels)
    assert warm["path"] == "warm", warm["path"]
    warm_nodes = warm["trace"]["nodes_visited"]
    cost_rel_delta = abs(warm_plan.est_total_cost - cold_plan.est_total_cost) \
        / cold_plan.est_total_cost
    same_cost = cost_rel_delta <= 0.05

    # ---- dissimilar query: tighter target + inverted selectivities ----
    q_far = make_query(ds, udfs, columns=[0, 1, 2], accuracy_target=0.95,
                      seed=seed + 1)
    far_sels = {0: 0.05, 1: 0.95, 2: 0.05}
    far_plan, far = cache.optimize_query(q_far, x, OPTS,
                                         selectivities=far_sels)
    dissimilar_cold = far["path"] == "cold"
    # no accuracy regression vs an uncached optimization of the same query
    x_eval = ds.x[1200:4200]
    orig = execute_plan(_full_plan(q_far), x_eval)
    acc_cached = plan_accuracy(execute_plan(far_plan, x_eval), orig)
    ref_plan = build_plan(q_far, x, OPTS)
    acc_uncached = plan_accuracy(execute_plan(ref_plan, x_eval), orig)

    return {
        "cold_nodes": int(cold_nodes),
        "warm_nodes": int(warm_nodes),
        "cold_build_ms": float(cold["build_ms"]),
        "hit_build_ms": float(hit["build_ms"]),
        "warm_build_ms": float(warm["build_ms"]),
        "hit_build_ratio": float(hit_ratio),
        "hit_same_order": bool(same_order_hit),
        "warm_cost_rel_delta": float(cost_rel_delta),
        "same_cost": bool(same_cost),
        "warm_distance": float(warm["distance"]),
        "dissimilar_cold": bool(dissimilar_cold),
        "dissimilar_accuracy_cached": float(acc_cached),
        "dissimilar_accuracy_uncached": float(acc_uncached),
        "accuracy_target": float(q_far.accuracy_target),
        "roundtrip_stable": bool(roundtrip_stable),
        "entries": len(cache),
        "stats": cache.stats.as_dict(),
    }


def bench_multidonor(*, seed: int = 21) -> dict:
    """Distance-weighted multi-donor warm starts vs single-donor: seed
    two caches (``k_donors=1`` and ``k_donors=3``) with the SAME three
    donor entries — distinct same-arity queries over the same columns
    (entries are digest-keyed, so multiple donors require multiple
    queries) — then warm-optimize a similar probe query.  The blended
    s* seed must not search more than the single-donor seed — averaging
    nearby incumbents can only tighten the stale L-node bounds — and the
    resulting plan must land on the same Eq. 3.1 cost."""
    ds, udfs, _ = _workload(seed)
    x = ds.x[:1200]
    donors = [make_query(ds, udfs, columns=[0, 1, 2], seed=s)
              for s in (seed + 10, seed + 11, seed + 12)]
    probe_q = make_query(ds, udfs, columns=[0, 1, 2], seed=seed + 13)
    out = {}
    for k in (1, 3):
        cache = PlanCache(k_donors=k)
        for dq in donors:
            cache.optimize_query(dq, x, OPTS)
        plan, info = cache.optimize_query(probe_q, x, OPTS)
        out[k] = {
            "path": info["path"],
            "donors": int(info.get("donors", 1)),
            "nodes": int(info["trace"]["nodes_visited"])
            if info.get("trace") else 0,
            "cost": float(plan.est_total_cost),
        }
    cost_delta = (abs(out[3]["cost"] - out[1]["cost"])
                  / max(out[1]["cost"], 1e-9))
    return {
        "single_donor_nodes": out[1]["nodes"],
        "multi_donor_nodes": out[3]["nodes"],
        "multi_donors_used": out[3]["donors"],
        "single_path": out[1]["path"],
        "multi_path": out[3]["path"],
        "multi_le_single": out[3]["nodes"] <= out[1]["nodes"],
        "cost_rel_delta": float(cost_delta),
        "same_cost": bool(cost_delta <= 0.05),
    }


def _full_plan(q):
    """The unproxied original plan (every UDF, input order) — the oracle
    plan_accuracy measures A against."""
    from repro.core.baselines import orig_plan

    return orig_plan(q)


def run(quick: bool = True):
    from benchmarks.common import csv_row

    out = bench_plan_cache()
    out["multidonor"] = bench_multidonor()
    csv_row(
        "plan_cache_warm_start", float(out["warm_nodes"]),
        (
            f"cold_nodes={out['cold_nodes']};"
            f"hit_ratio={out['hit_build_ratio']:.3f};"
            f"cost_delta={out['warm_cost_rel_delta']:.4f};"
            f"dissim_cold={int(out['dissimilar_cold'])};"
            f"roundtrip={int(out['roundtrip_stable'])};"
            f"multidonor_nodes={out['multidonor']['multi_donor_nodes']}"
        ),
    )
    return out


if __name__ == "__main__":
    import json
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    print(json.dumps(run(quick="--quick" in sys.argv[1:]), indent=2))
