"""Roofline table: reads the dry-run JSON cache and prints the per-cell
compute/memory/collective terms, dominant bottleneck, and MODEL_FLOPS
ratios (assignment deliverable g)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh_tag: str = "pod16x16"):
    out = []
    d = RESULTS / mesh_tag
    if not d.exists():
        return out
    for f in sorted(d.glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def run(quick: bool = True, mesh_tag: str = "pod16x16"):
    cells = load_cells(mesh_tag)
    if not cells:
        print(f"# no dry-run results under {RESULTS/mesh_tag}; run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print(f"# Roofline ({mesh_tag}): terms in seconds per step, per-device program")
    print("cell,us_per_call,derived")
    for c in cells:
        name = f"roofline_{c['arch']}__{c['shape']}"
        if c["status"] != "ok":
            print(f"{name},0,status={c['status']}")
            continue
        r = c["roofline"]
        t_bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        print(
            f"{name},{t_bound*1e6:.1f},"
            f"dom={r['dominant']};t_comp={r['t_compute_s']:.3g};"
            f"t_mem={r['t_memory_s']:.3g};t_coll={r['t_collective_s']:.3g};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
            f"roofline_fraction={r['roofline_fraction']:.4f};"
            f"mem_eff={r.get('memory_efficiency', 0):.4f}"
        )


if __name__ == "__main__":
    run()
