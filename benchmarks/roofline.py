"""Roofline sweep for the fused cascade scorer (default mode), plus the
legacy model-zoo dry-run table behind ``--zoo``.

Default mode drives ``repro.kernels.autotune.sweep_table`` over three
workload shapes x weight dtypes x serving-chunk sizes and prints, per
cell: the tuner's winning ``block_m`` vs the old static heuristic's
pick, exact modeled bytes moved, roofline time, and model bandwidth
utilization (MBU).  ``--json PATH`` additionally writes the full table
(the nightly CI artifact).  ``--measure`` appends an advisory wall-clock
column by timing ``score_masks`` on synthetic proxies — advisory because
in interpret mode (this container) it times Python, not the memory
system.

    PYTHONPATH=src python benchmarks/roofline.py
    PYTHONPATH=src python benchmarks/roofline.py --json results/autotune_sweep.json
    PYTHONPATH=src python benchmarks/roofline.py --zoo   # legacy table
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from repro.util import atomic_write_text

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

# the three gate shapes: (name, F, HP=stacked hidden, P=stages) spanning
# a small linear cascade, a mid mixed cascade, and a wide/deep one
SWEEP_SHAPES = (
    ("small-linear", 64, 128, 2),
    ("mid-mixed", 64, 512, 4),
    ("wide-mlp", 256, 2048, 16),
)
SWEEP_DTYPES = ("float32", "int8", "fp8")
SWEEP_HINTS = (256, 1024, 8192)


def cascade_sweep(measure: bool = False):
    """Run the autotune sweep; returns (rows, wins_by_shape)."""
    from repro.kernels import autotune

    rows = autotune.sweep_table(SWEEP_SHAPES, dtypes=SWEEP_DTYPES,
                                n_rows_hints=SWEEP_HINTS)
    if measure:
        from repro.kernels.ops import CascadeScorer
        from repro.training.proxy_models import MLPParams
        import numpy as np

        rng = np.random.RandomState(0)
        for r in rows:
            h = max(r["HP"] // r["P"], 2)
            params = [MLPParams(
                w1=rng.randn(r["F"], h).astype(np.float32),
                b1=rng.randn(h).astype(np.float32),
                w2=rng.randn(h).astype(np.float32), b2=np.float32(0),
                mean=np.zeros(r["F"], np.float32),
                scale=np.ones(r["F"], np.float32),
            ) for _ in range(r["P"])]
            scorer = CascadeScorer(params, [0.0] * r["P"],
                                   block_m=r["block_m"],
                                   max_tile=max(r["n_rows"], 256),
                                   dtype=r["dtype"])
            r["wall_s"] = autotune.measure_cell(scorer, r["n_rows"])
    wins = {}
    for r in rows:
        wins.setdefault(r["shape"], False)
        wins[r["shape"]] |= bool(r["beats_static"])
    return rows, wins


def print_sweep(rows, wins):
    print("# Cascade scorer autotune sweep: modeled roofline per "
          "(shape, dtype, chunk)")
    print("# t_model from exact operand bytes; block_m* marks cells where "
          "the tuner beats the old static heuristic")
    hdr = (f"{'shape':<14}{'dtype':<9}{'chunk':>6}{'block_m':>9}"
           f"{'static':>8}{'t_model':>10}{'t_static':>10}{'KB moved':>10}"
           f"{'MBU':>7}")
    if rows and "wall_s" in rows[0]:
        hdr += f"{'wall_ms':>9}"
    print(hdr)
    for r in rows:
        star = "*" if r["beats_static"] else " "
        line = (f"{r['shape']:<14}{r['dtype']:<9}{r['n_rows']:>6}"
                f"{r['block_m']:>8}{star}{r['static_block_m']:>8}"
                f"{r['t_model_us']:>8.1f}us{r['t_static_us']:>8.1f}us"
                f"{r['bytes_moved'] / 1024:>10.0f}{r['mbu']:>7.2f}")
        if "wall_s" in r:
            line += f"{r['wall_s'] * 1e3:>9.2f}"
        print(line)
    n_win = sum(wins.values())
    print(f"# autotune beats static on {n_win}/{len(wins)} shapes "
          f"({', '.join(s for s, w in wins.items() if w)})")


def zoo_table(mesh_tag: str = "pod16x16"):
    """Legacy model-zoo roofline table from the dry-run JSON cache."""
    cells = []
    d = RESULTS / mesh_tag
    if d.exists():
        for f in sorted(d.glob("*.json")):
            cells.append(json.loads(f.read_text()))
    if not cells:
        print(f"# no dry-run results under {RESULTS / mesh_tag}; run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print(f"# Roofline ({mesh_tag}): terms in seconds per step, "
          f"per-device program")
    print("cell,us_per_call,derived")
    for c in cells:
        name = f"roofline_{c['arch']}__{c['shape']}"
        if c["status"] != "ok":
            print(f"{name},0,status={c['status']}")
            continue
        r = c["roofline"]
        t_bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        print(
            f"{name},{t_bound*1e6:.1f},"
            f"dom={r['dominant']};t_comp={r['t_compute_s']:.3g};"
            f"t_mem={r['t_memory_s']:.3g};t_coll={r['t_collective_s']:.3g};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
            f"roofline_fraction={r['roofline_fraction']:.4f};"
            f"mem_eff={r.get('memory_efficiency', 0):.4f}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--zoo", action="store_true",
                    help="legacy model-zoo dry-run table instead of the "
                         "cascade scorer sweep")
    ap.add_argument("--mesh-tag", default="pod16x16")
    ap.add_argument("--measure", action="store_true",
                    help="append advisory wall-clock per cell (meaningful "
                         "on compiled backends only)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the sweep table as JSON (CI artifact)")
    args = ap.parse_args()
    if args.zoo:
        zoo_table(args.mesh_tag)
        return
    rows, wins = cascade_sweep(measure=args.measure)
    print_sweep(rows, wins)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        atomic_write_text(out, json.dumps(
            {"rows": rows, "wins_by_shape": wins}, indent=1))
        print(f"# wrote {out}")


if __name__ == "__main__":
    main()
