"""Shared workload construction for the benchmark suite.

Three synthetic stand-ins mirror the paper's datasets (offline container —
see DESIGN.md assumption log): feature dim / UDF cost ratios / selectivities
follow the paper's setup (text: cheap NLP UDFs; image: heavier detector;
video: heaviest).  Correlation is the controlled variable.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Tuple

import numpy as np

from repro.core import execute_plan, optimize, orig_plan, ns_plan, pp_plan, plan_accuracy
from repro.data.synthetic import Dataset, make_dataset, make_query, make_udfs

DATASET_PROFILES = {
    # name: (n, n_features, udf_cost_ms, cost_scale, k_frac)
    "twitter": dict(n=40_000, n_features=64, udf_cost=20.0, k_frac=0.05),
    "coco": dict(n=20_000, n_features=128, udf_cost=80.0, k_frac=0.08),
    "ucf101": dict(n=8_000, n_features=96, udf_cost=200.0, k_frac=0.15),
}


@dataclass
class Workload:
    ds: Dataset
    udfs: list
    k: int  # optimization-sample size

    @property
    def x_opt(self):
        return self.ds.x[: self.k]

    @property
    def x_exec(self):
        return self.ds.x[self.k :]


@lru_cache(maxsize=16)
def build_workload(name: str, correlation: float, seed: int = 0,
                   n_override: int = 0) -> Workload:
    prof = DATASET_PROFILES[name]
    n = n_override or prof["n"]
    ds = make_dataset(
        name=name, n=n, n_features=prof["n_features"], n_columns=4,
        correlation=correlation, feature_noise=1.1, label_noise=0.25, seed=seed,
    )
    udfs = make_udfs(
        ds, hidden=48, depth=2, train_rows=3000, seed=seed,
        declared_cost_ms=prof["udf_cost"],
        cost_scale={0: 1.0, 1: 3.0, 2: 0.3, 3: 1.5},
    )
    return Workload(ds=ds, udfs=udfs, k=int(prof["k_frac"] * n))


def build_queries(w: Workload, n_queries: int, *, n_preds=(2, 3), A=0.9, seed=0):
    rng = np.random.RandomState(seed)
    queries = []
    for qi in range(n_queries):
        k = n_preds[qi % len(n_preds)]
        cols = tuple(sorted(rng.choice(4, k, replace=False)))
        sel = float(rng.uniform(0.35, 0.6))
        queries.append(
            make_query(w.ds, w.udfs, columns=list(cols), target_selectivity=sel,
                       accuracy_target=A, seed=seed + qi)
        )
    return queries


def evaluate_all(w: Workload, query, *, modes=("orig", "ns", "pp", "core"), step=0.02):
    """Optimize + execute each mode; returns {mode: result dict}."""
    out = {}
    orig = orig_plan(query)
    orig_res = execute_plan(orig, w.x_exec)
    for mode in modes:
        t0 = time.perf_counter()
        if mode == "orig":
            plan = orig
        elif mode == "ns":
            plan = ns_plan(query, w.x_opt)
        elif mode == "pp":
            plan = pp_plan(query, w.x_opt, step=step)
        else:
            plan = optimize(query, w.x_opt, mode=mode, step=step)
        qo_ms = (time.perf_counter() - t0) * 1e3
        res = orig_res if mode == "orig" else execute_plan(plan, w.x_exec)
        out[mode] = {
            "plan": plan,
            "qo_ms": qo_ms,
            "exec_cost_ms": res.model_cost_ms,
            "cost_per_record_ms": res.cost_per_record(len(w.x_exec)),
            "wall_ms": res.wall_ms,
            "accuracy": plan_accuracy(res, orig_res),
            "total_ms": qo_ms + res.model_cost_ms,
            "stats": plan.meta.get("stats", {}),
        }
    return out


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")
