"""Cross-family consistency: the serving path (prefill + decode_step) must
agree with the training path (forward) for every architecture family —
this is the invariant that makes the cascade's UDF outputs identical
whether batched or streamed."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models.registry import get_family, make_batch

FAMILIES_TO_ARCH = {
    "dense": "deepseek-67b",
    "moe": "qwen3-moe-30b-a3b",
    "mla-moe": "deepseek-v2-lite-16b",
    "ssm": "mamba2-2.7b",
    "hybrid": "recurrentgemma-2b",
    "encdec": "seamless-m4t-medium",
    "vlm": "paligemma-3b",
    "qkv-bias": "qwen1.5-110b",
}


@pytest.mark.parametrize("arch", sorted(set(FAMILIES_TO_ARCH.values())))
def test_prefill_matches_forward(arch):
    cfg = reduced_config(arch).replace(remat=False)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(3)
    params = fam.init(key, cfg)
    batch = make_batch(cfg, 2, 32, key)
    full = jax.jit(lambda p, b: fam.forward(p, cfg, b))(params, batch)
    logits, cache = jax.jit(lambda p, b: fam.prefill(p, cfg, b))(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full[:, -1]), atol=5e-2, rtol=5e-2,
        err_msg=f"{arch}: prefill disagrees with forward",
    )


# stacked (L, B, S, ...) KV caches support test-side repadding; fixed-state /
# per-block families are covered by decode-from-scratch instead
_HANDOFF = {"deepseek-67b", "qwen1.5-110b", "paligemma-3b", "seamless-m4t-medium"}


@pytest.mark.parametrize("arch", sorted(set(FAMILIES_TO_ARCH.values())))
def test_decode_path_matches_forward(arch):
    """Serving path == training path: either prefill(S)+decode continuation,
    or full decode-from-scratch, must reproduce forward's last logits."""
    cfg = reduced_config(arch).replace(remat=False)
    if cfg.moe is not None:
        # capacity DROPPING legitimately differs between batched forward
        # (big N, overflow possible) and one-token decode (never overflows);
        # disable drops to compare pure numerics
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    fam = get_family(cfg)
    key = jax.random.PRNGKey(4)
    params = fam.init(key, cfg)
    total, S = 48, 32
    batch_full = make_batch(cfg, 2, total, key)
    tokens_full = batch_full["tokens"]
    full = jax.jit(lambda p, b: fam.forward(p, cfg, b))(params, batch_full)
    dstep = jax.jit(lambda p, c, t: fam.decode_step(p, cfg, c, t))

    if arch in _HANDOFF:
        batch_prefix = dict(batch_full)
        # VLM token length excludes the patch prefix
        S_tok = S if cfg.family != "vlm" else S - cfg.encoder.num_prefix
        batch_prefix["tokens"] = tokens_full[:, :S_tok]
        batch_prefix.pop("labels", None)
        lg, cache = jax.jit(lambda p, b: fam.prefill(p, cfg, b))(params, batch_prefix)
        prompt_len = int(cache["pos"])

        def pad(x):
            if x.ndim >= 3 and x.shape[2] == prompt_len:  # (L, B, S, ...) KV
                w = [(0, 0)] * x.ndim
                w[2] = (0, 16)
                return jnp.pad(x, w)
            return x

        cache = {k: (jax.tree.map(pad, v) if k != "pos" else v) for k, v in cache.items()}
        start = S_tok
    else:
        cache = fam.init_cache(cfg, 2, total)
        lg = None
        start = 0
    for t in range(start, tokens_full.shape[1]):
        lg, cache = dstep(params, cache, tokens_full[:, t])
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full[:, -1]), atol=8e-2, rtol=8e-2,
        err_msg=f"{arch}: decode path disagrees with forward",
    )


def test_moe_dispatch_properties():
    """Dense-dispatch invariants: capacity respected, dropped tokens get zero
    contribution, outputs are convex combos of expert outputs."""
    from repro.models import moe as M

    cfg = reduced_config("qwen3-moe-30b-a3b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = M.init_experts(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = jax.jit(lambda p, x: M.moe_apply(p, cfg, x))(p, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0
    # with absurdly low capacity everything drops -> output ~ 0
    cfg_low = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=1e-6))
    out_low, _ = jax.jit(lambda p, x: M.moe_apply(p, cfg_low, x))(p, x)
    # capacity floor is 8 slots/expert, so a few tokens still land; bounded
    assert float(jnp.abs(out_low).mean()) <= float(jnp.abs(out).mean()) + 1e-6


def test_mla_absorbed_decode_matches_naive():
    """The absorbed-matrix MLA decode must equal naive MLA attention."""
    from repro.models import mla as MLA

    cfg = reduced_config("deepseek-v2-lite-16b")
    a = cfg.attention
    key = jax.random.PRNGKey(5)
    p = MLA.init_mla(key, cfg)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, cfg.d_model), jnp.float32) * 0.5
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    naive = MLA.mla_attend(p, cfg, x, positions)
    # decode token-by-token with the latent cache
    ckv = jnp.zeros((B, S, a.kv_lora_rank), x.dtype)
    krope = jnp.zeros((B, S, a.qk_rope_head_dim), x.dtype)
    outs = []
    for t in range(S):
        o, ckv, krope = MLA.mla_decode(p, cfg, x[:, t : t + 1], ckv, krope, t)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(naive), atol=2e-3, rtol=2e-3)
