"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode),
sweeping shapes and dtypes per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.proxy_score import proxy_score
from repro.kernels.ssd_scan import ssd_chunk


# ------------------------------------------------------------- proxy_score
@pytest.mark.parametrize("n,f,p", [(64, 32, 1), (300, 64, 3), (1024, 128, 8), (97, 200, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_proxy_score_matches_ref(n, f, p, dtype):
    key = jax.random.PRNGKey(n + f + p)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (n, f), dtype)
    w = jax.random.normal(k2, (f, p), dtype)
    b = jax.random.normal(k3, (p,), jnp.float32)
    thr = jax.random.normal(k4, (p,), jnp.float32)
    scores, mask = proxy_score(x, w, b, thr, interpret=True)
    sref, mref = ref.proxy_score_ref(x, w, b, thr)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(scores), np.asarray(sref), rtol=tol, atol=tol)
    # mask may differ only at near-threshold ties under bf16
    disagree = np.mean(np.asarray(mask) != np.asarray(mref))
    assert disagree <= (0.0 if dtype == jnp.float32 else 0.02)


def test_proxy_score_folded_standardizer():
    from repro.kernels.ops import fold_standardizer, proxy_score_batch
    from repro.training.proxy_models import LinearParams, linear_score

    rng = np.random.RandomState(0)
    F = 48
    params = LinearParams(
        w=jnp.asarray(rng.randn(F), jnp.float32),
        b=jnp.asarray(0.3, jnp.float32),
        mean=jnp.asarray(rng.randn(F), jnp.float32),
        scale=jnp.asarray(np.abs(rng.randn(F)) + 0.5, jnp.float32),
    )
    x = rng.randn(500, F).astype(np.float32)
    direct = np.asarray(linear_score(params, jnp.asarray(x)))
    mask = proxy_score_batch(params, x, threshold=0.0)
    np.testing.assert_array_equal(mask, direct >= 0.0)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "b,sq,sk,h,kv,d",
    [(1, 128, 128, 4, 4, 32), (2, 256, 256, 8, 2, 64), (1, 128, 384, 4, 1, 128)],
)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, sq, sk, h, kv, d, causal, dtype):
    if causal and sq != sk:
        pytest.skip("causal requires square q/kv in this test")
    key = jax.random.PRNGKey(b * sq + h + d)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, d), dtype)
    k = jax.random.normal(k2, (b, sk, kv, d), dtype)
    v = jax.random.normal(k3, (b, sk, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    oref = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(oref, np.float32), rtol=tol, atol=tol
    )


# ------------------------------------------------------------------- SSD
@pytest.mark.parametrize("nc,q,h,p,n", [(2, 16, 4, 8, 16), (4, 64, 2, 16, 32)])
def test_ssd_chunk_matches_ref(nc, q, h, p, n):
    key = jax.random.PRNGKey(nc * q + h)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (nc, q, h, p), jnp.float32)
    dA = -jnp.abs(jax.random.normal(ks[1], (nc, q, h))) * 0.1
    B = jax.random.normal(ks[2], (nc, q, h, n), jnp.float32)
    C = jax.random.normal(ks[3], (nc, q, h, n), jnp.float32)
    y, st, dec = ssd_chunk(x, dA, B, C, interpret=True)
    yr, str_, decr = ref.ssd_chunk_ref(x, dA, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(decr), rtol=1e-5, atol=1e-5)


def test_ssd_ops_matches_model_reference():
    """kernels.ops.ssd (kernel + jnp combine) == models.ssm.ssd_chunked."""
    from repro.kernels.ops import ssd as ssd_ops
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    b, s, h, p, g, n, chunk = 2, 128, 4, 8, 1, 16, 32
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = jax.random.normal(ks[2], (h,)) * 0.5
    B = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)
    D = jnp.ones((h,))
    y1, f1 = ssd_ops(x, dt, A_log, B, C, D, chunk)
    y2, f2 = ssd_chunked(x, dt, A_log, B, C, D, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=2e-4, atol=2e-4)
