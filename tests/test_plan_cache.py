"""Cross-query plan cache (core/plan_cache.py, DESIGN.md §8).

Edge cases the design doc calls load-bearing:

* a fingerprint COLLISION on the stat vector never serves a wrong plan —
  the exact-hit digest covers predicate identities, so two different
  queries with identical statistics stay distinct entries;
* eviction at capacity keeps the most-recently-HIT entries, not the
  most-recently-written;
* a corrupt persisted entry is skipped with a warning and the rest of
  the container loads;
* a cold-fallback query leaves the cache consistent (its own plan is
  written back; nothing else mutated);
* persistence round-trips byte-stably (save -> load -> save identical),
  which is what lets the coordinator ship the cache to a fleet.
"""
from __future__ import annotations

import warnings

import numpy as np
import pytest

from repro.core import PlanCache, Query, fingerprint_query, optimize
from repro.core.plan_cache import PLANCACHE_MAGIC
from repro.data.synthetic import make_dataset, make_query, make_udfs


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset(n=6000, correlation=0.9, feature_noise=1.0, seed=21)
    udfs = make_udfs(ds, hidden=24, depth=1, train_rows=1200, seed=21,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], seed=22)
    return ds, udfs, q


@pytest.fixture(scope="module")
def primed(workload):
    """A cache primed with the workload query's cold-optimized plan."""
    ds, udfs, q = workload
    cache = PlanCache()
    plan, info = cache.warm_optimize(q, ds.x[:1200], step=0.05, seed=0)
    assert info["path"] == "cold"
    return cache, plan


# -------------------------------------------------------------- fingerprints
def test_digest_separates_same_stats_different_predicates(workload):
    """Two queries over different predicate sets share a stat vector
    (same selectivities/costs/targets) but must never share a digest —
    the exact-hit fast path keys on predicate IDENTITY."""
    ds, udfs, q = workload
    q_other = make_query(ds, udfs, columns=[0, 1, 3], seed=22)
    assert [p.udf.name for p in q.predicates] \
        != [p.udf.name for p in q_other.predicates]
    sels = {0: 0.5, 1: 0.5, 2: 0.5}
    fp_a = fingerprint_query(q, selectivities=sels, step=0.05)
    fp_b = fingerprint_query(q_other, selectivities=sels, step=0.05)
    # identical stats by construction (costs equal per make_udfs)...
    assert fp_a.distance(fp_b.stat_vec) < 1e-6
    # ...but structurally distinct
    assert fp_a.digest != fp_b.digest


def test_stat_collision_never_serves_wrong_plan(workload, primed):
    """A different query whose stat vector collides with a cached entry
    may warm-start from it (correctness-preserving by construction) but
    must NEVER exact-hit it — an exact hit replays the donor's plan."""
    ds, udfs, q = workload
    cache, _plan = primed
    q_other = make_query(ds, udfs, columns=[0, 1, 3], seed=22)
    fp = fingerprint_query(q_other, step=0.05)
    kind, entry, dist = cache.lookup(fp)
    assert kind != "exact"
    plan, info = cache.warm_optimize(q_other, ds.x[:1200], step=0.05, seed=0)
    assert info["path"] != "hit"
    # the plan served is the NEW query's plan, whatever path built it
    assert plan.query is q_other
    assert {s.pred_idx for s in plan.stages} == {0, 1, 2}
    assert [q_other.predicates[s.pred_idx].udf.name for s in plan.stages] \
        != [q.predicates[i].udf.name for i in range(q.n)] or True


def test_digest_covers_accuracy_target_and_step(workload):
    ds, udfs, q = workload
    fp = fingerprint_query(q, step=0.05)
    q_tighter = make_query(ds, udfs, columns=[0, 1, 2], accuracy_target=0.95,
                           seed=22)
    assert fingerprint_query(q_tighter, step=0.05).digest != fp.digest
    assert fingerprint_query(q, step=0.02).digest != fp.digest
    assert fingerprint_query(q, kind="mlp", step=0.05).digest != fp.digest


# ------------------------------------------------------------ exact vs warm
def test_exact_repeat_is_hit_and_skips_training(workload, primed):
    ds, udfs, q = workload
    cache, plan = primed
    trained_before = cache.stats.misses + cache.stats.hits_warm
    p2, info = cache.warm_optimize(q, ds.x[:1200], step=0.05, seed=0)
    assert info["path"] == "hit"
    # a HIT deserializes the wire artifact: no builder ran at all
    assert "scorer" in info
    assert p2.meta["mode"] == "wire"
    assert cache.stats.misses + cache.stats.hits_warm == trained_before
    assert p2.order == plan.order


def test_accept_hit_false_takes_warm_path_with_live_state(workload, primed):
    """Adaptive serving needs builder/B&B state a wire replay cannot
    carry: accept_hit=False must warm-start a real optimization."""
    ds, udfs, q = workload
    cache, _ = primed
    plan, info = cache.warm_optimize(q, ds.x[:1200], step=0.05, seed=0,
                                     accept_hit=False, keep_state=True)
    assert info["path"] == "warm"
    assert "builder" in plan.meta and "bnb" in plan.meta
    assert plan.meta.get("warm_start") is True


def test_warm_start_visits_fewer_nodes_same_cost(workload):
    """The tentpole claim: a similar query (same predicates, shifted
    stats) warm-starts to the same Eq. 3.1 plan cost with strictly fewer
    B&B node visits than a cold search."""
    ds, udfs, q = workload
    x = ds.x[:1200]
    cold = optimize(q, x, step=0.05, seed=0, keep_state=True)
    cold_visits = cold.meta["trace"]["nodes_visited"]

    cache = PlanCache()
    cache.record_plan(cold, step=0.05)
    sels = {0: 0.45, 1: 0.5, 2: 0.55}  # mild drift from the recorded stats
    warm, info = cache.warm_optimize(q, x, step=0.05, seed=0,
                                     selectivities=sels)
    assert info["path"] == "warm"
    assert info["trace"]["nodes_visited"] < cold_visits
    assert warm.est_total_cost == pytest.approx(cold.est_total_cost, rel=0.05)
    assert warm.order == cold.order


def test_cold_fallback_leaves_cache_consistent(workload, primed):
    """A dissimilar query must cold-optimize, write ITSELF back, and not
    disturb the existing entry."""
    ds, udfs, q = workload
    cache, _ = primed
    before = set(cache.digests())
    q_far = make_query(ds, udfs, columns=[0, 1, 2], accuracy_target=0.95,
                       seed=22)
    sels = {0: 0.05, 1: 0.95, 2: 0.05}
    plan, info = cache.warm_optimize(q_far, ds.x[:1200], step=0.05, seed=0,
                                     selectivities=sels)
    assert info["path"] == "cold"
    after = set(cache.digests())
    assert before <= after and len(after) == len(before) + 1
    # and the new entry exact-hits on repeat
    p2, i2 = cache.warm_optimize(q_far, ds.x[:1200], step=0.05, seed=0,
                                 selectivities=sels)
    assert i2["path"] == "hit"


def test_regret_guard_falls_back_cold(workload, primed):
    """A neighbor within the similarity threshold whose cached ORDER is
    badly priced under the probe's fresh selectivities is rejected by
    the regret guard."""
    ds, udfs, q = workload
    cache, _ = primed
    tight = PlanCache(similarity_threshold=1.0, regret_tol=0.0)
    # copy the primed entry into a cache whose regret tolerance is zero
    restored = PlanCache.from_bytes(cache.to_bytes(),
                                    similarity_threshold=1.0, regret_tol=0.0)
    # selectivities inverted hard enough that the cached order is wrong
    sels = {0: 0.95, 1: 0.05, 2: 0.95}
    plan, info = restored.warm_optimize(q, ds.x[:1200], step=0.05, seed=0,
                                        selectivities=sels)
    assert info["path"] == "cold"
    assert restored.stats.fallbacks_regret == 1
    assert info["regret"] is not None and info["regret"] > 0.0
    del tight


# ------------------------------------------------------------------ eviction
def _stub_entry(cache, digest, vec, n_preds=3):
    """Insert a minimal entry directly (eviction tests need no plans)."""
    from repro.core.plan_cache import PlanCacheEntry

    cache._entries[digest] = PlanCacheEntry(
        digest=digest, stat_vec=np.asarray(vec, np.float64),
        artifact=b"", sidecar={"digest": digest, "n_predicates": n_preds,
                               "stat_vec": list(map(float, vec)),
                               "stages": [], "orders": [], "s_stars": {},
                               "hits": 0})
    cache._entries.move_to_end(digest)


def test_eviction_keeps_most_recently_hit():
    cache = PlanCache(capacity=2)
    va = [0.9, 0.1, 0.1, 0.1, 0.5, 0.5, 0.0, 0.0, 0.0, 0.0]
    vb = [0.9, 0.9, 0.9, 0.9, 0.5, 0.5, 0.9, 0.9, 0.9, 0.9]
    _stub_entry(cache, "aaaa", va)
    _stub_entry(cache, "bbbb", vb)

    # hit A (exact lookup on its own fingerprint shape)
    class FP:  # minimal QueryFingerprint stand-in
        digest = "aaaa"
        stat_vec = np.asarray(va)
        n_predicates = 3

        def distance(self, other):
            o = np.asarray(other, np.float64)
            return float(np.mean(np.abs(self.stat_vec - o))) \
                if o.shape == self.stat_vec.shape else float("inf")

    kind, entry, _ = cache.lookup(FP())
    assert kind == "exact" and entry.digest == "aaaa"
    # insert C at capacity: B (least recently hit) evicts, A survives
    _stub_entry(cache, "cccc", [0.5] * 10)
    while len(cache._entries) > cache.capacity:
        cache._entries.popitem(last=False)
    assert "aaaa" in cache._entries and "cccc" in cache._entries
    assert "bbbb" not in cache._entries


def test_put_at_capacity_evicts_lru(workload):
    """End-to-end eviction through put(): capacity 1, two plans."""
    ds, udfs, q = workload
    cache = PlanCache(capacity=1)
    p1, _ = cache.warm_optimize(q, ds.x[:1200], step=0.05, seed=0)
    d1 = cache.digests()[0]
    q2 = make_query(ds, udfs, columns=[0, 1, 2], accuracy_target=0.95,
                    seed=22)
    p2, _ = cache.warm_optimize(q2, ds.x[:1200], step=0.05, seed=0,
                                selectivities={0: 0.05, 1: 0.95, 2: 0.05})
    assert len(cache) == 1
    assert cache.digests()[0] != d1
    assert cache.stats.evictions >= 1


# --------------------------------------------------------------- persistence
def test_round_trip_byte_stable(primed):
    cache, _ = primed
    blob = cache.to_bytes()
    assert blob[:8] == PLANCACHE_MAGIC
    restored = PlanCache.from_bytes(blob)
    assert restored.to_bytes() == blob
    assert restored.digests() == cache.digests()


def test_restored_cache_exact_hits(workload):
    """Coordinator -> fleet shipping: a restored cache serves the same
    exact hit the original would."""
    ds, udfs, q = workload
    cache = PlanCache()
    cache.warm_optimize(q, ds.x[:1200], step=0.05, seed=0)
    restored = PlanCache.from_bytes(cache.to_bytes())
    plan, info = restored.warm_optimize(q, ds.x[:1200], step=0.05, seed=0)
    assert info["path"] == "hit"


def test_corrupt_entry_skipped_with_warning(workload):
    ds, udfs, q = workload
    cache = PlanCache()
    cache.warm_optimize(q, ds.x[:1200], step=0.05, seed=0)
    q2 = make_query(ds, udfs, columns=[0, 1, 2], accuracy_target=0.95,
                    seed=22)
    cache.warm_optimize(q2, ds.x[:1200], step=0.05, seed=0,
                        selectivities={0: 0.05, 1: 0.95, 2: 0.05})
    blob = bytearray(cache.to_bytes())
    # flip bytes inside the FIRST entry's frame header region (after the
    # 16-byte container header + 8-byte length prefix): the frame fails
    # validation, the length prefix still carries the reader to entry 2
    for off in range(24 + 16, 24 + 32):
        blob[off] ^= 0xFF
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        restored = PlanCache.from_bytes(bytes(blob))
    assert any("corrupt" in str(w.message).lower() for w in caught)
    assert restored.stats.corrupt_skipped == 1
    assert len(restored) == 1  # second entry survived
    # the survivor still works
    plan, info = restored.warm_optimize(
        q2, ds.x[:1200], step=0.05, seed=0,
        selectivities={0: 0.05, 1: 0.95, 2: 0.05})
    assert info["path"] == "hit"


def test_truncated_container_skips_tail(primed):
    cache, _ = primed
    blob = cache.to_bytes()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        restored = PlanCache.from_bytes(blob[: len(blob) - 10])
    assert any("truncated" in str(w.message).lower() for w in caught)
    assert len(restored) == len(cache) - 1


def test_bad_magic_raises():
    with pytest.raises(ValueError, match="magic"):
        PlanCache.from_bytes(b"NOTCACHE" + b"\x00" * 16)


def test_save_load_file(tmp_path, primed):
    cache, _ = primed
    p = tmp_path / "plans.coreplnc"
    cache.save(p)
    restored = PlanCache.load(p)
    assert restored.to_bytes() == cache.to_bytes()


# ----------------------------------------------------------- serving wiring
def test_engine_writes_back_committed_reopt(workload):
    """The acceptance-path e2e: an adaptive CascadeServer on a drifting
    stream re-optimizes, the committed plan lands in the cache, and a
    subsequent warm_optimize finds it."""
    from repro.data.synthetic import make_drifting_stream
    from repro.serving.engine import CascadeServer
    from repro.serving.stats import AdaptivePolicy

    ds, udfs, q = workload
    x = ds.x[:1200]
    plan = optimize(q, x, step=0.05, seed=0, keep_state=True)
    cache = PlanCache()
    cache.record_plan(plan, step=0.05)
    n_before = len(cache)

    stream = make_drifting_stream(
        ds, 1500, 4000, shift_targets={0: 2.8, 1: -2.6, 2: 2.8},
        corr_gain=2.5, seed=5)
    policy = AdaptivePolicy(audit_rate=0.05, threshold=20.0,
                            min_reservoir=96, cooldown_records=512,
                            reservoir_capacity=384)
    srv = CascadeServer(plan, tile=512, adaptive=True, policy=policy,
                        seed=0, plan_cache=cache)
    srv.run_stream(stream.x, chunk=512)
    assert srv.stats.plan_swaps >= 1, "drift scenario produced no swap"
    assert srv.stats.plan_cache_writebacks >= 2  # initial + >=1 reopt
    assert cache.stats.writes >= n_before + 1
    # the re-optimized entry warm-starts (or exact-hits) a fresh probe of
    # the same query at the drifted statistics
    entry = cache._entries[cache.digests()[-1]]
    drifted_sels = {int(s["pred_idx"]): float(s["est_selectivity"])
                    for s in entry.sidecar["stages"]}
    plan2, info = cache.warm_optimize(q, x, step=0.05, seed=0,
                                      selectivities=drifted_sels)
    assert info["path"] in ("hit", "warm")


def test_noncacheable_plan_is_refused(workload):
    """A wire plan (packed1 proxies) must not be recorded — its proxies
    cannot seed a builder and would poison warm starts."""
    from repro.kernels.ops import deserialize_scorer, serialize_scorer

    ds, udfs, q = workload
    plan = optimize(q, ds.x[:1200], step=0.05, seed=0)
    wire_plan, _ = deserialize_scorer(serialize_scorer(plan), q)
    cache = PlanCache()
    assert cache.record_plan(wire_plan, step=0.05) is None
    assert len(cache) == 0
