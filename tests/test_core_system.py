"""System/integration tests for CORE: Theorem-1 commutativity, builder
reuse, allocation/B&B consistency, end-to-end accuracy + speedup."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BranchAndBound,
    ProxyBuilder,
    accuracy_allocation,
    execute_plan,
    ns_plan,
    optimize,
    orig_plan,
    plan_accuracy,
    pp_plan,
)
from repro.data.synthetic import make_dataset, make_query, make_udfs


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset(n=12000, correlation=0.9, feature_noise=1.0, label_noise=0.2, seed=3)
    udfs = make_udfs(ds, hidden=32, depth=2, train_rows=2500, seed=3,
                     declared_cost_ms=10.0, cost_scale={0: 1.0, 1: 2.0, 2: 0.5})
    q = make_query(ds, udfs, columns=[0, 1, 2], target_selectivity=0.5,
                   accuracy_target=0.9, seed=4)
    return ds, udfs, q


# ----------------------------------------------------- Theorem 1 (property)
@given(seed=st.integers(0, 10_000), alpha_q=st.floats(0.05, 0.95))
@settings(max_examples=30, deadline=None)
def test_commutativity_of_fixed_proxy_and_sigma(seed, alpha_q):
    """A trained sigma-hat with a FIXED threshold commutes with sigma:
    filtering order does not change the surviving set (Lemma 2)."""
    rng = np.random.RandomState(seed)
    n = 500
    scores = rng.randn(n)
    sigma = rng.rand(n) < 0.5
    thr = np.quantile(scores, alpha_q)
    keep_hat = scores >= thr
    a = np.flatnonzero(keep_hat & sigma)  # sigma-hat then sigma
    b = np.flatnonzero(sigma & keep_hat)  # sigma then sigma-hat
    assert np.array_equal(a, b)


def test_builder_sample_reuse_and_lazy_labeling(workload):
    ds, udfs, q = workload
    b = ProxyBuilder(q, ds.x[:1000], seed=0)
    r01 = b.rows_after_sigmas((0, 1))
    calls_after = dict(b.stats.udf_calls)
    # same set, different order: no new UDF calls (Theorem-1 set keying)
    r10 = b.rows_after_sigmas((1, 0))
    assert np.array_equal(np.sort(r01), np.sort(r10))
    assert b.stats.udf_calls == calls_after
    # pred 0 labeled on all 1000 rows; pred 1 only on sigma_0 survivors
    assert b.stats.udf_calls[0] == 1000
    assert b.stats.udf_calls[1] < 1000
    # relabeling is memoized
    b.sigma_mask(0, np.arange(1000))
    assert b.stats.udf_calls[0] == 1000


def test_classifier_reuse_on_similar_samples(workload):
    ds, udfs, q = workload
    b = ProxyBuilder(q, ds.x[:1500], eps=0.2, seed=0)
    p1, rows1 = b.get_proxy(1, (0,), ())
    p0, _ = b.get_proxy(0, (), ())
    n_trained = b.stats.n_trained
    # same relation refined by a high-accuracy prefix proxy -> eps-similar
    p2, rows2 = b.get_proxy(1, (0,), [(p0, 0.98)])
    assert b.stats.n_reused >= 1
    assert b.stats.n_trained == n_trained  # no retrain happened


def test_accuracy_allocation_product_constraint(workload):
    ds, udfs, q = workload
    b = ProxyBuilder(q, ds.x[:1500], seed=0)
    alloc = accuracy_allocation(b, (0, 1, 2), 0.9, step=0.05)
    prod = np.prod(alloc.alphas)
    assert prod >= 0.9 - 1e-9
    assert alloc.total_cost > 0
    assert len(alloc.proxies) == 3


def test_bnb_matches_exhaustive_plan_quality(workload):
    """B&B (Alg. 2) should find a plan within a few % of CORE-h (§6.5)."""
    ds, udfs, q = workload
    xs = ds.x[:1500]
    plan_h = optimize(q, xs, mode="core-h", step=0.05, seed=0)
    plan_bb = optimize(q, xs, mode="core", step=0.05, seed=0)
    assert plan_bb.est_total_cost <= plan_h.est_total_cost * 1.10
    tr = plan_bb.meta["trace"]
    assert tr["nodes_visited"] <= tr["nodes_total"]


def test_bnb_visits_fewer_nodes_than_exhaustive(workload):
    ds, udfs, q = workload
    b = ProxyBuilder(q, ds.x[:1500], seed=0)
    bb = BranchAndBound(b, 0.9, step=0.05, fine_grained=True)
    _alloc, trace = bb.run()
    # exhaustive visits all 15 nodes (n=3: 3+6+6); pruning must bite
    assert trace.nodes_visited < trace.nodes_total


# ------------------------------------------------------------- end-to-end
def test_core_meets_accuracy_and_beats_orig(workload):
    ds, udfs, q = workload
    k = 2000
    xs, xrest = ds.x[:k], ds.x[k:]
    plan = optimize(q, xs, mode="core", seed=0)
    orig = execute_plan(orig_plan(q), xrest)
    res = execute_plan(plan, xrest)
    acc = plan_accuracy(res, orig)
    assert acc >= q.accuracy_target - 0.03, f"empirical accuracy {acc}"
    assert res.model_cost_ms < orig.model_cost_ms, "CORE should cut cost vs ORIG"


def test_all_optimizers_produce_runnable_plans(workload):
    ds, udfs, q = workload
    xs, xrest = ds.x[:1500], ds.x[1500:4000]
    orig = execute_plan(orig_plan(q), xrest)
    for plan in (ns_plan(q, xs), pp_plan(q, xs), optimize(q, xs, mode="core-a")):
        res = execute_plan(plan, xrest)
        assert plan_accuracy(res, orig) > 0.75
        assert len(res.stages) == len(plan.stages)


def test_executor_bookkeeping(workload):
    ds, udfs, q = workload
    xrest = ds.x[2000:6000]
    res = execute_plan(orig_plan(q), xrest)
    st0 = res.stages[0]
    assert st0.n_in == len(xrest)
    assert st0.n_udf == st0.n_proxy_kept == st0.n_in  # no proxy on ORIG
    # monotone shrink through the cascade
    for a, b in zip(res.stages, res.stages[1:]):
        assert b.n_in <= a.n_pass or b.n_in == a.n_pass
