"""The paper's two reuse mechanisms must actually save work (§4.3/§4.4)."""
import numpy as np
import pytest

from repro.core import BranchAndBound, ProxyBuilder
from repro.data.synthetic import make_dataset, make_query, make_udfs


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset(n=6000, correlation=0.9, feature_noise=1.0, seed=21)
    udfs = make_udfs(ds, hidden=24, depth=1, train_rows=1200, seed=21,
                     declared_cost_ms=10.0)
    q = make_query(ds, udfs, columns=[0, 1, 2], seed=22)
    return ds, q


def _run(q, x, **kw):
    b = ProxyBuilder(q, x, seed=0, **kw)
    bb = BranchAndBound(b, q.accuracy_target, fine_grained=True, step=0.05)
    bb.run()
    return b.stats


def test_sample_reuse_cuts_udf_calls(workload):
    ds, q = workload
    x = ds.x[:800]
    with_reuse = _run(q, x)
    without = _run(q, x, reuse_samples=False)
    assert sum(without.udf_calls.values()) > 2 * sum(with_reuse.udf_calls.values()), (
        with_reuse.udf_calls, without.udf_calls,
    )
    # with reuse, labeling never exceeds n rows per predicate
    for c in with_reuse.udf_calls.values():
        assert c <= 800


def test_classifier_reuse_cuts_training(workload):
    ds, q = workload
    x = ds.x[:800]
    with_reuse = _run(q, x)
    without = _run(q, x, reuse_classifiers=False)
    assert without.n_trained > with_reuse.n_trained
    assert without.n_reused == 0
    assert with_reuse.n_reused > 0
