"""Fixture: stdout print, suppressed."""


def announce(epoch):
    print("installed epoch", epoch)  # corelint: disable=print-in-protocol
