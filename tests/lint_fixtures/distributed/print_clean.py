"""Fixture: clean twin — diagnostics go to stderr."""
import sys


def announce(epoch):
    print("installed epoch", epoch, file=sys.stderr)
