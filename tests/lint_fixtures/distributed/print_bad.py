"""Fixture: stdout print inside a distributed protocol module."""


def announce(epoch):
    print("installed epoch", epoch)
