"""Fixture: clean twin — same-dir temp file + os.replace publish."""
import json
import os


def publish(path, payload):
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as fh:
        json.dump(payload, fh)
    os.replace(tmp, path)
