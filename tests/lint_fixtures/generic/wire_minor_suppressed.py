"""Fixture: non-exhaustive minor dispatch, suppressed."""

WIRE_MINOR_FRAME = 1


def parse(minor, blob):
    if minor == WIRE_MINOR_FRAME:  # corelint: disable=wire-minor-exhaustive
        return blob
    return None
