"""Fixture: weight-less snapshot, suppressed."""
from repro.serving.stats import ReservoirSample


def snapshot(indices, x, known_sigma):
    return ReservoirSample(indices, x, known_sigma)  # corelint: disable=weights-travel
