"""Fixture: id() cache key, suppressed (strong-ref + `is`-recheck)."""

_CACHE = {}


def lookup(params):
    return _CACHE.get(id(params))  # corelint: disable=identity-cache-key
