"""Fixture: clean twin — unknown minors fail loudly."""

WIRE_MINOR_FRAME = 1


class WireFormatError(ValueError):
    pass


def parse(minor, blob):
    if minor == WIRE_MINOR_FRAME:
        return blob
    raise WireFormatError(f"unknown wire minor {minor}")
