"""Fixture: minor dispatch that silently ignores unknown minors."""

WIRE_MINOR_FRAME = 1


def parse(minor, blob):
    if minor == WIRE_MINOR_FRAME:
        return blob
    return None
