"""Fixture: in-place write of a shared path."""
import json


def publish(path, payload):
    with open(path, "w") as fh:
        json.dump(payload, fh)
