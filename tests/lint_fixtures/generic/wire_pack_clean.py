"""Fixture: clean twin — the canonical helper from kernels/ops.py."""
from repro.kernels.ops import pack_le


def header(version):
    return pack_le(version, 2)
