"""Fixture: clean twin — a content fingerprint keys the cache."""

_CACHE = {}


def lookup(fingerprint):
    return _CACHE.get(fingerprint)
