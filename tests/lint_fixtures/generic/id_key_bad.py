"""Fixture: id() keys a cache."""

_CACHE = {}


def lookup(params):
    return _CACHE.get(id(params))
