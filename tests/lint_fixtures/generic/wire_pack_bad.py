"""Fixture: raw integer wire packing outside kernels/ops.py."""


def header(version):
    return version.to_bytes(2, "little")
