"""Fixture: reservoir snapshot built without its IPW weights."""
from repro.serving.stats import ReservoirSample


def snapshot(indices, x, known_sigma):
    return ReservoirSample(indices, x, known_sigma)
