"""Fixture: clean twin — the weights travel with the sample."""
from repro.serving.stats import ReservoirSample


def snapshot(indices, x, known_sigma, weights):
    return ReservoirSample(indices, x, known_sigma, weights=weights)
