"""Fixture: raw packing, suppressed."""


def header(version):
    return version.to_bytes(2, "little")  # corelint: disable=wire-pack-outside-ops
