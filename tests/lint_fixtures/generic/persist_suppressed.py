"""Fixture: in-place write, suppressed."""
import json


def publish(path, payload):
    with open(path, "w") as fh:  # corelint: disable=atomic-persistence
        json.dump(payload, fh)
