"""Fixture: clean twin — values stay on device."""


def score_tile(scores, mask):
    return scores, mask
