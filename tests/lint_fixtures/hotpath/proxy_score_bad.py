"""Fixture: device->host sync inside the scoring hot path."""


def score_tile(scores):
    return scores.item()
