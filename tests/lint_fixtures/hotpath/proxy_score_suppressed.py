"""Fixture: host sync, suppressed."""


def score_tile(scores):
    return scores.item()  # corelint: disable=host-sync-hot-path
