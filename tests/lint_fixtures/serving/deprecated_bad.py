"""Fixture: internal decision-path code calling a deprecated shim."""
from repro.core.optimizer import reoptimize


def refresh(plan, x):
    return reoptimize(plan, x, mode="alloc")
