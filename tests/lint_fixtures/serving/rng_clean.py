"""Fixture: clean twin — explicit seed threads through."""
import numpy as np


def make_rng(seed):
    return np.random.default_rng(seed)
