"""Fixture: raw wall-clock read in a decision-path module (serving/)."""
import time


def decide_deadline(budget_ms):
    start = time.perf_counter()
    return start + budget_ms
