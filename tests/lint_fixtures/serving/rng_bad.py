"""Fixture: unseeded generator construction in a gated path."""
import numpy as np


def make_rng():
    return np.random.default_rng()
