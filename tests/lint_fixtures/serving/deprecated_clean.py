"""Fixture: the redesigned core.api surface (and the session handle's
``.optimize`` attribute, which the rule must NOT confuse with the
deprecated bare ``optimize`` shim)."""
from repro.core.api import REBUILD_DEFAULTS, rebuild_plan


def refresh(handle, plan, x):
    handle.optimize(x)
    return rebuild_plan(plan, x, REBUILD_DEFAULTS)
