"""Fixture: justified suppression on a deprecated shim call."""
from repro.core.optimizer import reoptimize


def refresh(plan, x):
    # corelint: disable=deprecated-entry-point
    return reoptimize(plan, x, mode="alloc")
