"""Fixture: clean twin — the advisory helper instead of a raw clock."""
from repro.util import advisory_wall_ms


def decide_deadline(budget_ms):
    start = advisory_wall_ms()
    return start + budget_ms
