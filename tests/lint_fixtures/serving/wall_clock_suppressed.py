"""Fixture: same read, explicitly suppressed with a justification."""
import time


def decide_deadline(budget_ms):
    # injectable-clock fixture twin; suppression must silence the finding
    start = time.perf_counter()  # corelint: disable=wall-clock-decision
    return start + budget_ms
