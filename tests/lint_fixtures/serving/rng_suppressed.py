"""Fixture: unseeded generator, suppressed."""
import numpy as np


def make_rng():
    return np.random.default_rng()  # corelint: disable=unseeded-randomness
